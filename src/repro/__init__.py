"""repro: production-grade JAX training/inference framework built around the
Delayed Feedback Reservoir online training system (Ikeda et al., TCAD 2025),
with a multi-pod LM substrate, Pallas TPU kernels, and fault-tolerant runtime.
"""
__version__ = "1.0.0"
