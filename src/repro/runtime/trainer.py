"""Fault-tolerant training loop + population-search runtime wrapper.

``Trainer`` responsibilities beyond calling train_step:
  * checkpoint/restart: periodic saves (keep-last-k), auto-resume from the
    newest valid checkpoint on (re)start,
  * failure handling: a step that raises (device loss, preemption signal,
    injected fault) triggers restore-from-checkpoint and replay; batches
    are a pure function of the step index so replay is deterministic,
  * elastic restart: on shrink/grow the caller rebuilds the mesh and calls
    ``Trainer.restore`` with new shardings - the numpy-shard checkpoint
    re-slices onto any device count,
  * straggler watchdog: per-step durations feed runtime/straggler.py; an
    evict verdict raises ElasticRestart so the driver can re-mesh,
  * optional int8 gradient compression across the 'pod' axis
    (optim/compression.py) - enabled by TrainerConfig.compress_grads.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.straggler import StragglerWatchdog


class ElasticRestart(Exception):
    """Raised when the mesh must be rebuilt (host eviction / resize)."""


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    max_retries_per_step: int = 2
    straggler_threshold: float = 2.5
    compress_grads: bool = False


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        train_step: Callable,           # (params, opt_state, step, batch) -> ...
        batch_fn: Callable[[int], Any],  # step index -> batch (deterministic)
        fault_hook: Optional[Callable[[int], None]] = None,  # test injection
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.fault_hook = fault_hook
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.watchdog = StragglerWatchdog(threshold=cfg.straggler_threshold)
        self.metrics_log: list = []

    # -- resume ----------------------------------------------------------------

    def restore(self, params, opt_state, shardings=None) -> Tuple[Any, Any, int]:
        res = self.ckpt.restore_latest((params, opt_state), shardings)
        if res is None:
            return params, opt_state, 0
        (params, opt_state), step, _meta = res
        return params, opt_state, step

    # -- main loop --------------------------------------------------------------

    def run(
        self,
        params,
        opt_state,
        num_steps: int,
        start_step: int = 0,
        host: str = "host0",
    ):
        step = start_step
        while step < num_steps:
            batch = self.batch_fn(step)
            retries = 0
            while True:
                t0 = time.perf_counter()
                try:
                    if self.fault_hook is not None:
                        self.fault_hook(step)  # may raise (injected fault)
                    params, opt_state, metrics = self.train_step(
                        params, opt_state, step, batch
                    )
                    jax.block_until_ready(metrics["loss"])
                    break
                except ElasticRestart:
                    raise
                except Exception:  # noqa: BLE001 - recover from step failure
                    retries += 1
                    if retries > self.cfg.max_retries_per_step:
                        raise
                    restored = self.ckpt.restore_latest((params, opt_state))
                    if restored is not None:
                        (params, opt_state), step, _ = restored
                        batch = self.batch_fn(step)
            dur = time.perf_counter() - t0
            verdict = self.watchdog.observe(host, dur)
            if verdict == "evict":
                # persist state, then ask the driver to re-mesh without us
                self.ckpt.save((params, opt_state), step, {"evicted": host})
                raise ElasticRestart(host)
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"]), "sec": dur}
            )
            step += 1
            if step % self.cfg.ckpt_every == 0 or step == num_steps:
                self.ckpt.save((params, opt_state), step)
        return params, opt_state, step


# ---------------------------------------------------------------------------
# Population hyperparameter search runtime
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PopulationTrainerConfig:
    """Knobs for the vmapped population search (core/population.py)."""

    divs: int = 4                   # grid seeds per axis -> K = divs^2 members
    rounds: int = 1                 # cull -> refine -> re-evaluate rounds
    steps_per_round: int = 1        # truncated-BP epochs per round
    minibatch: int = 4
    survive_frac: float = 0.5
    jitter: float = 0.15
    ckpt_dir: Optional[str] = None  # save the winning member when set


class PopulationTrainer:
    """Runtime wrapper over ``repro.core.population.train_population``.

    Runs the whole population as one jitted program per round, mirrors the
    ``Trainer`` conventions (a ``metrics_log`` of per-round dicts, optional
    checkpointing of the winning member via ``CheckpointManager``), and
    dispatches on the batch type: ``TimeSeriesBatch`` pairs run the
    classification path, ``RegressionBatch`` pairs the NRMSE/regression path.
    """

    def __init__(self, cfg: PopulationTrainerConfig):
        self.cfg = cfg
        self.metrics_log: list = []

    def fit(self, dfr_cfg, train, evalb, seed: int = 0, **overrides):
        from repro.core import population
        from repro.core.types import RegressionBatch

        runner = (
            population.train_population_regression
            if isinstance(train, RegressionBatch)
            else population.train_population_classification
        )
        kwargs = dict(
            divs=self.cfg.divs,
            rounds=self.cfg.rounds,
            steps_per_round=self.cfg.steps_per_round,
            minibatch=self.cfg.minibatch,
            survive_frac=self.cfg.survive_frac,
            jitter=self.cfg.jitter,
            seed=seed,
        )
        kwargs.update(overrides)
        result = runner(dfr_cfg, train, evalb, **kwargs)
        self.metrics_log = list(result.history)
        if self.cfg.ckpt_dir is not None:
            ckpt = CheckpointManager(self.cfg.ckpt_dir, keep=1)
            ckpt.save(
                result.best_params,
                step=kwargs["rounds"],
                metadata={
                    "best_nrmse": result.best_nrmse,
                    "best_acc": result.best_acc,
                    "best_beta": result.best_beta,
                    "best_p": result.best_p,
                    "best_q": result.best_q,
                },
            )
        return result
