"""Continuous-batching slot scheduler shared by the serving runtimes.

Both servers in this package keep a fixed number of *slots* so the jitted
step never re-specializes:

  * ``repro.runtime.server.Server``        - token decode slots (LM rows)
  * ``repro.runtime.stream_server.StreamServer`` - sensor-stream slots
    (per-slot ``OnlineState`` rows)

The admission/retire lifecycle is identical - requests queue up, free slots
are filled FIFO, finished slots retire into the completed list and are
immediately refillable - so it lives here once.  Per-slot device state
(decode cache rows, online-state rows) stays with the owning server; the
scheduler invokes the server's ``on_admit`` / ``on_retire`` callbacks at
the transitions so the server can reset exactly the affected row.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np


class RefreshCohorts:
    """Round-robin staggering of periodic per-slot maintenance rounds.

    The stream server's Ridge refresh is the textbook latency-tail problem:
    with a single global round every ``refresh_every`` steps, one step in
    ``refresh_every`` pays the whole O(S * s^3) (or O(S * s^2), incremental)
    refresh bill and the p99 window latency is that spike.  Staggering keeps
    the *per-slot* cadence identical - every slot is still refreshed exactly
    once per ``refresh_every`` server steps - but spreads the slots over the
    period: slot i belongs to cohort ``i % n_cohorts``, and cohort c comes
    due on steps where ``step % refresh_every`` hits c's offset, the offsets
    spread evenly over the period.  Each step then refreshes at most
    ``ceil(n_slots / n_cohorts)`` slots.

    ``n_cohorts=1`` is exactly the global round (every slot due when
    ``step % refresh_every == 0``) - the regression-tested identity.
    ``n_cohorts`` is clamped to ``refresh_every`` (more cohorts than phases
    cannot be scheduled without changing the per-slot cadence).
    """

    def __init__(self, n_slots: int, refresh_every: int, n_cohorts: int = 1):
        self.n_slots = int(n_slots)
        self.refresh_every = int(refresh_every)
        self.n_cohorts = max(1, min(int(n_cohorts), self.refresh_every))
        # evenly spread, strictly increasing phases (distinct by clamping)
        self.offsets = [
            (c * self.refresh_every) // self.n_cohorts
            for c in range(self.n_cohorts)
        ]
        self.cohort_of_slot = [i % self.n_cohorts for i in range(self.n_slots)]
        # fixed-shape schedule for the in-program (cond-gated) refresh: every
        # cohort's row list padded to the max cohort size with DISTINCT
        # non-cohort slot indices flagged ok=False, so a traced scatter over
        # the padded rows has no duplicate indices (a padded row writes its
        # own current value back - an exact no-op) and the jitted step
        # compiles once for every cohort.
        self.max_cohort_size = max(
            1, -(-self.n_slots // self.n_cohorts)
        )
        self._fixed: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for c in range(self.n_cohorts):
            rows = [i for i in range(self.n_slots)
                    if self.cohort_of_slot[i] == c]
            ok = [True] * len(rows)
            pad_pool = [i for i in range(self.n_slots) if i not in set(rows)]
            while len(rows) < self.max_cohort_size:
                rows.append(pad_pool.pop(0) if pad_pool else 0)
                ok.append(False)
            self._fixed[self.offsets[c]] = (
                np.asarray(rows, np.int32), np.asarray(ok, bool)
            )
        self._idle_rows = (
            np.arange(self.max_cohort_size, dtype=np.int32) % self.n_slots,
            np.zeros(self.max_cohort_size, bool),
        )

    def due_cohort(self, step: int) -> Optional[int]:
        """Cohort index due at this server step, or None."""
        phase = step % self.refresh_every
        try:
            return self.offsets.index(phase)
        except ValueError:
            return None

    def due_slots(self, step: int) -> Optional[List[int]]:
        """Slot indices due at this server step, or None between rounds."""
        c = self.due_cohort(step)
        if c is None:
            return None
        return [i for i in range(self.n_slots) if self.cohort_of_slot[i] == c]

    def due_rows_fixed(
        self, step: int
    ) -> Tuple[bool, np.ndarray, np.ndarray]:
        """Fixed-shape view of ``due_slots`` for the fused in-program refresh:
        ``(due, rows, ok)`` with ``rows``/``ok`` always ``max_cohort_size``
        long.  Between rounds ``due`` is False and the rows are an arbitrary
        valid index set (the cond never executes the refresh branch)."""
        phase = step % self.refresh_every
        fixed = self._fixed.get(phase)
        if fixed is None:
            rows, ok = self._idle_rows
            return False, rows, ok
        rows, ok = fixed
        return True, rows, ok

    def _sharded_fixed(
        self, n_shards: int
    ) -> Tuple[int, Dict[int, Tuple[np.ndarray, np.ndarray]]]:
        """Per-shard fixed-shape cohort schedules for the slot-sharded
        server: shard d owns the contiguous global slots
        ``[d * S/n, (d+1) * S/n)`` and its row lists hold *local* indices,
        so the shard_map'd refresh branch never indexes (or scatters) off
        its own device - the device-local invariant.

        Every (cohort, shard) row list is padded to one common width
        ``r_loc`` (the max over cohorts AND shards, so one jitted program
        serves every round) with DISTINCT local non-cohort indices flagged
        ok=False, exactly like the global ``due_rows_fixed`` padding.
        Returns ``(r_loc, {phase: (rows, ok)})`` where ``rows``/``ok`` are
        the shard-concatenated ``(n_shards * r_loc,)`` arrays a
        ``P('slot')`` in_spec splits back into per-shard blocks.
        """
        if self.n_slots % n_shards:
            raise ValueError(
                f"{self.n_slots} slots not divisible by {n_shards} shards"
            )
        s_loc = self.n_slots // n_shards
        members: Dict[int, list] = {}
        r_loc = 1
        for c in range(self.n_cohorts):
            for d in range(n_shards):
                local = [i - d * s_loc for i in range(self.n_slots)
                         if self.cohort_of_slot[i] == c
                         and d * s_loc <= i < (d + 1) * s_loc]
                members[(c, d)] = local
                r_loc = max(r_loc, len(local))
        fixed: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for c in range(self.n_cohorts):
            rows_all, ok_all = [], []
            for d in range(n_shards):
                rows = list(members[(c, d)])
                ok = [True] * len(rows)
                pad_pool = [j for j in range(s_loc) if j not in set(rows)]
                while len(rows) < r_loc:
                    rows.append(pad_pool.pop(0) if pad_pool else 0)
                    ok.append(False)
                rows_all += rows
                ok_all += ok
            fixed[self.offsets[c]] = (
                np.asarray(rows_all, np.int32), np.asarray(ok_all, bool)
            )
        return r_loc, fixed

    def due_rows_fixed_sharded(
        self, step: int, n_shards: int
    ) -> Tuple[bool, np.ndarray, np.ndarray]:
        """``due_rows_fixed`` for a slot axis sharded over ``n_shards``
        contiguous blocks: same ``(due, rows, ok)`` contract, but ``rows``
        holds shard-LOCAL indices, ``(n_shards * r_loc,)`` long (shard d's
        block at ``[d * r_loc, (d+1) * r_loc)``).  The padded rows write
        their own current values back, so the refreshed slot set - and
        therefore the served episode - is bitwise the unsharded schedule's.
        """
        cache = getattr(self, "_sharded_cache", None)
        if cache is None:
            cache = self._sharded_cache = {}
        hit = cache.get(n_shards)
        if hit is None:
            r_loc, fixed = self._sharded_fixed(n_shards)
            s_loc = self.n_slots // n_shards
            idle = (
                np.tile(np.arange(r_loc, dtype=np.int32) % s_loc, n_shards),
                np.zeros(n_shards * r_loc, bool),
            )
            hit = cache[n_shards] = (fixed, idle)
        fixed, idle = hit
        phase = step % self.refresh_every
        got = fixed.get(phase)
        if got is None:
            return False, idle[0], idle[1]
        return True, got[0], got[1]


class SlotScheduler:
    """Fixed-capacity slot pool with FIFO admission (continuous batching)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: Deque[Any] = deque()
        self.slots: List[Optional[Any]] = [None] * n_slots
        self.completed: List[Any] = []

    # -- queue -----------------------------------------------------------------

    def submit(self, item: Any) -> None:
        self.queue.append(item)

    # -- slot transitions --------------------------------------------------------

    def admit(
        self, on_admit: Optional[Callable[[int, Any], None]] = None
    ) -> List[int]:
        """Fill every free slot from the queue (FIFO); returns the indices
        admitted this round.  ``on_admit(slot, item)`` runs per admission so
        the owner can reset the slot's device-state row."""
        admitted = []
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                item = self.queue.popleft()
                self.slots[i] = item
                if on_admit is not None:
                    on_admit(i, item)
                admitted.append(i)
        return admitted

    def retire(
        self, i: int, on_retire: Optional[Callable[[int, Any], None]] = None
    ) -> Any:
        """Free slot ``i`` into the completed list (it refills on the next
        ``admit`` - continuous batching)."""
        item = self.slots[i]
        if item is None:
            raise ValueError(f"retire of empty slot {i}")
        self.slots[i] = None
        self.completed.append(item)
        if on_retire is not None:
            on_retire(i, item)
        return item

    # -- views -------------------------------------------------------------------

    def live(self) -> List[Tuple[int, Any]]:
        """(slot index, item) for every occupied slot."""
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def active(self) -> bool:
        """True while anything is in flight or waiting."""
        return any(s is not None for s in self.slots) or bool(self.queue)
