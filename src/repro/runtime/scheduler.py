"""Continuous-batching slot scheduler shared by the serving runtimes.

Both servers in this package keep a fixed number of *slots* so the jitted
step never re-specializes:

  * ``repro.runtime.server.Server``        - token decode slots (LM rows)
  * ``repro.runtime.stream_server.StreamServer`` - sensor-stream slots
    (per-slot ``OnlineState`` rows)

The admission/retire lifecycle is identical - requests queue up, free slots
are filled FIFO, finished slots retire into the completed list and are
immediately refillable - so it lives here once.  Per-slot device state
(decode cache rows, online-state rows) stays with the owning server; the
scheduler invokes the server's ``on_admit`` / ``on_retire`` callbacks at
the transitions so the server can reset exactly the affected row.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Deque, List, Optional, Tuple


class SlotScheduler:
    """Fixed-capacity slot pool with FIFO admission (continuous batching)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: Deque[Any] = deque()
        self.slots: List[Optional[Any]] = [None] * n_slots
        self.completed: List[Any] = []

    # -- queue -----------------------------------------------------------------

    def submit(self, item: Any) -> None:
        self.queue.append(item)

    # -- slot transitions --------------------------------------------------------

    def admit(
        self, on_admit: Optional[Callable[[int, Any], None]] = None
    ) -> List[int]:
        """Fill every free slot from the queue (FIFO); returns the indices
        admitted this round.  ``on_admit(slot, item)`` runs per admission so
        the owner can reset the slot's device-state row."""
        admitted = []
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                item = self.queue.popleft()
                self.slots[i] = item
                if on_admit is not None:
                    on_admit(i, item)
                admitted.append(i)
        return admitted

    def retire(
        self, i: int, on_retire: Optional[Callable[[int, Any], None]] = None
    ) -> Any:
        """Free slot ``i`` into the completed list (it refills on the next
        ``admit`` - continuous batching)."""
        item = self.slots[i]
        if item is None:
            raise ValueError(f"retire of empty slot {i}")
        self.slots[i] = None
        self.completed.append(item)
        if on_retire is not None:
            on_retire(i, item)
        return item

    # -- views -------------------------------------------------------------------

    def live(self) -> List[Tuple[int, Any]]:
        """(slot index, item) for every occupied slot."""
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def active(self) -> bool:
        """True while anything is in flight or waiting."""
        return any(s is not None for s in self.slots) or bool(self.queue)
