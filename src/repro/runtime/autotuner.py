"""Warm-pool background hyperparameter autotuner for the stream server.

The offline population engine (``repro.core.population``) finds good
(p, q) once, before serving; a long-lived stream server then holds those
hyperparameters forever, even when the streams it serves turn out to favor
different dynamics.  This module closes that loop at serving time:

  * Each refresh *cohort* of the server owns a small persistent candidate
    population over the (p, q, beta) triple - a *warm pool*: it survives
    across tuning rounds, so every round continues the search instead of
    restarting it.
  * At a low rate (every ``interval`` server steps) one live slot per
    cohort is visited round-robin.  The cohort's population - member 0
    pinned to that slot's live (p, q, beta), the incumbent - is evaluated
    on the slot's *recent retained windows* (the host-side request arrays
    the server already holds; no device traffic): ridge-refit readout on a
    fit split, NRMSE fitness on the most-recent validation split, one
    jitted program per round (``_evaluate_triples``).
  * The population is then culled CMA-ES-style
    (``candidates.survivor_parents`` + ``candidates.adapted_clones`` with
    D=3): survivors pass through verbatim, culled slots re-seed from the
    rank-weighted survivor covariance in log space.
  * When the round's winner beats the incumbent by ``margin`` (relative
    NRMSE), a hot swap is scheduled for that slot and applied just after
    the slot's next cohort *refresh boundary*: the winner's (p, q) rows
    scatter into the live slot tree, the readout warm-starts from the
    winner's ridge solve on the recent windows, and the Ridge statistics
    re-seed exactly like ``reset_statistics(factor_beta=beta)`` - A = B =
    0, count = 0, a fresh live factor ``sqrt(beta) I`` - so the
    incremental invariant ``Lt^T Lt == B + beta I`` survives the swap
    bit-exactly.  Any int8 serving scales for the slot disarm
    (``w_scale = 0``) and re-fold at its next refresh like a freshly
    admitted slot; the adaptive-retirement detector EMAs re-seed.

Scope notes: the beta dimension of the search only has a lasting effect
under ``refresh_mode='incremental'`` (the live factor carries the per-slot
beta; recompute-mode refreshes re-apply the server-wide beta).  Swaps are
applied between fused steps on the host thread, so they compose with slot
sharding, step blocking and int8 serving without touching the jitted step
programs; an attached tuner that never swaps leaves the served episode
bit-for-bit identical (the tuner only *reads* server state otherwise).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dprr, masking, ridge
from repro.kernels import ops as kops
from repro.core.candidates import (
    P_LOG_RANGE,
    Q_LOG_RANGE,
    adapted_clones,
    seed_candidates,
    survivor_parents,
)
from repro.core.online import OnlineState
from repro.core.types import Array, DFRConfig, QuantParams, RidgeState

# beta search box (log10): spans the typical cfg.betas sweep
BETA_LOG_RANGE = (-4.0, 0.0)


# ---------------------------------------------------------------------------
# One-program candidate evaluation with per-member beta
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg",))
def _evaluate_triples(
    cfg: DFRConfig,
    mask: Array,
    ps: Array,       # (K,)
    qs: Array,       # (K,)
    betas: Array,    # (K,) per-member ridge beta (traced, not a sweep)
    fit_u: Array,    # (B, T, n_in)
    fit_len: Array,  # (B,)
    y_fit: Array,    # (B, Ny) one-hot
    val_u: Array,
    val_len: Array,
    y_val: Array,
) -> Tuple[Array, Array, Array]:
    """Evaluate K (p, q, beta) triples in one XLA program.

    Unlike ``population.evaluate_population`` (which sweeps the static
    ``cfg.betas`` grid for every member), beta here is a *traced* (K,)
    vector - the autotuner adapts it continuously, and baking it into the
    static config would recompile every round.  Returns ``(nrmse, acc,
    Wt)`` with Wt (K, Ny, s) the ridge readout fitted on the fit split.

    Features come from the fused training forward (``kernels.ops.
    train_forward``): the reservoir scan and the DPRR accumulation run in
    one pass with the (B, T, Nx) state sequence never materialized, so a
    tuning round's activation memory is O(Nx^2) per member-sample instead
    of O(T Nx) - the same production path ``population.refine_population``
    trains through.
    """
    f = cfg.f()

    def feats(p, q, u, lengths):
        j_seq = masking.apply_mask(mask, u)
        r, _, _, _ = kops.train_forward(j_seq, lengths, p, q,
                                        cfg.n_nodes, f=f)
        return r

    vfeats = jax.vmap(feats, in_axes=(0, 0, None, None))
    rt_fit = dprr.r_tilde(vfeats(ps, qs, fit_u, fit_len))    # (K, B, s)
    rt_val = dprr.r_tilde(vfeats(ps, qs, val_u, val_len))    # (K, Bv, s)

    s = rt_fit.shape[-1]
    A = jnp.einsum("by,kbs->kys", y_fit, rt_fit)             # (K, Ny, s)
    Bm = jnp.einsum("kbs,kbt->kst", rt_fit, rt_fit)          # (K, s, s)
    Breg = Bm + betas[:, None, None] * jnp.eye(s, dtype=Bm.dtype)
    C = jnp.linalg.cholesky(Breg)
    Wt = jax.vmap(
        lambda c, a: jax.scipy.linalg.cho_solve((c, True), a.T).T
    )(C, A)                                                  # (K, Ny, s)

    pred = jnp.einsum("kbs,kys->kby", rt_val, Wt)            # (K, Bv, Ny)
    var = jnp.mean(jnp.square(y_val - jnp.mean(y_val))) + 1e-12
    err = pred - y_val[None]
    nrmse = jnp.sqrt(jnp.mean(err * err, axis=(1, 2)) / var)
    nrmse = jnp.where(jnp.isfinite(nrmse), nrmse, jnp.inf)
    hits = jnp.argmax(pred, -1) == jnp.argmax(y_val, -1)[None]
    acc = jnp.mean(hits.astype(jnp.float32), axis=1)
    return nrmse, acc, Wt


# ---------------------------------------------------------------------------
# The hot swap: winner rows into the live slot tree
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("maintain_factor",), donate_argnums=(0,))
def _swap_slot_row(
    states: OnlineState,
    row: Array,        # scalar int32 slot index
    p_new: Array,      # scalars
    q_new: Array,
    W_new: Array,      # (Ny, Nr)
    b_new: Array,      # (Ny,)
    beta_new: Array,   # scalar
    maintain_factor: bool,
) -> OnlineState:
    """Scatter one winner into slot ``row`` of the slot-batched state.

    (p, q) and the warm-start readout replace the row's parameters; the
    Ridge statistics re-seed exactly like ``reset_statistics(
    factor_beta=beta_new)``: A = B = 0, count = 0 and (incremental mode) a
    fresh live factor ``sqrt(beta) I``, preserving ``Lt^T Lt == B +
    factor_beta I``.  The slot's step counter survives (its lifecycle
    phase does not restart); int8 codes disarm (``w_scale = 0`` - fp32
    serving until the next refresh re-folds) and the adaptive-retirement
    detector EMAs re-seed.
    """
    pr = states.params
    dt = pr.W.dtype
    params = dataclasses.replace(
        pr,
        p=pr.p.at[row].set(p_new.astype(pr.p.dtype)),
        q=pr.q.at[row].set(q_new.astype(pr.q.dtype)),
        W=pr.W.at[row].set(W_new.astype(dt)),
        b=pr.b.at[row].set(b_new.astype(dt)),
    )
    rs = states.ridge
    s = rs.Lt.shape[-1]
    if maintain_factor:
        Lt_row = ridge.seed_factor(s, beta_new, rs.Lt.dtype)
        fb_row = beta_new.astype(rs.factor_beta.dtype)
    else:
        Lt_row = jnp.zeros((s, s), rs.Lt.dtype)
        fb_row = jnp.zeros((), rs.factor_beta.dtype)
    ridge_state = RidgeState(
        A=rs.A.at[row].set(0.0),
        B=rs.B.at[row].set(0.0),
        count=rs.count.at[row].set(0),
        Lt=rs.Lt.at[row].set(Lt_row),
        factor_beta=rs.factor_beta.at[row].set(fb_row),
    )
    q8 = states.quant
    quant = QuantParams(
        Wq=q8.Wq.at[row].set(jnp.zeros_like(q8.Wq[row])),
        w_scale=q8.w_scale.at[row].set(0.0),
        x_scale=q8.x_scale.at[row].set(0.0),
        x_absmax=q8.x_absmax.at[row].set(0.0),
    )
    return dataclasses.replace(
        states,
        params=params,
        ridge=ridge_state,
        quant=quant,
        loss_fast=states.loss_fast.at[row].set(0.0),
        loss_slow=states.loss_slow.at[row].set(0.0),
    )


# ---------------------------------------------------------------------------
# Per-cohort warm pool
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _CohortPool:
    """Persistent candidate population of one refresh cohort."""

    p: np.ndarray       # (K,)
    q: np.ndarray       # (K,)
    beta: np.ndarray    # (K,)
    visit: int = 0      # round-robin cursor over the cohort's slots
    rounds: int = 0
    swaps: int = 0


@dataclasses.dataclass
class _PendingSwap:
    slot: int
    rid: int            # request id the evaluation belonged to
    p: float
    q: float
    beta: float
    W: np.ndarray       # (Ny, Nr)
    b: np.ndarray       # (Ny,)


class WarmPoolAutotuner:
    """Background (p, q, beta) re-optimization for a live ``StreamServer``.

    Attach with ``server.attach_autotuner(tuner)``; the server then drives
    ``on_step()`` after every fused step.  See the module docstring for
    the algorithm; knobs:

      * ``population``  - warm-pool size K per cohort (incumbent included).
      * ``history``     - retained samples evaluated per round (fixed, so
        the evaluation program compiles once); a slot is only visited once
        it has consumed at least this many samples.
      * ``interval``    - server steps between tuning rounds.
      * ``val_frac``    - most-recent fraction of the history used as the
        validation split (fitness is val NRMSE, so candidates are selected
        for the *newest* regime - the drift-tracking objective).
      * ``margin``      - relative NRMSE improvement the winner must show
        over the incumbent before a swap is scheduled.
      * ``jitter``      - isotropic floor of the CMA-ES-style survivor
        covariance used to re-seed culled candidates.
    """

    def __init__(
        self,
        server,
        population: int = 8,
        history: int = 32,
        interval: int = 4,
        val_frac: float = 0.25,
        margin: float = 0.05,
        survive_frac: float = 0.5,
        jitter: float = 0.2,
        seed: int = 0,
    ):
        if population < 2:
            raise ValueError(f"population must be >= 2, got {population!r}")
        if history < 8:
            raise ValueError(f"history must be >= 8, got {history!r}")
        if not 0.0 < val_frac < 1.0:
            raise ValueError(f"val_frac must be in (0, 1), got {val_frac!r}")
        self.server = server
        self.population = int(population)
        self.history = int(history)
        self.interval = max(1, int(interval))
        self.val_frac = float(val_frac)
        self.margin = float(margin)
        self.survive_frac = float(survive_frac)
        self.jitter = float(jitter)
        self._key = jax.random.PRNGKey(seed)
        self._pools: Dict[int, _CohortPool] = {}
        self._pending: Dict[int, _PendingSwap] = {}
        self._steps_seen = 0
        self._last_seen_step = int(server.global_step)
        self.swaps_applied = 0
        self.rounds_run = 0

    # -- server hook -------------------------------------------------------------

    def on_step(self) -> None:
        """Called by the server after each fused step: apply any pending
        swaps whose cohort refresh boundary just fired, then (every
        ``interval`` steps) run one tuning round."""
        # steps the last dispatch advanced through (blocked dispatches
        # advance several schedule phases at once); track unconditionally
        # so a swap scheduled later never sees a stale boundary window
        lo, hi = self._last_seen_step, self.server.global_step
        self._last_seen_step = hi
        fired = set()
        for step in range(lo + 1, hi + 1):
            c = self.server.cohorts.due_cohort(step)
            if c is not None:
                fired.add(c)
        self._apply_due_swaps(fired)
        self._steps_seen += 1
        if self._steps_seen % self.interval == 0:
            self._tune_round()

    # -- swap application --------------------------------------------------------

    def _apply_due_swaps(self, fired) -> None:
        """Apply pending swaps immediately *after* the owning cohort's
        refresh fired (the boundary): the slot then serves the warm-start
        readout for a full refresh period before its next re-solve folds
        statistics accumulated purely on the post-swap regime."""
        if not self._pending or not fired:
            return
        srv = self.server
        live = dict(srv.sched.live())
        for slot in list(self._pending):
            pend = self._pending[slot]
            if srv.cohorts.cohort_of_slot[slot] not in fired:
                continue
            del self._pending[slot]
            req = live.get(slot)
            if req is None or req.rid != pend.rid:
                continue  # the stream retired; the evaluation is stale
            srv.states = _swap_slot_row(
                srv.states,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(pend.p, jnp.float32),
                jnp.asarray(pend.q, jnp.float32),
                jnp.asarray(pend.W),
                jnp.asarray(pend.b),
                jnp.asarray(pend.beta, jnp.float32),
                maintain_factor=(srv.refresh_mode == "incremental"),
            )
            self.swaps_applied += 1

    # -- tuning round ------------------------------------------------------------

    def _pool_for(self, cohort: int, p0: float, q0: float, b0: float
                  ) -> _CohortPool:
        pool = self._pools.get(cohort)
        if pool is None:
            self._key, sub = jax.random.split(self._key)
            k = self.population
            ps, qs = seed_candidates(sub, k, p0, q0, jitter=self.jitter)
            self._key, sub = jax.random.split(self._key)
            lo, hi = BETA_LOG_RANGE
            betas = b0 * np.exp(
                np.asarray(jax.random.normal(sub, (k,))) * self.jitter
            )
            betas[0] = b0
            betas = np.clip(betas, 10.0 ** lo, 10.0 ** hi)
            pool = self._pools[cohort] = _CohortPool(
                p=np.asarray(ps, np.float64),
                q=np.asarray(qs, np.float64),
                beta=betas.astype(np.float64),
            )
        return pool

    def _eligible_slots(self, cohort: int) -> List[Tuple[int, object]]:
        srv = self.server
        out = []
        warm = (int(np.asarray(srv.phase_steps)) + 1) * srv.window
        for slot, req in srv.sched.live():
            if srv.cohorts.cohort_of_slot[slot] != cohort:
                continue
            if srv.slot_pos[slot] >= max(self.history, warm):
                out.append((slot, req))
        return out

    def _tune_round(self) -> None:
        srv = self.server
        for cohort in range(srv.cohorts.n_cohorts):
            slots = self._eligible_slots(cohort)
            if not slots:
                continue
            pool = self._pools.get(cohort)
            visit = pool.visit if pool is not None else 0
            slot, req = slots[visit % len(slots)]
            self._tune_slot(cohort, slot, req)

    def _tune_slot(self, cohort: int, slot: int, req) -> None:
        srv = self.server
        cfg = srv.cfg
        # incumbent triple from the live slot row (tiny host reads, low rate)
        p0 = float(np.asarray(srv.states.params.p[slot]))
        q0 = float(np.asarray(srv.states.params.q[slot]))
        if srv.refresh_mode == "incremental":
            b0 = float(np.asarray(srv.states.ridge.factor_beta[slot]))
            if b0 <= 0:
                b0 = float(np.asarray(srv.beta))
        else:
            b0 = float(np.asarray(srv.beta))
        pool = self._pool_for(cohort, p0, q0, b0)
        pool.visit += 1
        pool.rounds += 1
        self.rounds_run += 1
        # pin the incumbent probe: member 0 is always the live triple
        pool.p[0], pool.q[0], pool.beta[0] = p0, q0, b0

        # the slot's most recent `history` consumed samples (host arrays)
        hi = int(srv.slot_pos[slot])
        lo = hi - self.history
        u = np.asarray(req.u[lo:hi], np.float32)
        length = np.asarray(req.length[lo:hi], np.int32)
        label = np.asarray(req.label[lo:hi], np.int32)
        n_val = max(1, int(round(self.history * self.val_frac)))
        n_fit = self.history - n_val
        y = np.eye(cfg.n_classes, dtype=np.float32)[label]

        nrmse, acc, Wt = _evaluate_triples(
            cfg, srv.mask,
            jnp.asarray(pool.p, np.float32), jnp.asarray(pool.q, np.float32),
            jnp.asarray(pool.beta, np.float32),
            jnp.asarray(u[:n_fit]), jnp.asarray(length[:n_fit]),
            jnp.asarray(y[:n_fit]),
            jnp.asarray(u[n_fit:]), jnp.asarray(length[n_fit:]),
            jnp.asarray(y[n_fit:]),
        )
        fitness = np.asarray(nrmse, np.float64)
        win = int(np.argmin(fitness))
        if (np.isfinite(fitness[win]) and win != 0
                and fitness[win] < fitness[0] * (1.0 - self.margin)):
            Wt_win = np.asarray(Wt[win])
            self._pending[slot] = _PendingSwap(
                slot=slot, rid=req.rid,
                p=float(pool.p[win]), q=float(pool.q[win]),
                beta=float(pool.beta[win]),
                W=Wt_win[:, :-1], b=Wt_win[:, -1],
            )
            pool.swaps += 1

        # evolve the warm pool: CMA-ES-style cull in (p, q, beta) log space
        parent, keep, _ = survivor_parents(
            jnp.asarray(fitness), self.survive_frac
        )
        parent = np.asarray(parent)
        coords = np.stack([pool.p[parent], pool.q[parent], pool.beta[parent]])
        self._key, sub = jax.random.split(self._key)
        new = np.asarray(adapted_clones(
            sub, jnp.asarray(coords, np.float32), jnp.asarray(keep),
            jitter=self.jitter,
            ranges=(P_LOG_RANGE, Q_LOG_RANGE, BETA_LOG_RANGE),
        ), np.float64)
        pool.p, pool.q, pool.beta = new[0], new[1], new[2]

    # -- diagnostics -------------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        return {
            "rounds_run": self.rounds_run,
            "swaps_applied": self.swaps_applied,
            "swaps_pending": len(self._pending),
            "cohort_pools": len(self._pools),
        }
