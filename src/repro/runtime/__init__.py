from repro.runtime.trainer import (  # noqa: F401
    ElasticRestart,
    PopulationTrainer,
    PopulationTrainerConfig,
    Trainer,
    TrainerConfig,
)
from repro.runtime.straggler import StragglerWatchdog  # noqa: F401
from repro.runtime.scheduler import SlotScheduler  # noqa: F401
from repro.runtime.server import Server, Request  # noqa: F401
from repro.runtime.stream_server import StreamRequest, StreamServer  # noqa: F401
from repro.runtime.autotuner import WarmPoolAutotuner  # noqa: F401
from repro.runtime.planner import (  # noqa: F401
    Calibration,
    Plan,
    Planner,
    get_calibration,
    predict_step_cost,
    replay_bench_tables,
)
