from repro.runtime.trainer import Trainer, TrainerConfig, ElasticRestart  # noqa: F401
from repro.runtime.straggler import StragglerWatchdog  # noqa: F401
from repro.runtime.server import Server, Request  # noqa: F401
