"""Batched inference server: continuous-batching decode loop.

A minimal-but-real serving runtime:
  * requests queue up with prompts; the slot scheduler
    (``repro.runtime.scheduler.SlotScheduler``, shared with the DFR stream
    server) packs up to ``max_batch`` concurrent sequences into the fixed
    decode batch (padding unused rows),
  * prefill runs chunk-wise through the decode path (token-by-token for
    recurrent archs; chunked cache append for attention archs),
  * each decode step emits one token for every live row; finished rows
    (EOS or max_tokens) retire and their slots are refilled (continuous
    batching),
  * per-row state is owned by the fixed-shape cache pytree, so the jitted
    decode step never re-specializes.

The dry-run's decode cells measure exactly the ``decode_step`` this server
drives.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Transformer
from repro.runtime.scheduler import SlotScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0


class Server:
    def __init__(
        self,
        model: Transformer,
        params,
        max_batch: int = 8,
        max_len: int = 512,
        eos_id: int = -1,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.sched = SlotScheduler(max_batch)
        self.slot_pos = np.zeros(max_batch, np.int32)   # tokens consumed
        self.cache = model.init_cache(max_batch, max_len)
        self._decode = jax.jit(model.decode_step)

    @property
    def slots(self):
        return self.sched.slots

    @property
    def completed(self) -> List[Request]:
        return self.sched.completed

    def submit(self, req: Request):
        req.submit_t = time.perf_counter()
        self.sched.submit(req)

    # -- scheduling --------------------------------------------------------------

    def _on_admit(self, i: int, req: Request):
        self.slot_pos[i] = 0
        self._reset_row(i)

    def _reset_row(self, i: int):
        """Zero row i of every per-row cache buffer (slot reuse)."""
        def zero_row(leaf):
            if leaf.ndim >= 2 and leaf.shape[1] == self.max_batch:
                return leaf.at[:, i].set(0)
            if leaf.ndim >= 1 and leaf.shape[0] == self.max_batch:
                return leaf.at[i].set(0)
            return leaf

        self.cache = jax.tree_util.tree_map(zero_row, self.cache)

    # -- the decode loop -----------------------------------------------------------

    def step(self):
        """One global decode step: feeds each live row its next input token
        (prompt token during prefill phase, else the last sampled token)."""
        self.sched.admit(self._on_admit)
        tok = np.zeros((self.max_batch, 1), np.int32)
        for i, req in self.sched.live():
            pos = self.slot_pos[i]
            if pos < len(req.prompt):
                tok[i, 0] = req.prompt[pos]          # prefill phase
            elif req.out_tokens:
                tok[i, 0] = req.out_tokens[-1]       # decode phase
        logits, self.cache = self._decode(self.params, jnp.asarray(tok), self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in self.sched.live():
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                req.out_tokens.append(int(nxt[i]))
                if (
                    len(req.out_tokens) >= req.max_tokens
                    or int(nxt[i]) == self.eos_id
                    or self.slot_pos[i] + len(req.out_tokens) >= self.max_len - 1
                ):
                    req.done = True
                    req.finish_t = time.perf_counter()
                    self.sched.retire(i)   # continuous batching: slot refills

    def run_until_drained(self, max_steps: int = 100000) -> List[Request]:
        steps = 0
        while self.sched.active() and steps < max_steps:
            self.step()
            steps += 1
        return self.sched.completed
