"""Calibrated analytical cost-model planner for the stream server.

The serving stack has re-grown the problem the paper set out to kill:
offline grid search.  Refresh mode flips winners with (window, Nx) (PR 3's
honest table), retirement overhead swings 0.35x-1.06x with Nx (PR 4),
step blocking pays off exactly when dispatch overhead dominates (PR 7),
and the int8 fast path loses wall-clock on CPU while winning bytes (PR 7's
honest columns).  Every new knob multiplies the hand-curated bench tables.

This module replaces the table lookup with the MATCH/ZigZag pattern
(SNIPPETS.md Snippet 1): a small analytical cost model - per-primitive
coefficients x exact work counts - that a scheduler searches.  Three
ingredients:

* **Micro-calibration** (``calibrate``): a short one-time run times six
  primitives on THIS host/backend - dispatch overhead, dot FLOP, HBM
  byte, cholupdate rotation element, triangular-substitution element,
  Cholesky-factorization element, quant/requant element - each normalized
  by the exact FLOPs/bytes of its own lowered program
  (``launch.hlo_cost``), so the coefficients are seconds-per-unit-of-work,
  not seconds-per-benchmark.  The result persists to a small JSON
  (``REPRO_PLANNER_CAL`` env var, default ``.planner_calibration.json``
  in the working directory) keyed by a host/backend fingerprint, so
  repeated servers skip re-measurement.

* **The cost model** (``predict_step_cost``): per served sample, the sum
  of (a) the serving program's exact HLO FLOPs/bytes (lowered once per
  (Nx, n_classes, S, window, t_len, quantize) and memoized -
  ``program_cost``), (b) the (A, B) accumulation work, (c) the
  refresh-mode-dependent maintenance: incremental pays W rank-1 rotation
  sweeps of s^2 per slot-step, recompute pays s^3/3 factorization
  elements per slot per refresh round, (d) retirement extras (window
  eviction doubles the rotation bill), and (e) dispatch overhead
  amortized over ``step_block`` sub-steps.  The structure reproduces the
  benched flips analytically: at W=1/Nx=16 the rotations are cheaper
  than the s^3 round, at W=8/Nx=8 they are not.

* **The search** (``Planner.search``): enumerate the feasible knob
  lattice (refresh_mode x cohorts x step_block x chunk_t, minus
  combinations the server rejects) and return the predicted-best
  ``Plan``.  The Pallas time-chunk ``chunk_t`` only reshapes the lowered
  program on a Pallas-capable backend, so the searched chunk sizes
  default to ``(None,)`` off-TPU - the XLA path ignores the knob and
  pricing identical programs repeatedly would only burn compiles.  The objective
  is predicted served-samples/sec; cohort staggering only reshapes the
  latency tail, so a pure-throughput search keeps cohorts=1 - ``Plan``
  carries the predicted per-step refresh spike so callers with a p99
  budget can stagger deliberately.

``StreamServer(..., config='auto')`` wires this in: knobs the caller left
unset are filled from ``Planner.search()``; explicit knobs always win.
``replay_bench_tables`` is the honesty gate: it replays the tracked
BENCH_*.json measurements and flags any shape where the planner's pick is
>1.3x worse than the measured best (CI fails on it - the planner is only
allowed to exist while it beats the tables it replaced).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import platform
import tempfile
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CAL_SCHEMA = 1
CAL_ENV = "REPRO_PLANNER_CAL"
DEFAULT_CAL_FILE = ".planner_calibration.json"

#: the validation gate: the planner's pick must be within this factor of
#: the measured best for every benched shape (ROADMAP contract; CI lane)
GATE_RATIO = 1.3


# ---------------------------------------------------------------------------
# Calibration: per-primitive seconds-per-unit coefficients
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Calibration:
    """Per-primitive cost coefficients for one (host, backend) pair.

    Units are seconds per unit of work; the work units are exact counts
    (HLO FLOPs/bytes from ``launch.hlo_cost`` or closed-form element
    counts), so ``predict_step_cost`` composes them without re-measuring.
    """

    c_dispatch: float     # s per jitted program dispatch (host overhead)
    c_flop: float         # s per dot FLOP (f32 GEMM-resident)
    c_byte: float         # s per HBM byte of elementwise traffic
    c_rot: float          # s per cholupdate rotation element (s^2 per row)
    c_sub: float          # s per triangular-substitution element
    c_chol: float         # s per Cholesky factorization element (~s^3/3)
    c_quant: float        # s per quant/requant element (round+clip+cast)
    backend: str = "cpu"
    fingerprint: Dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        return {"schema": CAL_SCHEMA, **dataclasses.asdict(self)}

    @classmethod
    def from_json(cls, doc: Dict) -> "Calibration":
        if doc.get("schema") != CAL_SCHEMA:
            raise ValueError(f"calibration schema {doc.get('schema')!r} != "
                             f"{CAL_SCHEMA}")
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in doc.items() if k in fields})


def _host_fingerprint() -> Dict:
    return {
        "backend": jax.default_backend(),
        "cores": os.cpu_count(),
        "machine": platform.machine(),
        "jax": jax.__version__,
    }


def _best_time(fn, *args, reps: int = 3, inner: int = 1) -> float:
    """Best-of-``reps`` wall time of one (blocked) jitted call, warmed."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def _program_flops_bytes(fn, *args) -> Tuple[float, float]:
    """Exact optimized-HLO FLOPs / HBM bytes of ``jit(fn)(*args)``."""
    from repro.launch import hlo_cost

    cost = hlo_cost.analyze(jax.jit(fn).lower(*args).compile().as_text())
    return cost.flops, cost.mem_bytes


def calibrate(reps: int = 3) -> Calibration:
    """The one-time micro-calibration run (~a few seconds).

    Each primitive is timed on a shape large enough to dominate dispatch,
    then normalized by its own program's exact work count; the dispatch
    constant itself comes from a near-empty program.  Coefficients are
    clamped positive so a noisy subtraction can never go negative.
    """
    from repro.core import ridge

    eps = 1e-15

    # 1. dispatch: a near-empty program, many calls per timing block
    x8 = jnp.zeros((8,), jnp.float32)
    c_dispatch = _best_time(jax.jit(lambda x: x + 1.0), x8,
                            reps=reps, inner=50)

    def _coeff(t: float, units: float) -> float:
        return max(t - c_dispatch, eps) / max(units, 1.0)

    # 2. dot FLOPs: one GEMM, FLOPs from its own lowered HLO
    a = jnp.ones((256, 512), jnp.float32)
    b = jnp.ones((512, 256), jnp.float32)
    mm = jax.jit(lambda a, b: a @ b)
    flops, _ = _program_flops_bytes(lambda a, b: a @ b, a, b)
    c_flop = _coeff(_best_time(mm, a, b, reps=reps), flops)

    # 3. HBM bytes: elementwise pass over a buffer far beyond L2
    big = jnp.ones((1 << 21,), jnp.float32)
    ew = jax.jit(lambda x: x * 1.0000001 + 0.5)
    _, mem = _program_flops_bytes(lambda x: x * 1.0000001 + 0.5, big)
    c_byte = _coeff(_best_time(ew, big, reps=reps), mem)

    # 4. cholupdate rotation: the server's own deferred-fold primitive,
    #    vmapped over slots exactly as the fused step runs it
    s0, S0, W0, Ny0 = 157, 8, 4, 4    # s(Nx=12); mid-size factor
    U = jnp.broadcast_to(ridge.seed_factor(s0, 1e-2), (S0, s0, s0)).copy()
    rows = jnp.ones((S0, W0, s0), jnp.float32) * 0.01
    rot = jax.jit(jax.vmap(ridge.cholupdate_window_t))
    c_rot = _coeff(_best_time(rot, U, rows, reps=reps), S0 * W0 * s0 * s0)

    # 5/6. the two refresh primitives, timed AS THE SERVER RUNS THEM (the
    # batched entry points, solves included) - a bare potrf underprices
    # the recompute round ~6x on this backend (blocked-solve + regularize
    # + layout traffic), enough to mispredict the W=1/Nx=16 winner
    A0 = jnp.ones((S0, Ny0, s0), jnp.float32)
    sub = jax.jit(ridge.ridge_solve_from_factor_t_batched)
    c_sub = _coeff(_best_time(sub, A0, U, reps=reps), S0 * s0 * s0 * Ny0)

    spd = jnp.eye(s0, dtype=jnp.float32) * 2.0
    spd = jnp.broadcast_to(spd, (S0, s0, s0)).copy()
    beta0 = jnp.float32(1e-2)
    chol = jax.jit(lambda A, B: ridge.ridge_cholesky_batched(
        A, ridge.regularize(B, beta0)))
    c_chol = _coeff(_best_time(chol, A0, spd, reps=reps), S0 * s0 ** 3 / 3.0)

    # 7. quant/requant: round+clip+cast to int8 and dequantize back
    qx = jnp.ones((1 << 20,), jnp.float32)

    def _qdq(x):
        q = jnp.clip(jnp.round(x * 127.0), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * (1.0 / 127.0)

    c_quant = _coeff(_best_time(jax.jit(_qdq), qx, reps=reps), 1 << 20)

    return Calibration(
        c_dispatch=c_dispatch, c_flop=c_flop, c_byte=c_byte, c_rot=c_rot,
        c_sub=c_sub, c_chol=c_chol, c_quant=c_quant,
        backend=jax.default_backend(), fingerprint=_host_fingerprint(),
    )


def default_cal_path() -> str:
    return os.environ.get(CAL_ENV, os.path.join(os.getcwd(),
                                                DEFAULT_CAL_FILE))


_CAL_CACHE: Dict[str, Calibration] = {}


def get_calibration(path: Optional[str] = None,
                    force: bool = False) -> Calibration:
    """Load (or measure-and-persist) this host's calibration.

    The JSON is reused only when its host/backend fingerprint matches -
    a calibration measured on another machine (or backend) silently
    re-measures instead of mis-pricing every primitive.  ``force``
    re-measures unconditionally.  In-process results are cached, so a
    fleet of ``config='auto'`` servers calibrates at most once.
    """
    path = path or default_cal_path()
    if not force:
        hit = _CAL_CACHE.get(path)
        if hit is not None:
            return hit
        if os.path.exists(path):
            try:
                with open(path) as fh:
                    cal = Calibration.from_json(json.load(fh))
                if cal.fingerprint == _host_fingerprint():
                    _CAL_CACHE[path] = cal
                    return cal
            except (ValueError, KeyError, TypeError, json.JSONDecodeError):
                pass        # stale/foreign file: fall through to re-measure
    cal = calibrate()
    try:
        # atomic publish: concurrent calibrators (the sharded bench's
        # re-exec subprocesses, the forced-8-device CI lane) must never
        # expose a torn half-written JSON to a concurrent reader
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(path) or ".",
            prefix=os.path.basename(path) + ".tmp",
        )
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(cal.to_json(), fh, indent=2)
                fh.write("\n")
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        pass                # read-only cwd: stay in-process-cached only
    _CAL_CACHE[path] = cal
    return cal


# ---------------------------------------------------------------------------
# Exact per-program serving cost (memoized - satellite fix for the bench's
# per-row re-lower/re-compile of the same logits program)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def program_cost(n_nodes: int, n_classes: int, n_streams: int, window: int,
                 t_len: int, quantize: str = "none",
                 chunk_t: Optional[int] = None) -> Tuple[float, float]:
    """(FLOPs, HBM bytes) of one slot-batched serving-logits dispatch.

    Lowers the fused streaming-logits program (S slots x W windows of T
    reservoir steps + the readout contraction) once per distinct
    ``(Nx, n_classes, S, window, t_len, quantize, chunk_t)`` and walks the
    optimized HLO with ``launch.hlo_cost`` - exact loop-aware dot FLOPs
    and memory traffic, memoized so bench sweeps and planner searches
    never pay a redundant lower+compile.

    The fp32 and int8 numbers are per-program absolute costs: the int8
    program expresses the ring recurrence as per-step int8 dots while the
    fp32 program keeps it elementwise, so the pair is comparable only
    through a backend calibration (exactly what the planner applies) -
    never as a raw FLOPs ratio.
    """
    from repro.kernels import ops
    from repro.launch import hlo_cost

    S, W, T, Nx = n_streams, window, t_len, n_nodes
    nr = Nx * (Nx + 1)
    j = jnp.zeros((S, W, T, Nx), jnp.float32)
    lengths = jnp.full((S, W), T, jnp.int32)
    p = jnp.full((S,), 0.5, jnp.float32)
    q = jnp.full((S,), 0.4, jnp.float32)
    b = jnp.zeros((S, n_classes), jnp.float32)
    if quantize == "int8":
        wq = jnp.zeros((S, n_classes, nr), jnp.int8)
        sc = jnp.full((S,), 0.01, jnp.float32)
        fn = jax.jit(functools.partial(
            ops.streaming_logits_slots_q8, n_nodes=Nx, chunk_t=chunk_t))
        lowered = fn.lower(j, lengths, p, q, wq, sc, sc, b)
    else:
        wf = jnp.zeros((S, n_classes, nr), jnp.float32)
        fn = jax.jit(functools.partial(
            ops.streaming_logits_slots, n_nodes=Nx, chunk_t=chunk_t))
        lowered = fn.lower(j, lengths, p, q, wf, b)
    cost = hlo_cost.analyze(lowered.compile().as_text())
    return cost.flops, cost.mem_bytes


# ---------------------------------------------------------------------------
# The analytical per-step cost model
# ---------------------------------------------------------------------------


def predict_step_cost(
    Nx: int,
    S: int,
    window: int,
    retirement: str = "none",
    refresh_mode: str = "recompute",
    cohorts: int = 1,
    step_block: int = 1,
    quantize: str = "none",
    backend: Optional[str] = None,
    *,
    chunk_t: Optional[int] = None,
    n_classes: int = 4,
    t_len: int = 24,
    refresh_every: int = 5,
    cal: Optional[Calibration] = None,
) -> float:
    """Predicted seconds per served sample for one knob setting.

    The model prices what each sub-step actually executes (module
    docstring): the serving program's exact HLO FLOPs/bytes, the (A, B)
    accumulation, refresh-mode maintenance amortized over the refresh
    cadence, retirement extras, the quantized path's second logits
    program, and dispatch overhead amortized over the ``step_block``
    scan.  ``backend`` only sanity-checks the calibration - coefficients
    are measured per backend, never rescaled across one.
    """
    cal = cal or get_calibration()
    if backend is not None and backend != cal.backend:
        raise ValueError(
            f"calibration measured on backend={cal.backend!r} cannot price "
            f"backend={backend!r}; re-run get_calibration on that backend"
        )
    W, B, C = int(window), max(1, int(step_block)), max(1, int(cohorts))
    s = Nx * Nx + Nx + 1
    Ny = int(n_classes)

    # (a) the serving-logits program, exact per-program work
    flops, mem = program_cost(Nx, Ny, S, W, t_len, "none", chunk_t)
    sub_step = flops * cal.c_flop + mem * cal.c_byte
    if quantize == "int8":
        # armed-lane int8 logits run IN ADDITION to the fp32 lane select
        # (unarmed slots serve fp32), plus the per-step absmax tracking
        qf, qm = program_cost(Nx, Ny, S, W, t_len, "int8", chunk_t)
        sub_step += qf * cal.c_flop + qm * cal.c_byte
        sub_step += S * W * t_len * Nx * cal.c_quant

    # (b) statistics accumulation: A += oh r~^T, B += r~ r~^T per sample
    sub_step += 2.0 * S * W * (s * s + Ny * s) * cal.c_flop
    sub_step += S * s * s * 4.0 * cal.c_byte          # B read+write traffic

    # (c) refresh-mode maintenance.  c_chol / c_sub are calibrated on the
    # server's own batched refresh entry points (solves included), so each
    # round is priced by ONE coefficient x its leading work count.
    if refresh_mode == "incremental":
        rot_sweeps = 1.0 + (1.0 if retirement == "window" else 0.0)
        sub_step += rot_sweeps * S * W * s * s * cal.c_rot
        refresh_work = S * s * s * Ny * cal.c_sub
    else:
        refresh_work = S * s ** 3 / 3.0 * cal.c_chol
    # each slot refreshes once per refresh_every steps; C cohort branches
    # per period each pay a small fixed gather/scatter-and-select cost
    sub_step += (refresh_work + C * 0.5 * cal.c_dispatch) / refresh_every

    if retirement == "window":
        # ring eviction: the evicted row is subtracted from (A, B) too
        sub_step += 2.0 * S * W * (s * s + Ny * s) * cal.c_flop

    # (e) host cost: one dispatch per block + per-sub-step control residue
    step_time = B * sub_step + cal.c_dispatch * (1.0 + 0.25 * (B - 1))
    return step_time / (B * S * W)


def predict_refresh_spike_s(
    Nx: int, S: int, refresh_mode: str = "recompute", cohorts: int = 1,
    *, n_classes: int = 4, cal: Optional[Calibration] = None,
) -> float:
    """Predicted extra wall time of a refresh-bearing step (the p99 spike
    cohort staggering divides by ~C): the whole refresh round's work over
    the ceil(S/C) slots due at once."""
    cal = cal or get_calibration()
    s = Nx * Nx + Nx + 1
    due = -(-S // max(1, int(cohorts)))
    if refresh_mode == "incremental":
        return due * s * s * n_classes * cal.c_sub
    return due * s ** 3 / 3.0 * cal.c_chol


# ---------------------------------------------------------------------------
# The planner: search the feasible knob lattice
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    """One point of the knob lattice plus its predicted cost."""

    refresh_mode: str
    refresh_cohorts: int
    step_block: int
    predicted_s_per_sample: float
    predicted_samples_per_s: float
    predicted_refresh_spike_s: float
    chunk_t: Optional[int] = None

    def knobs(self) -> Dict[str, object]:
        return {"refresh_mode": self.refresh_mode,
                "refresh_cohorts": self.refresh_cohorts,
                "step_block": self.step_block,
                "chunk_t": self.chunk_t}


DEFAULT_STEP_BLOCKS: Tuple[int, ...] = (1, 2, 4, 8)
#: searched Pallas time-chunk sizes on a Pallas-capable backend.  ``None``
#: (the kernels' own per-shape heuristic) comes FIRST: the search keeps the
#: first argmin on ties, so backends where chunk_t cannot change the program
#: (the XLA path ignores it) resolve to None and auto-config behavior is
#: bitwise what it was before the knob existed.
DEFAULT_CHUNK_TS: Tuple[Optional[int], ...] = (None, 64, 128, 256)


class Planner:
    """Searches the serving-knob lattice with the calibrated cost model.

    Shape/protocol inputs mirror ``StreamServer``'s; the semantic knobs
    (``retirement``, ``quantize``) are respected as constraints, never
    searched - retiring samples or quantizing logits changes what the
    server computes, which is the caller's call, not a cost tradeoff.
    """

    def __init__(
        self,
        Nx: int,
        S: int,
        window: int,
        t_len: int,
        n_classes: int = 4,
        refresh_every: int = 5,
        retirement: str = "none",
        quantize: str = "none",
        staging: str = "device",
        cal: Optional[Calibration] = None,
    ):
        self.Nx, self.S, self.window = int(Nx), int(S), int(window)
        self.t_len, self.n_classes = int(t_len), int(n_classes)
        self.refresh_every = max(1, int(refresh_every))
        self.retirement = retirement
        self.quantize = quantize
        self.staging = staging
        self.cal = cal or get_calibration()

    def predict(self, refresh_mode: str, refresh_cohorts: int = 1,
                step_block: int = 1,
                chunk_t: Optional[int] = None) -> float:
        return predict_step_cost(
            self.Nx, self.S, self.window, self.retirement, refresh_mode,
            refresh_cohorts, step_block, self.quantize,
            chunk_t=chunk_t, n_classes=self.n_classes, t_len=self.t_len,
            refresh_every=self.refresh_every, cal=self.cal,
        )

    def lattice(
        self,
        refresh_modes: Optional[Sequence[str]] = None,
        cohorts: Optional[Sequence[int]] = None,
        step_blocks: Optional[Sequence[int]] = None,
        chunk_ts: Optional[Sequence[Optional[int]]] = None,
    ) -> List[Tuple[str, int, int, Optional[int]]]:
        """The feasible (refresh_mode, cohorts, step_block, chunk_t)
        lattice under the server's own validity rules."""
        modes = tuple(refresh_modes or ("recompute", "incremental"))
        if self.retirement == "window":
            # the eviction downdates a live factor: incremental only
            modes = tuple(m for m in modes if m == "incremental") or (
                "incremental",)
        cs = sorted({min(max(1, int(c)), self.refresh_every)
                     for c in (cohorts or (1, self.refresh_every))})
        blocks = tuple(step_blocks or DEFAULT_STEP_BLOCKS)
        if self.staging != "device":
            blocks = (1,)           # the blocked scan needs the staged pool
        if chunk_ts is None:
            # chunk_t only reshapes the program on a Pallas-capable backend;
            # elsewhere every chunk lowers the identical XLA program, so
            # searching them would only pay redundant compiles
            chunk_ts = (DEFAULT_CHUNK_TS
                        if jax.default_backend() == "tpu" else (None,))
        cts = tuple(chunk_ts)
        return [(m, c, b, ct)
                for m in modes for c in cs for b in blocks for ct in cts]

    def search(
        self,
        refresh_modes: Optional[Sequence[str]] = None,
        cohorts: Optional[Sequence[int]] = None,
        step_blocks: Optional[Sequence[int]] = None,
        chunk_ts: Optional[Sequence[Optional[int]]] = None,
    ) -> Plan:
        """Predicted-best plan over the feasible lattice (throughput
        objective; see the module docstring on cohorts/p99).  Strict
        argmin keeps the FIRST minimum, so the ``None``-first chunk_t
        ordering resolves cost ties to the kernels' own heuristic."""
        best: Optional[Plan] = None
        for mode, c, b, ct in self.lattice(
                refresh_modes, cohorts, step_blocks, chunk_ts):
            t = self.predict(mode, c, b, ct)
            plan = Plan(
                refresh_mode=mode, refresh_cohorts=c, step_block=b,
                predicted_s_per_sample=t,
                predicted_samples_per_s=1.0 / max(t, 1e-30),
                predicted_refresh_spike_s=predict_refresh_spike_s(
                    self.Nx, self.S, mode, c, n_classes=self.n_classes,
                    cal=self.cal,
                ),
                chunk_t=ct,
            )
            if best is None or t < best.predicted_s_per_sample:
                best = plan
        assert best is not None
        return best


# ---------------------------------------------------------------------------
# The honesty gate: replay the tracked bench tables
# ---------------------------------------------------------------------------

#: bench policy name -> the knobs it measured (stream-quant table; all
#: rows ran refresh_mode='incremental', retirement='none')
_QUANT_POLICY_KNOBS: Dict[str, Dict] = {
    "fp32": {"quantize": "none", "step_block": 1},
    "int8": {"quantize": "int8", "step_block": 1},
    "fp32_b4": {"quantize": "none", "step_block": 4},
    "int8_b4": {"quantize": "int8", "step_block": 4},
}


def _parse_cell(cell: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for part in cell.split("/"):
        key = part.rstrip("0123456789")
        if key and part[len(key):]:
            out[key] = int(part[len(key):])
    return out


def replay_bench_tables(
    root: Optional[str] = None,
    cal: Optional[Calibration] = None,
    gate: float = GATE_RATIO,
) -> List[Dict]:
    """Validate the planner against the tracked BENCH_*.json measurements.

    For every benched shape whose policies map onto planner knobs
    (currently the ``stream-quant`` table: fp32/int8 x block 1/4), ask
    the cost model to rank exactly the measured configs; the row fails
    (``ok=False``) when the predicted-best config's MEASURED samples/sec
    is more than ``gate`` (1.3x) below the measured best.  Rows, not
    exceptions: callers (tests, the CI lane) assert on ``ok`` so a
    failure names every offending shape at once.
    """
    root = root or os.getcwd()
    cal = cal or get_calibration()
    results: List[Dict] = []
    path = os.path.join(root, "BENCH_stream_quant.json")
    if not os.path.exists(path):
        return results
    with open(path) as fh:
        doc = json.load(fh)
    for row in doc.get("rows", ()):
        if row.get("table") != "stream-quant":
            continue
        dims = _parse_cell(row.get("cell", ""))
        Nx, S, W = dims.get("Nx"), dims.get("S"), dims.get("W", 1)
        if not Nx or not S:
            continue
        t_len = int(row.get("t_len", 24))      # the quant suite's fixture
        measured = {
            name: row[f"{name}_samples_per_s"]
            for name in _QUANT_POLICY_KNOBS
            if f"{name}_samples_per_s" in row
        }
        if len(measured) < 2:
            continue
        predicted = {
            name: predict_step_cost(
                Nx, S, W, "none", "incremental", 1,
                knobs["step_block"], knobs["quantize"],
                n_classes=4, t_len=t_len, refresh_every=5, cal=cal,
            )
            for name, knobs in _QUANT_POLICY_KNOBS.items()
            if name in measured
        }
        pick = min(predicted, key=predicted.get)
        best = max(measured, key=measured.get)
        ratio = measured[best] / max(measured[pick], 1e-12)
        results.append({
            "source": os.path.basename(path),
            "cell": row["cell"],
            "pick": pick,
            "best": best,
            "pick_measured_samples_per_s": measured[pick],
            "best_measured_samples_per_s": measured[best],
            "best_over_pick_ratio": round(ratio, 3),
            "ok": ratio <= gate,
        })
    return results
