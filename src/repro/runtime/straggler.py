"""Straggler detection & mitigation.

At 1000+ nodes the p99 host decides step time.  The watchdog keeps an EWMA
of step durations; a step exceeding ``threshold x EWMA`` marks the
(simulated or real) slow host as suspect.  Mitigation hooks:

  * ``deadline_exceeded`` -> the trainer re-dispatches the step (the batch
    is deterministic in step index, so a re-dispatch is exactly-once in
    effect),
  * repeated offenders -> the elastic controller (runtime/trainer.py)
    rebuilds the mesh without the suspect host and restores from the last
    checkpoint (restore is resharding-capable, so N-1 hosts is fine).

On this single-process container the watchdog logic is exercised by unit
tests with simulated durations; on a real cluster the same object consumes
per-host step timings from the coordination service.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.5          # x EWMA => suspect
    ewma_alpha: float = 0.1
    strikes_to_evict: int = 3

    ewma: Optional[float] = None
    strikes: Dict[str, int] = dataclasses.field(default_factory=dict)
    evicted: List[str] = dataclasses.field(default_factory=list)

    def observe(self, host: str, duration_s: float) -> str:
        """Feed one step duration; returns 'ok' | 'suspect' | 'evict'."""
        if self.ewma is None:
            self.ewma = duration_s
            return "ok"
        verdict = "ok"
        if duration_s > self.threshold * self.ewma:
            self.strikes[host] = self.strikes.get(host, 0) + 1
            verdict = "suspect"
            if self.strikes[host] >= self.strikes_to_evict:
                self.evicted.append(host)
                self.strikes[host] = 0
                verdict = "evict"
        else:
            # healthy steps decay strikes and update the EWMA
            self.strikes[host] = max(0, self.strikes.get(host, 0) - 1)
            self.ewma = (1 - self.ewma_alpha) * self.ewma + self.ewma_alpha * duration_s
        return verdict

    def deadline(self) -> Optional[float]:
        return None if self.ewma is None else self.threshold * self.ewma
