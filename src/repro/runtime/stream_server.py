"""Continuous-batching stream server: train-while-serve for sensor streams.

This is the serving runtime for the paper's actual deployment scenario
(Sec. 3.1): many independent sensor streams (predictive maintenance, ECG
monitors, ...) each need an online DFR that (a) answers every window from
the parameters it had *before* seeing the labels (infer-before-update, the
honest online metric) and (b) keeps adapting - truncated-bp SGD on
(p, q, W, b) while the reservoir is still settling, then frozen-reservoir
(A, B) accumulation with periodic Ridge refreshes of the output layer.

Mapping to paper Sec. 3.1, per slot:

    window arrives -> fused reservoir -> DPRR -> readout   (inference;
                      optionally the one-kernel path in kernels.streaming)
                   -> truncated-bp SGD update of (p, q, W, b)   [phase 1,
                      while slot_step < phase_steps: Fig. 2's training mode]
                   -> streaming (A, B) accumulation (Eq. 21-22, 38)
                   -> at the phase boundary: reset_statistics (features
                      moved under SGD, so the stats restart - Sec. 3.6's
                      requirement that Ridge sees consistent features)
                   -> every refresh_every server steps: Ridge re-solve of
                      the slot's output layer (Eq. 39-41).  Three refresh
                      policies compose from two orthogonal knobs:

                      * ``refresh_mode='recompute'`` - batched (s, s)
                        Cholesky re-factorization from the accumulated B
                        (the PR-2 path; O(s^3) per slot per round).
                      * ``refresh_mode='incremental'`` - the slot carries a
                        live factor of B + beta I (seeded sqrt(beta) I at
                        admission, rotated forward by O(s^2) rank-1
                        cholupdates inside the SAME fused step as samples
                        accumulate - ``repro.core.ridge`` incremental
                        engine), so the refresh is just two batched
                        triangular solves, never a factorization.
                      * ``refresh_cohorts=C`` - stagger the refresh round
                        over C round-robin slot cohorts
                        (``scheduler.RefreshCohorts``): identical per-slot
                        cadence, but each step refreshes at most ceil(S/C)
                        slots, flattening the p99 latency spike.  C=1 is
                        bit-for-bit the global round.

                   -> sample retirement (``retirement=``): the paper's
                      grow-only (A, B) anchors a slot to every sample it
                      ever saw; a drifting sensor needs the opposite.  Two
                      policies retire old samples *inside the same fused
                      step* (no extra dispatches):

                      * ``'forget'`` - exponentially-weighted RLS: every
                        accumulated sample scales (A, B) by lambda and the
                        live factor by sqrt(lambda) before its fold
                        (exact: scaling commutes with the rank-1
                        rotation).  lambda=1 is bit-for-bit the
                        non-retiring path.
                      * ``'window'`` - a per-slot ring buffer
                        (``core.types.WindowState``) of the last
                        ``retire_window`` retained (r~, onehot) rows; on
                        overwrite the evicted row is subtracted from
                        (A, B) and hyperbolically downdated out of the
                        live factor (``cholupdate_* sign=-1``), with a
                        numerical-safety guard that re-factorizes
                        B + beta I for any slot whose downdate would
                        drive a diagonal non-positive.  A capacity >=
                        the stream length is bit-for-bit the
                        non-retiring path (empty ring rows evict as
                        exact no-ops).

The scaling idea is the same one the token server uses for LM decode
(``repro.runtime.server``), with the shared slot scheduler
(``repro.runtime.scheduler.SlotScheduler``): a fixed number of slots, each
holding one stream's ``OnlineState`` as row s of a single batched state
pytree.  One jitted fixed-shape step advances ALL live slots - per-slot
learning-rate phase, per-sample validity weights for tail windows, dead
slots frozen by a lane mask - so XLA never re-specializes as streams
retire and refill (continuous batching).  Per-slot state isolation is
structural: every lane of the vmapped step reads only its own state row.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masking, ridge
from repro.core.online import (
    OnlineState,
    init_state,
    online_serve_step,
    refresh_output_batched,
)
from repro.core.types import Array, DFRConfig, WindowState
from repro.kernels import ops
from repro.runtime.scheduler import RefreshCohorts, SlotScheduler


@dataclasses.dataclass
class StreamRequest:
    """One sensor stream: N labeled samples served window-by-window."""

    rid: int
    u: np.ndarray             # (N, T, n_in) float32 samples
    length: np.ndarray        # (N,) int32 valid lengths
    label: np.ndarray         # (N,) int32 labels
    preds: List[int] = dataclasses.field(default_factory=list)
    correct: int = 0
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0
    final_state: Optional[OnlineState] = None   # snapshot at retirement

    @property
    def n_samples(self) -> int:
        return self.u.shape[0]

    @property
    def online_accuracy(self) -> float:
        """Rolling infer-before-update accuracy over the served stream."""
        return self.correct / max(1, len(self.preds))


# ---------------------------------------------------------------------------
# The fixed-shape jitted step (all slots at once)
# ---------------------------------------------------------------------------


def _bcast_to(mask1d: Array, leaf: Array) -> Array:
    return mask1d.reshape((-1,) + (1,) * (leaf.ndim - 1))


def _retire_window_slot(
    U: Array,        # (s, s) transposed live factor
    A: Array,        # (Ny, s)
    B: Array,        # (s, s)
    count: Array,    # scalar int32 retained-sample count
    win: WindowState,  # single-slot ring buffer
    new_rows: Array,   # (W, s) gated r~ rows folded into (A, B) this step
    new_oh: Array,     # (W, Ny) matching label one-hots
    lv: Array,         # (W,) f32 0/1: row actually accumulated this step
) -> Tuple[Array, Array, Array, Array, WindowState, Array]:
    """Sequential sliding-window eviction for one slot (vmapped over S).

    Per accumulated row: the ring slot about to be overwritten is evicted -
    subtracted from (A, B), hyperbolically downdated out of the factor
    (guarded) - then the new row takes its place and the cursor advances.
    Dead rows (lv=0) touch nothing: the evicted row is zero-gated, the
    write and cursor advance are skipped, so tail windows and dead slots
    are exact no-ops.  Returns (U, A, B, count, win, bad): ``bad`` flags a
    guard-skipped downdate - the caller must re-factorize that slot from
    ``B + beta I`` (the factor is finite but stale).
    """
    cap = win.rows.shape[0]

    def fold(t, carry):
        U, A, B, count, rows, ohbuf, pos, bad = carry
        l = lv[t]
        ev_r = rows[pos] * l
        ev_o = ohbuf[pos] * l
        # every real r~ row ends in the constant-1 feature, so a nonzero
        # tail marks a genuine eviction (vs. never-written ring capacity)
        valid = ev_r[-1] > 0.5
        A = A - ev_o[:, None] * ev_r[None, :]
        B = B - jnp.outer(ev_r, ev_r)
        U, ok = ridge.cholupdate_dense_t_guarded(U, ev_r, -1.0)
        bad = bad | ~ok
        count = count - valid.astype(count.dtype)
        write = l > 0
        rows = rows.at[pos].set(jnp.where(write, new_rows[t], rows[pos]))
        ohbuf = ohbuf.at[pos].set(jnp.where(write, new_oh[t], ohbuf[pos]))
        pos = jnp.where(write, (pos + 1) % cap, pos)
        return U, A, B, count, rows, ohbuf, pos, bad

    U, A, B, count, rows, ohbuf, pos, bad = jax.lax.fori_loop(
        0, new_rows.shape[0], fold,
        (U, A, B, count, win.rows, win.onehot, win.pos,
         jnp.zeros((), jnp.bool_)),
    )
    return U, A, B, count, WindowState(rows=rows, onehot=ohbuf, pos=pos), bad


@partial(jax.jit, static_argnames=(
    "cfg", "fused_infer", "maintain_factor", "retirement"))
def _stream_step(
    cfg: DFRConfig,
    mask: Array,
    states: OnlineState,   # leading slot axis S on every leaf
    fresh: OnlineState,    # single-system state (no S axis): admission reset
    fresh_mask: Array,     # (S,) bool: slots admitted this step
    u: Array,              # (S, W, T, n_in)
    length: Array,         # (S, W) int32
    label: Array,          # (S, W) int32
    weight: Array,         # (S, W) f32 0/1 live-sample mask (tail windows)
    live: Array,           # (S,) bool live-slot mask
    lr: Array,             # scalar base learning rate
    phase_steps: Array,    # scalar int32: slot steps of reservoir adaptation
    beta: Array,           # scalar ridge beta (window-guard refactorization)
    forget: Array,         # scalar lambda (used when retirement='forget')
    win: Optional[WindowState],  # slot-axis ring buffers (window mode)
    fused_infer: bool = True,
    maintain_factor: bool = False,
    retirement: str = "none",
) -> Tuple[OnlineState, Optional[WindowState], Array, Dict[str, Array]]:
    """One server step: infer-before-update + train for every live slot.

    Returns (new states, predictions (S, W), per-slot metrics).  Dead slots
    compute garbage in their lanes (fixed shapes) and are frozen by the
    ``live`` mask; the host never reads their predictions.  Slot admission
    (resetting row s to the fresh single-system state) happens in-program
    via ``fresh_mask`` so slot churn costs zero extra dispatches.

    The heart is ``online_serve_step`` vmapped over the slot axis: ONE
    forward pass per slot window feeds the infer-before-update predictions,
    the truncated-BP gradients AND the frozen-phase (A, B) accumulation -
    the fusion a pair of separate infer/step calls cannot express.  Because
    the statistics only accumulate in the frozen phase, the phase-boundary
    ``reset_statistics`` of the single-stream protocol is a no-op here
    (phase-1 stats are never written in the first place).

    ``retirement`` (static) compiles in the sample-retirement policy (see
    the module docstring): ``'forget'`` threads the lambda decay through
    the vmapped serve step and the deferred factor fold; ``'window'`` runs
    the per-slot ring-buffer eviction (``_retire_window_slot``) after the
    deferred update fold, then - only when some slot's downdate hit the
    numerical guard - re-factorizes exactly those slots' live factors from
    their retained ``B + beta I`` (one cond-gated batched Cholesky, never
    executed on the clean steady-state path).
    """
    f = cfg.f()

    # continuous batching: admitted slots start from the fresh state.  The
    # select copies the whole batched state (the (S, s, s) B leaf dominates),
    # so it is cond-gated: steady-state steps with no admissions skip it.
    def _admit(st):
        return jax.tree_util.tree_map(
            lambda batched, single: jnp.where(
                _bcast_to(fresh_mask, batched), single[None], batched
            ),
            st, fresh,
        )

    states = jax.lax.cond(jnp.any(fresh_mask), _admit, lambda st: st, states)
    if retirement == "window":
        # admitted slots also restart their ring buffer (same cond gating)
        win = jax.lax.cond(
            jnp.any(fresh_mask),
            lambda w: jax.tree_util.tree_map(
                lambda leaf: jnp.where(
                    _bcast_to(fresh_mask, leaf), jnp.zeros_like(leaf), leaf
                ),
                w,
            ),
            lambda w: w,
            win,
        )

    # per-slot learning-rate phase: adapt (p, q, W, b) while the slot is
    # young, then freeze the reservoir for consistent Ridge features; the
    # (A, B) statistics accumulate only in the frozen phase
    in_phase1 = states.step < phase_steps
    lr_slot = jnp.where(in_phase1, lr, 0.0).astype(cfg.dtype)
    acc_slot = jnp.where(in_phase1, 0.0, 1.0).astype(cfg.dtype)

    new_states, logits, metrics = jax.vmap(
        lambda st, u_s, len_s, y_s, w_s, lr_s, a_s: online_serve_step(
            cfg, mask, st, u_s, len_s, y_s, lr_s, w_s, a_s,
            # 'defer': fold the factor AFTER the liveness cond below - an
            # inline fold under the conds keeps the pre-sweep factor alive,
            # forcing XLA to copy the (S, s, s) buffer per rotation instead
            # of updating it in place (see online_serve_step docstring)
            maintain_factor="defer" if maintain_factor else False,
            forget=forget if retirement == "forget" else None,
        )
    )(states, u, length, label, weight, lr_slot, acc_slot)

    if fused_infer:
        # route inference through the fused streaming kernel
        # (kernels.streaming: reservoir -> DPRR -> readout in one kernel
        # call, the TPU latency path; its XLA ref is the same math as the
        # shared forward, so on CPU this only adds the extra pass)
        j_seq = masking.apply_mask(mask, u)
        logits = jax.vmap(
            lambda j_s, len_s, st: ops.streaming_logits(
                j_s, len_s, st.params.p, st.params.q, st.params.W,
                st.params.b, cfg.n_nodes, f=f,
            )
        )(j_seq, length, states)
    preds = jnp.argmax(logits, axis=-1)  # (S, W)

    # dead slots keep their state untouched (cond-gated like admission:
    # a fully-live step - the steady state - pays no copy)
    new_states = jax.lax.cond(
        jnp.all(live),
        lambda pair: pair[0],
        lambda pair: jax.tree_util.tree_map(
            lambda n, o: jnp.where(_bcast_to(live, n), n, o), *pair
        ),
        (new_states, states),
    )
    if maintain_factor:
        # deferred rank-1 fold of the window into each slot's live factor
        # (the rows are exactly the gated r~ rows accumulated into B above:
        # dead/tail/adaptation-phase rows are zero, hence exact no-ops)
        rt_rows = metrics.pop("rt_rows")
        if retirement == "forget":
            scales = metrics.pop("fold_scale")
            Lt = jax.vmap(ridge.cholupdate_window_t_decay)(
                new_states.ridge.Lt, rt_rows, scales
            )
        else:
            Lt = jax.vmap(ridge.cholupdate_window_t)(
                new_states.ridge.Lt, rt_rows
            )
        new_states = dataclasses.replace(
            new_states,
            ridge=dataclasses.replace(new_states.ridge, Lt=Lt),
        )
        if retirement == "window":
            # retire the oldest retained sample per accumulated row: evict
            # from (A, B), downdate out of the live factor, refill the ring
            gate = weight * acc_slot[:, None]            # (S, W) 0/1
            oh_rows = jax.nn.one_hot(label, cfg.n_classes, dtype=cfg.dtype)
            Lt, A, B, count, win, bad = jax.vmap(_retire_window_slot)(
                new_states.ridge.Lt, new_states.ridge.A, new_states.ridge.B,
                new_states.ridge.count, win, rt_rows, oh_rows, gate,
            )
            # guard fallback: a clamp-skipped downdate left that slot's
            # factor stale - rebuild it from the retained B + beta I.  The
            # batched factorization is cond-gated on ANY slot flagging, so
            # the clean path (every realistic step) never pays it.
            Lt = jax.lax.cond(
                jnp.any(bad),
                lambda args: jnp.where(
                    bad[:, None, None],
                    jnp.swapaxes(
                        jnp.linalg.cholesky(ridge.regularize(args[1], beta)),
                        -1, -2,
                    ),
                    args[0],
                ),
                lambda args: args[0],
                (Lt, B),
            )
            new_states = dataclasses.replace(
                new_states,
                ridge=dataclasses.replace(
                    new_states.ridge, Lt=Lt, A=A, B=B, count=count
                ),
            )
    return new_states, win, preds, metrics


@jax.jit
def _snapshot_slot(states: OnlineState, i: Array) -> OnlineState:
    """Slot row i of the batched state as a single-system state (one
    dispatch for the whole tree; module-level so servers share the cache)."""
    return jax.tree_util.tree_map(lambda leaf: leaf[i], states)


@partial(jax.jit, static_argnames=())
def _stream_refresh(
    states: OnlineState, beta: Array, eligible: Array
) -> OnlineState:
    """Batched Ridge refresh of the eligible slots (one batched Cholesky).

    ``eligible`` (S,) marks live slots past the phase boundary with at
    least one accumulated sample; others keep their readout (solving a
    zero-stats system would zero a trained W).
    """
    refreshed = refresh_output_batched(states, beta)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(
            eligible.reshape((-1,) + (1,) * (a.ndim - 1)), a, b
        ),
        refreshed, states,
    )


def _scatter_readout(
    states: OnlineState, Wt: Array, eligible: Array, rows: Array
) -> OnlineState:
    """Write refreshed readouts Wt (C, Ny, s) into slot rows ``rows`` where
    ``eligible`` (S,) holds; everything else (and every non-readout leaf)
    is untouched - a refresh only ever moves (W, b)."""
    el = eligible[rows]
    W_rows = jnp.where(el[:, None, None], Wt[..., :, :-1], states.params.W[rows])
    b_rows = jnp.where(el[:, None], Wt[..., :, -1], states.params.b[rows])
    params = dataclasses.replace(
        states.params,
        W=states.params.W.at[rows].set(W_rows),
        b=states.params.b.at[rows].set(b_rows),
    )
    return dataclasses.replace(states, params=params)


@jax.jit
def _stream_refresh_rows(
    states: OnlineState, beta: Array, eligible: Array, rows: Array
) -> OnlineState:
    """Recompute-mode cohort refresh: gather the due cohort's rows, run the
    batched (s, s) Cholesky re-factorization over just those, scatter the
    refreshed readouts back.  With ``rows = arange(S)`` this is leaf-for-leaf
    identical to ``_stream_refresh`` (the staggering equivalence oracle)."""
    Wt = ridge.ridge_cholesky_batched(
        states.ridge.A[rows],
        ridge.regularize(states.ridge.B[rows], beta),
    )
    return _scatter_readout(states, Wt, eligible, rows)


@jax.jit
def _stream_refresh_factor_rows(
    states: OnlineState, eligible: Array, rows: Array
) -> OnlineState:
    """Incremental-mode cohort refresh: the due cohort's slots carry live
    factors of B + beta I (maintained rank-1 inside the serve step), so the
    refresh is one batched pair of blocked triangular substitutions -
    O(s^2 Ny) per slot, no factorization.  Beta is baked into the live
    factor at seeding."""
    Wt = ridge.ridge_solve_from_factor_t_batched(
        states.ridge.A[rows], states.ridge.Lt[rows]
    )
    return _scatter_readout(states, Wt, eligible, rows)


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------


class StreamServer:
    """Continuous-batching train-while-serve runtime for DFR streams.

    Fixed shapes everywhere: ``max_streams`` slots, ``window`` samples per
    slot per step, samples padded to ``t_max`` timesteps.  Requests whose
    sample count is not a multiple of ``window`` get a zero-weighted tail
    (exact: dead samples contribute nothing - see ``online_step``).

    Refresh policy (see the module docstring): ``refresh_mode`` picks
    recompute (O(s^3) batched re-factorization) vs incremental (live rank-1
    factor, O(s^2) solves); ``refresh_cohorts`` staggers the round over
    round-robin slot cohorts with identical per-slot cadence.  The defaults
    reproduce the PR-2 global-recompute behavior exactly.

    Retirement policy (drift adaptation, see the module docstring):

      * ``retirement='none'``   - grow-only statistics (the default; the
        PR-3 behavior, bit-for-bit).
      * ``retirement='forget'`` - forgetting factor ``forget`` = lambda in
        (0, 1]: per-sample exponential decay of (A, B, Lt).  The
        equivalence contract: lambda=1 serves bit-for-bit the
        ``retirement='none'`` episode.
      * ``retirement='window'`` - sliding window of the last
        ``retire_window`` retained samples per slot (ring-buffer eviction
        + guarded hyperbolic downdate of the live factor); requires
        ``refresh_mode='incremental'`` (the downdate needs the live
        factor).  The equivalence contract: a capacity >= the stream
        length serves bit-for-bit the ``retirement='none'`` episode.
    """

    def __init__(
        self,
        cfg: DFRConfig,
        t_max: int,
        max_streams: int = 8,
        window: int = 4,
        lr: float = 0.2,
        phase_steps: int = 8,
        refresh_every: int = 5,
        beta: float = 1e-2,
        mask: Optional[Array] = None,
        fused_infer: Optional[bool] = None,
        refresh_mode: str = "recompute",
        refresh_cohorts: int = 1,
        retirement: str = "none",
        forget: float = 1.0,
        retire_window: int = 0,
    ):
        if refresh_mode not in ("recompute", "incremental"):
            raise ValueError(f"unknown refresh_mode: {refresh_mode!r}")
        if retirement not in ("none", "forget", "window"):
            raise ValueError(f"unknown retirement: {retirement!r}")
        if retirement == "forget" and not 0.0 < forget <= 1.0:
            raise ValueError(f"forget must be in (0, 1], got {forget!r}")
        if retirement == "window":
            if refresh_mode != "incremental":
                raise ValueError(
                    "retirement='window' needs refresh_mode='incremental' "
                    "(the eviction downdates a live factor)"
                )
            if retire_window < 1:
                raise ValueError(
                    f"retirement='window' needs retire_window >= 1, got "
                    f"{retire_window!r}"
                )
        self.cfg = cfg
        self.t_max = int(t_max)
        self.max_streams = int(max_streams)
        self.window = int(window)
        self.lr = jnp.asarray(lr, cfg.dtype)
        self.phase_steps = jnp.asarray(phase_steps, jnp.int32)
        self.refresh_every = int(refresh_every)
        self.beta = jnp.asarray(beta, cfg.dtype)
        self.refresh_mode = refresh_mode
        self.retirement = retirement
        self.forget = jnp.asarray(forget, cfg.dtype)
        self.retire_window = int(retire_window)
        self.cohorts = RefreshCohorts(
            self.max_streams, self.refresh_every, refresh_cohorts
        )
        if fused_infer is None:
            # TPU: the one-call fused kernel (kernels.streaming) wins the
            # infer latency; CPU/XLA: reuse the serve step's shared forward
            fused_infer = jax.default_backend() == "tpu"
        self.fused_infer = bool(fused_infer)
        if mask is None:
            mask = masking.make_mask(
                jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype
            )
        self.mask = mask

        self.sched = SlotScheduler(self.max_streams)
        self.slot_pos = np.zeros(self.max_streams, np.int64)  # samples consumed
        # incremental mode: admitted slots carry a live factor seeded for the
        # empty system (sqrt(beta) I) - every later sample rotates it rank-1
        single = init_state(
            cfg, factor_beta=beta if refresh_mode == "incremental" else None
        )
        self._fresh_row = single
        self.states: OnlineState = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(
                leaf, (self.max_streams, *leaf.shape)
            ).copy(),
            single,
        )
        # sliding-window mode: per-slot ring buffers of retained samples
        self.win: Optional[WindowState] = None
        if retirement == "window":
            self.win = jax.tree_util.tree_map(
                lambda leaf: jnp.broadcast_to(
                    leaf, (self.max_streams, *leaf.shape)
                ).copy(),
                WindowState.zeros(
                    self.retire_window, cfg.s, cfg.n_classes, cfg.dtype
                ),
            )
        self._admitted_this_step: List[int] = []
        self.global_step = 0
        self.step_times_s: List[float] = []   # per-step wall time (latency)

    # -- request lifecycle -------------------------------------------------------

    def submit(self, req: StreamRequest) -> None:
        if req.u.shape[1] != self.t_max:
            raise ValueError(
                f"stream {req.rid}: samples padded to T={req.u.shape[1]}, "
                f"server expects t_max={self.t_max}"
            )
        req.submit_t = time.perf_counter()
        self.sched.submit(req)

    def _on_admit(self, i: int, req: StreamRequest) -> None:
        """Mark slot row i for the in-program fresh-state reset."""
        self.slot_pos[i] = 0
        self._admitted_this_step.append(i)

    def _snapshot_row(self, i: int) -> OnlineState:
        """Copy of slot i's state (the retiring stream's final model)."""
        return _snapshot_slot(self.states, jnp.asarray(i))

    # -- the serving loop --------------------------------------------------------

    def step(self) -> None:
        """One global step: admit, batch one window per live slot, run the
        jitted fixed-shape step, scatter predictions, retire finished."""
        self._admitted_this_step.clear()
        self.sched.admit(self._on_admit)
        S, W, T = self.max_streams, self.window, self.t_max
        u = np.zeros((S, W, T, self.cfg.n_in), np.float32)
        length = np.ones((S, W), np.int32)    # dead samples: length 1, weight 0
        label = np.zeros((S, W), np.int32)
        weight = np.zeros((S, W), np.float32)
        live = np.zeros((S,), bool)
        fresh_mask = np.zeros((S,), bool)
        fresh_mask[self._admitted_this_step] = True
        for i, req in self.sched.live():
            lo = int(self.slot_pos[i])
            n = min(W, req.n_samples - lo)
            u[i, :n] = req.u[lo:lo + n]
            length[i, :n] = req.length[lo:lo + n]
            label[i, :n] = req.label[lo:lo + n]
            weight[i, :n] = 1.0
            live[i] = True

        t0 = time.perf_counter()
        self.states, self.win, preds, _ = _stream_step(
            self.cfg, self.mask, self.states, self._fresh_row,
            jnp.asarray(fresh_mask),
            jnp.asarray(u), jnp.asarray(length), jnp.asarray(label),
            jnp.asarray(weight), jnp.asarray(live), self.lr,
            self.phase_steps, self.beta, self.forget, self.win,
            fused_infer=self.fused_infer,
            maintain_factor=(self.refresh_mode == "incremental"),
            retirement=self.retirement,
        )
        self.global_step += 1
        due = self.cohorts.due_slots(self.global_step)
        if due is not None:
            eligible = self._refresh_eligible(jnp.asarray(live))
            if len(due) < self.max_streams:
                cohort = np.zeros((self.max_streams,), bool)
                cohort[due] = True
                eligible = eligible & jnp.asarray(cohort)
            rows = jnp.asarray(due, jnp.int32)
            if self.refresh_mode == "incremental":
                self.states = _stream_refresh_factor_rows(
                    self.states, eligible, rows
                )
            else:
                self.states = _stream_refresh_rows(
                    self.states, self.beta, eligible, rows
                )
        preds_np = np.asarray(preds)   # blocks: the served predictions
        self.step_times_s.append(time.perf_counter() - t0)

        for i, req in self.sched.live():
            lo = int(self.slot_pos[i])
            n = min(W, req.n_samples - lo)
            for k in range(n):
                pred = int(preds_np[i, k])
                req.preds.append(pred)
                req.correct += int(pred == int(req.label[lo + k]))
            self.slot_pos[i] += n
            if self.slot_pos[i] >= req.n_samples:
                req.final_state = self._snapshot_row(i)
                req.done = True
                req.finish_t = time.perf_counter()
                self.sched.retire(i)   # continuous batching: slot refills

    def _refresh_eligible(self, live: Array) -> Array:
        """Live slots past the phase boundary with accumulated samples."""
        return (
            live
            & (self.states.step >= self.phase_steps)
            & (self.states.ridge.count > 0)
        )

    def run_until_drained(self, max_steps: int = 100000) -> List[StreamRequest]:
        steps = 0
        while self.sched.active() and steps < max_steps:
            self.step()
            steps += 1
        return self.sched.completed

    # -- diagnostics ---------------------------------------------------------------

    @property
    def completed(self) -> List[StreamRequest]:
        return self.sched.completed

    def latency_percentiles_ms(self) -> Dict[str, float]:
        """p50/p99 of the per-step (one window per live slot) wall time."""
        if not self.step_times_s:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        t = np.asarray(self.step_times_s) * 1e3
        return {
            "p50_ms": float(np.percentile(t, 50)),
            "p99_ms": float(np.percentile(t, 99)),
        }
