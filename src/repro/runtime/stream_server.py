"""Continuous-batching stream server: train-while-serve for sensor streams.

This is the serving runtime for the paper's actual deployment scenario
(Sec. 3.1): many independent sensor streams (predictive maintenance, ECG
monitors, ...) each need an online DFR that (a) answers every window from
the parameters it had *before* seeing the labels (infer-before-update, the
honest online metric) and (b) keeps adapting - truncated-bp SGD on
(p, q, W, b) while the reservoir is still settling, then frozen-reservoir
(A, B) accumulation with periodic Ridge refreshes of the output layer.

Mapping to paper Sec. 3.1, per slot:

    window arrives -> fused reservoir -> DPRR -> readout   (inference;
                      optionally the one-kernel path in kernels.streaming)
                   -> truncated-bp SGD update of (p, q, W, b)   [phase 1,
                      while slot_step < phase_steps: Fig. 2's training mode]
                   -> streaming (A, B) accumulation (Eq. 21-22, 38)
                   -> at the phase boundary: reset_statistics (features
                      moved under SGD, so the stats restart - Sec. 3.6's
                      requirement that Ridge sees consistent features)
                   -> every refresh_every server steps: Ridge re-solve of
                      the slot's output layer (Eq. 39-41).  Three refresh
                      policies compose from two orthogonal knobs:

                      * ``refresh_mode='recompute'`` - batched (s, s)
                        Cholesky re-factorization from the accumulated B
                        (the PR-2 path; O(s^3) per slot per round).
                      * ``refresh_mode='incremental'`` - the slot carries a
                        live factor of B + beta I (seeded sqrt(beta) I at
                        admission, rotated forward by O(s^2) rank-1
                        cholupdates inside the SAME fused step as samples
                        accumulate - ``repro.core.ridge`` incremental
                        engine), so the refresh is just two batched
                        triangular solves, never a factorization.
                      * ``refresh_cohorts=C`` - stagger the refresh round
                        over C round-robin slot cohorts
                        (``scheduler.RefreshCohorts``): identical per-slot
                        cadence, but each step refreshes at most ceil(S/C)
                        slots, flattening the p99 latency spike.  C=1 is
                        bit-for-bit the global round.

                   -> sample retirement (``retirement=``): the paper's
                      grow-only (A, B) anchors a slot to every sample it
                      ever saw; a drifting sensor needs the opposite.  Two
                      policies retire old samples *inside the same fused
                      step* (no extra dispatches):

                      * ``'forget'`` - exponentially-weighted RLS: every
                        accumulated sample scales (A, B) by lambda and the
                        live factor by sqrt(lambda) before its fold
                        (exact: scaling commutes with the rank-1
                        rotation).  lambda=1 is bit-for-bit the
                        non-retiring path.
                      * ``'window'`` - a per-slot ring buffer
                        (``core.types.WindowState``) of the last
                        ``retire_window`` retained (r~, onehot) rows; on
                        overwrite the evicted row is subtracted from
                        (A, B) and hyperbolically downdated out of the
                        live factor (``cholupdate_* sign=-1``), with a
                        numerical-safety guard that re-factorizes
                        B + beta I for any slot whose downdate would
                        drive a diagonal non-positive.  A capacity >=
                        the stream length is bit-for-bit the
                        non-retiring path (empty ring rows evict as
                        exact no-ops).

The scaling idea is the same one the token server uses for LM decode
(``repro.runtime.server``), with the shared slot scheduler
(``repro.runtime.scheduler.SlotScheduler``): a fixed number of slots, each
holding one stream's ``OnlineState`` as row s of a single batched state
pytree.  One jitted fixed-shape step advances ALL live slots - per-slot
learning-rate phase, per-sample validity weights for tail windows, dead
slots frozen by a lane mask - so XLA never re-specializes as streams
retire and refill (continuous batching).  Per-slot state isolation is
structural: every lane of the vmapped step reads only its own state row.

Device-resident serving pipeline (PR 5)
---------------------------------------

The paper's 1/13 computation-time win comes from keeping the whole
train-while-infer loop on the accelerator, no per-sample host round trips.
The software analogue is three orthogonal knobs (each independently
regression-tested bit-for-bit against the synchronous host-staged path):

* **Zero-copy request staging** (``staging='device'``, the default): a
  stream's padded payload is uploaded ONCE - staged at ``submit``
  (``core.types.RequestPool`` row), written into its slot row at admission
  via one donated in-place row write - and the per-step ``(S, W, T, n_in)``
  window batch is assembled *on device* by a cursor-indexed gather inside
  the fused jitted step.  The per-step host work drops from rebuilding and
  re-uploading the whole window batch in Python loops to shipping four
  tiny ``(S,)`` control vectors.  The periodic cohort Ridge refresh is
  folded into the same dispatch (``lax.cond``-gated on a traced due flag
  with a fixed-shape padded cohort row set), so a serving step is ONE
  program dispatch, refresh rounds included.  ``staging='host'`` retains
  the PR-4 host-staged batch build (and honors ``cfg.dtype``, which the
  PR-4 path silently upcast to float32).

* **Buffer donation** (``donate=True``, the default): the batched
  ``OnlineState`` / ``WindowState`` trees (the ``(S, s, s)`` ``B``/``Lt``
  leaves dominate) are donated to the step and refresh executables, so XLA
  updates them in place instead of copying the dominant buffers every
  dispatch.  Donation never changes numerics; ``donate=False`` keeps the
  copying PR-4 dispatch for A/B comparison.

* **Async pipelining** (``pipeline_depth=D``): predictions stay on device
  in a lag-``D`` ring; the host's per-step bookkeeping (accuracy,
  completion, retire/refill scatter) for step ``k`` runs while the device
  computes steps ``k+1 .. k+D``.  Only request completion or ``drain()``
  synchronizes.  Slot lifecycle (admission/retirement) is cursor-driven
  and therefore dispatch-time exact: pipelining delays only the *metric*
  bookkeeping, never the serving schedule, so ``pipeline_depth=0`` is
  bit-for-bit ``pipeline_depth=D`` over any episode.  Latency is reported
  honestly: ``latency_percentiles_ms`` separates dispatch time (host
  enqueue, never blocking on device compute) from drain time (the actual
  synchronization cost), so pipelining cannot hide its sync bill.

``bench_stream``'s ``pipeline`` table measures the three knobs against the
PR-4 synchronous host-staged server (see ROADMAP "Landed (PR 5)" for the
committed numbers).

Slot-sharded serving (PR 6)
---------------------------

``devices=n`` shards the slot axis over a 1-D ``("slot",)`` device mesh
(``launch.mesh.make_slot_mesh``; the ``slot`` logical-axis rule in
``distributed.sharding``): device d owns the contiguous slot block
``[d * S/n, (d+1) * S/n)``, fixed for the server's lifetime.  Slots are
independent streams, so the fused pool step runs under ``shard_map`` with
every per-slot operand - batched ``OnlineState``, ``WindowState`` rings,
the staged ``RequestPool``, the ``(S,)`` control vectors, and the padded
refresh-cohort row set (rewritten to shard-local indices by
``RefreshCohorts.due_rows_fixed_sharded``) - partitioned over ``"slot"``
and everything else replicated.  The device-local invariant: the hot path
contains NO cross-device collective; admission resets, the cursor-indexed
window gather, truncated-BP/accumulation, cohort Ridge refresh and sample
retirement all touch only the local block, and a live slot never migrates
between devices.  The ``lax.cond`` gates become per-device predicates
(``jnp.any`` over the local shard) whose untaken branches are exact
identities, so a sharded episode is BITWISE the single-device episode
across every retirement mode and pipeline depth
(``tests/test_stream_sharded.py``).  Donation, zero-copy staging and the
fused cohort refresh all survive sharding: payload uploads happen once
(the owning device keeps the in-place row write, the others drop it), and
a serving step is still ONE dispatch.  Try it on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (e.g.
``python examples/online_edge.py --devices 8``).

Quantized int8 fast path + step blocking (PR 7)
-----------------------------------------------

Two serving-only accelerations (training, statistics and refresh math stay
fp32 bit-for-bit):

* ``quantize='int8'`` - the serving logits of ARMED slots come from the
  int8 fused kernel (``kernels.streaming.streaming_step_pallas_q8`` /
  its XLA oracle): readout weights and the recurrent reservoir state live
  as int8 codes under per-slot symmetric scales, the reservoir mix, DPRR
  accumulation and readout contract in int8 x int8 -> int32 integer
  arithmetic, and only the final logits dequantize to fp32.  Calibration
  is free: the fused serve step tracks the running reservoir-state absmax
  in ``OnlineState.quant``, and the scales FOLD (requantize W, arm the
  slot) inside the same cohort-refresh branch the Ridge re-solve already
  rides - scale refresh costs zero extra dispatches and tracks every
  retirement mode's weight updates.  Unarmed slots (no refresh boundary
  crossed yet - e.g. during the SGD adaptation phase) serve fp32.  The
  coded readout is ~4x smaller per slot than the fp32 ``(Ny, Nr)`` row
  (BENCH_stream_quant records the measured accuracy band + throughput).

* ``step_block=T`` - multi-sample step blocking: a ``lax.scan`` over the
  fused pool step serves up to T windows per slot in ONE dispatch with one
  stacked refresh-schedule upload and one prediction readback.  The host
  clamps each block so no slot completes mid-block, so admissions (and
  hence the entire continuous-batching schedule) land exactly where the
  unblocked server puts them: a blocked episode reproduces the
  ``step_block=1`` predictions exactly, across retirement modes,
  pipeline depths and device counts.  ``step_block=1`` routes through the
  PR-6 step functions unchanged (bitwise regression-pinned by
  ``tests/golden/stream_fp32_golden.npz``).

Both knobs compose with each other and with slot sharding
(``tests/test_stream_quant.py``, ``tests/test_stream_sharded.py``).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from functools import partial
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import masking, online, ridge
from repro.core.online import (
    OnlineState,
    init_state,
    online_serve_step,
    refresh_output_batched,
    slot_logical_axes,
)
from repro.core.types import Array, DFRConfig, RequestPool, WindowState
from repro.distributed import sharding as shardrules
from repro.kernels import ops
from repro.launch.mesh import make_slot_mesh
from repro.runtime.scheduler import RefreshCohorts, SlotScheduler


@dataclasses.dataclass
class StreamRequest:
    """One sensor stream: N labeled samples served window-by-window."""

    rid: int
    u: np.ndarray             # (N, T, n_in) float32 samples
    length: np.ndarray        # (N,) int32 valid lengths
    label: np.ndarray         # (N,) int32 labels
    preds: List[int] = dataclasses.field(default_factory=list)
    correct: int = 0
    done: bool = False
    submit_t: float = 0.0
    finish_t: float = 0.0
    final_state: Optional[OnlineState] = None   # snapshot at retirement

    @property
    def n_samples(self) -> int:
        return self.u.shape[0]

    @property
    def online_accuracy(self) -> float:
        """Rolling infer-before-update accuracy over the served stream."""
        return self.correct / max(1, len(self.preds))


# ---------------------------------------------------------------------------
# The fixed-shape jitted step (all slots at once)
# ---------------------------------------------------------------------------


def _bcast_to(mask1d: Array, leaf: Array) -> Array:
    return mask1d.reshape((-1,) + (1,) * (leaf.ndim - 1))


def _retire_window_slot(
    U: Array,        # (s, s) transposed live factor
    A: Array,        # (Ny, s)
    B: Array,        # (s, s)
    count: Array,    # scalar int32 retained-sample count
    win: WindowState,  # single-slot ring buffer
    new_rows: Array,   # (W, s) gated r~ rows folded into (A, B) this step
    new_oh: Array,     # (W, Ny) matching label one-hots
    lv: Array,         # (W,) f32 0/1: row actually accumulated this step
) -> Tuple[Array, Array, Array, Array, WindowState, Array]:
    """Sequential sliding-window eviction for one slot (vmapped over S).

    Per accumulated row: the ring slot about to be overwritten is evicted -
    subtracted from (A, B), hyperbolically downdated out of the factor
    (guarded) - then the new row takes its place and the cursor advances.
    Dead rows (lv=0) touch nothing: the evicted row is zero-gated, the
    write and cursor advance are skipped, so tail windows and dead slots
    are exact no-ops.  Returns (U, A, B, count, win, bad): ``bad`` flags a
    guard-skipped downdate - the caller must re-factorize that slot from
    ``B + beta I`` (the factor is finite but stale).
    """
    cap = win.rows.shape[0]

    def fold(t, carry):
        U, A, B, count, rows, ohbuf, pos, bad = carry
        l = lv[t]
        ev_r = rows[pos] * l
        ev_o = ohbuf[pos] * l
        # every real r~ row ends in the constant-1 feature, so a nonzero
        # tail marks a genuine eviction (vs. never-written ring capacity)
        valid = ev_r[-1] > 0.5
        A = A - ev_o[:, None] * ev_r[None, :]
        B = B - jnp.outer(ev_r, ev_r)
        U, ok = ridge.cholupdate_dense_t_guarded(U, ev_r, -1.0)
        bad = bad | ~ok
        count = count - valid.astype(count.dtype)
        write = l > 0
        rows = rows.at[pos].set(jnp.where(write, new_rows[t], rows[pos]))
        ohbuf = ohbuf.at[pos].set(jnp.where(write, new_oh[t], ohbuf[pos]))
        pos = jnp.where(write, (pos + 1) % cap, pos)
        return U, A, B, count, rows, ohbuf, pos, bad

    U, A, B, count, rows, ohbuf, pos, bad = jax.lax.fori_loop(
        0, new_rows.shape[0], fold,
        (U, A, B, count, win.rows, win.onehot, win.pos,
         jnp.zeros((), jnp.bool_)),
    )
    return U, A, B, count, WindowState(rows=rows, onehot=ohbuf, pos=pos), bad


def _step_core(
    cfg: DFRConfig,
    mask: Array,
    states: OnlineState,   # leading slot axis S on every leaf
    fresh: OnlineState,    # single-system state (no S axis): admission reset
    fresh_mask: Array,     # (S,) bool: slots admitted this step
    u: Array,              # (S, W, T, n_in)
    length: Array,         # (S, W) int32
    label: Array,          # (S, W) int32
    weight: Array,         # (S, W) 0/1 live-sample mask (tail windows)
    live: Array,           # (S,) bool live-slot mask
    lr: Array,             # scalar base learning rate
    phase_steps: Array,    # scalar int32: slot steps of reservoir adaptation
    beta: Array,           # scalar ridge beta (window-guard refactorization)
    forget: Array,         # scalar lambda (used when retirement='forget')
    win: Optional[WindowState],  # slot-axis ring buffers (window mode)
    fused_infer: bool = True,
    maintain_factor: bool = False,
    retirement: str = "none",
    quantize: str = "none",
    adapt_ratio: float = 1.2,
    adapt_warmup: int = 4,
    chunk_t: Optional[int] = None,
) -> Tuple[OnlineState, Optional[WindowState], Array, Dict[str, Array]]:
    """One server step: infer-before-update + train for every live slot.

    Returns (new states, predictions (S, W), per-slot metrics).  Dead slots
    compute garbage in their lanes (fixed shapes) and are frozen by the
    ``live`` mask; the host never reads their predictions.  Slot admission
    (resetting row s to the fresh single-system state) happens in-program
    via ``fresh_mask`` so slot churn costs zero extra dispatches.

    The heart is ``online_serve_step`` vmapped over the slot axis: ONE
    forward pass per slot window feeds the infer-before-update predictions,
    the truncated-BP gradients AND the frozen-phase (A, B) accumulation -
    the fusion a pair of separate infer/step calls cannot express.  Because
    the statistics only accumulate in the frozen phase, the phase-boundary
    ``reset_statistics`` of the single-stream protocol is a no-op here
    (phase-1 stats are never written in the first place).

    ``retirement`` (static) compiles in the sample-retirement policy (see
    the module docstring): ``'forget'`` threads the lambda decay through
    the vmapped serve step and the deferred factor fold; ``'window'`` runs
    the per-slot ring-buffer eviction (``_retire_window_slot``) after the
    deferred update fold, then - only when some slot's downdate hit the
    numerical guard - re-factorizes exactly those slots' live factors from
    their retained ``B + beta I`` (one cond-gated batched Cholesky, never
    executed on the clean steady-state path); ``'adaptive'`` runs the
    per-slot loss-EMA breakpoint detector (``online.adaptive_anneal``)
    on the serve step's own loss metric - the ``forget`` operand becomes
    the fire-time lambda, applied through a traced (S,) per-slot forget
    vector only to tripped slots, cond-gated so a silent step is bitwise
    the ``retirement='none'`` step on everything but the two detector
    EMA leaves.

    ``quantize='int8'`` (static) serves ARMED slots from the int8 fast
    path (``ops.streaming_logits_slots_q8``: coded reservoir state +
    readout, int8 x int8 -> int32 compute, fp32 dequantized logits) built
    from the slot's PRE-update parameters - the same infer-before-update
    contract as the fp32 paths.  A slot arms when its quantization scales
    first fold at a ridge-refresh boundary (``online.fold_quant_rows``,
    see ``_stream_step_pool_impl``); unarmed slots (``w_scale == 0``)
    serve the fp32 logits, so early-phase accuracy never pays quantization
    noise before calibration exists.  Training, statistics and refreshes
    stay fp32 throughout - only serving logits change.  The serve step
    additionally tracks the running reservoir-state absmax
    (``track_state_absmax``) that calibrates the state scale at the next
    fold.  ``quantize='none'`` compiles the exact PR-6 program.
    """
    f = cfg.f()

    # continuous batching: admitted slots start from the fresh state.  The
    # select copies the whole batched state (the (S, s, s) B leaf dominates),
    # so it is cond-gated: steady-state steps with no admissions skip it.
    def _admit(st):
        return jax.tree_util.tree_map(
            lambda batched, single: jnp.where(
                _bcast_to(fresh_mask, batched), single[None], batched
            ),
            st, fresh,
        )

    states = jax.lax.cond(jnp.any(fresh_mask), _admit, lambda st: st, states)
    if retirement == "window":
        # admitted slots also restart their ring buffer (same cond gating)
        win = jax.lax.cond(
            jnp.any(fresh_mask),
            lambda w: jax.tree_util.tree_map(
                lambda leaf: jnp.where(
                    _bcast_to(fresh_mask, leaf), jnp.zeros_like(leaf), leaf
                ),
                w,
            ),
            lambda w: w,
            win,
        )

    # per-slot learning-rate phase: adapt (p, q, W, b) while the slot is
    # young, then freeze the reservoir for consistent Ridge features; the
    # (A, B) statistics accumulate only in the frozen phase
    in_phase1 = states.step < phase_steps
    lr_slot = jnp.where(in_phase1, lr, 0.0).astype(cfg.dtype)
    acc_slot = jnp.where(in_phase1, 0.0, 1.0).astype(cfg.dtype)

    def _serve_all(train):
        # one vmapped fused serve step over the slot axis; 'defer' folds
        # the factor AFTER the liveness cond below - an inline fold under
        # the conds keeps the pre-sweep factor alive, forcing XLA to copy
        # the (S, s, s) buffer per rotation instead of updating it in
        # place (see online_serve_step docstring)
        def go(operands):
            sts, u_, len_, y_, w_, lr_, a_ = operands
            return jax.vmap(
                lambda st, u_s, len_s, y_s, w_s, lr_s, a_s: online_serve_step(
                    cfg, mask, st, u_s, len_s, y_s, lr_s, w_s, a_s,
                    maintain_factor="defer" if maintain_factor else False,
                    forget=forget if retirement == "forget" else None,
                    train=train,
                    track_state_absmax=(quantize == "int8"),
                )
            )(sts, u_, len_, y_, w_, lr_, a_)
        return go

    # steady state (every live slot past its adaptation phase: lr = 0
    # everywhere) skips the whole truncated-BP backward - SGD with lr 0 is
    # the exact identity on range-clamped parameters, so the branches serve
    # the same episode and the cond only sheds dead compute.  The cond sits
    # OUTSIDE the vmap: vmapping a cond would lower to a select that runs
    # both branches for every lane.
    new_states, logits, metrics = jax.lax.cond(
        jnp.any(in_phase1 & live), _serve_all(True), _serve_all(False),
        (states, u, length, label, weight, lr_slot, acc_slot),
    )

    if fused_infer:
        # route inference through the fused streaming kernel
        # (kernels.streaming: reservoir -> DPRR -> readout in one kernel
        # call, the TPU latency path; its XLA ref is the same math as the
        # shared forward, so on CPU this only adds the extra pass)
        j_seq = masking.apply_mask(mask, u)
        logits = ops.streaming_logits_slots(
            j_seq, length, states.params.p, states.params.q,
            states.params.W, states.params.b, cfg.n_nodes, f=f,
            chunk_t=chunk_t,
        )
    if quantize == "int8":
        # int8 fast path for ARMED slots (scales folded at least once):
        # pre-update coded readout + coded recurrent state, integer
        # reservoir/DPRR/readout compute, fp32 dequantized logits.  Unarmed
        # slots (w_scale == 0: no refresh boundary crossed yet) keep the
        # fp32 logits computed above - the select is per slot lane.
        j_seq = masking.apply_mask(mask, u)
        q_logits = ops.streaming_logits_slots_q8(
            j_seq, length, states.params.p, states.params.q,
            states.quant.Wq, states.quant.w_scale, states.quant.x_scale,
            states.params.b, cfg.n_nodes, f=f, chunk_t=chunk_t,
        )
        armed = states.quant.w_scale > 0
        logits = jnp.where(
            armed[:, None, None], q_logits.astype(logits.dtype), logits
        )
    preds = jnp.argmax(logits, axis=-1)  # (S, W)

    # dead slots keep their state untouched (cond-gated like admission:
    # a fully-live step - the steady state - pays no copy)
    new_states = jax.lax.cond(
        jnp.all(live),
        lambda pair: pair[0],
        lambda pair: jax.tree_util.tree_map(
            lambda n, o: jnp.where(_bcast_to(live, n), n, o), *pair
        ),
        (new_states, states),
    )
    if maintain_factor:
        # deferred rank-1 fold of the window into each slot's live factor
        # (the rows are exactly the gated r~ rows accumulated into B above:
        # dead/tail/adaptation-phase rows are zero, hence exact no-ops)
        rt_rows = metrics.pop("rt_rows")
        if retirement == "forget":
            scales = metrics.pop("fold_scale")
            Lt = jax.vmap(ridge.cholupdate_window_t_decay)(
                new_states.ridge.Lt, rt_rows, scales
            )
        else:
            Lt = jax.vmap(ridge.cholupdate_window_t)(
                new_states.ridge.Lt, rt_rows
            )
        new_states = dataclasses.replace(
            new_states,
            ridge=dataclasses.replace(new_states.ridge, Lt=Lt),
        )
        if retirement == "window":
            # retire the oldest retained sample per accumulated row: evict
            # from (A, B), downdate out of the live factor, refill the ring
            gate = weight * acc_slot[:, None]            # (S, W) 0/1
            oh_rows = jax.nn.one_hot(label, cfg.n_classes, dtype=cfg.dtype)
            Lt, A, B, count, win, bad = jax.vmap(_retire_window_slot)(
                new_states.ridge.Lt, new_states.ridge.A, new_states.ridge.B,
                new_states.ridge.count, win, rt_rows, oh_rows, gate,
            )
            # guard fallback: a clamp-skipped downdate left that slot's
            # factor stale - rebuild it from the retained B + beta I.  The
            # batched factorization is cond-gated on ANY slot flagging, so
            # the clean path (every realistic step) never pays it.
            Lt = jax.lax.cond(
                jnp.any(bad),
                lambda args: jnp.where(
                    bad[:, None, None],
                    jnp.swapaxes(
                        jnp.linalg.cholesky(ridge.regularize(args[1], beta)),
                        -1, -2,
                    ),
                    args[0],
                ),
                lambda args: args[0],
                (Lt, B),
            )
            new_states = dataclasses.replace(
                new_states,
                ridge=dataclasses.replace(
                    new_states.ridge, Lt=Lt, A=A, B=B, count=count
                ),
            )
    if retirement == "adaptive":
        # per-slot drift detection on the serving error rate the serve step
        # already produced: EMAs update for live slots that folded
        # frozen-phase samples; a tripped slot's statistics anneal by the
        # traced (S,) forget vector (lam=1.0 elsewhere), cond-gated on any
        # trip so the silent path stays bitwise retirement='none'.  Runs
        # AFTER the factor fold: the anneal scales the post-fold factor
        # consistently (Lt by sqrt(lam), factor_beta by lam).  A tripped
        # int8 slot needs no special handling - its quant scales re-fold
        # (re-arm) at its next refresh boundary like any other refresh.
        update = live & (~in_phase1) & (jnp.sum(weight, axis=1) > 0)
        armed = new_states.step >= phase_steps + jnp.int32(adapt_warmup)
        new_states, _ = online.adaptive_anneal(
            new_states, 1.0 - metrics["acc"], update, armed,
            adapt_ratio, forget,
        )
    return new_states, win, preds, metrics


def _stream_step_impl(
    cfg: DFRConfig,
    mask: Array,
    states: OnlineState,
    fresh: OnlineState,
    fresh_mask: Array,
    u: Array,
    length: Array,
    label: Array,
    weight: Array,
    live: Array,
    lr: Array,
    phase_steps: Array,
    beta: Array,
    forget: Array,
    win: Optional[WindowState],
    fused_infer: bool = True,
    maintain_factor: bool = False,
    retirement: str = "none",
    adapt_ratio: float = 1.2,
    adapt_warmup: int = 4,
    chunk_t: Optional[int] = None,
) -> Tuple[OnlineState, Optional[WindowState], Array, Dict[str, Array]]:
    """Host-staged serving step (the retained PR-4 fallback): the caller
    builds and uploads the padded window batch; see ``_step_core``."""
    return _step_core(
        cfg, mask, states, fresh, fresh_mask, u, length, label, weight,
        live, lr, phase_steps, beta, forget, win,
        fused_infer=fused_infer, maintain_factor=maintain_factor,
        retirement=retirement,
        adapt_ratio=adapt_ratio, adapt_warmup=adapt_warmup,
        chunk_t=chunk_t,
    )


_STEP_STATICS = ("cfg", "fused_infer", "maintain_factor", "retirement",
                 "adapt_ratio", "adapt_warmup", "chunk_t")
_stream_step = jax.jit(_stream_step_impl, static_argnames=_STEP_STATICS)
# donated twin: OnlineState (arg 2) and WindowState (arg 14) update in place
_stream_step_donated = jax.jit(
    _stream_step_impl, static_argnames=_STEP_STATICS, donate_argnums=(2, 14)
)


def _gather_window(
    pool: RequestPool, cursor: Array, live: Array, window: int, dtype
) -> Tuple[Array, Array, Array, Array]:
    """Assemble the per-step (S, W, ...) window batch on device: one
    cursor-indexed ``dynamic_slice`` per slot row of the staged pool.

    Pool capacity is a multiple of ``window`` and live cursors are
    window-aligned and < capacity, so no slice ever clamps; the pad region
    carries the host-staging defaults (u=0, length=1, label=0), making the
    gathered batch bit-identical to the host-built one for live lanes.
    ``weight`` zero-gates tail samples past the stream end and every dead
    lane, exactly like the host path.
    """
    slice_d = jax.vmap(
        lambda row, pos: jax.lax.dynamic_slice_in_dim(row, pos, window, 0)
    )
    u = slice_d(pool.u, cursor)
    length = slice_d(pool.length, cursor)
    label = slice_d(pool.label, cursor)
    idx = cursor[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    weight = ((idx < pool.n[:, None]) & live[:, None]).astype(dtype)
    return u, length, label, weight


def _stream_step_pool_impl(
    cfg: DFRConfig,
    mask: Array,
    states: OnlineState,
    fresh: OnlineState,
    fresh_mask: Array,
    pool: RequestPool,
    cursor: Array,         # (S,) int32 per-slot sample cursor
    live: Array,
    lr: Array,
    phase_steps: Array,
    beta: Array,
    forget: Array,
    win: Optional[WindowState],
    refresh_due: Array,    # scalar bool: cohort refresh folds in this step
    refresh_rows: Array,   # (R,) int32 fixed-shape padded cohort rows
    refresh_ok: Array,     # (R,) bool: genuine cohort member (vs. padding)
    fused_infer: bool = True,
    maintain_factor: bool = False,
    retirement: str = "none",
    refresh_mode: str = "recompute",
    window: int = 1,
    quantize: str = "none",
    adapt_ratio: float = 1.2,
    adapt_warmup: int = 4,
    chunk_t: Optional[int] = None,
) -> Tuple[OnlineState, Optional[WindowState], Array]:
    """Device-resident serving step: cursor-indexed window gather from the
    staged ``RequestPool``, the fused serve step, and the cohort Ridge
    refresh - ONE dispatch for all three.

    The refresh is ``lax.cond``-gated on the traced ``refresh_due`` flag
    with a fixed-shape padded cohort row set (``RefreshCohorts.
    due_rows_fixed``), so refresh rounds cost zero extra dispatches and
    off-rounds skip the refresh compute entirely.  The refresh branch runs
    the exact math of the standalone ``_stream_refresh_rows`` /
    ``_stream_refresh_factor_rows`` entry points on the post-step state,
    preserving the PR-4 step->refresh ordering.

    ``quantize='int8'`` folds the quantization scales of the refreshed
    cohort in the SAME refresh branch (``online.fold_quant_rows``): the
    freshly re-solved readout rows re-quantize immediately, so the int8
    serving path is never staler than one refresh cadence, and scale
    refreshes ride the existing dispatch for free.
    """
    u, length, label, weight = _gather_window(
        pool, cursor, live, window, cfg.dtype
    )
    new_states, win, preds, _ = _step_core(
        cfg, mask, states, fresh, fresh_mask, u, length, label, weight,
        live, lr, phase_steps, beta, forget, win,
        fused_infer=fused_infer, maintain_factor=maintain_factor,
        retirement=retirement, quantize=quantize,
        adapt_ratio=adapt_ratio, adapt_warmup=adapt_warmup,
        chunk_t=chunk_t,
    )

    def _refresh(st: OnlineState) -> OnlineState:
        el = (
            refresh_ok
            & live[refresh_rows]
            & (st.step[refresh_rows] >= phase_steps)
            & (st.ridge.count[refresh_rows] > 0)
        )
        if refresh_mode == "incremental":
            st = online.refresh_output_factor_rows(st, refresh_rows, el)
        else:
            st = online.refresh_output_rows(st, beta, refresh_rows, el)
        if quantize == "int8":
            st = online.fold_quant_rows(st, refresh_rows, el)
        return st

    new_states = jax.lax.cond(
        refresh_due, _refresh, lambda st: st, new_states
    )
    return new_states, win, preds


_POOL_STATICS = ("cfg", "fused_infer", "maintain_factor", "retirement",
                 "refresh_mode", "window", "quantize",
                 "adapt_ratio", "adapt_warmup", "chunk_t")
_stream_step_pool = jax.jit(
    _stream_step_pool_impl, static_argnames=_POOL_STATICS
)
# donated twin: OnlineState (arg 2) and WindowState (arg 12) update in
# place; the pool (arg 5) is NOT donated - it is read-only here and reused
# verbatim by the next step
_stream_step_pool_donated = jax.jit(
    _stream_step_pool_impl, static_argnames=_POOL_STATICS,
    donate_argnums=(2, 12),
)


def _stream_step_pool_block_impl(
    cfg: DFRConfig,
    mask: Array,
    states: OnlineState,
    fresh: OnlineState,
    fresh_mask: Array,
    pool: RequestPool,
    cursor: Array,          # (S,) int32 cursors at the BLOCK start
    live: Array,
    lr: Array,
    phase_steps: Array,
    beta: Array,
    forget: Array,
    win: Optional[WindowState],
    active_b: Array,        # (B,) bool: sub-step t actually runs
    refresh_due_b: Array,   # (B,) bool per-sub-step refresh flags
    refresh_rows_b: Array,  # (B, R) int32 per-sub-step padded cohort rows
    refresh_ok_b: Array,    # (B, R) bool
    fused_infer: bool = True,
    maintain_factor: bool = False,
    retirement: str = "none",
    refresh_mode: str = "recompute",
    window: int = 1,
    quantize: str = "none",
    adapt_ratio: float = 1.2,
    adapt_warmup: int = 4,
    chunk_t: Optional[int] = None,
) -> Tuple[OnlineState, Optional[WindowState], Array]:
    """Multi-sample step blocking: up to B = ``step_block`` consecutive
    pool steps in ONE dispatch, a ``lax.scan`` over the fused serving step.

    Each sub-step is exactly ``_stream_step_pool_impl`` (gather + serve +
    cohort refresh) on an in-carry cursor advanced by ``window`` samples
    per live slot per sub-step; the host ships one stacked refresh
    schedule instead of B control uploads, and pays ONE dispatch + ONE
    prediction readback for the whole block.  The schedule contract that
    makes a blocked episode serve the unblocked one exactly:

      * admission only happens at block starts (``fresh_mask`` is consumed
        by sub-step 0 and zeroed in the carry), and
      * the host clamps the active length so no live slot completes
        mid-block (``StreamServer.step``) - so blocks end at every
        retirement boundary and the slot lifecycle schedule is identical.

    ``active_b`` keeps the executable fixed-shape: clamped blocks run with
    tail sub-steps inactive (a ``lax.cond`` identity - dead sub-steps skip
    the serve compute, not just its effects), so one program serves every
    block length 1..B.  Returns predictions shaped (B, S, W); inactive
    sub-steps yield zeros the host never reads.
    """
    S = live.shape[0]

    def _sub(carry, xs):
        st, w, cur, fm = carry
        act, due, rows, ok = xs

        def _run(oper):
            st, w, cur, fm = oper
            ns, nw, preds = _stream_step_pool_impl(
                cfg, mask, st, fresh, fm, pool, cur, live, lr,
                phase_steps, beta, forget, w, due, rows, ok,
                fused_infer=fused_infer, maintain_factor=maintain_factor,
                retirement=retirement, refresh_mode=refresh_mode,
                window=window, quantize=quantize,
                adapt_ratio=adapt_ratio, adapt_warmup=adapt_warmup,
                chunk_t=chunk_t,
            )
            return ns, nw, preds.astype(jnp.int32)

        def _skip(oper):
            st, w, _, _ = oper
            return st, w, jnp.zeros((S, window), jnp.int32)

        ns, nw, preds = jax.lax.cond(act, _run, _skip, (st, w, cur, fm))
        cur = cur + jnp.where(live & act, window, 0).astype(cur.dtype)
        fm = jnp.zeros_like(fm)   # admissions only at the block start
        return (ns, nw, cur, fm), preds

    (states, win, _, _), preds = jax.lax.scan(
        _sub, (states, win, cursor, fresh_mask),
        (active_b, refresh_due_b, refresh_rows_b, refresh_ok_b),
    )
    return states, win, preds    # preds: (B, S, W)


_stream_step_pool_block = jax.jit(
    _stream_step_pool_block_impl, static_argnames=_POOL_STATICS
)
_stream_step_pool_block_donated = jax.jit(
    _stream_step_pool_block_impl, static_argnames=_POOL_STATICS,
    donate_argnums=(2, 12),
)


# ---------------------------------------------------------------------------
# Slot-sharded serving (PR 6): the same fused pool step, shard_map'd over a
# 1-D ("slot",) device mesh.  Slots are embarrassingly parallel, so every
# per-slot operand (states / ring buffers / staged pool / control vectors /
# the padded refresh-cohort row set, rewritten to shard-LOCAL indices by
# RefreshCohorts.due_rows_fixed_sharded) shards over "slot" and every scalar
# or shared operand replicates - the body contains NO collective: admission,
# the cursor gather, the serve step, cohort refresh and retirement all act
# on the device-local slot block.  The lax.cond gates inside _step_core
# become per-device predicates (jnp.any over the local shard); an untaken
# branch is the exact identity, so the sharded episode is BITWISE the
# single-device episode (tests/test_stream_sharded.py holds this across
# device counts, retirement modes and pipeline depths).  Donation flows
# through jit(shard_map): out_specs match the donated operands' shardings,
# so the (S/n, s, s) factor buffers still update in place per device.
# ---------------------------------------------------------------------------

_SLOT, _REP = P("slot"), P()
# operand order of _stream_step_pool_impl after cfg:
#   mask, states, fresh, fresh_mask, pool, cursor, live, lr, phase_steps,
#   beta, forget, win, refresh_due, refresh_rows, refresh_ok
_POOL_IN_SPECS = (_REP, _SLOT, _REP, _SLOT, _SLOT, _SLOT, _SLOT, _REP,
                  _REP, _REP, _REP, _SLOT, _REP, _SLOT, _SLOT)
_POOL_OUT_SPECS = (_SLOT, _SLOT, _SLOT)      # states, win, preds
# blocked twin: the stacked (B, R) cohort row sets shard their SECOND axis
# (shard-local fixed-width blocks per device, one row set per sub-step);
# the (B,) active/due flags replicate; preds (B, S, W) shard axis 1
_BLOCK_IN_SPECS = (_REP, _SLOT, _REP, _SLOT, _SLOT, _SLOT, _SLOT, _REP,
                   _REP, _REP, _REP, _SLOT, _REP, _REP,
                   P(None, "slot"), P(None, "slot"))
_BLOCK_OUT_SPECS = (_SLOT, _SLOT, P(None, "slot"))
_SHARDED_STEP_CACHE: Dict[Tuple, object] = {}
_SHARDED_WRITE_CACHE: Dict[Mesh, object] = {}


def _sharded_pool_step(mesh: Mesh, cfg: DFRConfig, donate: bool, **statics):
    """jit(shard_map(_stream_step_pool_impl)) for this mesh/config, cached
    module-level so servers (and the bench's device-count sweep) share
    executables.  Donation mirrors the unsharded twin: states (operand 1)
    and win (operand 11) update in place."""
    key = (mesh, cfg, donate, tuple(sorted(statics.items())))
    hit = _SHARDED_STEP_CACHE.get(key)
    if hit is None:
        body = shard_map(
            partial(_stream_step_pool_impl, cfg, **statics),
            mesh=mesh, in_specs=_POOL_IN_SPECS, out_specs=_POOL_OUT_SPECS,
            check_rep=False,
        )
        hit = _SHARDED_STEP_CACHE[key] = jax.jit(
            body, donate_argnums=(1, 11) if donate else ()
        )
    return hit


def _sharded_pool_block_step(
    mesh: Mesh, cfg: DFRConfig, donate: bool, **statics
):
    """jit(shard_map(_stream_step_pool_block_impl)): the step-blocked scan
    with every sub-step acting on the device-local slot block.  The scan
    carries only slot-sharded or replicated values and the body is the
    collective-free pool step, so a blocked sharded episode is bitwise the
    blocked single-device episode (same argument as the unblocked twin)."""
    key = ("block", mesh, cfg, donate, tuple(sorted(statics.items())))
    hit = _SHARDED_STEP_CACHE.get(key)
    if hit is None:
        body = shard_map(
            partial(_stream_step_pool_block_impl, cfg, **statics),
            mesh=mesh, in_specs=_BLOCK_IN_SPECS, out_specs=_BLOCK_OUT_SPECS,
            check_rep=False,
        )
        hit = _SHARDED_STEP_CACHE[key] = jax.jit(
            body, donate_argnums=(1, 11) if donate else ()
        )
    return hit


def _pool_write_sharded_impl(
    pool: RequestPool, i: Array, u: Array, length: Array, label: Array,
    n: Array,
) -> RequestPool:
    """Per-shard body of the sharded admission write: the payload arrives
    replicated, the one device owning global row ``i`` (contiguous blocks
    of S/n slots) writes it into its local block, everyone else drops the
    scatter (out-of-range index + mode='drop') - no collective, and the
    owning device's write is the same in-place donated row write as the
    unsharded path."""
    s_loc = pool.n.shape[0]
    li = i - jax.lax.axis_index("slot") * s_loc
    li = jnp.where((li >= 0) & (li < s_loc), li, s_loc)
    return RequestPool(
        u=pool.u.at[li].set(u, mode="drop"),
        length=pool.length.at[li].set(length, mode="drop"),
        label=pool.label.at[li].set(label, mode="drop"),
        n=pool.n.at[li].set(n, mode="drop"),
    )


def _sharded_pool_write(mesh: Mesh):
    hit = _SHARDED_WRITE_CACHE.get(mesh)
    if hit is None:
        body = shard_map(
            _pool_write_sharded_impl, mesh=mesh,
            in_specs=(_SLOT, _REP, _REP, _REP, _REP, _REP),
            out_specs=_SLOT, check_rep=False,
        )
        hit = _SHARDED_WRITE_CACHE[mesh] = jax.jit(
            body, donate_argnums=(0,)
        )
    return hit


def _pool_write_impl(
    pool: RequestPool, i: Array, u: Array, length: Array, label: Array,
    n: Array,
) -> RequestPool:
    return RequestPool(
        u=pool.u.at[i].set(u),
        length=pool.length.at[i].set(length),
        label=pool.label.at[i].set(label),
        n=pool.n.at[i].set(n),
    )


# always donated: admission writes one slot row into the (dominant) staged
# u buffer in place instead of copying the whole pool per admission
_pool_write = jax.jit(_pool_write_impl, donate_argnums=(0,))


@jax.jit
def _snapshot_slot(states: OnlineState, i: Array) -> OnlineState:
    """Slot row i of the batched state as a single-system state (one
    dispatch for the whole tree; module-level so servers share the cache).
    The gather materializes fresh buffers, so the snapshot stays valid
    after later (donated) steps consume the batched state it was read
    from - the donation-safety contract of ``StreamRequest.final_state``."""
    return jax.tree_util.tree_map(lambda leaf: leaf[i], states)


@partial(jax.jit, static_argnames=())
def _stream_refresh(
    states: OnlineState, beta: Array, eligible: Array
) -> OnlineState:
    """Batched Ridge refresh of the eligible slots (one batched Cholesky).

    ``eligible`` (S,) marks live slots past the phase boundary with at
    least one accumulated sample; others keep their readout (solving a
    zero-stats system would zero a trained W).
    """
    refreshed = refresh_output_batched(states, beta)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(
            eligible.reshape((-1,) + (1,) * (a.ndim - 1)), a, b
        ),
        refreshed, states,
    )


def _stream_refresh_rows_impl(
    states: OnlineState, beta: Array, eligible: Array, rows: Array
) -> OnlineState:
    """Recompute-mode cohort refresh: gather the due cohort's rows, run the
    batched (s, s) Cholesky re-factorization over just those, scatter the
    refreshed readouts back.  With ``rows = arange(S)`` this is leaf-for-leaf
    identical to ``_stream_refresh`` (the staggering equivalence oracle)."""
    return online.refresh_output_rows(states, beta, rows, eligible[rows])


def _stream_refresh_factor_rows_impl(
    states: OnlineState, eligible: Array, rows: Array
) -> OnlineState:
    """Incremental-mode cohort refresh: the due cohort's slots carry live
    factors of B + beta I (maintained rank-1 inside the serve step), so the
    refresh is one batched pair of blocked triangular substitutions -
    O(s^2 Ny) per slot, no factorization.  Beta is baked into the live
    factor at seeding."""
    return online.refresh_output_factor_rows(states, rows, eligible[rows])


_stream_refresh_rows = jax.jit(_stream_refresh_rows_impl)
_stream_refresh_rows_donated = jax.jit(
    _stream_refresh_rows_impl, donate_argnums=(0,)
)
_stream_refresh_factor_rows = jax.jit(_stream_refresh_factor_rows_impl)
_stream_refresh_factor_rows_donated = jax.jit(
    _stream_refresh_factor_rows_impl, donate_argnums=(0,)
)


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------


class StreamServer:
    """Continuous-batching train-while-serve runtime for DFR streams.

    Fixed shapes everywhere: ``max_streams`` slots, ``window`` samples per
    slot per step, samples padded to ``t_max`` timesteps.  Requests whose
    sample count is not a multiple of ``window`` get a zero-weighted tail
    (exact: dead samples contribute nothing - see ``online_step``).

    Refresh policy (see the module docstring): ``refresh_mode`` picks
    recompute (O(s^3) batched re-factorization) vs incremental (live rank-1
    factor, O(s^2) solves); ``refresh_cohorts`` staggers the round over
    round-robin slot cohorts with identical per-slot cadence.  The defaults
    reproduce the PR-2 global-recompute behavior exactly.

    Retirement policy (drift adaptation, see the module docstring):

      * ``retirement='none'``   - grow-only statistics (the default; the
        PR-3 behavior, bit-for-bit).
      * ``retirement='forget'`` - forgetting factor ``forget`` = lambda in
        (0, 1]: per-sample exponential decay of (A, B, Lt).  The
        equivalence contract: lambda=1 serves bit-for-bit the
        ``retirement='none'`` episode.
      * ``retirement='window'`` - sliding window of the last
        ``retire_window`` retained samples per slot (ring-buffer eviction
        + guarded hyperbolic downdate of the live factor); requires
        ``refresh_mode='incremental'`` (the downdate needs the live
        factor).  The equivalence contract: a capacity >= the stream
        length serves bit-for-bit the ``retirement='none'`` episode.
      * ``retirement='adaptive'`` - per-slot loss-EMA breakpoint detector
        inside the fused step: when a slot's fast loss EMA exceeds
        ``adapt_ratio`` x its slow EMA (past a ``adapt_warmup``-step
        arming period), that slot's ridge statistics are annealed once by
        ``adapt_forget`` (the ``reset_statistics(forget=...)`` semantics)
        and the detector re-arms.  No per-sample decay, no window buffer,
        no extra knobs to hand-tune per stream.  The equivalence
        contract: an episode in which the detector never fires serves
        bit-for-bit the ``retirement='none'`` episode.

    Serving pipeline (PR 5, see the module docstring):

      * ``staging='device'`` (default) - zero-copy request staging: payloads
        upload once, the window batch is gathered on device and the cohort
        refresh folds into the same single dispatch.  ``'host'`` retains
        the PR-4 per-step host batch build.
      * ``donate=True`` (default) - the step/refresh executables update the
        batched state trees in place (never changes numerics).
      * ``pipeline_depth=D`` - overlap host bookkeeping for step k with
        device compute of steps k+1..k+D; predictions ride a lag-D device
        ring drained by ``drain()`` / completion.  D=0 is the synchronous
        PR-4 schedule bit-for-bit.
      * ``pool_capacity`` - pre-size the staged pool (samples per slot row,
        rounded up to a window multiple).  Leave None to let it grow to the
        largest submitted stream (each growth re-specializes the jitted
        gather, so pre-sizing is worth it when stream lengths are known).
      * ``devices=n`` - shard the slot axis over the first n devices
        (``S % n == 0``, ``staging='device'``; see the module docstring's
        slot-sharding section).  Bitwise the devices=1 episode; scales
        served-samples/sec with the device count (BENCH_stream_sharded).

    Quantized serving fast path (PR 7):

      * ``quantize='int8'`` - armed slots serve from int8 codes (readout
        weights + recurrent reservoir state, symmetric per-slot scales;
        int8 x int8 -> int32 reservoir/DPRR/readout compute, fp32
        dequantized logits).  Scales calibrate from the running state
        absmax and fold at ridge-refresh boundaries; a slot serves fp32
        until its first fold (``w_scale == 0``), and training/statistics
        stay fp32 always.  Requires ``staging='device'``.  ~4x smaller
        serving-state readout bytes per slot; accuracy cost measured
        honestly in BENCH_stream_quant.
      * ``step_block=T`` - multi-sample step blocking: up to T consecutive
        serving steps (T windows per slot) fuse into ONE dispatch via a
        ``lax.scan`` over the pool step, amortizing dispatch overhead and
        per-step control uploads.  Blocks clamp so no slot completes
        mid-block, making the blocked episode serve the ``step_block=1``
        episode exactly (same admissions, same refresh schedule, same
        predictions).  Requires ``staging='device'``.  T=1 routes through
        the unchanged PR-6 step functions.

    Auto-configuration (PR 8):

      * ``config='auto'`` - fill the pure-performance knobs the caller
        left unset (``refresh_mode``, ``refresh_cohorts``, ``step_block``)
        from ``runtime.planner``'s calibrated cost model instead of the
        static defaults; the chosen ``Plan`` is exposed as ``self.plan``.
        Explicitly passed knobs always override the planner, and without
        ``config='auto'`` unset knobs resolve to the historical defaults
        (recompute / 1 / 1) - existing call sites are bitwise unchanged.
        The first auto server on a host pays a few seconds of
        micro-calibration, persisted to ``.planner_calibration.json``
        (override via ``REPRO_PLANNER_CAL``) so later servers skip it.
    """

    def __init__(
        self,
        cfg: DFRConfig,
        t_max: int,
        max_streams: int = 8,
        window: int = 4,
        lr: float = 0.2,
        phase_steps: int = 8,
        refresh_every: int = 5,
        beta: float = 1e-2,
        mask: Optional[Array] = None,
        fused_infer: Optional[bool] = None,
        refresh_mode: Optional[str] = None,
        refresh_cohorts: Optional[int] = None,
        retirement: str = "none",
        forget: float = 1.0,
        retire_window: int = 0,
        adapt_forget: float = 0.12,
        adapt_ratio: float = 1.2,
        adapt_warmup: int = 4,
        staging: str = "device",
        pipeline_depth: int = 0,
        donate: bool = True,
        pool_capacity: Optional[int] = None,
        latency_window: int = 4096,
        devices: int = 1,
        quantize: str = "none",
        step_block: Optional[int] = None,
        chunk_t: Optional[int] = None,
        config: Optional[str] = None,
    ):
        # -- config='auto': fill UNSET performance knobs from the calibrated
        # cost-model planner (runtime.planner).  Explicit knobs always win,
        # so any PR-7 call site resolves to bitwise-identical behavior; only
        # the pure-performance knobs (refresh_mode / refresh_cohorts /
        # step_block) are planned - semantic knobs (retirement, quantize,
        # staging, devices) are constraints the planner respects, never
        # choices it makes.
        if config not in (None, "auto"):
            raise ValueError(f"unknown config: {config!r} (None or 'auto')")
        self.plan = None
        if config == "auto":
            from repro.runtime import planner as _planner

            _pl = _planner.Planner(
                cfg.n_nodes, max_streams, window, t_max,
                n_classes=cfg.n_classes, refresh_every=refresh_every,
                retirement=retirement, quantize=quantize, staging=staging,
            )
            self.plan = _pl.search()
            if refresh_mode is None:
                refresh_mode = self.plan.refresh_mode
            if refresh_cohorts is None:
                refresh_cohorts = self.plan.refresh_cohorts
            if step_block is None:
                step_block = self.plan.step_block
            if chunk_t is None:
                chunk_t = self.plan.chunk_t
        # unset knobs without config='auto' keep the historical defaults
        if refresh_mode is None:
            refresh_mode = "recompute"
        if refresh_cohorts is None:
            refresh_cohorts = 1
        if step_block is None:
            step_block = 1
        if refresh_mode not in ("recompute", "incremental"):
            raise ValueError(f"unknown refresh_mode: {refresh_mode!r}")
        if retirement not in ("none", "forget", "window", "adaptive"):
            raise ValueError(f"unknown retirement: {retirement!r}")
        if retirement == "forget" and not 0.0 < forget <= 1.0:
            raise ValueError(f"forget must be in (0, 1], got {forget!r}")
        if retirement == "adaptive":
            if not 0.0 < adapt_forget <= 1.0:
                raise ValueError(
                    f"adapt_forget must be in (0, 1], got {adapt_forget!r}"
                )
            if adapt_ratio <= 1.0:
                raise ValueError(
                    f"adapt_ratio must be > 1, got {adapt_ratio!r}"
                )
            if adapt_warmup < 0:
                raise ValueError(
                    f"adapt_warmup must be >= 0, got {adapt_warmup!r}"
                )
        if retirement == "window":
            if refresh_mode != "incremental":
                raise ValueError(
                    "retirement='window' needs refresh_mode='incremental' "
                    "(the eviction downdates a live factor)"
                )
            if retire_window < 1:
                raise ValueError(
                    f"retirement='window' needs retire_window >= 1, got "
                    f"{retire_window!r}"
                )
        if staging not in ("device", "host"):
            raise ValueError(f"unknown staging: {staging!r}")
        if pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {pipeline_depth!r}"
            )
        if latency_window < 1:
            raise ValueError(
                f"latency_window must be >= 1, got {latency_window!r}"
            )
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices!r}")
        if quantize not in ("none", "int8"):
            raise ValueError(f"unknown quantize: {quantize!r}")
        if quantize == "int8" and staging != "device":
            raise ValueError(
                "quantize='int8' requires staging='device' (the scale fold "
                "rides the fused cohort refresh of the pool step)"
            )
        if step_block < 1:
            raise ValueError(
                f"step_block must be >= 1, got {step_block!r}"
            )
        if chunk_t is not None and chunk_t < 1:
            raise ValueError(
                f"chunk_t must be None or >= 1, got {chunk_t!r}"
            )
        if step_block > 1 and staging != "device":
            raise ValueError(
                "step_block > 1 requires staging='device' (the blocked scan "
                "gathers every sub-step's window from the staged pool)"
            )
        if devices > 1:
            if staging != "device":
                raise ValueError(
                    "slot sharding (devices > 1) requires staging='device' "
                    "(the host-staged batch build re-uploads per step and "
                    "would serialize through one device)"
                )
            if max_streams % devices:
                raise ValueError(
                    f"max_streams={max_streams} must be divisible by "
                    f"devices={devices} (contiguous equal slot blocks)"
                )
        self.cfg = cfg
        self.t_max = int(t_max)
        self.max_streams = int(max_streams)
        self.window = int(window)
        self.lr = jnp.asarray(lr, cfg.dtype)
        self.phase_steps = jnp.asarray(phase_steps, jnp.int32)
        self.refresh_every = int(refresh_every)
        self.beta = jnp.asarray(beta, cfg.dtype)
        self.refresh_mode = refresh_mode
        self.retirement = retirement
        # adaptive mode re-purposes the ``forget`` operand slot as the
        # fire-time anneal factor (it is unused by 'none'/'window', and the
        # serve step still receives forget=None so no per-sample decay is
        # compiled in) - zero operand-signature changes across all modes
        self.forget = jnp.asarray(
            adapt_forget if retirement == "adaptive" else forget, cfg.dtype
        )
        self.adapt_ratio = float(adapt_ratio)
        self.adapt_warmup = int(adapt_warmup)
        self.retire_window = int(retire_window)
        self.staging = staging
        self.pipeline_depth = int(pipeline_depth)
        self.donate = bool(donate)
        self.quantize = quantize
        self.step_block = int(step_block)
        # Pallas time-chunk size for the fused streaming kernels; None keeps
        # the per-shape heuristic in kernels.ops (also the XLA-backend no-op)
        self.chunk_t = None if chunk_t is None else int(chunk_t)
        self._np_dtype = np.dtype(cfg.dtype)
        self.cohorts = RefreshCohorts(
            self.max_streams, self.refresh_every, refresh_cohorts
        )
        if fused_infer is None:
            # TPU: the one-call fused kernel (kernels.streaming) wins the
            # infer latency; CPU/XLA: reuse the serve step's shared forward
            fused_infer = jax.default_backend() == "tpu"
        self.fused_infer = bool(fused_infer)
        if mask is None:
            mask = masking.make_mask(
                jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype
            )
        self.mask = mask

        self.sched = SlotScheduler(self.max_streams)
        self.slot_pos = np.zeros(self.max_streams, np.int64)  # samples consumed
        # incremental mode: admitted slots carry a live factor seeded for the
        # empty system (sqrt(beta) I) - every later sample rotates it rank-1
        single = init_state(
            cfg, factor_beta=beta if refresh_mode == "incremental" else None
        )
        self._fresh_row = single
        self.states: OnlineState = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(
                leaf, (self.max_streams, *leaf.shape)
            ).copy(),
            single,
        )
        # sliding-window mode: per-slot ring buffers of retained samples
        self.win: Optional[WindowState] = None
        if retirement == "window":
            self.win = jax.tree_util.tree_map(
                lambda leaf: jnp.broadcast_to(
                    leaf, (self.max_streams, *leaf.shape)
                ).copy(),
                WindowState.zeros(
                    self.retire_window, cfg.s, cfg.n_classes, cfg.dtype
                ),
            )
        # device staging: the per-slot request pool (uploads happen once at
        # submit/admit; the jitted step gathers windows by cursor)
        self.pool: Optional[RequestPool] = None
        self._staged: Dict[int, Tuple] = {}
        if self.staging == "device":
            cap = self._round_capacity(pool_capacity or self.window)
            self.pool = RequestPool.zeros(
                self.max_streams, cap, self.t_max, cfg.n_in, cfg.dtype
            )
        # slot sharding (devices > 1): a 1-D ("slot",) mesh owning
        # contiguous blocks of S/devices slots per device.  Every per-slot
        # tree is placed device-local ONCE here (via the 'slot' logical-axis
        # rule in repro.distributed.sharding) and the shard_map'd step keeps
        # it there - a slot never migrates between devices for its lifetime
        # (tests/test_stream_sharded.py's placement property).
        self.devices = int(devices)
        self.mesh: Optional[Mesh] = None
        if self.devices > 1:
            self.mesh = make_slot_mesh(self.devices)

            def _place(tree, axes):
                return jax.device_put(
                    tree,
                    shardrules.guarded_shardings(
                        jax.eval_shape(lambda: tree), axes, mesh=self.mesh
                    ),
                )

            self.states = _place(self.states, slot_logical_axes())
            if self.win is not None:
                self.win = _place(self.win, WindowState.slot_axes())
            self.pool = _place(self.pool, RequestPool.slot_axes())
            rep = NamedSharding(self.mesh, P())
            self.mask = jax.device_put(self.mask, rep)
            self._fresh_row = jax.device_put(self._fresh_row, rep)
        self._admitted_this_step: List[int] = []
        # steady-state control vectors change rarely: cache their device
        # copies so a typical step uploads only the (S,) cursor (the
        # refresh schedule cycles through refresh_every phases, the live /
        # fresh masks only move on admission/retirement)
        self._mask_cache: Dict[bytes, Array] = {}
        self._due_cache: Dict[int, Tuple[Array, Array, Array]] = {}
        self._due_block_cache: Dict[Tuple, Tuple] = {}
        self.global_step = 0
        self._autotuner = None  # optional WarmPoolAutotuner (attach_autotuner)
        # async pipeline: (device preds, per-slot bookkeeping meta) entries,
        # drained once more than pipeline_depth steps are in flight
        self._inflight: Deque[Tuple[Array, List[Tuple]]] = deque()
        # bounded latency records (ring buffers): total per-step wall time,
        # plus the honest split into non-blocking dispatch vs blocking drain
        self.step_times_s: Deque[float] = deque(maxlen=latency_window)
        self.dispatch_times_s: Deque[float] = deque(maxlen=latency_window)
        self.drain_times_s: Deque[float] = deque(maxlen=latency_window)

    # -- request lifecycle -------------------------------------------------------

    def _round_capacity(self, n: int) -> int:
        """Pool rows are window-aligned so cursor slices never clamp."""
        return max(self.window, -(-int(n) // self.window) * self.window)

    def _stage_request(self, req: StreamRequest) -> None:
        """Pad + upload the stream's full payload ONCE (submit-time): the
        per-step path never touches the sample arrays again."""
        cap = self._round_capacity(req.n_samples)
        if cap > self.pool.capacity:
            self._grow_pool(cap)
        cap = self.pool.capacity
        u = np.zeros((cap, self.t_max, self.cfg.n_in), self._np_dtype)
        u[: req.n_samples] = req.u
        length = np.ones((cap,), np.int32)
        length[: req.n_samples] = req.length
        label = np.zeros((cap,), np.int32)
        label[: req.n_samples] = req.label
        self._staged[id(req)] = (
            jnp.asarray(u), jnp.asarray(length), jnp.asarray(label),
            jnp.asarray(req.n_samples, jnp.int32), cap,
        )

    def _grow_pool(self, cap: int) -> None:
        """Grow every slot row to ``cap`` samples (new longest stream).
        Pad values match the staging defaults; shapes change, so the jitted
        gather step re-specializes once per growth."""
        pad = cap - self.pool.capacity
        self.pool = RequestPool(
            u=jnp.pad(self.pool.u, ((0, 0), (0, pad), (0, 0), (0, 0))),
            length=jnp.pad(self.pool.length, ((0, 0), (0, pad)),
                           constant_values=1),
            label=jnp.pad(self.pool.label, ((0, 0), (0, pad))),
            n=self.pool.n,
        )
        if self.mesh is not None:
            # growth pads the (replicated-direction) capacity axis; re-pin
            # the grown pool to its canonical slot sharding (rare path)
            self.pool = jax.device_put(
                self.pool,
                shardrules.guarded_shardings(
                    jax.eval_shape(lambda: self.pool),
                    RequestPool.slot_axes(), mesh=self.mesh,
                ),
            )

    def attach_autotuner(self, tuner) -> None:
        """Attach a ``repro.runtime.autotuner.WarmPoolAutotuner``: after
        every ``step()`` the tuner applies any hyperparameter hot swaps due
        at a cohort refresh boundary and (at its own low rate) runs one
        background (p, q, beta) tuning round.  A tuner that never swaps
        leaves the served episode bit-for-bit unchanged."""
        if tuner.server is not self:
            raise ValueError("tuner was constructed for a different server")
        self._autotuner = tuner

    def submit(self, req: StreamRequest) -> None:
        if req.u.shape[1] != self.t_max:
            raise ValueError(
                f"stream {req.rid}: samples padded to T={req.u.shape[1]}, "
                f"server expects t_max={self.t_max}"
            )
        req.submit_t = time.perf_counter()
        if self.staging == "device":
            self._stage_request(req)
        self.sched.submit(req)

    def _on_admit(self, i: int, req: StreamRequest) -> None:
        """Mark slot row i for the in-program fresh-state reset and write
        the staged payload into its pool row (one donated in-place write)."""
        self.slot_pos[i] = 0
        self._admitted_this_step.append(i)
        if self.staging == "device":
            staged = self._staged.pop(id(req), None)
            if staged is None or staged[4] != self.pool.capacity:
                # the pool grew (or the entry predates a growth): re-stage
                # against the current capacity - rare, costs one upload
                self._stage_request(req)
                staged = self._staged.pop(id(req))
            u, length, label, n, _ = staged
            write = (_sharded_pool_write(self.mesh) if self.mesh is not None
                     else _pool_write)
            self.pool = write(
                self.pool, jnp.asarray(i, jnp.int32), u, length, label, n
            )

    def _snapshot_row(self, i: int) -> OnlineState:
        """Copy of slot i's state (the retiring stream's final model)."""
        return _snapshot_slot(self.states, jnp.asarray(i))

    def _cached_mask(self, mask_np: np.ndarray) -> Array:
        """Device copy of a small (S,) bool control mask, cached by value."""
        key = mask_np.tobytes()
        hit = self._mask_cache.get(key)
        if hit is None:
            if len(self._mask_cache) > 64:   # bounded (masks cycle)
                self._mask_cache.clear()
            hit = self._mask_cache[key] = jnp.asarray(mask_np)
        return hit

    def _cached_due(self, step: int) -> Tuple[Array, Array, Array]:
        """Device copy of the fixed-shape refresh schedule for this step's
        phase (cycles with period ``refresh_every``: cached once each)."""
        phase = step % self.refresh_every
        hit = self._due_cache.get(phase)
        if hit is None:
            if self.devices > 1:
                # shard-local row indices, one fixed-width block per device
                # (the P('slot') in_spec hands each device its own block)
                due, rows, ok = self.cohorts.due_rows_fixed_sharded(
                    step, self.devices
                )
            else:
                due, rows, ok = self.cohorts.due_rows_fixed(step)
            hit = self._due_cache[phase] = (
                jnp.asarray(due), jnp.asarray(rows), jnp.asarray(ok)
            )
        return hit

    def _cached_due_block(
        self, start: int, b_active: int
    ) -> Tuple[Array, Array, Array, Array]:
        """Stacked refresh schedule for a step block: the per-sub-step
        (due, rows, ok) triples for steps ``start .. start + B - 1`` plus
        the (B,) active flags for a clamped block.  The schedule cycles
        with period ``refresh_every`` (like ``_cached_due``), so the
        device copies are cached by (phase, active length)."""
        key = (start % self.refresh_every, b_active)
        hit = self._due_block_cache.get(key)
        if hit is None:
            B = self.step_block
            dues, rows, oks = [], [], []
            for t in range(B):
                if self.devices > 1:
                    d, r, o = self.cohorts.due_rows_fixed_sharded(
                        start + t, self.devices
                    )
                else:
                    d, r, o = self.cohorts.due_rows_fixed(start + t)
                dues.append(np.asarray(d))
                rows.append(np.asarray(r))
                oks.append(np.asarray(o))
            active = np.arange(B) < b_active
            # inactive tail sub-steps are cond-skipped anyway; zeroing
            # their due flags keeps the cached schedule canonical
            hit = self._due_block_cache[key] = (
                jnp.asarray(active),
                jnp.asarray(np.stack(dues).astype(bool) & active),
                jnp.asarray(np.stack(rows)),
                jnp.asarray(np.stack(oks)),
            )
        return hit

    # -- the serving loop --------------------------------------------------------

    def step(self) -> None:
        """One global step: admit, advance every live slot one window via
        the fused fixed-shape dispatch, book-keep at lag ``pipeline_depth``.

        ``staging='device'`` gathers the window batch on device from the
        staged pool (the host ships only (S,)-sized control vectors) and
        folds any due cohort refresh into the same dispatch;
        ``staging='host'`` retains the PR-4 build-pad-upload loop and the
        separate refresh dispatch.  Predictions enter the in-flight ring;
        entries deeper than ``pipeline_depth`` are drained (the only
        blocking device read), so depth 0 is fully synchronous.
        """
        t_start = time.perf_counter()
        self._admitted_this_step.clear()
        self.sched.admit(self._on_admit)
        S, W, T = self.max_streams, self.window, self.t_max
        live = np.zeros((S,), bool)
        fresh_mask = np.zeros((S,), bool)
        fresh_mask[self._admitted_this_step] = True
        slots = list(self.sched.live())
        meta: List[Tuple] = []
        for i, req in slots:
            lo = int(self.slot_pos[i])
            n = min(W, req.n_samples - lo)
            live[i] = True
            meta.append((0, i, req, lo, n))

        # step blocking: clamp the block so no live slot completes inside
        # it - blocks then end at every retirement boundary, so admission
        # timing (and with it the whole slot lifecycle schedule) matches
        # the step_block=1 episode exactly
        b_active = 1
        if self.step_block > 1 and slots:
            b_active = self.step_block
            for _t, i, req, lo, n in meta:
                b_active = min(b_active, -(-(req.n_samples - lo) // W))
            b_active = max(1, b_active)
            for t in range(1, b_active):
                for i, req in slots:
                    lo = int(self.slot_pos[i]) + t * W
                    n = min(W, req.n_samples - lo)
                    meta.append((t, i, req, lo, n))

        step_kw = dict(
            fused_infer=self.fused_infer,
            maintain_factor=(self.refresh_mode == "incremental"),
            retirement=self.retirement,
            adapt_ratio=self.adapt_ratio,
            adapt_warmup=self.adapt_warmup,
            chunk_t=self.chunk_t,
        )
        if self.staging == "device":
            pool_kw = dict(
                refresh_mode=self.refresh_mode, window=W,
                quantize=self.quantize, **step_kw,
            )
            operands = (
                self.mask, self.states, self._fresh_row,
                self._cached_mask(fresh_mask), self.pool,
                jnp.asarray(self.slot_pos.astype(np.int32)),
                self._cached_mask(live), self.lr, self.phase_steps,
                self.beta, self.forget, self.win,
            )
            if self.step_block > 1:
                active, due_b, rows_b, ok_b = self._cached_due_block(
                    self.global_step + 1, b_active
                )
                if self.mesh is not None:
                    step_fn = _sharded_pool_block_step(
                        self.mesh, self.cfg, self.donate, **pool_kw
                    )
                    self.states, self.win, preds = step_fn(
                        *operands, active, due_b, rows_b, ok_b
                    )
                else:
                    step_fn = (_stream_step_pool_block_donated if self.donate
                               else _stream_step_pool_block)
                    self.states, self.win, preds = step_fn(
                        self.cfg, *operands, active, due_b, rows_b, ok_b,
                        **pool_kw,
                    )
                self.global_step += b_active
            else:
                due, rows, ok = self._cached_due(self.global_step + 1)
                if self.mesh is not None:
                    step_fn = _sharded_pool_step(
                        self.mesh, self.cfg, self.donate, **pool_kw
                    )
                    self.states, self.win, preds = step_fn(
                        *operands, due, rows, ok
                    )
                else:
                    step_fn = (_stream_step_pool_donated if self.donate
                               else _stream_step_pool)
                    self.states, self.win, preds = step_fn(
                        self.cfg, *operands, due, rows, ok, **pool_kw,
                    )
                self.global_step += 1
        else:
            # PR-4 host staging: rebuild + upload the padded window batch
            # (in cfg.dtype - the PR-4 code hardcoded float32 here, silently
            # upcasting non-f32 configs)
            u = np.zeros((S, W, T, self.cfg.n_in), self._np_dtype)
            length = np.ones((S, W), np.int32)  # dead samples: len 1, w 0
            label = np.zeros((S, W), np.int32)
            weight = np.zeros((S, W), self._np_dtype)
            for _t, i, req, lo, n in meta:
                u[i, :n] = req.u[lo:lo + n]
                length[i, :n] = req.length[lo:lo + n]
                label[i, :n] = req.label[lo:lo + n]
                weight[i, :n] = 1.0
            step_fn = _stream_step_donated if self.donate else _stream_step
            self.states, self.win, preds, _ = step_fn(
                self.cfg, self.mask, self.states, self._fresh_row,
                jnp.asarray(fresh_mask),
                jnp.asarray(u), jnp.asarray(length), jnp.asarray(label),
                jnp.asarray(weight), jnp.asarray(live), self.lr,
                self.phase_steps, self.beta, self.forget, self.win, **step_kw,
            )
            self.global_step += 1
            due = self.cohorts.due_slots(self.global_step)
            if due is not None:
                eligible = self._refresh_eligible(jnp.asarray(live))
                if len(due) < self.max_streams:
                    cohort = np.zeros((self.max_streams,), bool)
                    cohort[due] = True
                    eligible = eligible & jnp.asarray(cohort)
                rows = jnp.asarray(due, jnp.int32)
                if self.refresh_mode == "incremental":
                    fn = (_stream_refresh_factor_rows_donated if self.donate
                          else _stream_refresh_factor_rows)
                    self.states = fn(self.states, eligible, rows)
                else:
                    fn = (_stream_refresh_rows_donated if self.donate
                          else _stream_refresh_rows)
                    self.states = fn(self.states, self.beta, eligible, rows)

        # dispatch-time bookkeeping: the slot lifecycle is cursor-driven
        # (independent of prediction values), so retirement/refill never
        # waits on the device - only the metric bookkeeping rides the ring.
        # Meta is sub-step-major, so a blocked step's cursor advances
        # accumulate in schedule order and a slot retires exactly at its
        # block's end (the clamp guarantees no earlier completion).
        for _t, i, req, lo, n in meta:
            self.slot_pos[i] += n
            if self.slot_pos[i] >= req.n_samples:
                req.final_state = self._snapshot_row(i)
                self.sched.retire(i)   # continuous batching: slot refills
        self._inflight.append((preds, meta))
        if self._autotuner is not None:
            self._autotuner.on_step()
        self.dispatch_times_s.append(time.perf_counter() - t_start)
        while len(self._inflight) > self.pipeline_depth:
            self._drain_one()
        self.step_times_s.append(time.perf_counter() - t_start)

    def _drain_one(self) -> None:
        """Materialize the oldest in-flight step's predictions (the only
        blocking device read) and run its per-sample bookkeeping."""
        preds, meta = self._inflight.popleft()
        t0 = time.perf_counter()
        preds_np = np.asarray(preds)   # blocks: the served predictions
        self.drain_times_s.append(time.perf_counter() - t0)
        for t, i, req, lo, n in meta:
            # blocked steps return (B, S, W); unblocked return (S, W)
            block = preds_np[t] if preds_np.ndim == 3 else preds_np
            for k in range(n):
                pred = int(block[i, k])
                req.preds.append(pred)
                req.correct += int(pred == int(req.label[lo + k]))
            if lo + n >= req.n_samples:
                req.done = True
                req.finish_t = time.perf_counter()

    def drain(self) -> None:
        """Synchronize: flush every in-flight pipeline entry (predictions,
        accuracy, completion flags).  Idempotent; called automatically by
        ``run_until_drained``."""
        while self._inflight:
            self._drain_one()

    def _refresh_eligible(self, live: Array) -> Array:
        """Live slots past the phase boundary with accumulated samples."""
        return (
            live
            & (self.states.step >= self.phase_steps)
            & (self.states.ridge.count > 0)
        )

    def run_until_drained(
        self, max_steps: int = 100000, strict: bool = False
    ) -> List[StreamRequest]:
        """Serve until every stream completes (then flush the pipeline).

        If ``max_steps`` elapses with streams still live or queued, the
        truncation is never silent: a ``RuntimeWarning`` reports how many
        streams were left undrained (``strict=True`` raises instead).
        """
        steps = 0
        while self.sched.active() and steps < max_steps:
            self.step()
            steps += 1
        self.drain()
        if self.sched.active():
            undrained = len(self.sched.live()) + len(self.sched.queue)
            msg = (
                f"run_until_drained stopped at max_steps={max_steps} with "
                f"{undrained} stream(s) still live or queued"
            )
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return self.sched.completed

    # -- diagnostics ---------------------------------------------------------------

    @property
    def completed(self) -> List[StreamRequest]:
        return self.sched.completed

    def latency_percentiles_ms(self) -> Dict[str, float]:
        """p50/p99 of the per-step wall time, split honestly for pipelining.

        ``p50_ms``/``p99_ms``: total wall time of ``step()`` (dispatch plus
        whatever draining that step performed), measured from ``step()``
        entry - so it includes admission and, on the host-staged path, the
        per-step batch build (which PR-4's timing excluded: its numbers are
        not directly comparable to these).  ``dispatch_*``: the non-blocking host
        portion (admit, control vectors, program enqueue).  ``drain_*``:
        the blocking device reads - the synchronization cost that async
        pipelining defers but must still pay, reported per drained entry so
        a deep pipeline cannot hide it.  All records ride bounded ring
        buffers (``latency_window`` entries), so long-lived servers don't
        grow without bound.

        A ring with no records reports ``NaN`` for its percentiles - a
        server that never stepped (or a depth-0 pipeline that never
        drained) is "no measurement", which must stay distinguishable from
        a genuine sub-resolution 0.0 ms reading.
        """
        out: Dict[str, float] = {}
        for prefix, rec in (("", self.step_times_s),
                            ("dispatch_", self.dispatch_times_s),
                            ("drain_", self.drain_times_s)):
            if rec:
                t = np.asarray(rec) * 1e3
                p50, p99 = (float(np.percentile(t, 50)),
                            float(np.percentile(t, 99)))
            else:
                p50 = p99 = float("nan")
            out[f"{prefix}p50_ms"] = p50
            out[f"{prefix}p99_ms"] = p99
        return out
