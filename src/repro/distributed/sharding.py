"""Logical-axis sharding rules engine (FSDP + TP over the production mesh).

Every parameter is initialized together with a tuple of *logical* axis names
(e.g. ("embed", "heads", "head_dim")).  A rule table maps logical names to
mesh axes; the same params code therefore runs on a single device (rules
resolve to nothing), one pod (data=16, model=16), or multi-pod
(pod=2, data=16, model=16).

Default placement (MaxText-style FSDP+TP hybrid):
    vocab / heads / kv / mlp / expert_mlp -> "model"   (tensor parallel)
    embed / expert                        -> "data"    (FSDP weight shard)
    batch                                 -> ("pod", "data") for activations
    slot / member                         -> the serving axes (below)
    layers / head_dim / seq / state       -> replicated

Serving axes: the stream server's slot axis and the online ensemble's
member axis are both embarrassingly parallel, so each rule lists the
dedicated serving-mesh axis first (``make_slot_mesh`` builds meshes whose
axes are literally named "slot" / "member") and falls back to the
production data axes when no serving mesh is active.  On a combined
``slot x member`` mesh the two logical axes resolve to their own mesh axes
independently, so a sharded ensemble-of-slots state (leaves leading with
``("slot", "member", ...)``) shards both ways at once; on the production
mesh the uniqueness guard lets the leading ``slot`` claim the data axes
and replicates ``member`` (slots are the coarser unit of serving
parallelism).  The slot rule needs no per-dtype special case: the PR-7
int8 quantization leaves (``QuantParams``: per-slot ``Wq`` int8 codes,
``w_scale``/``x_scale``/``x_absmax`` f32 scalars-per-slot) all lead with
the slot axis like every other ``SlotStates`` leaf, so the same
``P("slot", ...)`` placement covers them and the sharded quantized
episode stays bitwise the single-device one (CI: the forced-8-device
sharded x quantized parity tests).  The PR-9 adaptive-retirement
detector leaves (``loss_fast``/``loss_slow``: per-slot error-rate EMAs)
follow the identical pattern - ``(S,)`` scalars-per-slot leading with
the slot axis, annealing reads/writes only the owning device's rows, so
``retirement='adaptive'`` composes with slot sharding with no new rule.

A ``MeshContext`` (set by the launcher) makes ``shard_act`` constraints
active; without one everything is a no-op so unit tests run untouched.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxes = Tuple[Optional[str], ...]

# rule: logical name -> mesh axis name (or tuple of mesh axes, or None)
Rules = Dict[str, Any]

DEFAULT_RULES: Rules = {
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "expert_mlp": "model",
    "embed": "data",
    "embed_no_shard": None,
    "expert": "data",
    "batch": ("pod", "data"),
    # ensemble member axis (repro.core.online.OnlineEnsemble): members are
    # embarrassingly parallel, so the K axis shards over a dedicated
    # "member" serving-mesh axis when one exists and like data otherwise;
    # per-member (A, B)/grad reductions stay *within* a member (no
    # collective over 'member' - only the batch-sharded online_step psums
    # over data_axes()).
    "member": ("member", "pod", "data"),
    # stream-server slot axis (repro.runtime.stream_server.StreamServer):
    # slots are independent streams - embarrassingly parallel - so the S
    # axis shards over the dedicated "slot" serving-mesh axis
    # (launch.mesh.make_slot_mesh) when one exists and over the data axes
    # otherwise.  Nothing ever reduces over 'slot': admission, refresh
    # cohorts and retirement are all device-local by construction (the
    # shard_map'd serving step in runtime.stream_server).
    "slot": ("slot", "pod", "data"),
    "act_model": "model",
    "kv_alt": "model",
    "layers": None,
    "head_dim": None,
    "seq": None,
    "state": None,
    "conv": None,
    None: None,
}


@dataclasses.dataclass
class MeshContext:
    mesh: Optional[Mesh]
    rules: Rules

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[name]


_STATE = threading.local()


def current() -> MeshContext:
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        ctx = MeshContext(mesh=None, rules=dict(DEFAULT_RULES))
    return ctx


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Rules] = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh=mesh, rules=dict(rules or DEFAULT_RULES))
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def _resolve(logical: Optional[str], rules: Rules, mesh: Optional[Mesh]):
    """Logical axis -> mesh axis (filtered to axes that exist in the mesh)."""
    target = rules.get(logical, None)
    if target is None or mesh is None:
        return None
    names = mesh.axis_names
    if isinstance(target, (tuple, list)):
        present = tuple(t for t in target if t in names)
        return present if present else None
    return target if target in names else None


def spec_for(axes: LogicalAxes, rules: Optional[Rules] = None,
             mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a parameter with the given logical axes.

    Divisibility guard: a mesh axis is only applied if the (unknown here)
    dimension is assumed padded by the config layer; configs are responsible
    for padding vocab/mlp/etc. to multiples of the mesh axis size.
    """
    ctx = current()
    rules = rules or ctx.rules
    mesh = mesh or ctx.mesh
    return P(*[_resolve(a, rules, mesh) for a in axes])


def sharding_for(axes: LogicalAxes, mesh: Optional[Mesh] = None,
                 rules: Optional[Rules] = None) -> Optional[NamedSharding]:
    ctx = current()
    mesh = mesh or ctx.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes, rules, mesh))


def shard_act(x: jax.Array, axes: LogicalAxes) -> jax.Array:
    """with_sharding_constraint if a mesh context is active, else identity.

    Uses the divisibility-guarded spec: constraining an indivisible dim
    makes XLA SPMD fall back to full rematerialization (replicate +
    repartition), which is both slow and memory-hostile.
    """
    ctx = current()
    if ctx.mesh is None:
        return x
    spec = guarded_spec(tuple(x.shape), tuple(axes), ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def fsdp_gather(w: jax.Array, axes: LogicalAxes) -> jax.Array:
    """Constrain a parameter at its use site to its TP-only sharding (FSDP
    axes dropped) - the explicit 'gather weights over data' of FSDP/ZeRO-3.

    Without this, XLA SPMD sometimes reshards the (larger, f32) activations
    over 'model' instead of gathering the bf16 weight over 'data' when a dot
    contracts an fsdp-sharded dimension - measured 2-4x collective-bytes
    regressions (EXPERIMENTS.md S4, rwkv6 iterations).
    """
    ctx = current()
    if ctx.mesh is None:
        return w
    rules = dict(ctx.rules)
    rules["embed"] = None
    rules["expert"] = None
    spec = guarded_spec(tuple(w.shape), tuple(axes), ctx.mesh, rules)
    return jax.lax.with_sharding_constraint(w, NamedSharding(ctx.mesh, spec))


def tree_specs(axes_tree) -> Any:
    """Map a pytree of logical-axes tuples -> pytree of PartitionSpec."""
    return jax.tree_util.tree_map(
        lambda axes: spec_for(tuple(axes)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )


def tree_shardings(axes_tree, mesh: Optional[Mesh] = None) -> Any:
    ctx = current()
    mesh = mesh or ctx.mesh
    if mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(mesh, spec_for(tuple(axes), mesh=mesh)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )


def guarded_spec(
    shape: Tuple[int, ...],
    axes: LogicalAxes,
    mesh: Optional[Mesh] = None,
    rules: Optional[Rules] = None,
) -> P:
    """PartitionSpec with divisibility + uniqueness guards.

    A mesh axis is applied to a dimension only if (a) the dim size is
    divisible by the mesh-axis-product and (b) no earlier dimension of this
    array already claimed that mesh axis.  This is what lets one rule table
    serve every architecture (e.g. qwen1.5's 8 KV heads fall back from
    'kv'->model to 'kv_alt' on head_dim).
    """
    ctx = current()
    rules = rules or ctx.rules
    mesh = mesh or ctx.mesh
    if mesh is None:
        return P(*([None] * len(shape)))
    used: set = set()
    out = []
    for dim, logical in zip(shape, axes):
        resolved = _resolve(logical, rules, mesh)
        names = (
            resolved if isinstance(resolved, tuple)
            else (resolved,) if resolved else ()
        )
        names = tuple(n for n in names if n not in used)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if names and size > 1 and dim % size == 0:
            used.update(names)
            out.append(names if len(names) > 1 else names[0])
        else:
            out.append(None)
    return P(*out)


def guarded_shardings(shapes_tree, axes_tree, mesh: Optional[Mesh] = None,
                      rules: Optional[Rules] = None):
    """Pytree of ShapeDtypeStruct x pytree of axes -> NamedShardings."""
    ctx = current()
    mesh = mesh or ctx.mesh
    if mesh is None:
        return None
    # tree_map flattens axes_tree up to shapes_tree's structure, so the
    # per-leaf axes tuples arrive intact
    return jax.tree_util.tree_map(
        lambda sh, axes: NamedSharding(
            mesh, guarded_spec(tuple(sh.shape), tuple(axes), mesh, rules)
        ),
        shapes_tree,
        axes_tree,
    )


def data_axes(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    """Mesh axes that carry data parallelism (for psums/grad reductions)."""
    ctx = current()
    mesh = mesh or ctx.mesh
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
