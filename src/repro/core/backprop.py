"""Backpropagation through the DFR (paper Sec. 3.2-3.5).

Three gradient paths are implemented:

* ``grads_truncated_manual`` - the paper's hand-derived truncated equations
  (Eq. 25-26, 33-36), written exactly as the FPGA datapath computes them.
* ``grads_truncated`` - the same truncated objective expressed with
  ``stop_gradient`` so ``jax.grad`` reproduces Eq. 33-36 (validated
  against the manual path in tests); this is the production batched path.
* ``grads_full_bptt`` - full unrolled backprop through all T steps
  (the expensive reference the truncation approximates; Eq. 29-32).

Loss: softmax cross-entropy (Eq. 24), with dL/dlogits = y - e (Eq. 25).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dprr as dprr_mod
from repro.core import reservoir as res_mod
from repro.core.types import Array, DFRConfig, DFRParams


class ForwardAux(NamedTuple):
    logits: Array     # (..., Ny)
    probs: Array      # (..., Ny)
    r: Array          # (..., Nr)
    x_last: Array     # (..., Nx)  x(T)
    x_prev: Array     # (..., Nx)  x(T-1)
    j_last: Array     # (..., Nx)  j(T)


def loss_from_logits(logits: Array, onehot: Array) -> Array:
    """Cross-entropy (Eq. 24) with a numerically-safe log-softmax."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(onehot * logp, axis=-1)


def loss_mse(logits: Array, targets: Array) -> Array:
    """Squared-error loss for regression readouts (population engine).

    0.5 * ||logits - targets||^2 per sample, so dL/dlogits = logits - targets
    mirrors the cross-entropy case's (probs - onehot) in Eq. 25 and the same
    truncated-BP machinery applies unchanged.
    """
    d = logits - targets
    return 0.5 * jnp.sum(d * d, axis=-1)


def forward(
    params: DFRParams,
    j_seq: Array,
    f: Callable[[Array], Array],
    lengths: Optional[Array] = None,
) -> ForwardAux:
    """Full forward pass: reservoir -> DPRR -> output layer.

    j_seq: (T, Nx) or (B, T, Nx) masked inputs.
    """
    batched = j_seq.ndim == 3
    x = res_mod.run_reservoir(params.p, params.q, j_seq, f=f, lengths=lengths)
    r = dprr_mod.compute_dprr(x, lengths=lengths)
    logits = r @ params.W.T + params.b
    probs = jax.nn.softmax(logits, axis=-1)
    # gather x(T), x(T-1), j(T) (with variable lengths, T = lengths per row)
    if lengths is None:
        x_last = x[..., -1, :]
        x_prev0 = dprr_mod.shifted_states(x)
        x_prev = x_prev0[..., -1, :]
        j_last = j_seq[..., -1, :]
    else:
        idx_last = jnp.maximum(lengths - 1, 0)
        idx_prev = lengths - 2  # may be -1 -> x(0) = 0 handled below
        if batched:
            barange = jnp.arange(x.shape[0])
            x_last = x[barange, idx_last]
            x_prev = jnp.where(
                (idx_prev >= 0)[:, None], x[barange, jnp.maximum(idx_prev, 0)], 0.0
            )
            j_last = j_seq[barange, idx_last]
        else:
            x_last = x[idx_last]
            x_prev = jnp.where(idx_prev >= 0, x[jnp.maximum(idx_prev, 0)], 0.0)
            j_last = j_seq[idx_last]
    return ForwardAux(logits, probs, r, x_last, x_prev, j_last)


# ---------------------------------------------------------------------------
# Manual truncated backprop: Eq. (25)-(26) + (33)-(36), verbatim.
# ---------------------------------------------------------------------------


def grads_truncated_manual(
    params: DFRParams,
    j_seq: Array,
    onehot: Array,
    f: Callable[[Array], Array],
    f_prime: Callable[[Array], Array],
    lengths: Optional[Array] = None,
) -> Tuple[Array, DFRParams]:
    """Single-sample (or batched) truncated gradients, paper equations.

    Returns (loss, grads) where grads is a DFRParams pytree; batched inputs
    produce *summed* gradients (divide by batch for the mean).
    """
    aux = forward(params, j_seq, f, lengths)
    n_nodes = aux.x_last.shape[-1]
    n_y = onehot.shape[-1]

    dlogits = aux.probs - onehot                                 # Eq. 25
    batched = j_seq.ndim == 3

    def _sum_b(x):
        return jnp.sum(x, axis=0) if batched else x

    grad_b = _sum_b(dlogits)                                     # Eq. 26
    grad_W = (
        jnp.einsum("bc,br->cr", dlogits, aux.r) if batched
        else jnp.outer(dlogits, aux.r)
    )
    dr = jnp.einsum("cr,...c->...r", params.W, dlogits)          # Eq. 26

    # Eq. 33:  bpv_n = sum_j x(T-1)_j dL/dr_{(n-1)Nx+j} + dL/dr_{Nx^2+n}
    dr_outer = dr[..., : n_nodes * n_nodes].reshape(*dr.shape[:-1], n_nodes, n_nodes)
    dr_sum = dr[..., n_nodes * n_nodes :]
    bpv = jnp.einsum("...nj,...j->...n", dr_outer, aux.x_prev) + dr_sum

    # Eq. 34:  dL/dx(T)_n = bpv_n + q * dL/dx(T)_{n+1}   (n = Nx .. 1)
    # -> reversed first-order linear recurrence; reuse the ring closed form.
    Lq = res_mod.ring_matrix(params.q, n_nodes, bpv.dtype)
    dx = jnp.einsum("nm,...n->...m", Lq, bpv)  # dx_m = sum_{n>=m} q^(n-m) bpv_n

    # Eq. 35:  dL/dp = sum_n f(j(T)_n + x(T-1)_n) dL/dx(T)_n
    f_T = f(aux.j_last + aux.x_prev)
    grad_p = jnp.sum(f_T * dx)

    # Eq. 36:  dL/dq = sum_n x(T)_{n-1} dL/dx(T)_n  (x(T)_0 = x(T-1)_{Nx})
    x_shift = jnp.concatenate(
        [aux.x_prev[..., -1:], aux.x_last[..., :-1]], axis=-1
    )
    grad_q = jnp.sum(x_shift * dx)

    loss = jnp.sum(loss_from_logits(aux.logits, onehot))
    grads = DFRParams(p=grad_p.astype(params.p.dtype),
                      q=grad_q.astype(params.q.dtype),
                      W=grad_W.astype(params.W.dtype),
                      b=grad_b.astype(params.b.dtype))
    return loss, grads


# ---------------------------------------------------------------------------
# Truncated backprop via autodiff of the truncated objective.
#
# The truncation keeps gradient flow ONLY through x(T) (and its within-step
# ring chain) - everything earlier is stop_gradient'ed, exactly matching
# Eq. 33-36 (see tests/test_backprop.py for the numerical identity).
# ---------------------------------------------------------------------------


def truncated_loss_from_aux(
    params: DFRParams,
    aux: ForwardAux,
    onehot: Array,
    f: Callable[[Array], Array],
    loss_fn: Callable[[Array, Array], Array] = loss_from_logits,
) -> Array:
    """Truncated objective from a precomputed forward pass.

    Every use of ``aux`` below is stop_gradient'ed, so gradients flow only
    through the re-derived k = T step and the readout - which is why the
    forward pass can be computed once and shared (e.g. with the serving
    path's infer-before-update, ``repro.core.online.online_serve_step``)
    without changing the gradients at all.
    """
    sg = jax.lax.stop_gradient
    n_nodes = aux.x_last.shape[-1]

    x_prev = sg(aux.x_prev)
    # recompute x(T) with gradient flowing only through (p, q) and the
    # within-step ring chain (Eq. 14 at k = T with x(T-1) detached)
    x_last = res_mod.reservoir_step(params.p, params.q, f, sg(aux.j_last), x_prev)

    # r = sg(prefix) + the k = T contribution, with the x(T-1) pairing frozen
    prev_tilde = jnp.concatenate(
        [x_prev, jnp.ones((*x_prev.shape[:-1], 1), x_prev.dtype)], -1
    )
    contrib_T = jnp.einsum("...i,...j->...ij", x_last, prev_tilde)
    contrib_T_sg = jnp.einsum("...i,...j->...ij", sg(aux.x_last), prev_tilde)
    # gradient-carrying part; its *value* is identically zero, so r keeps the
    # exact forward value while autodiff sees only the k = T contribution
    delta = contrib_T - contrib_T_sg
    delta_outer = delta[..., :, :n_nodes].reshape(*x_last.shape[:-1], -1)
    delta_sum = delta[..., :, n_nodes]
    r = sg(aux.r) + jnp.concatenate([delta_outer, delta_sum], axis=-1)

    logits = r @ params.W.T + params.b
    return jnp.sum(loss_fn(logits, onehot))


def _truncated_loss(
    params: DFRParams,
    j_seq: Array,
    onehot: Array,
    f: Callable[[Array], Array],
    lengths: Optional[Array] = None,
    loss_fn: Callable[[Array, Array], Array] = loss_from_logits,
) -> Array:
    aux = forward(params, j_seq, f, lengths)
    return truncated_loss_from_aux(params, aux, onehot, f, loss_fn)


def grads_truncated_from_aux(
    params: DFRParams,
    aux: ForwardAux,
    onehot: Array,
    f: Callable[[Array], Array],
    loss_fn: Callable[[Array, Array], Array] = loss_from_logits,
) -> Tuple[Array, DFRParams]:
    """Truncated-BP gradients reusing a precomputed forward pass (identical
    to ``grads_truncated`` - the truncation stop_gradients everything the
    forward produced, so sharing it is free)."""
    loss, g = jax.value_and_grad(truncated_loss_from_aux)(
        params, aux, onehot, f, loss_fn
    )
    return loss, g


def grads_truncated(
    params: DFRParams,
    j_seq: Array,
    onehot: Array,
    f: Callable[[Array], Array],
    lengths: Optional[Array] = None,
    loss_fn: Callable[[Array, Array], Array] = loss_from_logits,
) -> Tuple[Array, DFRParams]:
    """Truncated-BP gradients; ``loss_fn`` selects the readout objective
    (cross-entropy default; ``loss_mse`` for regression populations)."""
    loss, g = jax.value_and_grad(_truncated_loss)(
        params, j_seq, onehot, f, lengths, loss_fn
    )
    return loss, g


# ---------------------------------------------------------------------------
# Fused truncated backprop: the production training path.
#
# The forward runs the fused reservoir->DPRR kernel (``kernels.ops.
# train_forward``) that never materializes the state sequence X, and the
# backward is a ``jax.custom_vjp`` implementing Eq. 33-36 in closed form
# from the four emitted tensors (r, x(T), x(T-1), j(T)) - the exact
# quantities the FPGA latches for its truncated update.  Validated against
# both ``grads_truncated_manual`` and the stop_gradient autodiff path in
# tests/test_train_fused.py.
# ---------------------------------------------------------------------------


class _FusedSpec(NamedTuple):
    """Static (hashable) half of the fused forward's signature: the
    nonlinearity plus the kernel dispatch knobs, and the time length the
    backward needs to rebuild j_seq's (identically zero) cotangent."""

    f: Callable[[Array], Array]
    backend: Optional[str]
    chunk_t: Optional[int]
    block_b: int
    t_len: int


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_features(spec, p, q, j_seq, lengths):
    from repro.kernels import ops as kops

    return kops.train_forward(
        j_seq, lengths, p, q, j_seq.shape[-1],
        f=spec.f, block_b=spec.block_b, chunk_t=spec.chunk_t,
        backend=spec.backend,
    )


def _fused_features_fwd(spec, p, q, j_seq, lengths):
    out = _fused_features(spec, p, q, j_seq, lengths)
    r, x_last, x_prev, j_last = out
    # residuals are O(Nx) per sample - X was never materialized, and the
    # backward re-reads nothing else (Table 7's truncated storage words)
    return out, (p, q, x_last, x_prev, j_last, lengths)


def _fused_features_bwd(spec, res, cts):
    p, q, x_last, x_prev, j_last, lengths = res
    # only r's cotangent is honored: the truncation stop_gradients the
    # boundary tensors wherever they are consumed (truncated_loss_from_aux),
    # so their cotangents are identically zero on every training path
    dr = cts[0]
    n_nodes = x_last.shape[-1]

    # Eq. 33:  bpv_n = sum_j x(T-1)_j dL/dr_{(n-1)Nx+j} + dL/dr_{Nx^2+n}
    dr_outer = dr[..., : n_nodes * n_nodes].reshape(
        *dr.shape[:-1], n_nodes, n_nodes
    )
    dr_sum = dr[..., n_nodes * n_nodes:]
    bpv = jnp.einsum("...nj,...j->...n", dr_outer, x_prev) + dr_sum

    # Eq. 34: reversed ring recurrence, closed form via L(q)
    Lq = res_mod.ring_matrix(q, n_nodes, bpv.dtype)
    dx = jnp.einsum("nm,...n->...m", Lq, bpv)

    # Eq. 35 / Eq. 36
    f_T = spec.f(j_last + x_prev)
    grad_p = jnp.sum(f_T * dx).astype(p.dtype)
    x_shift = jnp.concatenate(
        [x_prev[..., -1:], x_last[..., :-1]], axis=-1
    )
    grad_q = jnp.sum(x_shift * dx).astype(q.dtype)

    dj = jnp.zeros(
        (*x_prev.shape[:-1], spec.t_len, n_nodes), x_prev.dtype
    )
    dlen = np.zeros(np.shape(lengths), jax.dtypes.float0)
    return grad_p, grad_q, dj, dlen


_fused_features.defvjp(_fused_features_fwd, _fused_features_bwd)


def forward_fused(
    params: DFRParams,
    j_seq: Array,
    f: Callable[[Array], Array],
    lengths: Optional[Array] = None,
    *,
    backend: Optional[str] = None,
    chunk_t: Optional[int] = None,
    block_b: int = 8,
) -> ForwardAux:
    """``forward`` through the fused no-materialized-X kernel path.

    Same ForwardAux contract (values equal to ``forward`` up to the fp
    reordering of the DPRR reduction); differentiable, with the custom
    truncated VJP - ``jax.grad`` of a loss over its logits/r IS the
    truncated gradient, no stop_gradient machinery needed.
    """
    t_len = j_seq.shape[-2]
    if lengths is None:
        lengths = jnp.full(j_seq.shape[:-2], t_len, jnp.int32)
    spec = _FusedSpec(f, backend, chunk_t, block_b, t_len)
    r, x_last, x_prev, j_last = _fused_features(
        spec, params.p, params.q, j_seq, lengths
    )
    logits = r @ params.W.T + params.b
    probs = jax.nn.softmax(logits, axis=-1)
    return ForwardAux(logits, probs, r, x_last, x_prev, j_last)


def grads_truncated_fused(
    params: DFRParams,
    j_seq: Array,
    onehot: Array,
    f: Callable[[Array], Array],
    lengths: Optional[Array] = None,
    loss_fn: Callable[[Array, Array], Array] = loss_from_logits,
    *,
    backend: Optional[str] = None,
    chunk_t: Optional[int] = None,
    block_b: int = 8,
) -> Tuple[Array, DFRParams]:
    """Truncated-BP gradients through the fused forward (production path).

    Identical contract to ``grads_truncated``; (W, b) gradients flow
    through the readout autodiff while (p, q) come from the closed-form
    custom VJP, so the whole backward is O(Nx^2) work with no scan
    transpose."""

    def _loss(prm):
        aux = forward_fused(
            prm, j_seq, f, lengths,
            backend=backend, chunk_t=chunk_t, block_b=block_b,
        )
        return jnp.sum(loss_fn(aux.logits, onehot))

    return jax.value_and_grad(_loss)(params)


# ---------------------------------------------------------------------------
# Full BPTT (reference; memory grows with T - the cost Eq. 29-32 pay).
# ---------------------------------------------------------------------------


def _full_loss(
    params: DFRParams,
    j_seq: Array,
    onehot: Array,
    f: Callable[[Array], Array],
    lengths: Optional[Array] = None,
    loss_fn: Callable[[Array, Array], Array] = loss_from_logits,
) -> Array:
    aux = forward(params, j_seq, f, lengths)
    return jnp.sum(loss_fn(aux.logits, onehot))


def grads_full_bptt(
    params: DFRParams,
    j_seq: Array,
    onehot: Array,
    f: Callable[[Array], Array],
    lengths: Optional[Array] = None,
    loss_fn: Callable[[Array, Array], Array] = loss_from_logits,
) -> Tuple[Array, DFRParams]:
    loss, g = jax.value_and_grad(_full_loss)(
        params, j_seq, onehot, f, lengths, loss_fn
    )
    return loss, g


# ---------------------------------------------------------------------------
# SGD update rule shared by the offline/online/distributed trainers.
#
# Two guards are added on top of the paper's plain SGD (noted in DESIGN.md):
# global-norm gradient clipping, and clamping (p, q) to the paper's own
# grid-search ranges (p in [10^-3.75, 10^-0.25], q in [10^-2.75, 10^-0.25]).
# Without them lr = 1.0 can push q past the reservoir's stability edge where
# states grow as q^T and the loss overflows; the clamp box is exactly the
# region the paper itself declares to "cover the optimal parameters".
# ---------------------------------------------------------------------------

P_RANGE = (10.0 ** -3.75, 10.0 ** -0.25)
Q_RANGE = (10.0 ** -2.75, 10.0 ** -0.25)


def clip_by_global_norm(grads: DFRParams, max_norm: float) -> DFRParams:
    """Clip the reservoir grads (p, q) and output grads (W, b) as two
    independent groups, so a large output-layer gradient cannot mute the
    two-scalar reservoir gradient (and vice versa)."""

    def _clip(leaves):
        # norm accumulates in f32 for range, but the scale is applied in
        # the grads' own dtype: a low-precision config (bf16) must not be
        # silently promoted here - the f32 scale would infect the grads,
        # then the params, then the reservoir scan carry
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
        return scale.astype(leaves[0].dtype)

    s_res = _clip([grads.p, grads.q])
    s_out = _clip([grads.W, grads.b])
    return DFRParams(p=grads.p * s_res, q=grads.q * s_res,
                     W=grads.W * s_out, b=grads.b * s_out)


def apply_sgd(
    params: DFRParams,
    grads: DFRParams,
    lr_res: Array,
    lr_out: Array,
    inv_batch: float | Array = 1.0,
    grad_clip: float = 1.0,
    clamp_pq: bool = True,
) -> DFRParams:
    g = jax.tree_util.tree_map(lambda t: t * inv_batch, grads)
    if grad_clip is not None:
        g = clip_by_global_norm(g, grad_clip)
    p = params.p - lr_res * g.p
    q = params.q - lr_res * g.q
    if clamp_pq:
        p = jnp.clip(p, *P_RANGE)
        q = jnp.clip(q, *Q_RANGE)
    return DFRParams(
        p=p,
        q=q,
        W=params.W - lr_out * g.W,
        b=params.b - lr_out * g.b,
    )


# ---------------------------------------------------------------------------
# Storage accounting for the truncation (paper Table 7).
# ---------------------------------------------------------------------------


def storage_words_naive(cfg: DFRConfig, t_len: int) -> int:
    """(T+1) reservoir states + reservoir representation + output weights."""
    return (t_len + 1) * cfg.n_nodes + cfg.n_rep + cfg.n_classes * (cfg.n_rep + 1)


def storage_words_truncated(cfg: DFRConfig, t_len: int) -> int:
    """Only x(T-1), x(T) are kept (+ representation + output weights)."""
    del t_len
    return 2 * cfg.n_nodes + cfg.n_rep + cfg.n_classes * (cfg.n_rep + 1)
