"""Core dataclasses for the modular Delayed Feedback Reservoir (DFR).

The modular DFR model (paper Eq. 14):

    x(k)_n = p * f(j(k)_n + x(k-1)_n) + q * x(k)_{n-1}

with the loop-wrap convention x(k)_0 := x(k-1)_{Nx} (the feedback loop is a
ring of virtual nodes), masking j(k) = M @ u(k), and the DPRR readout

    r = vec( sum_k x(k) [x(k-1), 1]^T ),   r_tilde = [r, 1].

Only two reservoir parameters (p, q) plus the output layer (W, b) are
trainable; the mask M is fixed random, as in the paper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Nonlinearities f for the modular DFR block.  The paper's evaluation uses
# f(x) = alpha * x (recommended in [11]); Mackey-Glass and tanh are provided
# for the analog-DFR reference path and ablations.
# ---------------------------------------------------------------------------

def f_linear(z: Array, alpha: float = 1.0) -> Array:
    return alpha * z


def f_tanh(z: Array, alpha: float = 1.0) -> Array:
    return jnp.tanh(alpha * z)


def f_mackey_glass(z: Array, mg_p: float = 2.0) -> Array:
    """Mackey-Glass style saturation f(z) = z / (1 + z^p) (paper Eq. 3)."""
    return z / (1.0 + jnp.abs(z) ** mg_p)


NONLINEARITIES: dict[str, Callable[..., Array]] = {
    "linear": f_linear,
    "tanh": f_tanh,
    "mackey_glass": f_mackey_glass,
}


@functools.lru_cache(maxsize=None)
def cached_nonlinearity(nonlinearity: str, alpha: float) -> Callable[[Array], Array]:
    """The bound nonlinearity ``z -> f(z, alpha)`` as a *stable* callable.

    Every jitted entry point that takes ``f`` as a static argument
    (``run_reservoir``, ``ops.reservoir_states``, ``ops.streaming_logits*``,
    the backprop paths) keys its compilation cache on the callable's
    identity.  ``DFRConfig.f()`` used to build a fresh lambda per call, so
    any call site outside a jit trace silently recompiled the same program
    on every invocation.  The lru_cache makes repeated requests for the
    same (nonlinearity, alpha) return the *same object*, turning those
    retraces into cache hits (regression-tested via ``jit._cache_size()``).
    """
    fn = NONLINEARITIES[nonlinearity]
    if nonlinearity == "mackey_glass":
        return fn  # ignores alpha; default mg_p=2.0 (matches the old lambda)
    return functools.partial(fn, alpha=alpha)


@dataclasses.dataclass(frozen=True)
class DFRConfig:
    """Static configuration of a modular DFR classifier."""

    n_in: int                      # #V  input channels
    n_classes: int                 # #C  output classes
    n_nodes: int = 30              # Nx  virtual nodes (paper uses 30)
    nonlinearity: str = "linear"   # f;  paper evaluation uses linear
    alpha: float = 1.0             # f scale (folded into p for linear f)
    p_init: float = 0.01           # paper Sec. 4.1
    q_init: float = 0.01           # paper Sec. 4.1
    epochs: int = 25               # paper Sec. 4.1
    lr: float = 1.0                # paper Sec. 4.1
    # LR is multiplied by 0.1 at these epochs (reservoir / output layer):
    res_lr_drop_epochs: Tuple[int, ...] = (5, 10, 15, 20)
    out_lr_drop_epochs: Tuple[int, ...] = (10, 15, 20)
    betas: Tuple[float, ...] = (1e-6, 1e-4, 1e-2, 1e0)  # ridge reg. sweep
    mask_seed: int = 0
    dtype: Any = jnp.float32

    @property
    def n_rep(self) -> int:
        """N_r: DPRR feature count = Nx * (Nx + 1)."""
        return self.n_nodes * (self.n_nodes + 1)

    @property
    def s(self) -> int:
        """s = Nx^2 + Nx + 1 (paper Eq. 20): ridge system size."""
        return self.n_nodes * self.n_nodes + self.n_nodes + 1

    def f(self) -> Callable[[Array], Array]:
        """The config's nonlinearity as a stable (identity-cached) callable,
        safe to pass as a static jit argument from non-traced call sites."""
        return cached_nonlinearity(self.nonlinearity, float(self.alpha))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DFRParams:
    """Trainable parameters of the DFR system (a pytree)."""

    p: Array      # scalar reservoir gain on the nonlinear branch
    q: Array      # scalar reservoir gain on the ring branch
    W: Array      # (Ny, Nr) output weights
    b: Array      # (Ny,)    output bias

    def tree_flatten(self):
        return (self.p, self.q, self.W, self.b), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @classmethod
    def init(cls, cfg: DFRConfig) -> "DFRParams":
        dt = cfg.dtype
        return cls(
            p=jnp.asarray(cfg.p_init, dt),
            q=jnp.asarray(cfg.q_init, dt),
            W=jnp.zeros((cfg.n_classes, cfg.n_rep), dt),
            b=jnp.zeros((cfg.n_classes,), dt),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantParams:
    """Per-model int8 serving state for the quantized inference fast path.

    Symmetric (zero-point-free) int8 quantization of the two serving-path
    operands: the readout weights and the reservoir state.  Training and
    the ridge statistics stay fp32 - this state only feeds the
    ``quantize='int8'`` serving kernel (``kernels.ops.streaming_logits_q8``).

    Scales are *folded* at ridge-refresh boundaries (where W changes
    anyway, see ``online.fold_quant_rows``): ``w_scale``/``Wq`` from the
    freshly refreshed readout, ``x_scale`` from the running reservoir
    amplitude calibration ``x_absmax`` tracked during fp32 serving.
    ``w_scale == 0`` means "not yet armed" - the server keeps serving fp32
    logits for that slot until the first refresh folds live scales.

    Wq:       (Ny, Nr) int8  quantized readout codes (W ~= Wq * w_scale).
    w_scale:  scalar f32     readout scale, 0 until first fold.
    x_scale:  scalar f32     reservoir-state scale, 0 until first fold.
    x_absmax: scalar f32     running max |x| seen while serving (calibration).
    """

    Wq: Array
    w_scale: Array
    x_scale: Array
    x_absmax: Array

    def tree_flatten(self):
        return (self.Wq, self.w_scale, self.x_scale, self.x_absmax), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @classmethod
    def zeros(cls, n_classes: int, n_rep: int) -> "QuantParams":
        """Codes and scales; scales stay fp32 even under a bf16 config -
        the quantization *bookkeeping* is part of the fp32 statistics."""
        return cls(
            Wq=jnp.zeros((n_classes, n_rep), jnp.int8),
            w_scale=jnp.zeros((), jnp.float32),
            x_scale=jnp.zeros((), jnp.float32),
            x_absmax=jnp.zeros((), jnp.float32),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RidgeState:
    """Streaming sufficient statistics for Ridge regression (paper Eq. 21-22).

    A = E R~^T      (Ny, s)
    B = R~ R~^T     (s, s)   (beta * I added at solve time)

    Both are sums over samples, hence associative: they accumulate online
    one sample at a time (the paper's edge system) and reduce across data
    shards with a single psum (this framework's at-scale extension).

    ``Lt``/``factor_beta`` carry the *incremental* Cholesky engine
    (``repro.core.ridge.cholupdate_*``): when ``factor_beta > 0``, ``Lt``
    is the live factor, stored *transposed* (upper-triangular U = L^T with
    L L^T = B + factor_beta * I), kept current by O(s^2) rank-1 rotations
    as samples stream in, so a Ridge refresh is just two triangular
    substitutions instead of an O(s^3) factorization.  Transposed because
    the rotation sweep touches one factor column per step, and column k of
    L is row k of U - contiguous in row-major storage, where the strided
    column walk wastes a cache line per element (see
    ``ridge.cholupdate_dense_t``).  ``factor_beta <= 0`` (the ``zeros``
    default) means no live factor - refreshes re-factorize from B.  The
    factor is *not* an associative sum, so it never psums across shards:
    paths that accumulate (A, B) without rotating it (``online_step``)
    invalidate it.
    """

    A: Array
    B: Array
    count: Array  # number of accumulated samples (scalar)
    Lt: Array           # (s, s) transposed live factor (garbage unless live)
    factor_beta: Array  # scalar; > 0 marks Lt live for that regularization

    def tree_flatten(self):
        return (self.A, self.B, self.count, self.Lt, self.factor_beta), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @classmethod
    def zeros(cls, s: int, n_classes: int, dtype=jnp.float32) -> "RidgeState":
        return cls(
            A=jnp.zeros((n_classes, s), dtype),
            B=jnp.zeros((s, s), dtype),
            count=jnp.zeros((), jnp.int32),
            Lt=jnp.zeros((s, s), dtype),
            factor_beta=jnp.zeros((), dtype),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WindowState:
    """Fixed-shape ring buffer of the last ``capacity`` *retained* samples
    for sliding-window retirement (one per stream slot; the stream server
    batches a leading slot axis onto every leaf).

    ``rows[pos]`` is the next eviction victim: when a new sample is
    retained with the buffer full, the overwritten row is subtracted back
    out of (A, B) and hyperbolically downdated out of the live Cholesky
    factor - the runtime path that turns the growing-memory incremental
    engine into a drift-tracking one.  Zero rows mark never-written
    capacity: every r~ row ends in the constant-1 feature
    (``dprr.r_tilde``), so ``rows[i, -1] == 0`` <=> slot i is empty, and
    evicting an empty row is an exact no-op everywhere (subtracting zeros,
    downdating by the zero vector) - no separate validity mask is needed,
    and a capacity >= the stream length is bit-for-bit the non-retiring
    path.

    rows:   (capacity, s)  retained r~ rows, ring order.
    onehot: (capacity, Ny) the matching label one-hots (A's other factor).
    pos:    scalar int32 write cursor (next slot to evict/overwrite).
    """

    rows: Array
    onehot: Array
    pos: Array

    def tree_flatten(self):
        return (self.rows, self.onehot, self.pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @classmethod
    def zeros(cls, capacity: int, s: int, n_classes: int,
              dtype=jnp.float32) -> "WindowState":
        return cls(
            rows=jnp.zeros((capacity, s), dtype),
            onehot=jnp.zeros((capacity, n_classes), dtype),
            pos=jnp.zeros((), jnp.int32),
        )

    @classmethod
    def slot_axes(cls) -> "WindowState":
        """Logical-axes pytree for the slot-batched ring buffers (leaves
        stacked ``(S, ...)``): ``slot`` leads, everything else replicated.
        Feed to ``repro.distributed.sharding.guarded_shardings`` - each
        slot's ring lives wholly on the device that owns the slot (the
        eviction loop is per-slot, never cross-slot)."""
        return cls(
            rows=("slot", None, None),
            onehot=("slot", None, None),
            pos=("slot",),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RequestPool:
    """Device-resident staged stream payloads, one row per serving slot.

    The stream server's zero-copy request staging (``StreamServer``,
    ``staging='device'``): a stream's padded samples are uploaded ONCE -
    staged at ``submit``, written into the slot row at admission - and the
    per-step ``(S, W, T, n_in)`` window batch is assembled on device by a
    cursor-indexed gather inside the fused jitted step.  The host never
    rebuilds or re-uploads a sample after admission.

    Capacity is padded to a multiple of the serving window so every
    cursor-aligned ``dynamic_slice`` stays in bounds without clamping; the
    pad rows carry the same defaults the host-staging path uses for dead
    samples (``u=0``, ``length=1``, ``label=0``), which keeps the gathered
    batch bit-identical to host staging.

    u:      (S, C, T, n_in) staged samples, ``cfg.dtype``.
    length: (S, C) int32 valid lengths (1 on pad rows).
    label:  (S, C) int32 labels (0 on pad rows).
    n:      (S,)   int32 true sample count per slot row.
    """

    u: Array
    length: Array
    label: Array
    n: Array

    def tree_flatten(self):
        return (self.u, self.length, self.label, self.n), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.u.shape[1]

    @classmethod
    def zeros(cls, n_slots: int, capacity: int, t_max: int, n_in: int,
              dtype=jnp.float32) -> "RequestPool":
        return cls(
            u=jnp.zeros((n_slots, capacity, t_max, n_in), dtype),
            length=jnp.ones((n_slots, capacity), jnp.int32),
            label=jnp.zeros((n_slots, capacity), jnp.int32),
            n=jnp.zeros((n_slots,), jnp.int32),
        )

    @classmethod
    def slot_axes(cls) -> "RequestPool":
        """Logical-axes pytree for the staged pool: ``slot`` leads every
        leaf, so each device of a slot-sharded serving mesh holds only its
        own slots' staged payloads and the cursor-indexed window gather
        inside the sharded step never leaves the device."""
        return cls(
            u=("slot", None, None, None),
            length=("slot", None),
            label=("slot", None),
            n=("slot",),
        )


@dataclasses.dataclass(frozen=True)
class RegressionBatch:
    """A padded batch of input series with continuous targets.

    The population engine (``repro.core.population``) optimizes NRMSE on
    batches of this shape for sequence-regression tasks (e.g. the NARMA10
    benchmark in ``repro.data.timeseries.make_narma10``).

    u:       (B, T_max, n_in) float inputs, zero padded past `length`.
    length:  (B,) int32 true lengths  (1 <= length <= T_max).
    y:       (B, n_out) float regression targets (one vector per sequence).
    """

    u: Array
    length: Array
    y: Array

    @property
    def batch(self) -> int:
        return self.u.shape[0]

    @property
    def t_max(self) -> int:
        return self.u.shape[1]


@dataclasses.dataclass(frozen=True)
class TimeSeriesBatch:
    """A padded batch of variable-length multivariate time series.

    u:       (B, T_max, n_in) float inputs, zero padded past `length`.
    length:  (B,) int32 true lengths  (1 <= length <= T_max).
    label:   (B,) int32 class ids.
    """

    u: Array
    length: Array
    label: Array

    @property
    def batch(self) -> int:
        return self.u.shape[0]

    @property
    def t_max(self) -> int:
        return self.u.shape[1]
