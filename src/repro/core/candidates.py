"""Candidate (p, q) machinery shared by the offline population engine and
the online ensemble.

The paper's search box (Sec. 4.1) and its companion optimization paper
(arXiv:2504.12363) treat the reservoir hyperparameters (p, q) as a
*candidate set* problem: seed many starts, evaluate, cull the losers and
re-seed them near the survivors.  PR 1 built that machinery inside
``repro.core.population`` for offline hyperparameter search; this module
extracts the pieces that the *online* ensemble (``repro.core.online``)
reuses so members of a live serving ensemble can be periodically culled and
re-seeded exactly like offline candidates:

  * ``grid_points`` / ``grid_candidates``  - log-space grid seeding
  * ``seed_candidates``                    - jittered seeds around an anchor
                                             (member 0 stays exact, so a
                                             K=1 ensemble equals the single
                                             system bit-for-bit)
  * ``survivor_parents``                   - rank-order parent assignment
  * ``jitter_clones``                      - multiplicative log-normal
                                             jitter on culled slots
  * ``cull_population``                    - the offline composition of the
                                             two (moved here verbatim from
                                             ``population``; re-exported
                                             there for compatibility)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Array, DFRConfig, DFRParams

P_LOG_RANGE = (-3.75, -0.25)  # paper Sec. 4.1 search box, log10
Q_LOG_RANGE = (-2.75, -0.25)


# ---------------------------------------------------------------------------
# Grid seeding
# ---------------------------------------------------------------------------


def grid_points(divs: int, lo: float, hi: float) -> np.ndarray:
    """``divs`` equidistant points in log10 space, inclusive of endpoints."""
    if divs == 1:
        return np.array([10.0 ** ((lo + hi) / 2.0)])
    return 10.0 ** np.linspace(lo, hi, divs)


def grid_candidates(
    divs: int,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
    dtype=jnp.float32,
) -> Tuple[Array, Array]:
    """K = divs^2 grid-seeded (p, q) pairs, in ``itertools.product`` order
    (p-major), matching the serial grid search's iteration order so rankings
    and tie-breaks line up exactly."""
    ps = grid_points(divs, *p_range)
    qs = grid_points(divs, *q_range)
    pp, qq = np.meshgrid(ps, qs, indexing="ij")
    return jnp.asarray(pp.reshape(-1), dtype), jnp.asarray(qq.reshape(-1), dtype)


def init_population(cfg: DFRConfig, ps: Array, qs: Array) -> DFRParams:
    """Stacked population pytree from (K,) candidate vectors."""
    k = ps.shape[0]
    return DFRParams(
        p=ps.astype(cfg.dtype),
        q=qs.astype(cfg.dtype),
        W=jnp.zeros((k, cfg.n_classes, cfg.n_rep), cfg.dtype),
        b=jnp.zeros((k, cfg.n_classes), cfg.dtype),
    )


def seed_candidates(
    key: Array,
    k: int,
    p_init: float,
    q_init: float,
    jitter: float = 0.1,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
    dtype=jnp.float32,
) -> Tuple[Array, Array]:
    """K jittered (p, q) seeds around an anchor point.

    Member 0 is the *exact* anchor (no jitter), so a K=1 ensemble reproduces
    the single-system initialization identically; members 1..K-1 get
    multiplicative log-normal jitter, clipped back into the search box.
    """
    eps = jax.random.normal(key, (2, k), dtype)
    scale = jnp.where(jnp.arange(k) == 0, 0.0, jitter)
    p = jnp.asarray(p_init, dtype) * jnp.exp(scale * eps[0])
    q = jnp.asarray(q_init, dtype) * jnp.exp(scale * eps[1])
    p = jnp.clip(p, 10.0 ** p_range[0], 10.0 ** p_range[1])
    q = jnp.clip(q, 10.0 ** q_range[0], 10.0 ** q_range[1])
    return p, q


# ---------------------------------------------------------------------------
# Rank-ordered selection / culling
# ---------------------------------------------------------------------------


def survivor_parents(
    fitness: Array, survive_frac: float = 0.5
) -> Tuple[Array, Array, int]:
    """Parent assignment for a cull round.

    ``fitness`` is (K,), lower-is-better.  Returns ``(parent, keep, n_keep)``
    where ``parent`` (K,) indexes the member each slot inherits from (the
    top ``ceil(K * survive_frac)`` slots take the survivors in rank order;
    each culled slot cycles through the survivors), and ``keep`` (K,) is the
    boolean survivor mask *after* the reorder (first ``n_keep`` slots).
    """
    k = fitness.shape[0]
    n_keep = max(1, min(k, int(np.ceil(k * survive_frac))))
    order = jnp.argsort(fitness)  # ascending: best first
    parent = jnp.concatenate(
        [order[:n_keep], order[jnp.arange(k - n_keep) % n_keep]]
    )
    keep = jnp.arange(k) < n_keep
    return parent, keep, n_keep


def jitter_clones(
    key: Array,
    p: Array,
    q: Array,
    keep: Array,
    jitter: float = 0.15,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
) -> Tuple[Array, Array]:
    """Log-normal jitter on the non-surviving slots of (p, q), clipped back
    into the search box; surviving slots (``keep`` True) pass unchanged."""
    k = p.shape[0]
    eps = jax.random.normal(key, (2, k), p.dtype)
    scale = jnp.where(keep, 0.0, jitter)
    new_p = p * jnp.exp(scale * eps[0])
    new_q = q * jnp.exp(scale * eps[1])
    new_p = jnp.clip(new_p, 10.0 ** p_range[0], 10.0 ** p_range[1])
    new_q = jnp.clip(new_q, 10.0 ** q_range[0], 10.0 ** q_range[1])
    return new_p, new_q


def cull_population(
    pop: DFRParams,
    fitness: Array,
    key: Array,
    survive_frac: float = 0.5,
    jitter: float = 0.15,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
) -> DFRParams:
    """Replace the worst members with jittered clones of the best.

    ``fitness`` is (K,), lower-is-better (NRMSE, or -accuracy).  The top
    ``ceil(K * survive_frac)`` members survive verbatim (rank order); each
    culled slot is re-seeded from a survivor (cycled) with multiplicative
    log-normal jitter on (p, q), clipped back into the search box.  K stays
    constant so every downstream program keeps its static shapes.
    """
    parent, keep, _ = survivor_parents(fitness, survive_frac)
    new_p, new_q = jitter_clones(
        key, pop.p[parent], pop.q[parent], keep, jitter, p_range, q_range
    )
    return DFRParams(p=new_p, q=new_q, W=pop.W[parent], b=pop.b[parent])
