"""Candidate (p, q) machinery shared by the offline population engine and
the online ensemble.

The paper's search box (Sec. 4.1) and its companion optimization paper
(arXiv:2504.12363) treat the reservoir hyperparameters (p, q) as a
*candidate set* problem: seed many starts, evaluate, cull the losers and
re-seed them near the survivors.  PR 1 built that machinery inside
``repro.core.population`` for offline hyperparameter search; this module
extracts the pieces that the *online* ensemble (``repro.core.online``)
reuses so members of a live serving ensemble can be periodically culled and
re-seeded exactly like offline candidates:

  * ``grid_points`` / ``grid_candidates``  - log-space grid seeding
  * ``seed_candidates``                    - jittered seeds around an anchor
                                             (member 0 stays exact, so a
                                             K=1 ensemble equals the single
                                             system bit-for-bit)
  * ``survivor_parents``                   - rank-order parent assignment
  * ``sampling_cov_chol`` / ``adapted_clones`` - CMA-ES-style survivor
                                             covariance sampling in log space
  * ``jitter_clones``                      - covariance-adapted log-normal
                                             jitter on culled slots
  * ``cull_population``                    - the offline composition of the
                                             two (moved here verbatim from
                                             ``population``; re-exported
                                             there for compatibility)
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Array, DFRConfig, DFRParams

P_LOG_RANGE = (-3.75, -0.25)  # paper Sec. 4.1 search box, log10
Q_LOG_RANGE = (-2.75, -0.25)


# ---------------------------------------------------------------------------
# Grid seeding
# ---------------------------------------------------------------------------


def grid_points(divs: int, lo: float, hi: float) -> np.ndarray:
    """``divs`` equidistant points in log10 space, inclusive of endpoints."""
    if divs == 1:
        return np.array([10.0 ** ((lo + hi) / 2.0)])
    return 10.0 ** np.linspace(lo, hi, divs)


def grid_candidates(
    divs: int,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
    dtype=jnp.float32,
) -> Tuple[Array, Array]:
    """K = divs^2 grid-seeded (p, q) pairs, in ``itertools.product`` order
    (p-major), matching the serial grid search's iteration order so rankings
    and tie-breaks line up exactly."""
    ps = grid_points(divs, *p_range)
    qs = grid_points(divs, *q_range)
    pp, qq = np.meshgrid(ps, qs, indexing="ij")
    return jnp.asarray(pp.reshape(-1), dtype), jnp.asarray(qq.reshape(-1), dtype)


def init_population(cfg: DFRConfig, ps: Array, qs: Array) -> DFRParams:
    """Stacked population pytree from (K,) candidate vectors."""
    k = ps.shape[0]
    return DFRParams(
        p=ps.astype(cfg.dtype),
        q=qs.astype(cfg.dtype),
        W=jnp.zeros((k, cfg.n_classes, cfg.n_rep), cfg.dtype),
        b=jnp.zeros((k, cfg.n_classes), cfg.dtype),
    )


def seed_candidates(
    key: Array,
    k: int,
    p_init: float,
    q_init: float,
    jitter: float = 0.1,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
    dtype=jnp.float32,
) -> Tuple[Array, Array]:
    """K jittered (p, q) seeds around an anchor point.

    Member 0 is the *exact* anchor (no jitter), so a K=1 ensemble reproduces
    the single-system initialization identically; members 1..K-1 get
    multiplicative log-normal jitter, clipped back into the search box.
    """
    eps = jax.random.normal(key, (2, k), dtype)
    scale = jnp.where(jnp.arange(k) == 0, 0.0, jitter)
    p = jnp.asarray(p_init, dtype) * jnp.exp(scale * eps[0])
    q = jnp.asarray(q_init, dtype) * jnp.exp(scale * eps[1])
    p = jnp.clip(p, 10.0 ** p_range[0], 10.0 ** p_range[1])
    q = jnp.clip(q, 10.0 ** q_range[0], 10.0 ** q_range[1])
    # member 0 is the documented *exact* anchor (the K=1 == single-system
    # parity contract) - an out-of-box p_init/q_init must not be silently
    # moved by the clip, so restore it after clipping members 1..K-1
    anchor = jnp.arange(k) == 0
    p = jnp.where(anchor, jnp.asarray(p_init, dtype), p)
    q = jnp.where(anchor, jnp.asarray(q_init, dtype), q)
    return p, q


# ---------------------------------------------------------------------------
# Rank-ordered selection / culling
# ---------------------------------------------------------------------------


def survivor_parents(
    fitness: Array, survive_frac: float = 0.5
) -> Tuple[Array, Array, int]:
    """Parent assignment for a cull round.

    ``fitness`` is (K,), lower-is-better.  Returns ``(parent, keep, n_keep)``
    where ``parent`` (K,) indexes the member each slot inherits from (the
    top ``ceil(K * survive_frac)`` slots take the survivors in rank order;
    each culled slot cycles through the survivors), and ``keep`` (K,) is the
    boolean survivor mask *after* the reorder (first ``n_keep`` slots).
    """
    k = fitness.shape[0]
    n_keep = max(1, min(k, int(np.ceil(k * survive_frac))))
    order = jnp.argsort(fitness)  # ascending: best first
    parent = jnp.concatenate(
        [order[:n_keep], order[jnp.arange(k - n_keep) % n_keep]]
    )
    keep = jnp.arange(k) < n_keep
    return parent, keep, n_keep


def sampling_cov_chol(coords_log: Array, keep: Array, jitter: float) -> Array:
    """CMA-ES-style sampling covariance (lower Cholesky) from the survivors.

    ``coords_log`` is (D, K) log-space coordinates; ``keep`` (K,) marks the
    survivors, which occupy the *first* ``n_keep`` slots in rank order (the
    ``survivor_parents`` layout), so slot index doubles as rank.  Survivor
    statistics use CMA-ES log-rank weights (best member weighted most); the
    sampling covariance is that weighted survivor covariance plus an
    isotropic ``jitter**2`` floor.  With one survivor (or zero spread) the
    covariance vanishes and this reduces exactly to the historical isotropic
    log-normal jitter; with several survivors spread along a ridge of the
    fitness landscape, offspring steps elongate along that ridge.
    """
    d, k = coords_log.shape
    dt = coords_log.dtype
    n = jnp.maximum(jnp.sum(keep.astype(dt)), 1.0)
    rank = jnp.arange(k, dtype=dt)
    w = jnp.where(keep, jnp.log(n + 0.5) - jnp.log1p(rank), 0.0)
    w = jnp.maximum(w, 0.0)
    w = w / jnp.maximum(jnp.sum(w), jnp.asarray(1e-12, dt))
    mean = coords_log @ w                                # (D,)
    cen = (coords_log - mean[:, None]) * jnp.where(keep, 1.0, 0.0)
    cov = (cen * w) @ cen.T                              # (D, D)
    C = cov + (jitter ** 2) * jnp.eye(d, dtype=dt)
    return jnp.linalg.cholesky(C)


def adapted_clones(
    key: Array,
    coords: Array,
    keep: Array,
    jitter: float = 0.15,
    ranges: Optional[Sequence[Tuple[float, float]]] = None,
) -> Array:
    """Covariance-adapted log-normal jitter on the non-surviving slots.

    ``coords`` is (D, K) positive candidate coordinates (rows = dimensions,
    e.g. (p, q) or (p, q, beta)); slots with ``keep`` True pass through
    unchanged (bitwise).  Culled slots step from their parent coordinates by
    a correlated draw ``L @ eps`` in log space, where ``L`` is the survivor
    covariance Cholesky of :func:`sampling_cov_chol` - the shared CMA-ES-ish
    upgrade of the old isotropic jitter, used by both the offline population
    engine and the online ensemble (and the warm-pool autotuner for D=3).
    ``ranges`` optionally clips each row back into a log10 search box.
    """
    d, k = coords.shape
    eps = jax.random.normal(key, (d, k), coords.dtype)
    L = sampling_cov_chol(jnp.log(coords), keep, jitter)
    step = L @ eps                                       # (D, K) correlated
    gate = jnp.where(keep, 0.0, 1.0)
    out = coords * jnp.exp(gate * step)
    if ranges is not None:
        lo = jnp.asarray([10.0 ** r[0] for r in ranges], coords.dtype)
        hi = jnp.asarray([10.0 ** r[1] for r in ranges], coords.dtype)
        out = jnp.clip(out, lo[:, None], hi[:, None])
    return out


def jitter_clones(
    key: Array,
    p: Array,
    q: Array,
    keep: Array,
    jitter: float = 0.15,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
) -> Tuple[Array, Array]:
    """Covariance-adapted log-normal jitter on the non-surviving slots of
    (p, q), clipped back into the search box; surviving slots (``keep``
    True) pass unchanged.  See :func:`adapted_clones` for the sampling
    model (survivor-covariance CMA-ES-style steps with an isotropic
    ``jitter`` floor)."""
    new = adapted_clones(
        key, jnp.stack([p, q]), keep, jitter, ranges=(p_range, q_range)
    )
    return new[0], new[1]


def cull_population(
    pop: DFRParams,
    fitness: Array,
    key: Array,
    survive_frac: float = 0.5,
    jitter: float = 0.15,
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
) -> DFRParams:
    """Replace the worst members with jittered clones of the best.

    ``fitness`` is (K,), lower-is-better (NRMSE, or -accuracy).  The top
    ``ceil(K * survive_frac)`` members survive verbatim (rank order); each
    culled slot is re-seeded from a survivor (cycled) with covariance-adapted
    log-normal jitter on (p, q) (CMA-ES-style: steps are drawn from the
    rank-weighted survivor covariance in log space plus a ``jitter`` floor),
    clipped back into the search box.  K stays constant so every downstream
    program keeps its static shapes.
    """
    parent, keep, _ = survivor_parents(fitness, survive_frac)
    new_p, new_q = jitter_clones(
        key, pop.p[parent], pop.q[parent], keep, jitter, p_range, q_range
    )
    return DFRParams(p=new_p, q=new_q, W=pop.W[parent], b=pop.b[parent])
