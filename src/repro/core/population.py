"""Population-parallel DFR hyperparameter engine.

The paper replaces offline grid search with single-start truncated-BP
gradient descent on (p, q); its companion work (arXiv:2504.12363) shows the
loss landscape is multi-modal, so a single start can land in a poor basin.
This module runs an entire *population* of K candidates concurrently through
the reservoir -> DPRR -> truncated-BP pipeline as one vmapped/jitted XLA
program:

  1. ``grid_candidates``       - grid-seeded (p, q) starts (the paper's own
                                 log-space search box, Sec. 4.1).
  2. ``evaluate_population``   - one jitted program: vmapped reservoir+DPRR
                                 features, population-axis sufficient
                                 statistics A (K, Ny, s) / B (K, s, s),
                                 batched packed ridge solves over the beta
                                 sweep (``ridge.ridge_solve_batched``; the
                                 Pallas tile driver is
                                 ``kernels.ridge_solve.ridge_solve_blocked_batched``),
                                 and per-member NRMSE / accuracy on a held-out
                                 split.
  3. ``refine_population``     - per-member truncated-BP SGD
                                 (``backprop.grads_truncated``), vmapped over
                                 the population, scanned over minibatches.
  4. ``cull_population``       - NRMSE-ranked selection: survivors keep their
                                 parameters, culled slots are re-seeded with
                                 log-space-jittered clones of the survivors
                                 (the seeding/culling primitives live in
                                 ``repro.core.candidates``, shared with the
                                 online ensemble; re-exported here).
  5. ``train_population``      - the round driver (evaluate -> cull ->
                                 refine -> evaluate), with elitist tracking:
                                 the best member ever evaluated is returned,
                                 so the result is never worse than the best
                                 grid seed.

Fitness is NRMSE of the ridge-refit readout on the evaluation split:
``sqrt(mean((pred - y)^2) / var(y))``.  For classification the targets are
one-hot rows (NRMSE then tracks the Brier-style readout error) and accuracy
is also computed; ``select='acc'`` reproduces the serial grid-search ranking
exactly when refinement is disabled (``repro.core.grid_search`` is now a thin
shim over this path).

Shapes: every population tensor carries a leading K axis; ``DFRParams`` is
reused as the population pytree with leaves p (K,), q (K,), W (K, Ny, Nr),
b (K, Ny).  Memory in ``evaluate_population`` scales as K * B * s for the
feature matrices - size the population to the accelerator accordingly.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backprop, dprr, masking, reservoir, ridge
from repro.core.candidates import (  # noqa: F401  (shared candidate machinery,
    P_LOG_RANGE,                     # re-exported for compatibility - the
    Q_LOG_RANGE,                     # online ensemble imports the same
    cull_population,                 # primitives from repro.core.candidates)
    grid_candidates,
    grid_points,
    init_population,
)
from repro.core.types import (
    Array,
    DFRConfig,
    DFRParams,
    RegressionBatch,
    TimeSeriesBatch,
)


# ---------------------------------------------------------------------------
# Vmapped evaluation: features -> batched ridge -> NRMSE/accuracy
# ---------------------------------------------------------------------------


class PopulationEval(NamedTuple):
    """Per-member evaluation at each member's best beta."""

    nrmse: Array      # (K,) eval-split NRMSE
    acc: Array        # (K,) eval-split argmax accuracy (degenerate for Ny=1)
    beta_idx: Array   # (K,) int32 index into cfg.betas
    Wt: Array         # (K, Ny, s) ridge readout [W | b]
    nrmse_all: Array  # (K, n_beta) full sweep (diagnostics / shim)
    acc_all: Array    # (K, n_beta)


@partial(jax.jit, static_argnames=("cfg", "select", "ridge_method", "solver"))
def evaluate_population(
    cfg: DFRConfig,
    mask: Array,
    ps: Array,
    qs: Array,
    train_u: Array,
    train_len: Array,
    y_train: Array,
    eval_u: Array,
    eval_len: Array,
    y_eval: Array,
    select: str = "nrmse",
    ridge_method: str = "cholesky_blocked",
    solver: str = "auto",
) -> PopulationEval:
    """Evaluate K (p, q) candidates in one XLA program.

    y_train: (B, Ny) targets (one-hot rows for classification);
    y_eval: (Be, Ny).  ``select`` picks each member's beta by 'nrmse'
    (lower wins) or 'acc' (higher wins; serial-grid-search-compatible,
    first-best tie-break in cfg.betas order).

    ``solver`` chooses the ridge formulation:
      * 'primal' - per-beta batched Cholesky of B = R~^T R~ + beta I
        (s, s); the serial grid search's formulation, so rankings agree
        with it wherever the factorization is numerically healthy (when
        beta sits below the float32 noise floor both produce garbage, not
        necessarily the same garbage).
      * 'dual'   - kernel form W~ = Y^T (R~ R~^T + beta I)^{-1} R~ with ONE
        batched factorization over the whole (beta, member) sweep.  Exact
        same solution when B samples >= rank, far better conditioned and
        much cheaper when B < s (the search regime), since the factored
        system is (B, B) instead of (s, s).
      * 'auto'   - 'dual' when the train split has fewer samples than s.
    """
    f = cfg.f()

    def feats(p, q, u, lengths):
        j_seq = masking.apply_mask(mask, u)
        x = reservoir.run_reservoir(p, q, j_seq, f=f, lengths=lengths)
        return dprr.compute_dprr(x, lengths=lengths)

    vfeats = jax.vmap(feats, in_axes=(0, 0, None, None))
    rt_train = dprr.r_tilde(vfeats(ps, qs, train_u, train_len))  # (K, B, s)
    rt_eval = dprr.r_tilde(vfeats(ps, qs, eval_u, eval_len))     # (K, Be, s)

    k = rt_train.shape[0]
    n_train, s = rt_train.shape[1], rt_train.shape[2]
    n_beta = len(cfg.betas)
    betas = jnp.asarray(cfg.betas, rt_train.dtype)
    use_dual = solver == "dual" or (solver == "auto" and n_train < s)

    if use_dual:
        # one factorization for the whole (beta, member) sweep
        Kmat = jnp.einsum("kbs,kcs->kbc", rt_train, rt_train)   # (K, B, B)
        eye = jnp.eye(n_train, dtype=Kmat.dtype)
        G = Kmat[None] + betas[:, None, None, None] * eye        # (nb, K, B, B)
        C = jnp.linalg.cholesky(G.reshape(n_beta * k, n_train, n_train))
        y_b = jnp.broadcast_to(y_train, (n_beta * k, *y_train.shape))
        X = jax.vmap(
            lambda c, y: jax.scipy.linalg.cho_solve((c, True), y)
        )(C, y_b).reshape(n_beta, k, n_train, -1)
        Wt_all = jnp.einsum("nkby,kbs->nkys", X, rt_train)       # (nb, K, Ny, s)
    else:
        A = jnp.einsum("by,kbs->kys", y_train, rt_train)
        Bmat = jnp.einsum("kbs,kbt->kst", rt_train, rt_train)
        Wt_all = jnp.stack([
            ridge.ridge_solve_batched(
                A, ridge.regularize(Bmat, beta.astype(Bmat.dtype)), ridge_method
            )
            for beta in betas
        ])

    pred = jnp.einsum("kbs,nkys->nkby", rt_eval, Wt_all)         # (nb, K, Be, Ny)
    var = jnp.mean(jnp.square(y_eval - jnp.mean(y_eval))) + 1e-12
    err = pred - y_eval[None, None]
    nrmse = jnp.sqrt(jnp.mean(err * err, axis=(2, 3)) / var)     # (nb, K)
    nrmse = jnp.where(jnp.isfinite(nrmse), nrmse, jnp.inf)
    labels_eval = jnp.argmax(y_eval, axis=-1)
    acc = jnp.mean(
        (jnp.argmax(pred, -1) == labels_eval[None, None]).astype(jnp.float32),
        axis=2,
    )                                                            # (nb, K)

    # argmax/argmin keep the earliest beta on ties, matching the serial grid
    # search's argmax semantics over the beta sweep
    beta_idx = (jnp.argmax(acc, 0) if select == "acc"
                else jnp.argmin(nrmse, 0)).astype(jnp.int32)     # (K,)
    arange_k = jnp.arange(k)
    return PopulationEval(
        nrmse=nrmse[beta_idx, arange_k],
        acc=acc[beta_idx, arange_k],
        beta_idx=beta_idx,
        Wt=Wt_all[beta_idx, arange_k],
        nrmse_all=nrmse.T,
        acc_all=acc.T,
    )


# ---------------------------------------------------------------------------
# Vmapped truncated-BP refinement
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "steps", "minibatch", "loss", "fused"))
def refine_population(
    cfg: DFRConfig,
    mask: Array,
    pop: DFRParams,
    u: Array,
    lengths: Array,
    y: Array,
    lr_res: Array,
    lr_out: Array,
    steps: int = 1,
    minibatch: int = 4,
    loss: str = "ce",
    fused: bool = True,
) -> Tuple[DFRParams, Array]:
    """``steps`` epochs of truncated-BP SGD on every member concurrently.

    All members see the same minibatch schedule; the member loop is a vmap,
    the minibatch loop a lax.scan - one fused program for the whole
    population.  Returns (refined population, (K,) final-epoch mean loss).

    ``fused=True`` (production default) runs each SGD step through the
    fused reservoir->DPRR forward with the closed-form truncated VJP
    (``backprop.grads_truncated_fused``): the state sequence is never
    materialized and the backward is O(Nx^2).  ``fused=False`` keeps the
    scan + stop_gradient autodiff path (the same gradients up to fp
    reduction order - the benchmark baseline).
    """
    if steps == 0:
        return pop, jnp.zeros(pop.p.shape, pop.p.dtype)
    f = cfg.f()
    loss_fn = backprop.loss_from_logits if loss == "ce" else backprop.loss_mse
    grads = (backprop.grads_truncated_fused if fused
             else backprop.grads_truncated)
    mb = min(minibatch, u.shape[0])
    n = u.shape[0] // mb * mb
    u_b = u[:n].reshape(-1, mb, *u.shape[1:])
    len_b = lengths[:n].reshape(-1, mb)
    y_b = y[:n].reshape(-1, mb, y.shape[-1])

    def member(params_k: DFRParams):
        def sgd_step(params, inp):
            ub, lb, yb = inp
            j_seq = masking.apply_mask(mask, ub)
            l, g = grads(
                params, j_seq, yb, f, lengths=lb, loss_fn=loss_fn
            )
            new = backprop.apply_sgd(
                params, g, lr_res, lr_out, inv_batch=1.0 / mb
            )
            return new, l / mb

        def epoch(params, _):
            params, losses = jax.lax.scan(sgd_step, params, (u_b, len_b, y_b))
            return params, jnp.mean(losses)

        params_k, losses = jax.lax.scan(epoch, params_k, None, length=steps)
        return params_k, losses[-1]

    return jax.vmap(member)(pop)


# ---------------------------------------------------------------------------
# Round driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PopulationResult:
    """Outcome of a population search (elitist: never worse than the best
    grid seed, because the best member ever evaluated is what's returned)."""

    best_params: DFRParams  # single member; (W, b) are the ridge readout
    best_nrmse: float
    best_acc: float
    best_beta: float
    best_p: float
    best_q: float
    history: List[dict]
    population: DFRParams   # final stacked population
    final_eval: PopulationEval
    time_s: float


def _load_readout(pop: DFRParams, Wt: Array) -> DFRParams:
    """Fold each member's ridge solution into its (W, b) so refinement's SGD
    starts from the solved readout rather than a stale one."""
    return DFRParams(p=pop.p, q=pop.q, W=Wt[..., :-1], b=Wt[..., -1])


def _best_member(pop: DFRParams, ev: PopulationEval, cfg: DFRConfig,
                 select: str) -> dict:
    metric = np.asarray(ev.acc) if select == "acc" else -np.asarray(ev.nrmse)
    bi = int(np.argmax(metric))
    params = DFRParams(
        p=pop.p[bi], q=pop.q[bi],
        W=ev.Wt[bi, :, :-1], b=ev.Wt[bi, :, -1],
    )
    return {
        "metric": float(metric[bi]),
        "params": params,
        "nrmse": float(ev.nrmse[bi]),
        "acc": float(ev.acc[bi]),
        "beta": float(cfg.betas[int(ev.beta_idx[bi])]),
        "p": float(pop.p[bi]),
        "q": float(pop.q[bi]),
    }


def train_population(
    cfg: DFRConfig,
    train_u: Array,
    train_len: Array,
    y_train: Array,
    eval_u: Array,
    eval_len: Array,
    y_eval: Array,
    *,
    divs: int = 4,
    rounds: int = 1,
    steps_per_round: int = 1,
    minibatch: int = 4,
    survive_frac: float = 0.5,
    jitter: float = 0.15,
    task: str = "classification",
    select: Optional[str] = None,
    lr: Optional[float] = None,
    solver: str = "auto",
    p_range: Tuple[float, float] = P_LOG_RANGE,
    q_range: Tuple[float, float] = Q_LOG_RANGE,
    mask: Optional[Array] = None,
    seed: int = 0,
) -> PopulationResult:
    """Grid-seed K = divs^2 members, then ``rounds`` of (cull -> truncated-BP
    refine -> ridge re-evaluate), returning the best member ever evaluated.

    ``rounds=0`` is a pure vmapped grid search.  The per-round learning rate
    anneals as lr * 0.1^round (the paper's drop schedule compressed to round
    granularity); ``lr`` defaults to cfg.lr for classification and to a
    gentler 0.3 * cfg.lr for regression, where the unnormalized MSE gradient
    runs much hotter than cross-entropy's.
    """
    if task not in ("classification", "regression"):
        raise ValueError(f"unknown task: {task}")
    if select is None:
        select = "acc" if task == "classification" else "nrmse"
    loss = "ce" if task == "classification" else "mse"
    if lr is None:
        lr = cfg.lr if task == "classification" else 0.3 * cfg.lr
    if mask is None:
        mask = masking.make_mask(
            jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype
        )

    t0 = time.perf_counter()
    ps, qs = grid_candidates(divs, p_range, q_range, cfg.dtype)
    pop = init_population(cfg, ps, qs)
    key = jax.random.PRNGKey(seed)

    def ev_pop(pop):
        return evaluate_population(
            cfg, mask, pop.p, pop.q, train_u, train_len, y_train,
            eval_u, eval_len, y_eval, select=select, solver=solver,
        )

    ev = ev_pop(pop)
    elite = _best_member(pop, ev, cfg, select)
    history = [{
        "round": 0, "best_nrmse": elite["nrmse"], "best_acc": elite["acc"],
        "mean_nrmse": float(np.mean(np.asarray(ev.nrmse))), "refine_loss": None,
    }]

    for r in range(rounds):
        fitness = -ev.acc if select == "acc" else ev.nrmse
        key, kc = jax.random.split(key)
        pop = cull_population(
            _load_readout(pop, ev.Wt), fitness, kc,
            survive_frac=survive_frac, jitter=jitter,
            p_range=p_range, q_range=q_range,
        )
        lr_r = jnp.asarray(lr * (0.1 ** r), cfg.dtype)
        pop, losses = refine_population(
            cfg, mask, pop, train_u, train_len, y_train, lr_r, lr_r,
            steps=steps_per_round, minibatch=minibatch, loss=loss,
        )
        ev = ev_pop(pop)
        cand = _best_member(pop, ev, cfg, select)
        if cand["metric"] > elite["metric"]:
            elite = cand
        history.append({
            "round": r + 1, "best_nrmse": elite["nrmse"],
            "best_acc": elite["acc"],
            "mean_nrmse": float(np.mean(np.asarray(ev.nrmse))),
            "refine_loss": float(np.mean(np.asarray(losses))),
        })

    return PopulationResult(
        best_params=elite["params"],
        best_nrmse=elite["nrmse"],
        best_acc=elite["acc"],
        best_beta=elite["beta"],
        best_p=elite["p"],
        best_q=elite["q"],
        history=history,
        population=pop,
        final_eval=ev,
        time_s=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Batch-type conveniences
# ---------------------------------------------------------------------------


def train_population_classification(
    cfg: DFRConfig,
    train: TimeSeriesBatch,
    evalb: TimeSeriesBatch,
    **kwargs,
) -> PopulationResult:
    """Population search on a labeled batch pair (targets one-hot encoded)."""
    y_tr = jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype)
    y_ev = jax.nn.one_hot(evalb.label, cfg.n_classes, dtype=cfg.dtype)
    return train_population(
        cfg, train.u, train.length, y_tr, evalb.u, evalb.length, y_ev,
        task="classification", **kwargs,
    )


def train_population_regression(
    cfg: DFRConfig,
    train: RegressionBatch,
    evalb: RegressionBatch,
    **kwargs,
) -> PopulationResult:
    """Population search on a regression batch pair (NRMSE fitness)."""
    return train_population(
        cfg, train.u, train.length, train.y, evalb.u, evalb.length, evalb.y,
        task="regression", **kwargs,
    )
