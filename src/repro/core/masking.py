"""Input masking for the DFR (paper Sec. 2.1-2.2).

The digital DFR multiplies the (held) input sample by a per-virtual-node mask:
``j(k) = M @ u(k)`` where ``M`` is an (Nx, n_in) random matrix fixed at system
construction.  For multivariate inputs this follows the authors' prior
hardware-friendly DFR [10]: each virtual node sees a random +/-1 combination
of the input channels.  Input scaling gamma is folded into the trainable
reservoir gain ``p`` of the modular model (f is linear in the evaluation), so
the mask itself is unit-magnitude.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import Array


def make_mask(
    key: jax.Array, n_nodes: int, n_in: int, dtype=jnp.float32, mode: str = "select"
) -> Array:
    """Mask matrix M of shape (Nx, n_in).

    mode='select' (default): each virtual node reads ONE random input channel
    with a random +/-1 sign - the multivariate masking of the authors'
    hardware-friendly DFR [10]; keeps j(k) at the input's unit scale.
    mode='dense': every node reads a +/-1 combination of all channels.
    """
    k_sign, k_sel = jax.random.split(key)
    bits = jax.random.bernoulli(k_sign, 0.5, (n_nodes, n_in))
    signs = jnp.where(bits, 1.0, -1.0).astype(dtype)
    if mode == "dense":
        return signs
    if mode == "select":
        sel = jax.random.randint(k_sel, (n_nodes,), 0, n_in)
        onehot = jax.nn.one_hot(sel, n_in, dtype=dtype)
        return signs * onehot
    raise ValueError(f"unknown mask mode: {mode}")


def apply_mask(mask: Array, u: Array) -> Array:
    """j(k) = M u(k), batched over any leading dims of ``u``.

    u: (..., n_in)  ->  j: (..., Nx)
    """
    return jnp.einsum("ni,...i->...n", mask, u)
