"""Modular DFR reservoir forward pass (paper Eq. 14) in JAX.

Recurrence (with ring wrap x(k)_0 := x(k-1)_{Nx}):

    a(k)_n  = p * f(j(k)_n + x(k-1)_n)          # nonlinear branch
    x(k)_n  = a(k)_n + q * x(k)_{n-1}           # ring accumulation

The ring accumulation is a first-order linear recurrence along the node axis.
On an FPGA the paper pipelines the node loop; on TPU we exploit the closed
form

    x(k) = L(q) @ a(k) + q^{1..Nx} * x(k-1)_{Nx}

where L(q)[n, i] = q^(n-i) for i <= n (lower triangular).  One reservoir step
is therefore a small (Nx x Nx) GEMM batched over samples - an MXU-friendly
reorganization of the same dataflow (see DESIGN.md 'Hardware adaptation').

Two implementations are provided:
  * ``reservoir_step_naive`` - the per-node sequential reference (faithful to
    the paper's order of operations, used as the oracle),
  * ``run_reservoir`` - time-scan over the GEMM step (production path; the
    Pallas kernel in ``repro.kernels.reservoir`` fuses chunks of it).

The legacy *digital DFR* of Eq. (8)-(9) (exp(-theta) Euler step of the
Mackey-Glass delay ODE) is included as ``run_reservoir_legacy`` because the
paper compares against it (grid-search baselines run on the same modular
model, but Eq. 8-9 defines the pre-modular system).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import Array


def ring_matrix(q: Array, n_nodes: int, dtype=jnp.float32) -> Array:
    """L(q)[n, i] = q^(n-i) for i <= n else 0;  shape (Nx, Nx)."""
    n = jnp.arange(n_nodes)
    expo = n[:, None] - n[None, :]
    low = expo >= 0
    # q ** expo with masked negative exponents (avoid nan for q == 0)
    powed = jnp.where(low, jnp.abs(q) ** jnp.maximum(expo, 0), 0.0)
    sign = jnp.where(q < 0, jnp.where((expo % 2) == 1, -1.0, 1.0), 1.0)
    return (jnp.where(low, powed * sign, 0.0)).astype(dtype)


def ring_powers(q: Array, n_nodes: int, dtype=jnp.float32) -> Array:
    """[q^1, q^2, ..., q^Nx] - carries x(k-1)_{Nx} around the ring."""
    expo = jnp.arange(1, n_nodes + 1)
    powed = jnp.abs(q) ** expo
    sign = jnp.where(q < 0, jnp.where((expo % 2) == 1, -1.0, 1.0), 1.0)
    return (powed * sign).astype(dtype)


def reservoir_step_naive(
    p: Array, q: Array, f: Callable[[Array], Array], j_k: Array, x_prev: Array
) -> Array:
    """One time step, sequential over nodes (paper-faithful reference).

    j_k, x_prev: (Nx,) -> x_k: (Nx,)
    """
    n_nodes = x_prev.shape[-1]
    a = p * f(j_k + x_prev)  # (Nx,) nonlinear branch, depends on k-1 only

    def body(n, carry):
        x_k, ring = carry
        val = a[n] + q * ring
        x_k = x_k.at[n].set(val)
        return (x_k, val)

    x0 = jnp.zeros_like(x_prev)
    ring0 = x_prev[n_nodes - 1]  # x(k)_0 := x(k-1)_{Nx}
    x_k, _ = jax.lax.fori_loop(0, n_nodes, body, (x0, ring0))
    return x_k


def reservoir_step(
    p: Array,
    q: Array,
    f: Callable[[Array], Array],
    j_k: Array,
    x_prev: Array,
    L: Optional[Array] = None,
    qpow: Optional[Array] = None,
) -> Array:
    """One time step in GEMM form, batched over leading dims.

    j_k, x_prev: (..., Nx) -> x_k: (..., Nx)
    """
    n_nodes = x_prev.shape[-1]
    if L is None:
        L = ring_matrix(q, n_nodes, x_prev.dtype)
    if qpow is None:
        qpow = ring_powers(q, n_nodes, x_prev.dtype)
    a = p * f(j_k + x_prev)
    ring_in = x_prev[..., -1:]  # x(k-1)_{Nx}
    return a @ L.T + ring_in * qpow


@partial(jax.jit, static_argnames=("f", "with_lengths"))
def run_reservoir(
    p: Array,
    q: Array,
    j_seq: Array,
    x0: Optional[Array] = None,
    *,
    f: Callable[[Array], Array] = lambda z: z,
    lengths: Optional[Array] = None,
    with_lengths: bool = False,
) -> Array:
    """Run the reservoir over a full (batched) masked input sequence.

    j_seq: (T, Nx) or (B, T, Nx)  ->  states X with matching layout
    (T, Nx) or (B, T, Nx).

    If ``lengths`` is given (B,), the state is frozen once k >= length so that
    X[b, length-1] is the final state x(T) for every sample (padding cannot
    perturb it).  The reservoir state is initialized to zero (paper Sec. 2.2).
    """
    batched = j_seq.ndim == 3
    jt = jnp.swapaxes(j_seq, 0, 1) if batched else j_seq  # (T, [B,] Nx)
    n_nodes = jt.shape[-1]
    if x0 is None:
        # derive from the input so shard_map varying axes are inherited
        x0 = jnp.zeros_like(jt[0])
    L = ring_matrix(q, n_nodes, jt.dtype)
    qpow = ring_powers(q, n_nodes, jt.dtype)

    def step(carry, inp):
        x_prev, k = carry
        j_k = inp
        x_k = reservoir_step(p, q, f, j_k, x_prev, L, qpow)
        if lengths is not None:
            live = (k < lengths)[..., None] if batched else (k < lengths)
            x_k = jnp.where(live, x_k, x_prev)
        return (x_k, k + 1), x_k

    (_, _), xs = jax.lax.scan(step, (x0, jnp.zeros((), jnp.int32)), jt)
    return jnp.swapaxes(xs, 0, 1) if batched else xs


def run_reservoir_legacy(
    eta: Array,
    gamma: Array,
    theta: float,
    j_seq: Array,
    f: Callable[[Array, Array], Array],
) -> Array:
    """Pre-modular digital DFR, Eq. (8)-(9):

        x(k)_1 = x(k-1)_{Nx} e^-theta + (1-e^-theta) f(x(k-1)_1, j(k)_1)
        x(k)_n = x(k)_{n-1}  e^-theta + (1-e^-theta) f(x(k-1)_n, j(k)_n)

    Provided for the baseline comparison; f(x, j) = eta * mg(x + gamma j).
    Same linear-recurrence structure with decay e^-theta playing q's role.
    """
    decay = jnp.exp(-jnp.asarray(theta, j_seq.dtype))
    n_nodes = j_seq.shape[-1]
    L = ring_matrix(decay, n_nodes, j_seq.dtype)
    qpow = ring_powers(decay, n_nodes, j_seq.dtype)

    def step(x_prev, j_k):
        a = (1.0 - decay) * f(x_prev, j_k)
        x_k = a @ L.T if a.ndim > 1 else L @ a
        x_k = x_k + x_prev[..., -1:] * qpow
        return x_k, x_k

    x0 = jnp.zeros(j_seq.shape[1:] if j_seq.ndim == 2 else j_seq.shape[2:], j_seq.dtype)
    if j_seq.ndim == 3:  # (B, T, Nx)
        jt = jnp.swapaxes(j_seq, 0, 1)
        x0 = jnp.zeros((j_seq.shape[0], n_nodes), j_seq.dtype)
        _, xs = jax.lax.scan(step, x0, jt)
        return jnp.swapaxes(xs, 0, 1)
    _, xs = jax.lax.scan(step, x0, j_seq)
    return xs
