"""End-to-end DFR classifier: the paper's training recipe (Sec. 4.1).

Pipeline:
  1. SGD with truncated backprop for 25 epochs on (p, q, W, b); LR starts at
     1.0, x0.1 for the reservoir params at epochs {5,10,15,20} and for the
     output params at {10,15,20}.
  2. Re-fit the output layer with Ridge regression; sweep
     beta in {1e-6, 1e-4, 1e-2, 1} and keep the lowest training loss.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backprop, dprr, masking, reservoir, ridge
from repro.core.types import Array, DFRConfig, DFRParams, TimeSeriesBatch


@partial(jax.jit, static_argnames=("cfg", "minibatch"))
def _sgd_epoch(
    cfg: DFRConfig,
    mask: Array,
    params: DFRParams,
    u: Array,
    length: Array,
    onehot: Array,
    lr_res: Array,
    lr_out: Array,
    minibatch: int = 1,
) -> Tuple[DFRParams, Array]:
    """One SGD epoch over a padded dataset, minibatch at a time."""
    f = cfg.f()
    n = u.shape[0] // minibatch * minibatch
    u_b = u[:n].reshape(-1, minibatch, *u.shape[1:])
    len_b = length[:n].reshape(-1, minibatch)
    oh_b = onehot[:n].reshape(-1, minibatch, onehot.shape[-1])

    def step(params, inp):
        ub, lb, ohb = inp
        j_seq = masking.apply_mask(mask, ub)
        loss, g = backprop.grads_truncated(params, j_seq, ohb, f, lengths=lb)
        inv = 1.0 / minibatch
        new = backprop.apply_sgd(params, g, lr_res, lr_out, inv_batch=inv)
        return new, loss * inv

    params, losses = jax.lax.scan(step, params, (u_b, len_b, oh_b))
    return params, jnp.mean(losses)


@dataclasses.dataclass
class DFRModel:
    cfg: DFRConfig
    mask: Array  # (Nx, n_in)

    @classmethod
    def create(cls, cfg: DFRConfig) -> "DFRModel":
        key = jax.random.PRNGKey(cfg.mask_seed)
        return cls(cfg=cfg, mask=masking.make_mask(key, cfg.n_nodes, cfg.n_in, cfg.dtype))

    # -- forward ------------------------------------------------------------

    def mask_inputs(self, u: Array) -> Array:
        return masking.apply_mask(self.mask, u)

    def features(self, batch: TimeSeriesBatch, params: DFRParams) -> Array:
        """DPRR feature vectors r for a batch: (B, Nr)."""
        j_seq = self.mask_inputs(batch.u)
        f = self.cfg.f()
        x = reservoir.run_reservoir(params.p, params.q, j_seq, f=f, lengths=batch.length)
        return dprr.compute_dprr(x, lengths=batch.length)

    def logits(self, batch: TimeSeriesBatch, params: DFRParams) -> Array:
        r = self.features(batch, params)
        return r @ params.W.T + params.b

    def predict(self, batch: TimeSeriesBatch, params: DFRParams) -> Array:
        return jnp.argmax(self.logits(batch, params), axis=-1)

    def accuracy(self, batch: TimeSeriesBatch, params: DFRParams) -> Array:
        return jnp.mean((self.predict(batch, params) == batch.label).astype(jnp.float32))

    # -- SGD with truncated backprop -----------------------------------------

    def _lr_at(self, epoch: int) -> Tuple[float, float]:
        cfg = self.cfg
        lr_res = cfg.lr * (0.1 ** sum(1 for e in cfg.res_lr_drop_epochs if epoch >= e))
        lr_out = cfg.lr * (0.1 ** sum(1 for e in cfg.out_lr_drop_epochs if epoch >= e))
        return lr_res, lr_out

    def _epoch(self, params, u, length, onehot, lr_res, lr_out, minibatch=1):
        return _sgd_epoch(
            self.cfg, self.mask, params, u, length, onehot, lr_res, lr_out, minibatch
        )

    def fit_sgd(
        self,
        train: TimeSeriesBatch,
        params: Optional[DFRParams] = None,
        minibatch: int = 1,
        shuffle_seed: int = 0,
        verbose: bool = False,
    ) -> Tuple[DFRParams, list]:
        cfg = self.cfg
        if params is None:
            params = DFRParams.init(cfg)
        onehot = jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype)
        rng = np.random.default_rng(shuffle_seed)
        history = []
        for epoch in range(cfg.epochs):
            lr_res, lr_out = self._lr_at(epoch)
            perm = rng.permutation(train.batch)
            params, loss = self._epoch(
                params,
                train.u[perm],
                train.length[perm],
                onehot[perm],
                jnp.asarray(lr_res, cfg.dtype),
                jnp.asarray(lr_out, cfg.dtype),
                minibatch=minibatch,
            )
            history.append((float(loss), params))
            if verbose:
                print(f"epoch {epoch:3d}  loss {float(loss):.5f}  lr ({lr_res:g},{lr_out:g})")
        return params, history

    # -- Ridge refit of the output layer --------------------------------------

    def fit_ridge(
        self,
        train: TimeSeriesBatch,
        params: DFRParams,
        method: str = "cholesky_blocked",
        chunk: int = 256,
    ) -> DFRParams:
        """Re-train (W, b) with Ridge regression, sweeping beta (paper 4.1)."""
        cfg = self.cfg
        s = cfg.s
        A = jnp.zeros((cfg.n_classes, s), cfg.dtype)
        B = jnp.zeros((s, s), cfg.dtype)
        onehot = jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype)
        # stream (A, B) in chunks - the same associative accumulation the
        # edge system performs sample-by-sample (Eq. 38)
        for lo in range(0, train.batch, chunk):
            sub = TimeSeriesBatch(
                u=train.u[lo : lo + chunk],
                length=train.length[lo : lo + chunk],
                label=train.label[lo : lo + chunk],
            )
            r = self.features(sub, params)
            rt = dprr.r_tilde(r)
            A, B = ridge.accumulate_ab(A, B, rt, onehot[lo : lo + chunk])

        best = None
        for beta in cfg.betas:
            Wt = ridge.ridge_solve(A, ridge.regularize(B, jnp.asarray(beta, B.dtype)), method)
            if not bool(jnp.all(jnp.isfinite(Wt))):
                # beta below float32 noise floor of this B: Cholesky/elimination
                # breaks down; the paper's sweep simply moves to the next beta
                continue
            W, b = Wt[:, :-1], Wt[:, -1]
            cand = DFRParams(p=params.p, q=params.q, W=W, b=b)
            logits = self.logits(train, cand)
            loss = float(jnp.mean(backprop.loss_from_logits(logits, onehot)))
            if jnp.isfinite(loss) and (best is None or loss < best[0]):
                best = (loss, cand)
        return best[1] if best is not None else params

    def fit(
        self,
        train: TimeSeriesBatch,
        minibatch: int = 1,
        ridge_method: str = "cholesky_blocked",
        select: str = "val",
        val_fraction: float = 0.25,
        verbose: bool = False,
        seed: int = 0,
    ) -> DFRParams:
        """Truncated-bp SGD then Ridge refit.

        select='final' is the paper's recipe verbatim (keep the last-epoch
        (p, q)).  select='val' (default) additionally holds out
        ``val_fraction`` of the training set and picks the epoch checkpoint
        whose ridge-refit validation accuracy is best, then refits on the
        full training set - a guard for loss landscapes where train CE and
        generalization decouple (observed on the synthetic datasets; see
        DESIGN.md Sec. 9).  All of this cost is charged to 'bp time' in the
        benchmarks.
        """
        if select == "final":
            params, _ = self.fit_sgd(train, minibatch=minibatch, verbose=verbose)
            return self.fit_ridge(train, params, method=ridge_method)
        if select != "val":
            raise ValueError(f"unknown select mode: {select}")
        rng = np.random.default_rng(seed)
        perm = rng.permutation(train.batch)
        n_val = max(1, int(train.batch * val_fraction))
        val_idx, tr_idx = perm[:n_val], perm[n_val:]
        sub = lambda b, idx: TimeSeriesBatch(u=b.u[idx], length=b.length[idx], label=b.label[idx])
        tr, val = sub(train, tr_idx), sub(train, val_idx)
        _, history = self.fit_sgd(tr, minibatch=minibatch, verbose=verbose)
        # evaluate distinct (p, q) checkpoints on the held-out split
        best, seen = None, set()
        for _, ckpt in history:
            key = (round(float(ckpt.p), 6), round(float(ckpt.q), 6))
            if key in seen:
                continue
            seen.add(key)
            fitted = self.fit_ridge(tr, ckpt, method=ridge_method)
            acc = float(self.accuracy(val, fitted))
            if best is None or acc > best[0]:
                best = (acc, ckpt)
        return self.fit_ridge(train, best[1], method=ridge_method)
