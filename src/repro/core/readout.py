"""DFR readout at scale: the paper's online trainer distributed over a mesh.

Lifts the edge system to pods: a frozen LM backbone emits a feature stream
h(k) (B, T, D); a fixed random mask projects it to the Nx-node reservoir; the
modular DFR + DPRR produce r; Ridge sufficient statistics (A, B) are
*associative sums over samples* (paper Eq. 38), so a single ``psum`` over the
data axes makes the online trainer exactly correct under data parallelism -
every pod sees the global (A, B) and solves the same small Cholesky system.

This module is mesh-agnostic: it works inside ``shard_map`` (axis names
present) or single-device (axis_names=()); the launcher wires it to the
production mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import backprop, dprr, masking, reservoir, ridge
from repro.core.types import Array, DFRConfig, DFRParams, RidgeState


def _maybe_psum(x, axis_names: Sequence[str]):
    return jax.lax.psum(x, axis_names) if axis_names else x


@dataclasses.dataclass(frozen=True)
class ReadoutConfig:
    feature_dim: int          # D of the backbone features
    n_classes: int
    n_nodes: int = 30
    nonlinearity: str = "tanh"  # features are unbounded -> saturating f
    alpha: float = 1.0
    mask_seed: int = 0
    dtype: type = jnp.float32

    def dfr(self) -> DFRConfig:
        return DFRConfig(
            n_in=self.feature_dim,
            n_classes=self.n_classes,
            n_nodes=self.n_nodes,
            nonlinearity=self.nonlinearity,
            alpha=self.alpha,
            mask_seed=self.mask_seed,
        )


class DistributedDFRReadout:
    """Online DFR classification head over frozen backbone features."""

    def __init__(self, cfg: ReadoutConfig, axis_names: Sequence[str] = ()):
        self.cfg = cfg
        self.dfr_cfg = cfg.dfr()
        self.axis_names = tuple(axis_names)
        key = jax.random.PRNGKey(cfg.mask_seed)
        # scale by 1/sqrt(D): keeps the masked projection O(1) for
        # unit-variance features regardless of backbone width
        self.mask = masking.make_mask(key, cfg.n_nodes, cfg.feature_dim, cfg.dtype)
        self.mask = self.mask / jnp.sqrt(jnp.asarray(cfg.feature_dim, cfg.dtype))

    def init(self) -> Tuple[DFRParams, RidgeState]:
        return (
            DFRParams.init(self.dfr_cfg),
            RidgeState.zeros(self.dfr_cfg.s, self.cfg.n_classes, self.cfg.dtype),
        )

    # -- pure functions usable inside shard_map -------------------------------

    def features(self, params: DFRParams, h: Array, lengths: Optional[Array] = None) -> Array:
        """h: (B, T, D) backbone features -> r: (B, Nr)."""
        j_seq = masking.apply_mask(self.mask, h.astype(self.cfg.dtype))
        f = self.dfr_cfg.f()
        x = reservoir.run_reservoir(params.p, params.q, j_seq, f=f, lengths=lengths)
        return dprr.compute_dprr(x, lengths=lengths)

    def accumulate(
        self,
        ridge_state: RidgeState,
        params: DFRParams,
        h: Array,
        label: Array,
        lengths: Optional[Array] = None,
    ) -> RidgeState:
        """Accumulate LOCAL (A, B) contributions (no collective yet)."""
        r = self.features(params, h, lengths)
        rt = dprr.r_tilde(r)
        onehot = jax.nn.one_hot(label, self.cfg.n_classes, dtype=self.cfg.dtype)
        A, B = ridge.accumulate_ab(ridge_state.A, ridge_state.B, rt, onehot)
        # B moved without rotating L: invalidate any live factor
        return RidgeState(A=A, B=B, count=ridge_state.count + h.shape[0],
                          Lt=ridge_state.Lt,
                          factor_beta=jnp.zeros_like(ridge_state.factor_beta))

    def solve(
        self, ridge_state: RidgeState, params: DFRParams, beta: Array,
        method: str = "cholesky_blocked",
    ) -> DFRParams:
        """Global Ridge solve: psum the sufficient statistics, then factor.

        The psum is the ONLY collective the readout needs - the paper's
        memory argument (state is O(s^2), independent of stream length)
        becomes a bandwidth argument at scale: s^2 floats per refresh versus
        shipping features.
        """
        A = _maybe_psum(ridge_state.A, self.axis_names)
        B = _maybe_psum(ridge_state.B, self.axis_names)
        Wt = ridge.ridge_solve(A, ridge.regularize(B, beta), method)
        return DFRParams(p=params.p, q=params.q, W=Wt[:, :-1], b=Wt[:, -1])

    def sgd_step(
        self,
        params: DFRParams,
        h: Array,
        label: Array,
        lr_res: Array,
        lr_out: Array,
        lengths: Optional[Array] = None,
    ) -> Tuple[DFRParams, Array]:
        """Truncated-bp SGD step with gradients psum-averaged over the mesh."""
        f = self.dfr_cfg.f()
        j_seq = masking.apply_mask(self.mask, h.astype(self.cfg.dtype))
        onehot = jax.nn.one_hot(label, self.cfg.n_classes, dtype=self.cfg.dtype)
        loss, g = backprop.grads_truncated(params, j_seq, onehot, f, lengths=lengths)
        bsz = jnp.asarray(h.shape[0], self.cfg.dtype)
        loss = _maybe_psum(loss, self.axis_names)
        g = jax.tree_util.tree_map(lambda t: _maybe_psum(t, self.axis_names), g)
        total = _maybe_psum(bsz, self.axis_names)
        inv = 1.0 / total
        new = backprop.apply_sgd(params, g, lr_res, lr_out, inv_batch=inv)
        return new, loss * inv

    def predict(self, params: DFRParams, h: Array, lengths: Optional[Array] = None) -> Array:
        r = self.features(params, h, lengths)
        return jnp.argmax(r @ params.W.T + params.b, axis=-1)
