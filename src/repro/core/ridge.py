"""Ridge regression for the DFR output layer (paper Sec. 2.5 / 3.6).

Solves  W~ = A B^{-1}  with  A = E R~^T (Ny, s),  B = R~ R~^T + beta I (s, s),
s = Nx^2 + Nx + 1.

Four implementations, from paper-faithful to TPU-production:

1. ``ridge_gaussian_numpy``  - Algorithm 1 verbatim (Gauss-Jordan with an
   explicit B^{-1}); the paper's "naive" baseline.  O(2s^3) flops,
   2s(s+Ny)+1 words.
2. ``ridge_cholesky_packed_numpy`` - Algorithms 2/3/4 verbatim: in-place
   Cholesky inside a single 1-D packed array P[s(s+1)/2], then two in-place
   triangular substitutions sharing Q with A/D/W.  s(s+2Ny)/2 + s/2 words.
3. ``ridge_cholesky_packed_jax`` - the same packed in-place algorithm,
   jit-compiled (vectorized inner dot products over contiguous packed rows -
   the packed row-major layout the paper chose is exactly what makes this
   possible).
4. ``ridge_cholesky_blocked`` - the TPU adaptation: right-looking blocked
   Cholesky + blocked TRSMs on 2-D tiles (MXU-aligned); the Pallas kernels in
   ``repro.kernels`` implement the per-tile work, this module carries the
   pure-jnp blocked reference.

Incremental rank-1 engine (``cholupdate_*``): the streaming extension of the
paper's in-place 1-D Cholesky.  Each streamed sample adds one outer product
``r r^T`` to B, so instead of re-factorizing ``B + beta I`` from scratch at
every refresh (O(s^3)), a *live factor* ``L`` is carried next to the (A, B)
statistics and rotated forward per sample with an O(s^2) ``cholupdate``
(hyperbolic variant for the downdate / forgetting path).  A refresh with a
live factor is then just the two triangular substitutions (Algorithms 3/4),
O(s^2 Ny).

When is which path used?

  * **Incremental** (live factor): the continuous-batching stream server in
    ``refresh_mode='incremental'`` - samples arrive rank-1 (small windows),
    the factor is seeded at slot admission as ``sqrt(beta) I`` (B = 0) and
    every accumulated sample rotates it, so no O(s^3) factorization ever
    runs for that slot.  ``repro.core.online.refresh_output`` takes this
    fast path automatically whenever ``RidgeState.factor_beta`` matches the
    requested beta.
  * **Full factorization**: no live factor (offline ridge, the population
    engine, ensemble refresh), a beta different from the seeded one
    (regularization sweeps), or mass accumulation - when many samples land
    between refreshes (large windows / batch admission) the sequential
    rank-1 rotations cost ``n_new * O(s^2)`` with poor arithmetic intensity
    and one blocked/LAPACK O(s^3) factorization wins again; the benchmark's
    honest columns (``bench_stream`` refresh-mode table) quantify the
    crossover.

Memory-word and arithmetic-op count formulas of Tables 2/3 are provided for
the benchmark harness.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Array

# ---------------------------------------------------------------------------
# Packed 1-D triangular indexing (paper Eq. 41): P[i(i+1)/2 + j] = B[i][j],
# j <= i, rows stored contiguously.
# ---------------------------------------------------------------------------


def packed_size(s: int) -> int:
    return s * (s + 1) // 2


def packed_index(i, j):
    return i * (i + 1) // 2 + j


def pack_lower(B: Array) -> Array:
    """Dense symmetric (s, s) -> packed 1-D lower triangle P[s(s+1)/2]."""
    s = B.shape[0]
    i, j = np.tril_indices(s)
    return B[(i, j)]


def unpack_lower(P: Array, s: int) -> Array:
    """Packed 1-D -> dense lower-triangular (s, s) (upper = 0)."""
    i, j = np.tril_indices(s)
    out = jnp.zeros((s, s), P.dtype)
    return out.at[(i, j)].set(P)


# ---------------------------------------------------------------------------
# 1. Paper Algorithm 1: Ridge via Gauss-Jordan elimination (the baseline).
# ---------------------------------------------------------------------------


def ridge_gaussian_numpy(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Verbatim Algorithm 1 (loops and all).  Returns W~ (Ny, s)."""
    A = np.asarray(A, np.float64 if A.dtype == np.float64 else np.float32).copy()
    B = np.array(B, copy=True)
    n_y, s = A.shape
    Binv = np.zeros_like(B)
    for i in range(s):  # lines 1-9: identity init
        Binv[i, i] = 1.0
    for i in range(s):  # lines 10-25: Gauss-Jordan
        buf = 1.0 / B[i, i]
        for j in range(s):
            B[i, j] *= buf
            Binv[i, j] *= buf
        for j in range(s):
            if i != j:
                buf = B[j, i]
                for k in range(s):
                    B[j, k] -= B[i, k] * buf
                    Binv[j, k] -= Binv[i, k] * buf
    W = np.zeros((n_y, s), A.dtype)
    for i in range(n_y):  # lines 26-33
        for j in range(s):
            acc = 0.0
            for k in range(s):
                acc += A[i, k] * Binv[k, j]
            W[i, j] = acc
    return W


@jax.jit
def ridge_gaussian(A: Array, B: Array) -> Array:
    """Algorithm 1 with row operations vectorized (same pivot order, no
    pivot search - B is SPD so the diagonal never vanishes)."""
    s = B.shape[0]
    Binv = jnp.eye(s, dtype=B.dtype)

    def pivot(i, carry):
        B, Binv = carry
        buf = 1.0 / B[i, i]
        brow = B[i] * buf
        binvrow = Binv[i] * buf
        B = B.at[i].set(brow)
        Binv = Binv.at[i].set(binvrow)
        col = B[:, i].at[i].set(0.0)  # eliminate everywhere but the pivot row
        B = B - col[:, None] * brow[None, :]
        Binv = Binv - col[:, None] * binvrow[None, :]
        return B, Binv

    B, Binv = jax.lax.fori_loop(0, s, pivot, (B, Binv))
    return A @ Binv


# ---------------------------------------------------------------------------
# 2. Paper Algorithms 2/3/4 verbatim (numpy reference).
# ---------------------------------------------------------------------------


def cholesky_packed_numpy(P: np.ndarray, s: int) -> np.ndarray:
    """Algorithm 2: in-place Cholesky in the packed 1-D array."""
    P = np.array(P, copy=True)
    for i in range(s):
        for j in range(i):  # lines 2-4: diagonal update
            P[i * (i + 1) // 2 + i] -= P[i * (i + 1) // 2 + j] ** 2
        P[i * (i + 1) // 2 + i] = np.sqrt(P[i * (i + 1) // 2 + i])
        buf = 1.0 / P[i * (i + 1) // 2 + i]
        for j in range(i + 1, s):  # lines 7-12: column below the diagonal
            for k in range(i):
                P[j * (j + 1) // 2 + i] -= P[i * (i + 1) // 2 + k] * P[j * (j + 1) // 2 + k]
            P[j * (j + 1) // 2 + i] *= buf
    return P


def trsm_packed_numpy(Q: np.ndarray, P: np.ndarray, s: int) -> np.ndarray:
    """Algorithm 3: Q (storing A) -> D = A (C^T)^{-1}, in place."""
    Q = np.array(Q, copy=True)
    n_y = Q.shape[0]
    for i in range(n_y):
        for j in range(s):
            for k in range(j):
                Q[i, j] -= Q[i, k] * P[j * (j + 1) // 2 + k]
            Q[i, j] /= P[j * (j + 1) // 2 + j]
    return Q


def trsm_packed_rev_numpy(Q: np.ndarray, P: np.ndarray, s: int) -> np.ndarray:
    """Algorithm 4: Q (storing D) -> W~ = D C^{-1}, in place."""
    Q = np.array(Q, copy=True)
    n_y = Q.shape[0]
    for i in range(n_y):
        for j in range(s - 1, -1, -1):
            for k in range(s - 1, j, -1):
                Q[i, j] -= Q[i, k] * P[k * (k + 1) // 2 + j]
            Q[i, j] /= P[j * (j + 1) // 2 + j]
    return Q


def ridge_cholesky_packed_numpy(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Paper's full proposed pipeline: pack -> Alg 2 -> Alg 3 -> Alg 4."""
    s = B.shape[0]
    i, j = np.tril_indices(s)
    P = np.ascontiguousarray(np.asarray(B)[(i, j)])
    P = cholesky_packed_numpy(P, s)
    Q = trsm_packed_numpy(np.asarray(A), P, s)
    Q = trsm_packed_rev_numpy(Q, P, s)
    return Q


# ---------------------------------------------------------------------------
# 3. The packed in-place algorithm, jit-compiled.
#
# The key observation that keeps this faithful *and* vectorizable: the paper's
# row-major packed layout makes every inner dot product (Alg 2 line 9, Alg 3
# line 4, Alg 4 line 4) a read over a *contiguous* packed row prefix.  We
# slice fixed-size windows and mask, so the jitted program performs the exact
# same in-place update order over the exact same 1-D array.
# ---------------------------------------------------------------------------


def _row_slice(Ppad: Array, j, s: int) -> Array:
    """Packed row j (length j+1), zero-masked to fixed size s."""
    start = j * (j + 1) // 2
    row = jax.lax.dynamic_slice(Ppad, (start,), (s,))
    return jnp.where(jnp.arange(s) <= j, row, 0.0)


@partial(jax.jit, static_argnames=("s",))
def cholesky_packed_jax(P: Array, s: int) -> Array:
    """Algorithm 2, jitted; P has size s(s+1)/2 (padded internally)."""
    Ppad = jnp.concatenate([P, jnp.zeros((s,), P.dtype)])
    ar = jnp.arange(s)

    def col_i(i, Ppad):
        rowi = _row_slice(Ppad, i, s)
        mask_lt_i = ar < i
        diag = rowi[i] - jnp.sum(jnp.where(mask_lt_i, rowi * rowi, 0.0))
        diag = jnp.sqrt(diag)
        buf = 1.0 / diag
        Ppad = Ppad.at[i * (i + 1) // 2 + i].set(diag)
        rowi = rowi.at[i].set(diag)

        def row_j(j, Ppad):
            rowj = _row_slice(Ppad, j, s)
            dot = jnp.sum(jnp.where(mask_lt_i, rowi * rowj, 0.0))
            val = (rowj[i] - dot) * buf
            return Ppad.at[j * (j + 1) // 2 + i].set(val)

        return jax.lax.fori_loop(i + 1, s, row_j, Ppad)

    Ppad = jax.lax.fori_loop(0, s, col_i, Ppad)
    return Ppad[: packed_size(s)]


@partial(jax.jit, static_argnames=("s",))
def trsm_packed_jax(Q: Array, P: Array, s: int) -> Array:
    """Algorithm 3 jitted: rows of Q solved left-to-right (vectorized over
    the Ny rows, which the FPGA implementation partitions - Alg 5)."""
    Ppad = jnp.concatenate([P, jnp.zeros((s,), P.dtype)])
    ar = jnp.arange(s)

    def col_j(j, Q):
        rowj = _row_slice(Ppad, j, s)  # C[j, :j+1]
        dot = Q @ jnp.where(ar < j, rowj, 0.0)  # (Ny,)
        val = (Q[:, j] - dot) / rowj[j]
        return Q.at[:, j].set(val)

    return jax.lax.fori_loop(0, s, col_j, Q)


@partial(jax.jit, static_argnames=("s",))
def trsm_packed_rev_jax(Q: Array, P: Array, s: int) -> Array:
    """Algorithm 4 jitted: W~ = D C^{-1}, columns solved right-to-left.

    Alg 4's inner dot reads C[k, j] for k > j - a packed *column*, which is
    strided.  We read it as a masked gather of P (the same memory, same
    values; the FPGA pays the same BRAM accesses)."""
    ar = jnp.arange(s)
    col_starts = ar * (ar + 1) // 2  # start of each packed row

    def col_j(t, Q):
        j = s - 1 - t
        colj = P[col_starts + j] * (ar >= j)  # C[:, j] masked (k >= j)
        dot = Q @ jnp.where(ar > j, colj, 0.0)
        val = (Q[:, j] - dot) / colj[j]
        return Q.at[:, j].set(val)

    return jax.lax.fori_loop(0, s, col_j, Q)


def ridge_cholesky_packed(A: Array, B: Array) -> Array:
    """Jitted packed pipeline (pack -> Alg 2 -> Alg 3 -> Alg 4)."""
    s = B.shape[0]
    i, j = np.tril_indices(s)
    P = B[(i, j)]
    P = cholesky_packed_jax(P, s)
    Q = trsm_packed_jax(A, P, s)
    return trsm_packed_rev_jax(Q, P, s)


# ---------------------------------------------------------------------------
# 4. Blocked (TPU-shaped) Cholesky ridge: pure-jnp reference of the Pallas
#    kernels.  Right-looking, tile-by-tile in-place in a (nb, nb) grid of
#    (bs, bs) tiles - only the lower triangle of tiles is ever touched,
#    preserving the paper's storage insight at tile granularity.
# ---------------------------------------------------------------------------


def _chol_unblocked(a: Array) -> Array:
    """Unblocked lower Cholesky of one tile via vectorized rank-1 updates."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, a):
        d = jnp.sqrt(a[j, j])
        col = jnp.where(idx > j, a[:, j] / d, 0.0).at[j].set(d)
        a = a.at[:, j].set(jnp.where(idx >= j, col, a[:, j]))
        # trailing update: a[j+1:, j+1:] -= col[j+1:] col[j+1:]^T
        mask = (idx > j).astype(a.dtype)
        upd = (col * mask)[:, None] * (col * mask)[None, :]
        return a - upd

    a = jax.lax.fori_loop(0, n, body, a)
    return jnp.tril(a)


def _trsm_right_lower_t(a: Array, L: Array) -> Array:
    """Solve X L^T = a for X (columns left-to-right), L lower-triangular."""
    n = L.shape[0]

    def body(j, x):
        dot = x @ L[j, :]  # only cols < j of x are final; L[j, k>j] = 0
        # subtract the k == j self term that is not yet valid
        val = (a[:, j] - dot + x[:, j] * L[j, j]) / L[j, j]
        return x.at[:, j].set(val)

    x0 = jnp.zeros_like(a)
    return jax.lax.fori_loop(0, n, body, x0)


def cholesky_blocked_jnp(B: Array, block: int = 128) -> Array:
    """Blocked right-looking Cholesky (reference for the Pallas kernel)."""
    s = B.shape[0]
    pad = (-s) % block
    Bp = jnp.pad(B, ((0, pad), (0, pad)))
    # keep padded diagonal identity so the factorization stays defined
    if pad:
        eye = jnp.eye(s + pad, dtype=B.dtype)
        Bp = Bp + eye * jnp.pad(jnp.zeros((s,), B.dtype), (0, pad), constant_values=1.0)
    n = s + pad
    nb = n // block
    a = Bp
    for kb in range(nb):
        k0 = kb * block
        diag = jax.lax.dynamic_slice(a, (k0, k0), (block, block))
        Lkk = _chol_unblocked(diag)
        a = jax.lax.dynamic_update_slice(a, Lkk, (k0, k0))
        if kb + 1 < nb:
            rest = n - k0 - block
            panel = jax.lax.dynamic_slice(a, (k0 + block, k0), (rest, block))
            Lpanel = _trsm_right_lower_t(panel, Lkk)
            a = jax.lax.dynamic_update_slice(a, Lpanel, (k0 + block, k0))
            trail = jax.lax.dynamic_slice(a, (k0 + block, k0 + block), (rest, rest))
            trail = trail - Lpanel @ Lpanel.T
            a = jax.lax.dynamic_update_slice(a, trail, (k0 + block, k0 + block))
    return jnp.tril(a)[:s, :s]


@jax.jit
def ridge_cholesky_blocked(A: Array, B: Array, block: int = 128) -> Array:
    """Production ridge solve: Cholesky + two triangular solves.

    Never materializes B^{-1}; storage is one triangle + the (Ny, s) Q buffer,
    i.e. the paper's memory claim at tile granularity.  On CPU the factor
    comes from LAPACK potrf; on TPU the Pallas blocked kernels in
    repro.kernels.ridge_solve implement the same pipeline
    (cholesky_blocked_jnp below is their pure-jnp structural reference).
    """
    del block
    C = jnp.linalg.cholesky(B)
    # D = A (C^T)^{-1}  <=>  C D^T = A^T  (forward substitution)
    D = jax.scipy.linalg.solve_triangular(C, A.T, lower=True).T
    # W = D C^{-1}      <=>  C^T W^T = D^T (backward substitution)
    W = jax.scipy.linalg.solve_triangular(C.T, D.T, lower=False).T
    return W


def ridge_cholesky_blocked_ref(A: Array, B: Array, block: int = 128) -> Array:
    """Blocked-tile variant mirroring the Pallas kernel composition."""
    C = cholesky_blocked_jnp(B, block)
    D = jax.scipy.linalg.solve_triangular(C, A.T, lower=True).T
    return jax.scipy.linalg.solve_triangular(C.T, D.T, lower=False).T


def ridge_solve(A: Array, B: Array, method: str = "cholesky_blocked") -> Array:
    """Dispatch: 'gaussian' | 'cholesky_packed' | 'cholesky_blocked'."""
    if method == "gaussian":
        return ridge_gaussian(A, B)
    if method == "cholesky_packed":
        return ridge_cholesky_packed(A, B)
    if method == "cholesky_blocked":
        return ridge_cholesky_blocked(A, B)
    raise ValueError(f"unknown ridge method: {method}")


# ---------------------------------------------------------------------------
# Population-axis (batched) solves: one factorization per population member,
# all in a single XLA program.  These back the vmapped hyperparameter engine
# (repro.core.population); the Pallas tile pipeline has a matching batched
# driver in repro.kernels.ridge_solve.ridge_solve_blocked_batched.
# ---------------------------------------------------------------------------


@jax.jit
def ridge_cholesky_batched(A: Array, B: Array) -> Array:
    """Batched ridge solve:  A (K, Ny, s), B (K, s, s)  ->  W~ (K, Ny, s).

    Same math as ``ridge_cholesky_blocked`` per member (Cholesky + two
    triangular solves, no inverse materialized), with the population axis K
    handled by the batched LAPACK/XLA primitives.
    """
    C = jnp.linalg.cholesky(B)  # (K, s, s), natively batched
    # natively-batched cho_solve (B X = A^T) instead of a vmap of per-member
    # TRSM pairs: one batched triangular-solve primitive for the whole K axis
    # (measurably faster on CPU, where the vmapped path lowers poorly)
    X = jax.scipy.linalg.cho_solve((C, True), jnp.swapaxes(A, -1, -2))
    return jnp.swapaxes(X, -1, -2)


def ridge_solve_batched(A: Array, B: Array, method: str = "cholesky_blocked") -> Array:
    """Population-axis dispatch mirroring ``ridge_solve``.

    A: (K, Ny, s), B: (K, s, s) -> (K, Ny, s).
    """
    if method == "cholesky_blocked":
        return ridge_cholesky_batched(A, B)
    if method == "gaussian":
        return jax.vmap(ridge_gaussian)(A, B)
    raise ValueError(f"unknown batched ridge method: {method}")


# ---------------------------------------------------------------------------
# 5. Incremental rank-1 Cholesky: cholupdate / choldowndate.
#
# Streamed samples perturb B by rank-1 outer products, so the live factor L
# of B + beta I is rotated forward in O(s^2) instead of re-factorized in
# O(s^3): with A = L L^T,
#
#     A + sign * x x^T = L' L'^T
#
# via the LINPACK rotation sweep (sign=+1: Givens-style update; sign=-1:
# hyperbolic downdate, the forgetting-factor / retired-sample path).  Three
# forms, mirroring the factorization section above:
#
#   * ``cholupdate_packed_numpy`` - the paper-shaped oracle: in-place sweep
#     over the same packed 1-D array P[s(s+1)/2] Algorithm 2 factors into
#     (column k of C is the strided packed read the FPGA BRAM pays too).
#   * ``cholupdate_packed_jax``   - the same sweep jitted over the packed
#     array (fori_loop; masked strided column gather/scatter).
#   * ``cholupdate_dense``        - the production form on a dense lower
#     (s, s) factor: the packed addressing defeats the VPU exactly as it
#     defeats the MXU for the factorization (see repro.kernels.cholesky),
#     so the in-state factor is dense-lower and the sweep updates whole
#     columns; ``cholupdate_dense_batched`` vmaps it over a member/slot
#     axis, ``cholupdate_window`` folds a window of samples sequentially.
#     The Pallas tile kernel in ``repro.kernels.cholupdate`` runs the same
#     sweep with the factor resident in VMEM.
#
# The downdate requires  x^T (L L^T)^{-1} x < 1  (the result must stay SPD).
# Degenerate downdates are *guarded*, not NaN-propagated: every rotation
# whose radicand  d_k^2 - x_k^2  falls at or below ``DOWNDATE_GUARD_REL *
# d_k^2`` is skipped entirely (the factor column, diagonal and the rotated x
# are left untouched), so the factor always stays finite, triangular and
# positive-diagonal.  The ``*_guarded`` variants additionally return an
# ``ok`` flag so callers can fall back to a full re-factorization (the
# stream server's sliding-window retirement does exactly that); the
# unflagged forms share the same clamp but silently degrade to "factor no
# longer matches B - x x^T" - documented, tested behavior instead of NaNs.
# The packed numpy *oracle* raises ``numpy.linalg.LinAlgError`` instead:
# as the reference implementation it must never return a silently-wrong
# factor.  The sweeps still assume a positive diagonal on entry (the same
# contract as the factorizations above).
# ---------------------------------------------------------------------------

# Relative radicand floor of the downdate guard: a rotation with
# d_k^2 + sign * x_k^2 <= DOWNDATE_GUARD_REL * d_k^2 is treated as
# indefinite (it would zero or destroy the diagonal in working precision).
# Only reachable for sign=-1: the update radicand is >= d_k^2.
DOWNDATE_GUARD_REL = 1e-6


def _guarded_rotation(dk, xk, sign):
    """One rotation's (r, c, s) with the downdate guard applied.

    Good rotations (radicand > DOWNDATE_GUARD_REL * d_k^2 - every update,
    and every downdate that keeps the diagonal safely positive) compute
    bit-identically to the unguarded sweep.  Bad rotations degrade to the
    exact identity (r = d_k, c = 1, s = 0: column, diagonal and x all
    untouched) and raise the returned ``bad`` flag.  Shared by every jax
    sweep in this module and the Pallas tile kernel in
    ``repro.kernels.cholupdate`` so all forms stay bit-parity-comparable.
    """
    rad = dk * dk + sign * xk * xk
    bad = rad <= DOWNDATE_GUARD_REL * (dk * dk)
    r = jnp.where(bad, dk, jnp.sqrt(jnp.where(bad, jnp.ones_like(rad), rad)))
    c = r / dk
    sk = jnp.where(bad, jnp.zeros_like(xk), xk / dk)
    return r, c, sk, bad


def pad_factor_identity(F: Array, pad: int) -> Array:
    """Zero-pad a (..., s, s) triangular factor by ``pad`` rows/cols with
    ones on the padded diagonal: padded rotations and substitutions become
    exact no-ops instead of zero-pivot divisions.  Shared by the Pallas
    window wrapper (``kernels.ops.cholupdate_window``) and the blocked
    batched substitution below - the invariant lives in one place.
    """
    if not pad:
        return F
    s = F.shape[-1]
    eye_tail = jnp.diag(
        jnp.pad(jnp.zeros((s,), F.dtype), (0, pad), constant_values=1.0)
    )
    widths = ((0, 0),) * (F.ndim - 2) + ((0, pad), (0, pad))
    return jnp.pad(F, widths) + eye_tail.reshape(
        (1,) * (F.ndim - 2) + eye_tail.shape
    )


def seed_factor(s: int, beta, dtype=jnp.float32) -> Array:
    """Factor of the empty system: chol(0 + beta I) = sqrt(beta) I.

    Seeding a fresh slot with this makes every later ``cholupdate`` exact:
    no O(s^3) factorization is ever needed on the incremental path.
    """
    return jnp.sqrt(jnp.asarray(beta, dtype)) * jnp.eye(s, dtype=dtype)


def cholupdate_packed_numpy(P: np.ndarray, x: np.ndarray, s: int,
                            sign: float = 1.0) -> np.ndarray:
    """Rank-1 update of the packed factor, loops and all (the oracle).

    P holds C with C C^T = B (Algorithm 2's output); returns the packed
    factor of B + sign * x x^T.  In-place update order: one rotation per
    column k, touching only packed column k and the tail of x - the same
    storage discipline as Algorithms 2-4.

    Raises ``numpy.linalg.LinAlgError`` on an indefinite downdate (a
    rotation radicand <= 0, i.e. ``x^T (C C^T)^{-1} x >= 1``): the oracle
    never returns a silently-NaN factor.  The production jax forms clamp
    and signal instead (see the section comment / ``*_guarded``).
    """
    P = np.array(P, copy=True)
    x = np.array(x, copy=True).astype(P.dtype)
    for k in range(s):
        dk = P[k * (k + 1) // 2 + k]
        rad = dk * dk + sign * x[k] * x[k]
        if rad <= 0.0:
            raise np.linalg.LinAlgError(
                f"indefinite downdate: rotation {k} radicand {rad!r} <= 0 "
                "(x^T B^{-1} x >= 1; the downdated matrix is not SPD)"
            )
        r = np.sqrt(rad)
        c = r / dk
        sk = x[k] / dk
        P[k * (k + 1) // 2 + k] = r
        for j in range(k + 1, s):
            pj = (P[j * (j + 1) // 2 + k] + sign * sk * x[j]) / c
            P[j * (j + 1) // 2 + k] = pj
            x[j] = c * x[j] - sk * pj
    return P


@partial(jax.jit, static_argnames=("s",))
def cholupdate_packed_jax(P: Array, x: Array, s: int, sign=1.0) -> Array:
    """``cholupdate_packed_numpy`` jitted: the same sweep over the same
    packed 1-D array.  Column k of C is a strided packed read (as in
    Algorithm 4's inner loop), masked to rows >= k."""
    ar = jnp.arange(s)
    col_starts = ar * (ar + 1) // 2  # start of each packed row

    def rot_k(k, carry):
        P, x = carry
        colk = P[col_starts + k]  # C[:, k], valid where ar >= k
        dk = colk[k]
        xk = x[k]
        r, c, sk, _ = _guarded_rotation(dk, xk, sign)
        new = (colk + sign * sk * x) / c
        new = jnp.where(ar > k, new, colk).at[k].set(r)
        x = jnp.where(ar > k, c * x - sk * new, x)
        P = P.at[col_starts + k].set(jnp.where(ar >= k, new, colk))
        return P, x

    P, _ = jax.lax.fori_loop(0, s, rot_k, (P, x))
    return P


def _cholupdate_dense_flagged(L: Array, x: Array, sign) -> Tuple[Array, Array]:
    """One rotation sweep over a dense lower factor (vectorized columns).

    Returns (L', bad): ``bad`` is True iff any rotation hit the downdate
    guard (and was skipped - see the section comment)."""
    n = L.shape[0]
    ridx = jnp.arange(n)

    def rot_k(k, carry):
        L, x, bad_any = carry
        dk = L[k, k]
        xk = x[k]
        r, c, sk, bad = _guarded_rotation(dk, xk, sign)
        col = (L[:, k] + sign * sk * x) / c
        col = jnp.where(ridx > k, col, L[:, k]).at[k].set(r)
        L = L.at[:, k].set(col)
        x = jnp.where(ridx > k, c * x - sk * col, x)
        return L, x, bad_any | bad

    L, _, bad = jax.lax.fori_loop(
        0, n, rot_k, (L, x, jnp.zeros((), jnp.bool_))
    )
    return L, bad


def _cholupdate_dense(L: Array, x: Array, sign) -> Array:
    return _cholupdate_dense_flagged(L, x, sign)[0]


@jax.jit
def cholupdate_dense(L: Array, x: Array, sign=1.0) -> Array:
    """Rank-1 update/downdate of a dense lower factor: L (s, s), x (s,).

    Indefinite downdate rotations are clamp-skipped (finite result, no
    NaNs) - use ``cholupdate_dense_guarded`` when the caller needs to know.
    """
    return _cholupdate_dense(L, x, jnp.asarray(sign, L.dtype))


@jax.jit
def cholupdate_dense_guarded(L: Array, x: Array, sign=1.0) -> Tuple[Array, Array]:
    """``cholupdate_dense`` + guard flag: returns (L', ok).

    ``ok`` is False iff a rotation was guard-skipped (the downdate would
    have driven the diagonal non-positive); the returned factor is then
    still finite, triangular and positive-diagonal, but no longer factors
    ``B + sign * x x^T`` - re-factorize from the statistics.
    """
    L, bad = _cholupdate_dense_flagged(L, x, jnp.asarray(sign, L.dtype))
    return L, ~bad


@jax.jit
def cholupdate_dense_batched(L: Array, x: Array, sign=1.0) -> Array:
    """Member/slot-axis rank-1 update: L (K, s, s), x (K, s)."""
    sg = jnp.asarray(sign, L.dtype)
    return jax.vmap(lambda l, v: _cholupdate_dense(l, v, sg))(L, x)


def cholupdate_window(L: Array, X: Array, sign=1.0) -> Array:
    """Fold a window of samples into the factor: X (W, s), rows applied in
    stream order.  A zero row is an exact no-op (r = |d|, c = 1, sk = 0), so
    callers gate dead/tail samples by scaling rows to zero - the same 0/1
    weight discipline as ``repro.core.online.online_step``."""
    sg = jnp.asarray(sign, L.dtype)

    def fold(t, L):
        return _cholupdate_dense(L, X[t], sg)

    return jax.lax.fori_loop(0, X.shape[0], fold, L)


def _cholupdate_dense_t_flagged(U: Array, x: Array, sign) -> Tuple[Array, Array]:
    """The rotation sweep on the *transposed* factor U = L^T.

    Column k of L is row k of U - a contiguous read/write in row-major
    storage.  The strided column access of the untransposed sweep wastes a
    full cache line per element on CPU (and lane shuffles on TPU), which is
    why the in-state factor (``RidgeState.Lt``) is stored transposed: the
    vmapped per-slot sweep runs ~2x faster than the column form at the
    server's (S, s, s) shapes.  Bit-identical to
    ``cholupdate_dense(U.T, x).T``.  Returns (U', bad) - see
    ``_cholupdate_dense_flagged``.
    """
    n = U.shape[0]
    cidx = jnp.arange(n)

    # The sweep touches exactly one factor row per rotation step (row k is
    # read, rotated, written; every other row is untouched), so it is a
    # ``lax.scan`` over the stacked rows with only (x, bad) in the carry -
    # each output row is written ONCE into the stacked ys.  The equivalent
    # ``fori_loop`` carrying the whole factor forces XLA:CPU to copy the
    # full (.., s, s) buffer every iteration when it cannot prove aliasing
    # (under vmap at the stream server's (S, s, s) shapes that copy was
    # ~95% of the serving step).  Identical arithmetic per element, so the
    # scan is bit-for-bit the loop it replaces.
    def rot_k(carry, inp):
        x, bad_any = carry
        k, rowk = inp
        dk = rowk[k]
        xk = x[k]
        r, c, sk, bad = _guarded_rotation(dk, xk, sign)
        new = (rowk + sign * sk * x) / c
        new = jnp.where(cidx > k, new, rowk).at[k].set(r)
        x = jnp.where(cidx > k, c * x - sk * new, x)
        return (x, bad_any | bad), new

    (_, bad), U = jax.lax.scan(
        rot_k, (x, jnp.zeros((), jnp.bool_)), (cidx, U)
    )
    return U, bad


def _cholupdate_dense_t(U: Array, x: Array, sign) -> Array:
    return _cholupdate_dense_t_flagged(U, x, sign)[0]


@jax.jit
def cholupdate_dense_t(U: Array, x: Array, sign=1.0) -> Array:
    """Rank-1 update/downdate of a transposed factor: U = L^T (s, s)."""
    return _cholupdate_dense_t(U, x, jnp.asarray(sign, U.dtype))


@jax.jit
def cholupdate_dense_t_guarded(U: Array, x: Array, sign=1.0) -> Tuple[Array, Array]:
    """``cholupdate_dense_guarded`` on the transposed factor: (U', ok)."""
    U, bad = _cholupdate_dense_t_flagged(U, x, jnp.asarray(sign, U.dtype))
    return U, ~bad


def cholupdate_window_t(U: Array, X: Array, sign=1.0) -> Array:
    """``cholupdate_window`` on the transposed in-state factor."""
    sg = jnp.asarray(sign, U.dtype)

    def fold(t, U):
        return _cholupdate_dense_t(U, X[t], sg)

    return jax.lax.fori_loop(0, X.shape[0], fold, U)


def cholupdate_window_t_decay(
    U: Array, X: Array, scale: Array, sign=1.0
) -> Array:
    """``cholupdate_window_t`` with a per-row pre-scaling of the factor.

    Before rotating row t of X into U, the whole factor is scaled by
    ``scale[t]`` - the forgetting-factor hook: with scale[t] = sqrt(lambda)
    for live rows (and exactly 1.0 for dead/gated rows, an exact bitwise
    no-op), the maintained system decays as  L L^T <- lambda L L^T + x x^T
    per retained sample, which is exact because scaling commutes with the
    rank-1 rotation.  ``scale = ones`` reduces to ``cholupdate_window_t``
    bit-for-bit (multiplication by 1.0 is the identity).
    """
    sg = jnp.asarray(sign, U.dtype)

    def fold(t, U):
        return _cholupdate_dense_t(U * scale[t], X[t], sg)

    return jax.lax.fori_loop(0, X.shape[0], fold, U)


@jax.jit
def ridge_solve_from_factor(A: Array, L: Array) -> Array:
    """Refresh from a live factor: W~ = A (L L^T)^{-1}, two triangular
    substitutions (Algorithms 3/4), O(s^2 Ny) - no factorization."""
    D = jax.scipy.linalg.solve_triangular(L, A.T, lower=True).T
    return jax.scipy.linalg.solve_triangular(L.T, D.T, lower=False).T


@jax.jit
def ridge_solve_from_factor_t(A: Array, U: Array) -> Array:
    """``ridge_solve_from_factor`` on the transposed factor U = L^T:
    U^T Y = A^T forward, then U W~^T = Y backward (LAPACK handles the
    transpose by flag, no copy)."""
    Y = jax.scipy.linalg.solve_triangular(U, A.T, lower=False, trans="T")
    return jax.scipy.linalg.solve_triangular(U, Y, lower=False).T


@jax.jit
def ridge_solve_from_factor_batched(A: Array, L: Array) -> Array:
    """Batched refresh from live factors: A (K, Ny, s), L (K, s, s)."""
    X = jax.scipy.linalg.cho_solve((L, True), jnp.swapaxes(A, -1, -2))
    return jnp.swapaxes(X, -1, -2)


@partial(jax.jit, static_argnames=("block",))
def ridge_solve_from_factor_t_batched(
    A: Array, U: Array, block: int = 16
) -> Array:
    """Batched refresh from transposed live factors by *blocked
    substitution*:  A (K, Ny, s), U (K, s, s) with U = L^T.

    XLA:CPU lowers the batched triangular-solve primitive poorly (worse
    than the batched factorization it should undercut - the same lowering
    gap PR 1 found for vmapped TRSMs), so the two substitutions run as
    explicit row-block sweeps: per block, an unrolled in-block solve plus
    one batched GEMM for the trailing update.  O(s^2 Ny) per member, ~4x
    faster than ``cho_solve`` at the stream server's (S, s, s) shapes.

    The system pads to a block multiple with an identity diagonal (padded
    rows solve to zero exactly, as in ``repro.kernels.ridge_solve``).
    """
    k, ny, s = A.shape
    pad = (-s) % block
    if pad:
        U = pad_factor_identity(U, pad)
        A = jnp.pad(A, ((0, 0), (0, 0), (0, pad)))
    n = s + pad
    nb = n // block
    ridx = jnp.arange(n)

    # forward:  U^T Y = A^T  (U^T is lower; row block j of U^T is the
    # column block j of U, read as rows of U - contiguous)
    Y = jnp.swapaxes(A, -1, -2)  # (K, n, Ny)

    def fwd(j, Y):
        j0 = j * block
        cols = jax.lax.dynamic_slice(U, (0, 0, j0), (k, n, block))
        done = jnp.where(ridx[None, :, None] < j0, Y, 0.0)
        rhs = jax.lax.dynamic_slice(Y, (0, j0, 0), (k, block, ny))
        rhs = rhs - jnp.einsum("ksb,ksn->kbn", cols, done)
        Tb = jax.lax.dynamic_slice(cols, (0, j0, 0), (k, block, block))
        sol = jnp.zeros_like(rhs)
        for i in range(block):  # unrolled in-block forward substitution
            v = (rhs[:, i, :] - jnp.einsum("kb,kbn->kn", Tb[:, :, i], sol))
            sol = sol.at[:, i, :].set(v / Tb[:, i, i][:, None])
        return jax.lax.dynamic_update_slice(Y, sol, (0, j0, 0))

    Y = jax.lax.fori_loop(0, nb, fwd, Y)

    # backward:  U W~^T = Y  (U upper; high row blocks first)
    Wt = Y

    def bwd(t, Wt):
        j0 = (nb - 1 - t) * block
        rows = jax.lax.dynamic_slice(U, (0, j0, 0), (k, block, n))
        solved = jnp.where(ridx[None, :, None] >= j0 + block, Wt, 0.0)
        rhs = jax.lax.dynamic_slice(Y, (0, j0, 0), (k, block, ny))
        rhs = rhs - jnp.einsum("kbs,ksn->kbn", rows, solved)
        Tb = jax.lax.dynamic_slice(rows, (0, 0, j0), (k, block, block))
        sol = jnp.zeros_like(rhs)
        for i in range(block - 1, -1, -1):  # unrolled backward substitution
            v = (rhs[:, i, :] - jnp.einsum("kb,kbn->kn", Tb[:, i, :], sol))
            sol = sol.at[:, i, :].set(v / Tb[:, i, i][:, None])
        return jax.lax.dynamic_update_slice(Wt, sol, (0, j0, 0))

    Wt = jax.lax.fori_loop(0, nb, bwd, Wt)
    return jnp.swapaxes(Wt, -1, -2)[:, :, :s]


# ---------------------------------------------------------------------------
# Streaming sufficient statistics (paper Eq. 21-22, 38).
# ---------------------------------------------------------------------------


def accumulate_ab(A: Array, B: Array, r_tilde: Array, onehot: Array) -> Tuple[Array, Array]:
    """Rank-k update of (A, B) with a batch of samples.

    r_tilde: (batch, s), onehot: (batch, Ny).
    """
    A = A + jnp.einsum("bc,bs->cs", onehot, r_tilde)
    B = B + jnp.einsum("bs,bt->st", r_tilde, r_tilde)
    return A, B


def regularize(B: Array, beta: Array) -> Array:
    """B + beta I, broadcasting over any leading (population) axes."""
    return B + beta * jnp.eye(B.shape[-1], dtype=B.dtype)


# ---------------------------------------------------------------------------
# Table 2 / Table 3 formulas (for the benchmark harness).
# ---------------------------------------------------------------------------


def memory_words_naive(s: int, n_y: int) -> int:
    """Table 2 'naive': B + B^{-1} + A + W~ + buf = 2s(s+Ny) + 1 words."""
    return 2 * s * (s + n_y) + 1


def memory_words_proposed(s: int, n_y: int) -> int:
    """Table 2 'proposed': P + Q = s(s+2Ny)/2 + s/2 words."""
    return (s * (s + 2 * n_y) + s) // 2


def op_counts_naive(s: int, n_y: int) -> dict:
    """Table 3 'naive' (Gauss-Jordan) arithmetic op counts.

    add: 2s^2(s + Ny/2) - 2s^2 = s^2(2s + Ny) - 2s^2;  mul: s^2(2s + Ny).
    """
    return {
        "add": float(s * s * (2 * s + n_y) - 2 * s * s),
        "mul": float(s * s * (2 * s + n_y)),
        "div": float(s),
        "sqrt": 0.0,
    }


def op_counts_proposed(s: int, n_y: int) -> dict:
    """Table 3 'proposed' (1-D Cholesky) arithmetic op counts."""
    return {
        "add": s * s * (s + n_y) / 6 - s / 6 - s * n_y,
        "mul": s * s * (s + n_y) / 6 + s * s / 2 - 2 * s / 3 - s * n_y,
        "div": float(s + 2 * s * n_y),
        "sqrt": float(s),
    }


def count_ops_packed(s: int, n_y: int) -> dict:
    """Exact op count of Algorithms 2+3+4 by loop enumeration (used to
    cross-check the Table 3 closed forms in the benchmark)."""
    add = mul = div = sqrt = 0
    for i in range(s):
        add += i            # diagonal update subs
        mul += i            # squares
        sqrt += 1
        div += 1            # buf = 1/diag  (paper counts the reciprocal)
        for j in range(i + 1, s):
            add += i
            mul += i + 1    # dots + final *buf
    # Alg 3: for each of Ny rows: sum_j (j subs + j muls + 1 div)
    add += n_y * (s * (s - 1) // 2)
    mul += n_y * (s * (s - 1) // 2)
    div += n_y * s
    # Alg 4: mirror of Alg 3
    add += n_y * (s * (s - 1) // 2)
    mul += n_y * (s * (s - 1) // 2)
    div += n_y * s
    return {"add": add, "mul": mul, "div": div, "sqrt": sqrt}
