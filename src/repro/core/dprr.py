"""Dot-Product Reservoir Representation (DPRR), paper Sec. 2.3.

    r_{(i-1)Nx+j} = sum_{k=1..T} x(k)_i x(k-1)_j      (Eq. 27)
    r_{Nx^2 + i}  = sum_{k=1..T} x(k)_i               (Eq. 28)
    with x(0) = 0.

Equivalently  R = X1^T @ X0~  where X1 = X[1..T] (T, Nx) and
X0~ = [X[0..T-1], 1] (T, Nx+1) - i.e. the DPRR **is** a GEMM.  The FPGA
implementation accumulates it element-wise; on TPU we feed the MXU (the
Pallas kernel ``repro.kernels.dprr`` fuses the shift/append with the
T-blocked matmul accumulation).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import Array


def shifted_states(x: Array) -> Array:
    """X0 = [0, x(1), ..., x(T-1)]: the x(k-1) stream with x(0) = 0.

    x: (..., T, Nx) -> (..., T, Nx)
    """
    pad = [(0, 0)] * (x.ndim - 2) + [(1, 0), (0, 0)]
    return jnp.pad(x, pad)[..., :-1, :]


@partial(jax.jit, static_argnames=())
def compute_dprr(x: Array, lengths: Optional[Array] = None) -> Array:
    """DPRR vector r of a state sequence.

    x: (T, Nx) or (B, T, Nx) reservoir states.
    lengths: optional (B,) valid lengths; padded steps contribute nothing.

    Returns r: (Nx*(Nx+1),) or (B, Nx*(Nx+1)), laid out as the flattened
    (Nx, Nx) dot-product block followed by the Nx sum block - matching the
    paper's index convention r_{(i-1)Nx+j}, r_{Nx^2+i}.
    """
    n_nodes = x.shape[-1]
    x0 = shifted_states(x)
    if lengths is not None:
        t = jnp.arange(x.shape[-2])
        live = (t[None, :] < lengths[:, None]).astype(x.dtype)  # (B, T)
        x1m = x * live[..., None]
    else:
        x1m = x
    # R[i, j] = sum_k x(k)_i x(k-1)_j   -> contraction over time on the MXU
    outer = jnp.einsum("...ki,...kj->...ij", x1m, x0)
    sums = jnp.sum(x1m, axis=-2)  # (..., Nx)
    flat = outer.reshape(*outer.shape[:-2], n_nodes * n_nodes)
    return jnp.concatenate([flat, sums], axis=-1)


def r_tilde(r: Array) -> Array:
    """r~ = [r, 1] (paper Eq. 16), batched over leading dims."""
    ones = jnp.ones((*r.shape[:-1], 1), r.dtype)
    return jnp.concatenate([r, ones], axis=-1)


def dprr_truncated_coefficients(x_last: Array, x_prev: Array) -> Array:
    """Gradient coefficients of r w.r.t. x(T) used by truncated backprop.

    d r_{(n-1)Nx+j} / d x(T)_n = x(T-1)_j ;  d r_{Nx^2+n} / d x(T)_n = 1.
    Returns (Nx, Nx+1): row n = [x(T-1), 1] (paper Eq. 33's pairing).
    """
    n_nodes = x_last.shape[-1]
    del x_last  # present for signature symmetry / batching clarity
    row = jnp.concatenate([x_prev, jnp.ones((*x_prev.shape[:-1], 1), x_prev.dtype)], -1)
    return jnp.broadcast_to(row[..., None, :], (*x_prev.shape[:-1], n_nodes, n_nodes + 1))
