"""repro.core - the paper's contribution: modular DFR online training system.

Public API surface; see DESIGN.md for the paper-to-module map.
"""
from repro.core.types import (  # noqa: F401
    DFRConfig,
    DFRParams,
    RegressionBatch,
    RidgeState,
    TimeSeriesBatch,
)
from repro.core.masking import make_mask, apply_mask  # noqa: F401
from repro.core.reservoir import (  # noqa: F401
    run_reservoir,
    reservoir_step,
    reservoir_step_naive,
    ring_matrix,
    ring_powers,
)
from repro.core.dprr import compute_dprr, r_tilde, shifted_states  # noqa: F401
from repro.core.ridge import (  # noqa: F401
    ridge_solve,
    ridge_solve_batched,
    ridge_gaussian,
    ridge_cholesky_packed,
    ridge_cholesky_blocked,
    ridge_cholesky_batched,
    accumulate_ab,
    regularize,
    cholupdate_dense,
    cholupdate_dense_batched,
    cholupdate_dense_t,
    cholupdate_window,
    cholupdate_window_t,
    ridge_solve_from_factor,
    ridge_solve_from_factor_batched,
    ridge_solve_from_factor_t,
    ridge_solve_from_factor_t_batched,
    seed_factor,
)
from repro.core.backprop import (  # noqa: F401
    forward,
    grads_truncated,
    grads_truncated_manual,
    grads_full_bptt,
    loss_from_logits,
)
from repro.core.dfr import DFRModel  # noqa: F401
from repro.core.online import (  # noqa: F401
    OnlineDFR,
    OnlineEnsemble,
    OnlineState,
    init_state,
    online_infer,
    online_logits,
    online_serve_step,
    online_step,
    refresh_output,
    refresh_output_batched,
    reset_statistics,
)
from repro.core.readout import DistributedDFRReadout, ReadoutConfig  # noqa: F401
from repro.core.population import (  # noqa: F401
    PopulationEval,
    PopulationResult,
    cull_population,
    evaluate_population,
    grid_candidates,
    init_population,
    refine_population,
    train_population,
    train_population_classification,
    train_population_regression,
)
from repro.core.grid_search import (  # noqa: F401
    grid_search,
    grid_search_serial,
    grid_search_until,
)
