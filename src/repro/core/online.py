"""Online edge training + inference loop (paper Sec. 3.1): one fused step.

The paper's system processes a stream sample-by-sample, entirely on-device:

    reservoir forward -> DPRR -> (a) inference: y = W r + b
                               -> (b) training: truncated-bp SGD update of
                                      (p, q, W, b) AND streaming (A, B)
                                      accumulation; the Ridge solve runs
                                      periodically (or on demand) to refresh
                                      the output layer.

Everything below is a single jitted program per step - the TPU analogue of
"everything on the FPGA, no host round trips".  ``OnlineDFR.step`` is also
the unit that scales out: (A, B) and the parameter grads are associative
sums, so the distributed variant (repro.core.readout) psums them across the
data axes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backprop, dprr, masking, reservoir, ridge
from repro.core.types import Array, DFRConfig, DFRParams, RidgeState


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OnlineState:
    """Carry of the online system (a pytree)."""

    params: DFRParams
    ridge: RidgeState
    step: Array          # int32 counter
    loss_ema: Array      # scalar diagnostics

    def tree_flatten(self):
        return (self.params, self.ridge, self.step, self.loss_ema), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


class OnlineDFR:
    """Fused online train/infer stepper for a fixed-length stream window."""

    def __init__(self, cfg: DFRConfig, mask: Optional[Array] = None):
        self.cfg = cfg
        if mask is None:
            mask = masking.make_mask(
                jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype
            )
        self.mask = mask

    def init(self) -> OnlineState:
        cfg = self.cfg
        return OnlineState(
            params=DFRParams.init(cfg),
            ridge=RidgeState.zeros(cfg.s, cfg.n_classes, cfg.dtype),
            step=jnp.zeros((), jnp.int32),
            loss_ema=jnp.zeros((), cfg.dtype),
        )

    @partial(jax.jit, static_argnames=("self",))
    def step(
        self,
        state: OnlineState,
        u: Array,        # (B, T, n_in) window of streamed samples
        length: Array,   # (B,)
        label: Array,    # (B,) int32
        lr_res: Array,
        lr_out: Array,
    ) -> Tuple[OnlineState, dict]:
        """One online training step: SGD update + (A, B) accumulation."""
        cfg = self.cfg
        f = cfg.f()
        j_seq = masking.apply_mask(self.mask, u)
        onehot = jax.nn.one_hot(label, cfg.n_classes, dtype=cfg.dtype)
        loss, g = backprop.grads_truncated(state.params, j_seq, onehot, f, lengths=length)
        bsz = u.shape[0]
        inv = 1.0 / bsz
        params = backprop.apply_sgd(state.params, g, lr_res, lr_out, inv_batch=inv)
        # streaming sufficient statistics with the *updated* reservoir params
        x = reservoir.run_reservoir(params.p, params.q, j_seq, f=f, lengths=length)
        r = dprr.compute_dprr(x, lengths=length)
        rt = dprr.r_tilde(r)
        A, B = ridge.accumulate_ab(state.ridge.A, state.ridge.B, rt, onehot)
        new = OnlineState(
            params=params,
            ridge=RidgeState(A=A, B=B, count=state.ridge.count + bsz),
            step=state.step + 1,
            loss_ema=0.99 * state.loss_ema + 0.01 * loss * inv,
        )
        logits = r @ params.W.T + params.b
        metrics = {
            "loss": loss * inv,
            "acc": jnp.mean((jnp.argmax(logits, -1) == label).astype(jnp.float32)),
        }
        return new, metrics

    @partial(jax.jit, static_argnames=("self",))
    def infer(self, state: OnlineState, u: Array, length: Array) -> Array:
        """Inference on a window: class predictions (B,)."""
        cfg = self.cfg
        f = cfg.f()
        j_seq = masking.apply_mask(self.mask, u)
        x = reservoir.run_reservoir(state.params.p, state.params.q, j_seq, f=f, lengths=length)
        r = dprr.compute_dprr(x, lengths=length)
        return jnp.argmax(r @ state.params.W.T + state.params.b, axis=-1)

    @partial(jax.jit, static_argnames=("self", "method"))
    def refresh_output(
        self, state: OnlineState, beta: Array, method: str = "cholesky_blocked"
    ) -> OnlineState:
        """Ridge re-solve of the output layer from the streamed (A, B)."""
        Wt = ridge.ridge_solve(
            state.ridge.A, ridge.regularize(state.ridge.B, beta), method
        )
        params = DFRParams(
            p=state.params.p, q=state.params.q, W=Wt[:, :-1], b=Wt[:, -1]
        )
        return dataclasses.replace(state, params=params)
