"""Online edge training + inference (paper Sec. 3.1): one fused step.

The paper's system processes a stream sample-by-sample, entirely on-device:

    reservoir forward -> DPRR -> (a) inference: y = W r + b
                               -> (b) training: truncated-bp SGD update of
                                      (p, q, W, b) AND streaming (A, B)
                                      accumulation; the Ridge solve runs
                                      periodically (or on demand) to refresh
                                      the output layer.

Everything below is a single jitted program per step - the TPU analogue of
"everything on the FPGA, no host round trips".

The module is organized as a *functional* core plus thin stateful wrappers:

  * ``online_step`` / ``online_infer`` / ``online_logits`` /
    ``refresh_output`` / ``reset_statistics`` - pure functions over
    ``OnlineState`` pytrees.  All of them vmap cleanly over a leading
    population axis (``OnlineEnsemble``) or a leading slot axis (the
    continuous-batching stream server in ``repro.runtime.stream_server``).
  * ``OnlineDFR``   - the single-stream system (thin jitted wrapper).
  * ``OnlineEnsemble`` - K independent members (jittered (p, q) seeds,
    shared mask) vmapped over the member axis, with online culling /
    re-seeding via the shared candidate machinery in
    ``repro.core.candidates`` - the offline population engine's protocol
    applied to a live serving ensemble.

Scale-out: (A, B) and the parameter grads are associative sums, so
``online_step(axis_names=...)`` psums them across the data axes
(``repro.distributed.sharding.data_axes()``) for data-parallel streams, and
the ensemble's member axis shards across devices via the ``member`` logical
axis in the sharding rule table (members are embarrassingly parallel).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import backprop, candidates, dprr, masking, reservoir, ridge
from repro.core.types import (Array, DFRConfig, DFRParams, QuantParams,
                              RidgeState)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OnlineState:
    """Carry of the online system (a pytree).

    Leaves may carry a leading member/slot axis: every pure function below
    is written for the single-system shapes and vmapped by the ensemble and
    stream-server wrappers.

    ``quant`` is the int8 serving fast-path state (``QuantParams``): inert
    zeros unless the serving stack runs with ``quantize='int8'``.  It rides
    the state tree so admission resets, retirement snapshots, donation and
    slot sharding all cover it for free; the fp32 math never reads it.
    """

    params: DFRParams
    ridge: RidgeState
    step: Array          # int32 counter
    loss_ema: Array      # scalar diagnostics
    quant: QuantParams   # int8 serving codes + scales (inert when fp32)
    # per-slot drift detector (retirement='adaptive'): fast/slow EMAs of
    # the serve step's 0/1 error rate.  Inert zeros in every other mode -
    # they ride the state tree so admission resets, retirement snapshots,
    # donation and slot sharding cover them for free (the QuantParams
    # pattern); the serving math never reads them.
    loss_fast: Array     # scalar fast error EMA (drift detector numerator)
    loss_slow: Array     # scalar slow error EMA (drift detector baseline)

    def tree_flatten(self):
        return (self.params, self.ridge, self.step, self.loss_ema,
                self.quant, self.loss_fast, self.loss_slow), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


# ---------------------------------------------------------------------------
# Pure functional API (vmappable / shard_map-able)
# ---------------------------------------------------------------------------


def init_state(cfg: DFRConfig, factor_beta: Optional[float] = None) -> OnlineState:
    """Fresh single-system state: paper init (p, q), zero readout + stats.

    With ``factor_beta`` set, the state additionally carries a *live*
    incremental Cholesky factor seeded for the empty system
    (``ridge.seed_factor``: chol(0 + beta I) = sqrt(beta) I), enabling the
    O(s^2) rank-1 maintenance path of ``online_serve_step`` and the
    triangular-solve fast path of ``refresh_output`` - no O(s^3)
    factorization ever runs for this stream.
    """
    rs = RidgeState.zeros(cfg.s, cfg.n_classes, cfg.dtype)
    if factor_beta is not None:
        rs = RidgeState(
            A=rs.A, B=rs.B, count=rs.count,
            Lt=ridge.seed_factor(cfg.s, factor_beta, cfg.dtype),
            factor_beta=jnp.asarray(factor_beta, cfg.dtype),
        )
    return OnlineState(
        params=DFRParams.init(cfg),
        ridge=rs,
        step=jnp.zeros((), jnp.int32),
        loss_ema=jnp.zeros((), cfg.dtype),
        quant=QuantParams.zeros(cfg.n_classes, cfg.n_rep),
        loss_fast=jnp.zeros((), cfg.dtype),
        loss_slow=jnp.zeros((), cfg.dtype),
    )


def reset_statistics(
    state: OnlineState,
    factor_beta: Optional[float] = None,
    forget: Optional[Array] = None,
) -> OnlineState:
    """Zero the Ridge sufficient statistics, keeping (p, q, W, b) and the
    step counter.

    This is the phase-switch primitive of the paper's protocol: once the
    reservoir parameters stop moving (truncated-bp phase ends), the (A, B)
    accumulated under the *old* features are stale and must be restarted.
    Pure and shape-preserving, so it vmaps over member/slot axes and can be
    applied selectively with ``jax.tree_util.tree_map`` + ``jnp.where``.

    The zeroed ``factor_beta`` also drops any live incremental factor (it
    factored the stale B); pass ``factor_beta`` to re-seed a fresh live
    factor for the restarted statistics, as ``init_state`` does.

    ``forget`` (exclusive with ``factor_beta``) is the *soft* reset: one
    forgetting-factor application that scales (A, B) - and any live factor
    consistently, ``Lt`` by sqrt(lambda) and ``factor_beta`` by lambda, so
    ``Lt^T Lt == B + factor_beta I`` is preserved exactly - instead of
    zeroing.  ``forget=1.0`` is bit-for-bit the identity (multiplying by
    1.0 changes no value); the sample ``count`` keeps the raw number of
    folded samples either way.
    """
    if forget is not None and factor_beta is not None:
        raise ValueError(
            "reset_statistics: factor_beta (hard reset re-seed) and forget "
            "(soft decaying reset) are exclusive - the soft reset keeps the "
            "existing decayed prior"
        )
    if forget is not None:
        try:
            lam_concrete = float(forget)
        except TypeError:          # traced value: the caller holds the
            lam_concrete = None    # (0, 1] contract (as StreamServer does)
        if lam_concrete is not None and not 0.0 < lam_concrete <= 1.0:
            # lambda = 0 would zero the live factor, and the next
            # maintained fold divides by its zero diagonal -> NaNs
            raise ValueError(
                f"forget must be in (0, 1], got {lam_concrete!r}"
            )
        lam = jnp.asarray(forget, state.ridge.B.dtype)
        rs = RidgeState(
            A=state.ridge.A * lam,
            B=state.ridge.B * lam,
            count=state.ridge.count,
            Lt=state.ridge.Lt * jnp.sqrt(lam),
            factor_beta=state.ridge.factor_beta * lam,
        )
        return dataclasses.replace(state, ridge=rs)
    rs = jax.tree_util.tree_map(jnp.zeros_like, state.ridge)
    if factor_beta is not None:
        rs = RidgeState(
            A=rs.A, B=rs.B, count=rs.count,
            Lt=ridge.seed_factor(rs.B.shape[-1], factor_beta, rs.B.dtype),
            factor_beta=jnp.asarray(factor_beta, rs.B.dtype),
        )
    return dataclasses.replace(state, ridge=rs)


# retirement='adaptive': loss-EMA breakpoint detector rates (per serving
# step, not per sample - a step folds up to `window` samples).  The fast
# EMA tracks the current regime over a few steps; the slow EMA is the
# baseline the break is measured against.  Server-tunable knobs (trip
# ratio, fire-time lambda, warmup) live on StreamServer; these two rates
# are the detector's fixed time constants.
ADAPT_FAST_ALPHA = 0.3
# the slow baseline is asymmetric: it chases improvements quickly (the
# noisy just-admitted phase seeds both EMAs near error 1.0 and the
# baseline must fall to the converged error before the detector can see a
# jump over it) but degrades only glacially, so at a drift point it stays
# anchored at the pre-drift error while the fast EMA runs away from it
ADAPT_SLOW_ALPHA_DOWN = 0.15
ADAPT_SLOW_ALPHA_UP = 0.01
# additive trip margin on the error-rate EMAs: guards against false fires
# when the slow baseline sits near zero (a near-perfect slot), where any
# multiplicative ratio alone would trip on the first stray miss
ADAPT_MARGIN = 0.25
# floor applied to the slow baseline after its first update, so "slow == 0"
# stays an unambiguous not-yet-initialized marker even for a slot whose
# first observed window had zero error
_ADAPT_EPS = 1e-6


def adaptive_anneal(
    states: OnlineState,
    step_err: Array,    # (S,) this step's serving error rate (1 - acc)
    update: Array,      # (S,) bool: slot folded live frozen-phase samples
    armed: Array,       # (S,) bool: slot past its detector warmup
    ratio: float,
    forget: Array,      # scalar lambda in (0, 1] applied to a tripped slot
) -> Tuple[OnlineState, Array]:
    """Per-slot drift detection + soft statistics anneal (batched).

    The slot-batched composition of ``reset_statistics(forget=...)`` with
    an in-step breakpoint detector: each slot keeps fast/slow EMAs of its
    serve-step *error rate* (the two detector leaves on ``OnlineState``);
    a slot whose fast EMA exceeds ``ratio * slow + ADAPT_MARGIN`` *trips*
    and has its Ridge statistics annealed by the traced per-slot forget
    vector ``lam = where(trip, forget, 1.0)`` - (A, B) and
    ``factor_beta`` scale by lam, any live factor by sqrt(lam), so
    ``Lt^T Lt == B + factor_beta I`` survives exactly (the
    ``reset_statistics`` soft-reset contract).  Tripping re-arms the
    detector by snapping the slow baseline to the fast EMA, so it cannot
    re-fire until the error rises again *relative to the post-drift
    regime*.

    The detector watches the 0/1 serving error (DDM-style) rather than
    the cross-entropy loss the serve step also reports: near a drift
    point the saturating CE loss moves by ~20% while the error rate jumps
    several-fold, so the error signal separates drift from stationary
    noise at far safer thresholds.

    Bitwise-silence contract: the anneal is ``lax.cond``-gated on any slot
    tripping, so a step where no detector fires leaves ``ridge`` (and
    everything downstream of it) bit-for-bit untouched - only the two
    detector leaves move.  EMAs update only where ``update`` is set (live
    slots folding frozen-phase samples); the first such step seeds both
    EMAs with the observed error.
    """
    fast0, slow0 = states.loss_fast, states.loss_slow
    init = update & (slow0 <= 0)
    fa = jnp.asarray(ADAPT_FAST_ALPHA, fast0.dtype)
    sa = jnp.where(
        step_err <= slow0,
        jnp.asarray(ADAPT_SLOW_ALPHA_DOWN, slow0.dtype),
        jnp.asarray(ADAPT_SLOW_ALPHA_UP, slow0.dtype),
    )
    fast = jnp.where(
        init, step_err,
        jnp.where(update, (1.0 - fa) * fast0 + fa * step_err, fast0),
    )
    slow = jnp.where(
        init, step_err,
        jnp.where(update, (1.0 - sa) * slow0 + sa * step_err, slow0),
    )
    slow = jnp.where(
        update, jnp.maximum(slow, jnp.asarray(_ADAPT_EPS, slow.dtype)), slow
    )
    trip = (
        update & armed & ~init
        & (fast > jnp.asarray(ratio, fast.dtype) * slow
           + jnp.asarray(ADAPT_MARGIN, fast.dtype))
    )
    lam = jnp.where(trip, jnp.asarray(forget, fast.dtype), 1.0)  # (S,)

    def _anneal(rs: RidgeState) -> RidgeState:
        lam2 = lam[:, None, None]
        return RidgeState(
            A=rs.A * lam2, B=rs.B * lam2, count=rs.count,
            Lt=rs.Lt * jnp.sqrt(lam)[:, None, None],
            factor_beta=rs.factor_beta * lam,
        )

    ridge_state = jax.lax.cond(
        jnp.any(trip), _anneal, lambda rs: rs, states.ridge
    )
    slow = jnp.where(trip, fast, slow)
    return dataclasses.replace(
        states, ridge=ridge_state, loss_fast=fast, loss_slow=slow
    ), trip


def online_logits(
    cfg: DFRConfig,
    mask: Array,
    state: OnlineState,
    u: Array,        # (B, T, n_in)
    length: Array,   # (B,)
) -> Array:
    """Readout logits on a window: (B, Ny)."""
    f = cfg.f()
    j_seq = masking.apply_mask(mask, u)
    x = reservoir.run_reservoir(
        state.params.p, state.params.q, j_seq, f=f, lengths=length
    )
    r = dprr.compute_dprr(x, lengths=length)
    return r @ state.params.W.T + state.params.b


def online_infer(
    cfg: DFRConfig,
    mask: Array,
    state: OnlineState,
    u: Array,
    length: Array,
) -> Array:
    """Inference on a window: class predictions (B,)."""
    return jnp.argmax(online_logits(cfg, mask, state, u, length), axis=-1)


def online_step(
    cfg: DFRConfig,
    mask: Array,
    state: OnlineState,
    u: Array,        # (B, T, n_in) window of streamed samples
    length: Array,   # (B,)
    label: Array,    # (B,) int32
    lr_res: Array,
    lr_out: Array,
    axis_names: Sequence[str] = (),
    weight: Optional[Array] = None,
) -> Tuple[OnlineState, Dict[str, Array]]:
    """One online training step: SGD update + (A, B) accumulation.

    With ``axis_names`` (inside ``shard_map`` over the data axes), the loss,
    grads, (A, B) increments and sample count are psum-reduced so every
    shard applies the identical global update - the sums are associative
    (paper Eq. 38), so this is exact, not an approximation.

    ``weight`` is an optional (B,) 0/1 live-sample mask for fixed-shape
    batching (the stream server's tail windows): dead samples contribute
    nothing to the loss, the grads, the (A, B) statistics or the count.
    ``weight=None`` is the exact unweighted path.
    """
    f = cfg.f()
    axis_names = tuple(axis_names)

    def _psum(x):
        return jax.lax.psum(x, axis_names) if axis_names else x

    j_seq = masking.apply_mask(mask, u)
    onehot = jax.nn.one_hot(label, cfg.n_classes, dtype=cfg.dtype)
    if weight is None:
        loss_fn = backprop.loss_from_logits
        n_live = jnp.asarray(u.shape[0], cfg.dtype)
    else:
        weight = weight.astype(cfg.dtype)
        loss_fn = lambda lg, oh: weight * backprop.loss_from_logits(lg, oh)  # noqa: E731
        n_live = jnp.sum(weight)
    loss, g = backprop.grads_truncated(
        state.params, j_seq, onehot, f, lengths=length, loss_fn=loss_fn
    )
    loss = _psum(loss)
    g = jax.tree_util.tree_map(_psum, g)
    bsz = jnp.maximum(_psum(n_live), 1.0)
    inv = 1.0 / bsz
    params = backprop.apply_sgd(state.params, g, lr_res, lr_out, inv_batch=inv)
    # streaming sufficient statistics with the *updated* reservoir params
    x = reservoir.run_reservoir(params.p, params.q, j_seq, f=f, lengths=length)
    r = dprr.compute_dprr(x, lengths=length)
    rt = dprr.r_tilde(r)
    # 0/1 weights scale rt once: both the A contraction (onehot . rt) and the
    # B outer product (rt . rt, where w^2 = w) drop dead samples exactly
    rt_acc = rt if weight is None else rt * weight[:, None]
    dA, dB = ridge.accumulate_ab(
        jnp.zeros_like(state.ridge.A), jnp.zeros_like(state.ridge.B), rt_acc, onehot
    )
    new = OnlineState(
        params=params,
        ridge=RidgeState(
            A=state.ridge.A + _psum(dA),
            B=state.ridge.B + _psum(dB),
            count=state.ridge.count + _psum(n_live).astype(state.ridge.count.dtype),
            # B moved (and psums across shards) without rotating L: any live
            # incremental factor is stale now - invalidate it.  Rank-1
            # maintenance lives in online_serve_step, the per-sample path.
            Lt=state.ridge.Lt,
            factor_beta=jnp.zeros_like(state.ridge.factor_beta),
        ),
        step=state.step + 1,
        loss_ema=0.99 * state.loss_ema + 0.01 * loss * inv,
        quant=state.quant,
        loss_fast=state.loss_fast,
        loss_slow=state.loss_slow,
    )
    logits = r @ params.W.T + params.b
    hits = (jnp.argmax(logits, -1) == label).astype(jnp.float32)
    if weight is not None:
        hits = hits * weight
    metrics = {
        "loss": loss * inv,
        "acc": _psum(jnp.sum(hits)) / bsz.astype(jnp.float32),
    }
    return new, metrics


def online_serve_step(
    cfg: DFRConfig,
    mask: Array,
    state: OnlineState,
    u: Array,        # (B, T, n_in) window of streamed samples
    length: Array,   # (B,)
    label: Array,    # (B,) int32
    lr: Array,       # scalar slot learning rate (0 in the frozen phase)
    weight: Array,   # (B,) 0/1 live-sample mask
    accumulate: Array,  # scalar 0/1: accumulate (A, B) this step?
    maintain_factor: "bool | str" = False,  # False | True | 'defer'
    forget: Optional[Array] = None,  # lambda in (0, 1]: decay per sample
    train: bool = True,
    track_state_absmax: bool = False,
    fused: bool = False,
) -> Tuple[OnlineState, Array, Dict[str, Array]]:
    """Fused infer-before-update + train step for the serving path.

    One forward pass serves three consumers (the advantage a fused serving
    step has over separate ``infer``/``step`` calls):

      * the returned ``logits`` are the infer-before-update predictions
        (old parameters - the honest online metric),
      * the truncated-BP gradients reuse the same pass
        (``backprop.grads_truncated_from_aux``: the truncation
        stop_gradients everything the forward produced, so this is exact),
      * the (A, B) statistics reuse ``aux.r`` - gated by ``accumulate``,
        which the stream server sets only in the frozen-reservoir phase
        where the parameters producing ``aux.r`` are by construction the
        post-update parameters.  (Accumulating during the adaptation phase
        would be discarded at the phase boundary anyway - see
        ``reset_statistics``.)

    ``maintain_factor`` (static) compiles in the incremental Cholesky
    engine: every r~ row folded into B is simultaneously rotated into the
    live factor with an O(s^2) ``cholupdate`` (zero-gated rows are exact
    no-ops), keeping  L L^T = B + factor_beta I  current so the next
    refresh is two triangular solves instead of a factorization.  The
    caller must have seeded a live factor (``init_state(factor_beta=...)``)
    - the stream server's ``refresh_mode='incremental'`` invariant.  With
    ``maintain_factor=False`` no factor math is compiled and any live
    factor is invalidated once statistics move.

    ``maintain_factor='defer'`` keeps the factor valid but does NOT rotate
    it; the exact gated rows are returned as ``metrics['rt_rows']`` for the
    caller to fold (``ridge.cholupdate_window_t``) *outside* its
    select/cond plumbing.  This exists for the stream server: folding
    inside its admission/liveness conds keeps the pre-sweep factor alive
    across the rotation loop, which forces XLA to copy the (S, s, s)
    buffer every iteration instead of updating in place - deferring the
    fold past the conds restores the in-place loop (~2.5x per-step at
    S=32, Nx=16).  Numerically identical to the inline fold: dead/tail
    rows are zero-gated no-ops either way.

    ``forget`` (static None, or a traced lambda in (0, 1]) is the
    forgetting-factor retirement: *before each accumulated sample's fold*,
    (A, B) are scaled by lambda and the live factor by sqrt(lambda), the
    exponentially-weighted RLS recursion

        B <- lambda B + r~ r~^T,   A <- lambda A + onehot x r~.

    The regularizing prior decays with everything else (``factor_beta``
    picks up the same lambda^m), so the decomposition stays consistent:
    scaling commutes with the rank-1 rotation, and
    ``Lt^T Lt == B + factor_beta I`` keeps holding - exactly in real
    arithmetic, to fp rounding in practice (the (A, B) side applies
    closed-form lambda powers, the factor side one sqrt(lambda) per row;
    the interleaved property battery pins the tolerance).  Decay is
    applied once per *accumulated live sample* (dead/tail rows and
    adaptation-phase windows decay nothing), so its meaning is independent
    of the serving window size.  The equivalence contract: ``forget=1.0``
    is bit-for-bit the ``forget=None`` path (every scaling is a multiply
    by exactly 1.0), and ``forget=None`` compiles no decay math at all.
    With ``maintain_factor='defer'`` the per-row factor scalings are
    returned as ``metrics['fold_scale']`` (sqrt(lambda) for live rows,
    exactly 1.0 for gated rows) for the caller's
    ``ridge.cholupdate_window_t_decay`` fold.

    ``train`` (static) compiles in the truncated-BP machinery.  With
    ``train=False`` no gradient or SGD math is compiled: the parameters
    pass through untouched and the loss is evaluated as the same truncated
    objective's primal (``backprop.truncated_loss_from_aux``).  This is
    exactly the ``lr = 0`` step up to op scheduling: SGD with a zero
    learning rate subtracts exactly 0 from every (finite-gradient, already
    range-clamped) parameter, so the stream server cond-gates the whole
    backward out of its steady state (every live slot frozen) without
    changing the episode served.

    ``track_state_absmax`` (static) compiles in the int8 calibration
    statistic: ``quant.x_absmax`` picks up the max |x| over the window's
    live boundary states (``aux.x_last``/``aux.x_prev`` - the states the
    shared forward already materializes).  Off (the default) no quant leaf
    moves and no extra math is compiled, keeping the fp32 serving program
    identical to the pre-quantization build.

    ``fused`` (static) routes the shared forward through the fused
    reservoir->DPRR kernel path (``backprop.forward_fused``) that never
    materializes the state sequence.  The truncated gradients, statistics
    and calibration all consume the same ForwardAux fields, so nothing
    downstream changes.  Default False: the fused DPRR reduction reorders
    fp accumulation, and the fp32 serving episode is regression-pinned
    bitwise to the PR-6 golden - opt in per server, not globally.

    Returns (new state, logits (B, Ny), metrics).
    """
    f = cfg.f()
    j_seq = masking.apply_mask(mask, u)
    onehot = jax.nn.one_hot(label, cfg.n_classes, dtype=cfg.dtype)
    fwd = backprop.forward_fused if fused else backprop.forward
    aux = fwd(state.params, j_seq, f, lengths=length)

    w = weight.astype(cfg.dtype)
    loss_fn = lambda lg, oh: w * backprop.loss_from_logits(lg, oh)  # noqa: E731
    n_live = jnp.maximum(jnp.sum(w), 1.0)
    inv = 1.0 / n_live
    if train:
        loss, g = backprop.grads_truncated_from_aux(
            state.params, aux, onehot, f, loss_fn=loss_fn
        )
        params = backprop.apply_sgd(state.params, g, lr, lr, inv_batch=inv)
    else:
        loss = backprop.truncated_loss_from_aux(
            state.params, aux, onehot, f, loss_fn
        )
        params = state.params

    acc = accumulate.astype(cfg.dtype)
    live = w * acc                              # (B,) 0/1 accumulated rows
    rt = dprr.r_tilde(aux.r) * live[:, None]
    if forget is None:
        A_base, B_base = state.ridge.A, state.ridge.B
        decay = None
        rt_acc, oh_acc = rt, onehot
        fold_scale = None
    else:
        lam = jnp.asarray(forget, cfg.dtype)
        m = jnp.sum(live)
        # suffix_t: live rows folded strictly after row t - the later a
        # sample lands, the less it has decayed.  Each row's (A, B)
        # contribution carries lambda^suffix, split sqrt/sqrt between the
        # two accumulate_ab factors; the carried-over statistics decay by
        # the full lambda^m.  lambda=1 makes every power exactly 1.0.
        suffix = jnp.cumsum(live[::-1])[::-1] - live
        half = lam ** (0.5 * suffix)
        rt_acc = rt * half[:, None]
        oh_acc = onehot * half[:, None]
        decay = lam ** m
        A_base, B_base = state.ridge.A * decay, state.ridge.B * decay
        fold_scale = jnp.where(live > 0, jnp.sqrt(lam), jnp.ones_like(live))
    dA, dB = ridge.accumulate_ab(
        jnp.zeros_like(state.ridge.A), jnp.zeros_like(state.ridge.B),
        rt_acc, oh_acc,
    )
    if maintain_factor == "defer":
        # caller folds rt into the factor itself (see docstring)
        Lt = state.ridge.Lt
        factor_beta = state.ridge.factor_beta
    elif maintain_factor:
        # fold the same gated rows into the live factor: one O(s^2) rotation
        # sweep per streamed sample (zero rows are exact no-ops, so dead
        # samples and adaptation-phase windows leave the factor untouched -
        # in lockstep with the gated B accumulation above)
        if forget is None:
            Lt = ridge.cholupdate_window_t(state.ridge.Lt, rt)
        else:
            Lt = ridge.cholupdate_window_t_decay(state.ridge.Lt, rt, fold_scale)
        factor_beta = state.ridge.factor_beta
    else:
        Lt = state.ridge.Lt
        # statistics move without rotating the factor: drop any live factor
        factor_beta = jnp.where(
            acc * jnp.sum(w) > 0,
            jnp.zeros_like(state.ridge.factor_beta),
            state.ridge.factor_beta,
        )
    if forget is not None and maintain_factor:
        # the prior decays with the data (exponentially-weighted RLS), so
        # the factor keeps factoring  B + factor_beta I  exactly
        factor_beta = factor_beta * decay
    if track_state_absmax:
        # int8 calibration: running max |x| over the live boundary states
        # the forward already produced (weight-gated so dead/tail rows are
        # exact no-ops; scales fold from this at refresh boundaries)
        amax = jnp.maximum(
            jnp.max(jnp.abs(aux.x_last) * w[:, None]),
            jnp.max(jnp.abs(aux.x_prev) * w[:, None]),
        ).astype(state.quant.x_absmax.dtype)
        quant = dataclasses.replace(
            state.quant, x_absmax=jnp.maximum(state.quant.x_absmax, amax)
        )
    else:
        quant = state.quant
    new = OnlineState(
        params=params,
        ridge=RidgeState(
            A=A_base + dA,
            B=B_base + dB,
            count=state.ridge.count
            + (acc * jnp.sum(w)).astype(state.ridge.count.dtype),
            Lt=Lt,
            factor_beta=factor_beta,
        ),
        step=state.step + 1,
        loss_ema=0.99 * state.loss_ema + 0.01 * loss * inv,
        quant=quant,
        loss_fast=state.loss_fast,
        loss_slow=state.loss_slow,
    )
    hits = (jnp.argmax(aux.logits, -1) == label).astype(jnp.float32) * w
    metrics = {"loss": loss * inv, "acc": jnp.sum(hits) * inv}
    if maintain_factor == "defer":
        metrics["rt_rows"] = rt
        if forget is not None:
            metrics["fold_scale"] = fold_scale
    return new, aux.logits, metrics


def refresh_output(
    state: OnlineState, beta: Array, method: str = "cholesky_blocked"
) -> OnlineState:
    """Ridge re-solve of the output layer from the streamed (A, B).

    Fast path: when the state carries a live incremental factor for this
    exact ``beta`` (``RidgeState.factor_beta``), the solve is two
    triangular substitutions against L - O(s^2 Ny), no factorization
    (``lax.cond`` executes only the taken branch).  Otherwise the full
    O(s^3) pipeline of ``ridge.ridge_solve`` runs, so a mismatched beta
    (e.g. a regularization sweep over frozen statistics) stays correct.
    """
    beta = jnp.asarray(beta, state.ridge.B.dtype)

    def _from_factor(_):
        return ridge.ridge_solve_from_factor_t(state.ridge.A, state.ridge.Lt)

    def _full(_):
        return ridge.ridge_solve(
            state.ridge.A, ridge.regularize(state.ridge.B, beta), method
        )

    live = (state.ridge.factor_beta > 0) & (state.ridge.factor_beta == beta)
    Wt = jax.lax.cond(live, _from_factor, _full, None)
    params = DFRParams(
        p=state.params.p, q=state.params.q, W=Wt[:, :-1], b=Wt[:, -1]
    )
    return dataclasses.replace(state, params=params)


def refresh_output_batched(state: OnlineState, beta: Array) -> OnlineState:
    """Batched Ridge refresh over a leading member/slot axis.

    One batched Cholesky factors every member's (s, s) system in a single
    XLA program (``ridge.ridge_cholesky_batched``) - the stream server's
    periodic refresh of all live slots is one call, not a slot loop.
    """
    Wt = ridge.ridge_cholesky_batched(
        state.ridge.A, ridge.regularize(state.ridge.B, beta)
    )
    params = DFRParams(
        p=state.params.p, q=state.params.q, W=Wt[..., :, :-1], b=Wt[..., :, -1]
    )
    return dataclasses.replace(state, params=params)


def scatter_readout_rows(
    state: OnlineState, Wt: Array, eligible_rows: Array, rows: Array
) -> OnlineState:
    """Write refreshed readouts ``Wt`` (R, Ny, s) into slot rows ``rows`` of
    a slot-axis state where ``eligible_rows`` (R,) holds; everything else
    (and every non-readout leaf) is untouched - a refresh only ever moves
    (W, b).  ``rows`` must be duplicate-free (``RefreshCohorts`` pads its
    fixed-shape schedules with distinct non-cohort indices, so an
    ineligible pad row writes its own current value back - a no-op)."""
    W_rows = jnp.where(
        eligible_rows[:, None, None], Wt[..., :, :-1], state.params.W[rows]
    )
    b_rows = jnp.where(eligible_rows[:, None], Wt[..., :, -1],
                       state.params.b[rows])
    params = dataclasses.replace(
        state.params,
        W=state.params.W.at[rows].set(W_rows),
        b=state.params.b.at[rows].set(b_rows),
    )
    return dataclasses.replace(state, params=params)


def refresh_output_rows(
    state: OnlineState, beta: Array, rows: Array, eligible_rows: Array
) -> OnlineState:
    """Recompute-mode cohort refresh of a slot-axis state: gather the due
    rows, run the batched (s, s) Cholesky re-factorization over just those,
    scatter the refreshed readouts back.  With ``rows = arange(S)`` and all
    rows eligible this is leaf-for-leaf ``refresh_output_batched``."""
    Wt = ridge.ridge_cholesky_batched(
        state.ridge.A[rows],
        ridge.regularize(state.ridge.B[rows], beta),
    )
    return scatter_readout_rows(state, Wt, eligible_rows, rows)


def refresh_output_factor_rows(
    state: OnlineState, rows: Array, eligible_rows: Array
) -> OnlineState:
    """Incremental-mode cohort refresh of a slot-axis state: the due rows
    carry live factors of B + beta I (maintained rank-1 inside the serve
    step), so the refresh is one batched pair of blocked triangular
    substitutions - O(s^2 Ny) per slot, no factorization.  Beta is baked
    into the live factor at seeding."""
    Wt = ridge.ridge_solve_from_factor_t_batched(
        state.ridge.A[rows], state.ridge.Lt[rows]
    )
    return scatter_readout_rows(state, Wt, eligible_rows, rows)


def fold_quant_rows(
    state: OnlineState, rows: Array, eligible_rows: Array
) -> OnlineState:
    """Fold fresh int8 serving scales for slot rows ``rows`` of a slot-axis
    state where ``eligible_rows`` holds (same scatter contract as
    ``scatter_readout_rows``).

    Runs at ridge-refresh boundaries - the only place W moves in the
    serving steady state, so requantizing there keeps ``Wq * w_scale ~= W``
    without any per-step requantization cost.  ``w_scale`` comes from the
    freshly refreshed readout row, ``x_scale`` from the running
    ``x_absmax`` calibration tracked by ``online_serve_step``.  The scales
    are strictly positive after the first fold, which is what arms the
    server's quantized logits path for that slot.
    """
    from repro.kernels import ops as kops  # local: kernels import core

    q = state.quant
    el = eligible_rows
    W_rows = state.params.W[rows].astype(jnp.float32)       # (R, Ny, Nr)
    w_scale = kops.symmetric_scale(
        jnp.max(jnp.abs(W_rows), axis=(-2, -1)))            # (R,)
    Wq = kops.quantize_symmetric(W_rows, w_scale[:, None, None])
    x_scale = kops.symmetric_scale(q.x_absmax[rows])        # (R,)
    quant = QuantParams(
        Wq=q.Wq.at[rows].set(
            jnp.where(el[:, None, None], Wq, q.Wq[rows])),
        w_scale=q.w_scale.at[rows].set(
            jnp.where(el, w_scale, q.w_scale[rows])),
        x_scale=q.x_scale.at[rows].set(
            jnp.where(el, x_scale, q.x_scale[rows])),
        x_absmax=q.x_absmax,
    )
    return dataclasses.replace(state, quant=quant)


def _state_logical_axes(*leading: str) -> OnlineState:
    """``OnlineState``-shaped pytree of logical-axes tuples: every leaf
    leads with ``leading`` (one name per stacked leading dim), trailing
    dims replicated.  Feed to ``repro.distributed.sharding``."""
    lead = tuple(leading)
    return OnlineState(
        params=DFRParams(
            p=lead, q=lead,
            W=lead + (None, None), b=lead + (None,),
        ),
        ridge=RidgeState(
            A=lead + (None, None), B=lead + (None, None),
            count=lead,
            Lt=lead + (None, None), factor_beta=lead,
        ),
        step=lead,
        loss_ema=lead,
        quant=QuantParams(
            Wq=lead + (None, None),
            w_scale=lead, x_scale=lead, x_absmax=lead,
        ),
        loss_fast=lead,
        loss_slow=lead,
    )


def ensemble_logical_axes() -> OnlineState:
    """Logical-axis pytree of an ensemble ``OnlineState`` for the sharding
    rule table: every leaf leads with the ``member`` axis (sharded across
    devices - members are embarrassingly parallel), trailing dims
    replicated.  Feed to ``repro.distributed.sharding.guarded_shardings``.
    """
    return _state_logical_axes("member")


def slot_logical_axes() -> OnlineState:
    """Logical-axis pytree of a slot-batched ``OnlineState`` (the stream
    server's state tree): every leaf leads with the ``slot`` axis - slots
    are independent streams, embarrassingly parallel across the serving
    mesh (``launch.mesh.make_slot_mesh``)."""
    return _state_logical_axes("slot")


def ensemble_slot_logical_axes() -> OnlineState:
    """Logical-axis pytree for an ensemble-of-slots state (leaves stacked
    ``(S, K, ...)``): ``slot`` leads, ``member`` second, so a combined
    ``("slot", "member")`` serving mesh shards both ways at once and the
    production mesh's uniqueness guard gives ``slot`` the data axes."""
    return _state_logical_axes("slot", "member")


# ---------------------------------------------------------------------------
# Single-stream wrapper (the paper's one-device system)
# ---------------------------------------------------------------------------


class OnlineDFR:
    """Fused online train/infer stepper for a fixed-length stream window."""

    def __init__(self, cfg: DFRConfig, mask: Optional[Array] = None):
        self.cfg = cfg
        if mask is None:
            mask = masking.make_mask(
                jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype
            )
        self.mask = mask

    def init(self) -> OnlineState:
        return init_state(self.cfg)

    @partial(jax.jit, static_argnames=("self", "axis_names"))
    def step(
        self,
        state: OnlineState,
        u: Array,
        length: Array,
        label: Array,
        lr_res: Array,
        lr_out: Array,
        axis_names: Sequence[str] = (),
    ) -> Tuple[OnlineState, dict]:
        """One online training step: SGD update + (A, B) accumulation."""
        return online_step(
            self.cfg, self.mask, state, u, length, label, lr_res, lr_out,
            axis_names=axis_names,
        )

    @partial(jax.jit, static_argnames=("self",))
    def infer(self, state: OnlineState, u: Array, length: Array) -> Array:
        """Inference on a window: class predictions (B,)."""
        return online_infer(self.cfg, self.mask, state, u, length)

    @partial(jax.jit, static_argnames=("self", "method"))
    def refresh_output(
        self, state: OnlineState, beta: Array, method: str = "cholesky_blocked"
    ) -> OnlineState:
        """Ridge re-solve of the output layer from the streamed (A, B)."""
        return refresh_output(state, beta, method)

    @partial(jax.jit, static_argnames=("self",))
    def reset_statistics(self, state: OnlineState) -> OnlineState:
        """Restart the (A, B) accumulation (phase switch)."""
        return reset_statistics(state)


# ---------------------------------------------------------------------------
# Population-parallel online ensemble
# ---------------------------------------------------------------------------


class OnlineEnsemble:
    """K independent online DFR members vmapped over the member axis.

    All members share the fixed random mask (so the masked input j(k) is
    computed once per member by the same program) and see the same stream;
    they differ in their (p, q) seeds - member 0 is the exact paper init,
    members 1..K-1 are log-normal-jittered clones (``candidates.
    seed_candidates``).  ``step``/``infer_members`` are one vmapped jitted
    program over the member axis; ``infer`` combines members by averaging
    softmax probabilities (majority-of-evidence vote).

    ``cull`` applies the offline population engine's selection protocol to
    the live ensemble: members are ranked by loss EMA, losers are re-seeded
    near survivors with jittered (p, q) (``candidates.survivor_parents`` /
    ``candidates.jitter_clones``), and the re-seeded slots' Ridge statistics
    are restarted (their features changed, so the old (A, B) are stale -
    the online analogue of the offline engine re-evaluating from scratch).

    A K=1 ensemble is numerically identical to ``OnlineDFR`` step-for-step
    (the parity oracle in tests/test_stream_server.py).
    """

    def __init__(
        self,
        cfg: DFRConfig,
        n_members: int,
        mask: Optional[Array] = None,
        seed: int = 0,
        seed_jitter: float = 0.1,
    ):
        self.cfg = cfg
        self.n_members = int(n_members)
        self.seed = seed
        self.seed_jitter = seed_jitter
        if mask is None:
            mask = masking.make_mask(
                jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype
            )
        self.mask = mask

    def init(self, key: Optional[Array] = None) -> OnlineState:
        """Stacked ensemble state: every leaf leads with the K member axis."""
        cfg, k = self.cfg, self.n_members
        if key is None:
            key = jax.random.PRNGKey(self.seed)
        ps, qs = candidates.seed_candidates(
            key, k, cfg.p_init, cfg.q_init, self.seed_jitter, dtype=cfg.dtype
        )
        single = init_state(cfg)
        stacked = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf, (k, *leaf.shape)), single
        )
        params = DFRParams(p=ps, q=qs, W=stacked.params.W, b=stacked.params.b)
        return dataclasses.replace(stacked, params=params)

    @partial(jax.jit, static_argnames=("self",))
    def step(
        self,
        state: OnlineState,
        u: Array,
        length: Array,
        label: Array,
        lr_res: Array,
        lr_out: Array,
    ) -> Tuple[OnlineState, dict]:
        """All K members train on the shared window in one vmapped program;
        metrics come back per-member, shape (K,)."""
        return jax.vmap(
            lambda st: online_step(
                self.cfg, self.mask, st, u, length, label, lr_res, lr_out
            )
        )(state)

    @partial(jax.jit, static_argnames=("self",))
    def logits_members(self, state: OnlineState, u: Array, length: Array) -> Array:
        """Per-member logits (K, B, Ny)."""
        return jax.vmap(
            lambda st: online_logits(self.cfg, self.mask, st, u, length)
        )(state)

    @partial(jax.jit, static_argnames=("self",))
    def infer_members(self, state: OnlineState, u: Array, length: Array) -> Array:
        """Per-member predictions (K, B) (the K=1 parity surface)."""
        return jnp.argmax(self.logits_members(state, u, length), axis=-1)

    @partial(jax.jit, static_argnames=("self",))
    def infer(self, state: OnlineState, u: Array, length: Array) -> Array:
        """Ensemble predictions (B,): mean of member softmax probabilities.

        For K=1 this reduces to argmax of the single member's logits
        (softmax is monotone per row), preserving OnlineDFR parity.
        """
        probs = jax.nn.softmax(self.logits_members(state, u, length), axis=-1)
        return jnp.argmax(jnp.mean(probs, axis=0), axis=-1)

    @partial(jax.jit, static_argnames=("self",))
    def refresh_output(self, state: OnlineState, beta: Array) -> OnlineState:
        """Batched Ridge refresh of every member (one batched Cholesky)."""
        return refresh_output_batched(state, beta)

    @partial(jax.jit, static_argnames=("self", "survive_frac", "jitter"))
    def cull(
        self,
        state: OnlineState,
        key: Array,
        survive_frac: float = 0.5,
        jitter: float = 0.15,
    ) -> OnlineState:
        """Rank members by loss EMA, re-seed the losers near survivors.

        Survivors keep everything; each culled slot inherits its parent's
        full state, gets jittered (p, q), and restarts its Ridge statistics
        (stale under the moved reservoir parameters).  The restart follows
        ``reset_statistics(factor_beta=...)``: a culled row that inherited a
        *live* incremental factor gets a fresh ``ridge.seed_factor`` seed
        (chol(0 + beta I) = sqrt(beta) I) rather than an all-zero ``Lt``,
        which would be a singular fake factor violating
        ``Lt^T Lt == B + factor_beta I`` and NaN on the next maintained fold.
        """
        parent, keep, _ = candidates.survivor_parents(
            state.loss_ema, survive_frac
        )
        inherited = jax.tree_util.tree_map(lambda leaf: leaf[parent], state)
        new_p, new_q = candidates.jitter_clones(
            key, inherited.params.p, inherited.params.q, keep, jitter
        )
        params = dataclasses.replace(inherited.params, p=new_p, q=new_q)

        def _keep_or_zero(leaf):
            k_mask = keep.reshape((-1,) + (1,) * (leaf.ndim - 1))
            return jnp.where(k_mask, leaf, jnp.zeros_like(leaf))

        zeroed = jax.tree_util.tree_map(_keep_or_zero, inherited.ridge)
        beta_inh = inherited.ridge.factor_beta            # (K,)
        s = inherited.ridge.Lt.shape[-1]
        seeded_Lt = jnp.sqrt(beta_inh)[:, None, None] * jnp.eye(
            s, dtype=inherited.ridge.Lt.dtype
        )
        ridge_state = dataclasses.replace(
            zeroed,
            Lt=jnp.where(keep[:, None, None], inherited.ridge.Lt, seeded_Lt),
            factor_beta=beta_inh,
        )
        return dataclasses.replace(inherited, params=params, ridge=ridge_state)
