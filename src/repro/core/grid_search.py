"""Grid search over (p, q, beta) - the paper's baseline optimizer (Sec. 4.1).

Ranges (paper): p in [10^-3.75, 10^-0.25], q in [10^-2.75, 10^-0.25],
divided into ``divs`` equidistant points in log space simultaneously; beta is
swept over the same four values as the proposed method.

``grid_search`` is now a thin compatibility shim over the vmapped population
engine (``repro.core.population``): all K = divs^2 candidates run through the
reservoir -> DPRR -> batched-ridge pipeline in ONE jitted program instead of
a per-candidate Python loop.  The original per-candidate implementation is
kept as ``grid_search_serial`` - it is the honest serial baseline the
population engine's throughput is benchmarked against
(``benchmarks/bench_population.py``), and the oracle its ranking is tested
against (``tests/test_population.py``).
"""
from __future__ import annotations

import itertools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backprop, dprr, masking, population, reservoir, ridge
from repro.core.population import grid_points  # noqa: F401  (compat re-export)
from repro.core.types import Array, DFRConfig, DFRParams, TimeSeriesBatch


def _eval_pq(
    cfg: DFRConfig,
    mask: Array,
    p: Array,
    q: Array,
    train: TimeSeriesBatch,
    test: TimeSeriesBatch,
    betas: Tuple[float, ...],
) -> Tuple[Array, Array]:
    """Accuracy (test) and loss (train) for one (p, q) across all betas."""
    f = cfg.f()

    def feats(batch: TimeSeriesBatch) -> Array:
        j_seq = masking.apply_mask(mask, batch.u)
        x = reservoir.run_reservoir(p, q, j_seq, f=f, lengths=batch.length)
        return dprr.compute_dprr(x, lengths=batch.length)

    r_train = feats(train)
    r_test = feats(test)
    rt = dprr.r_tilde(r_train)
    onehot = jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype)
    A = jnp.einsum("bc,bs->cs", onehot, rt)
    B = jnp.einsum("bs,bt->st", rt, rt)

    accs, losses = [], []
    for beta in betas:
        Wt = ridge.ridge_cholesky_blocked(A, ridge.regularize(B, jnp.asarray(beta, B.dtype)))
        W, b = Wt[:, :-1], Wt[:, -1]
        logits_test = r_test @ W.T + b
        acc = jnp.mean((jnp.argmax(logits_test, -1) == test.label).astype(jnp.float32))
        logits_train = r_train @ W.T + b
        loss = jnp.mean(backprop.loss_from_logits(
            logits_train, jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype)))
        accs.append(acc)
        losses.append(loss)
    return jnp.stack(accs), jnp.stack(losses)


def grid_search_serial(
    cfg: DFRConfig,
    train: TimeSeriesBatch,
    test: TimeSeriesBatch,
    divs: int,
    p_range: Tuple[float, float] = population.P_LOG_RANGE,
    q_range: Tuple[float, float] = population.Q_LOG_RANGE,
    mask: Optional[Array] = None,
) -> dict:
    """Per-candidate serial sweep (one jitted eval per grid point).

    The pre-population-engine implementation, retained as the benchmark
    baseline and ranking oracle.  Returns the same dict as ``grid_search``.
    """
    if mask is None:
        mask = masking.make_mask(jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype)
    ps = grid_points(divs, *p_range)
    qs = grid_points(divs, *q_range)

    t0 = time.perf_counter()
    eval_j = jax.jit(lambda p, q: _eval_pq(cfg, mask, p, q, train, test, cfg.betas))
    best = {"acc": -1.0, "p": None, "q": None, "beta": None}
    for p, q in itertools.product(ps, qs):
        accs, _ = eval_j(jnp.asarray(p, cfg.dtype), jnp.asarray(q, cfg.dtype))
        accs = np.asarray(accs)
        bi = int(np.argmax(accs))
        if accs[bi] > best["acc"]:
            best = {"acc": float(accs[bi]), "p": float(p), "q": float(q),
                    "beta": float(cfg.betas[bi])}
    best["time_s"] = time.perf_counter() - t0
    best["n_points"] = len(ps) * len(qs) * len(cfg.betas)
    return best


def grid_search(
    cfg: DFRConfig,
    train: TimeSeriesBatch,
    test: TimeSeriesBatch,
    divs: int,
    p_range: Tuple[float, float] = population.P_LOG_RANGE,
    q_range: Tuple[float, float] = population.Q_LOG_RANGE,
    mask: Optional[Array] = None,
) -> dict:
    """Full (p, q, beta) grid sweep; returns best accuracy + params + timing.

    Thin shim over ``population.evaluate_population`` with zero refinement:
    the whole sweep is one vmapped program.  Candidate order, accuracy
    selection, and first-best tie-breaking match ``grid_search_serial``.
    """
    if mask is None:
        mask = masking.make_mask(jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype)

    t0 = time.perf_counter()
    ps, qs = population.grid_candidates(divs, p_range, q_range, cfg.dtype)
    y_tr = jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype)
    y_ev = jax.nn.one_hot(test.label, cfg.n_classes, dtype=cfg.dtype)
    # solver='primal' uses the serial sweep's formulation (factor the (s, s)
    # normal matrix per beta), so rankings agree wherever that factorization
    # is numerically healthy; in float32-degenerate cells (beta below the
    # noise floor of a rank-deficient B) both paths produce garbage, and not
    # necessarily the same garbage
    ev = population.evaluate_population(
        cfg, mask, ps, qs, train.u, train.length, y_tr,
        test.u, test.length, y_ev, select="acc", solver="primal",
    )
    accs = np.asarray(ev.acc)
    bi = int(np.argmax(accs))  # product order + first-max == serial tie-break
    return {
        "acc": float(accs[bi]),
        "p": float(ps[bi]),
        "q": float(qs[bi]),
        "beta": float(cfg.betas[int(ev.beta_idx[bi])]),
        "time_s": time.perf_counter() - t0,
        "n_points": int(ps.shape[0]) * len(cfg.betas),
    }


def grid_search_until(
    cfg: DFRConfig,
    train: TimeSeriesBatch,
    test: TimeSeriesBatch,
    target_acc: float,
    max_divs: int = 20,
) -> dict:
    """Paper protocol: increase divisions from 1 until matching target_acc."""
    total_t = 0.0
    out = None
    for divs in range(1, max_divs + 1):
        out = grid_search(cfg, train, test, divs)
        total_t += out["time_s"]
        out["divs"] = divs
        out["total_time_s"] = total_t
        if out["acc"] >= target_acc - 1e-9:
            return out
    return out
