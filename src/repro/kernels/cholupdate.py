"""Pallas TPU kernel: rank-1 Cholesky update/downdate of a resident factor.

The streaming extension of the blocked factorization kernels in
``repro.kernels.cholesky``: instead of re-factorizing ``B + beta I`` after
every window of streamed samples, the live lower factor L stays resident in
VMEM and each sample's r~ row is rotated into it with the LINPACK sweep

    L L^T + sign * x x^T = L' L'^T        (sign=-1: hyperbolic downdate)

one column rotation per step, whole columns vectorized on the VPU - the
same adaptation argument as the factorization kernels: the paper's packed
1-D addressing suits FPGA BRAM but defeats the vector unit, so the packed
*oracle* lives in ``repro.core.ridge`` (``cholupdate_packed_numpy`` /
``cholupdate_packed_jax``) and the tile kernel carries the identical
update order on a dense (bs, bs) tile.

Kernels:

  * ``cholupdate_block``         - fold a (W, bs) window of sample rows into
                                   one (bs, bs) factor tile, rows in stream
                                   order (W = 1 is the plain rank-1 form).
                                   The factor is read once, rotated W times
                                   in VMEM, written once - the fusion the
                                   per-sample XLA path cannot express.
  * ``cholupdate_block_batched`` - one grid step per member/slot: the stream
                                   server's S live slots rotate their
                                   factors in a single kernel launch.

Zero rows are exact no-ops (r = d, c = 1, s = 0), so callers gate dead/tail
samples by zero-scaling rows - the serving runtime's 0/1 weight discipline.
Wrappers with padding contracts and backend dispatch: ``repro.kernels.ops.
cholupdate_window``.

Both signs dispatch through the same sweep: sign=-1 is the hyperbolic
downdate (the sliding-window retirement path), with the shared downdate
guard (``repro.core.ridge._guarded_rotation``): an indefinite rotation is
clamp-skipped in VMEM exactly as in the jnp sweep, so the kernel stays
bit-parity-comparable and never writes NaNs back; callers that need the
guard *flag* (to trigger re-factorization) use the core guarded forms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ridge import _guarded_rotation


def _cholupd_tile(L: jax.Array, X: jax.Array, sign: float) -> jax.Array:
    """Rotate the (W, bs) rows of X into the (bs, bs) lower factor L."""
    n = L.shape[0]
    cidx = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    rowpos = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def rot_k(k, carry):
        L, x = carry
        dk = L[k, k]
        xk = x[k]
        r, c, sk, _ = _guarded_rotation(dk, xk, sign)
        col = (L[:, k] + sign * sk * x) / c
        col = jnp.where(rowpos > k, col, L[:, k]).at[k].set(r)
        L = jnp.where(cidx == k, col[:, None], L)
        x = jnp.where(rowpos > k, c * x - sk * col, x)
        return L, x

    def fold_row(t, L):
        L, _ = jax.lax.fori_loop(0, n, rot_k, (L, X[t]))
        return L

    return jax.lax.fori_loop(0, X.shape[0], fold_row, L)


def _cholupd_kernel(l_ref, x_ref, o_ref, *, sign: float):
    o_ref[...] = _cholupd_tile(l_ref[...], x_ref[...], sign)


def _cholupd_batched_kernel(l_ref, x_ref, o_ref, *, sign: float):
    # refs carry one member/slot per grid step: (1, bs, bs) / (1, W, bs)
    o_ref[0] = _cholupd_tile(l_ref[0], x_ref[0], sign)


def cholupdate_block(L: jax.Array, X: jax.Array, *, sign: float = 1.0,
                     interpret: bool = False) -> jax.Array:
    """Fold X (W, bs) into the factor tile L (bs, bs), resident in VMEM."""
    bs = L.shape[0]
    w = X.shape[0]
    return pl.pallas_call(
        functools.partial(_cholupd_kernel, sign=sign),
        out_shape=jax.ShapeDtypeStruct((bs, bs), L.dtype),
        in_specs=[
            pl.BlockSpec((bs, bs), lambda: (0, 0)),
            pl.BlockSpec((w, bs), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, bs), lambda: (0, 0)),
        interpret=interpret,
    )(L, X)


def cholupdate_block_batched(L: jax.Array, X: jax.Array, *, sign: float = 1.0,
                             interpret: bool = False) -> jax.Array:
    """Slot/member-axis window fold: L (K, bs, bs), X (K, W, bs).

    One grid step per member; each keeps its own factor tile resident while
    rotating its window through - the S live slots of the stream server
    update in one launch, no host round trips.
    """
    k, bs, _ = L.shape
    w = X.shape[1]
    return pl.pallas_call(
        functools.partial(_cholupd_batched_kernel, sign=sign),
        grid=(k,),
        out_shape=jax.ShapeDtypeStruct((k, bs, bs), L.dtype),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w, bs), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bs), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(L, X)
