"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function mirrors one kernel's contract exactly (same padding, same
masking semantics) using only jax.numpy - no Pallas, no loops over scalars.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import dprr as core_dprr
from repro.core import reservoir as core_res


def dprr_ref(x: jax.Array, length: jax.Array, n_nodes: int) -> jax.Array:
    """Oracle of kernels.dprr.dprr_pallas: (T_pad, n_pad) -> (n_pad, n_pad)."""
    t_pad, n_pad = x.shape
    row = jnp.arange(t_pad)[:, None]
    col = jnp.arange(n_pad)[None, :]
    x1 = jnp.where((row < length) & (col < n_nodes), x, 0.0)
    x0 = jnp.pad(x, ((1, 0), (0, 0)))[:-1]
    x0_aug = jnp.where(col < n_nodes, x0, jnp.where(col == n_nodes, 1.0, 0.0))
    return x1.T @ x0_aug


def chol_ref(a: jax.Array) -> jax.Array:
    """Oracle of kernels.cholesky.chol_block."""
    return jnp.linalg.cholesky(a)


def trsm_lower_t_ref(a: jax.Array, L: jax.Array) -> jax.Array:
    """Oracle of kernels.cholesky.trsm_lower_t: X L^T = a."""
    return jax.scipy.linalg.solve_triangular(L, a.T, lower=True).T


def trsm_lower_ref(d: jax.Array, L: jax.Array) -> jax.Array:
    """Oracle of kernels.cholesky.trsm_lower: X L = d."""
    return jax.scipy.linalg.solve_triangular(L.T, d.T, lower=False).T


def ridge_solve_ref(A: jax.Array, B: jax.Array) -> jax.Array:
    """Oracle of kernels.ridge_solve.ridge_solve_blocked: A B^{-1}."""
    C = jnp.linalg.cholesky(B)
    D = jax.scipy.linalg.solve_triangular(C, A.T, lower=True)
    return jax.scipy.linalg.solve_triangular(C.T, D, lower=False).T


def flash_attention_ref(
    q: jax.Array,   # (B, H, Tq, D)
    k: jax.Array,   # (B, KV, Tk, D)
    v: jax.Array,   # (B, KV, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Oracle of kernels.flash_attention (dense masked softmax)."""
    b, h, tq, d = q.shape
    _, kv, tk, _ = k.shape
    g = h // kv
    scale = softmax_scale if softmax_scale is not None else d**-0.5
    qg = q.reshape(b, kv, g, tq, d).astype(jnp.float32)
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(tq)[:, None]
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", p, v.astype(jnp.float32))
    return out.reshape(b, h, tq, d).astype(q.dtype)


def streaming_logits_ref(
    j_seq: jax.Array,      # (B, T, Nx) masked inputs (logical shapes)
    lengths: jax.Array,    # (B,)
    p: jax.Array,
    q: jax.Array,
    W: jax.Array,          # (Ny, Nr)
    b: jax.Array,          # (Ny,)
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
) -> jax.Array:
    """Oracle of kernels.streaming.streaming_step_pallas (+ bias): the
    unfused reservoir -> DPRR -> readout composition on logical shapes."""
    x = core_res.run_reservoir(p, q, j_seq, f=f, lengths=lengths)
    r = core_dprr.compute_dprr(x, lengths=lengths)
    return r @ W.T + b


def streaming_q8_sim(
    j_seq: jax.Array,      # (B, T_pad, n_pad) f32 masked inputs, zero padded
    Lq: jax.Array,         # (n_pad, n_pad) int8 ring-matrix codes (scale sL)
    qpow: jax.Array,       # (n_pad,) f32 ring powers (fp32 path, not coded)
    lengths: jax.Array,    # (B,) int32
    w3q: jax.Array,        # (ny_pad, n_pad, n_pad) int8 readout codes
    scales: jax.Array,     # (4,) f32: [p, sx, sL, sw] (all > 0)
    n_nodes: int,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
) -> jax.Array:
    """Oracle of kernels.streaming.streaming_step_pallas_q8: the quantized
    fused step's *exact* integer math on padded shapes.

    The int8 contract (shared bit-for-bit with the kernel - integer
    arithmetic is exact, so op order doesn't matter):

      * the recurrent state lives as int8 codes ``xq`` with scale ``sx``
        (dequantize, apply the fp32 nonlinearity, requantize - the
        nonlinearity and the ring wrap stay fp32, everything else is
        integer),
      * the reservoir mix is an int8 x int8 -> int32 dot against the coded
        ring matrix (scale ``sL``), dequantized by ``sx * sL``,
      * dead steps freeze in the *code* domain (bitwise no-op, matching the
        fp32 kernel's freeze),
      * the DPRR accumulator is int32 over code outer products; the ones
        column carries the integer constant 1 (exact), so its dequant
        scale is ``sx`` where the x-columns carry ``sx^2``,
      * the readout dequantizes the accumulator per column and contracts
        in fp32 against the dequantized int8 readout tile (scale ``sw``) -
        the "fp32 dequantized logits" half of the contract.

    Overflow headroom: reservoir dot <= 127^2 * n_pad, DPRR accumulator
    <= 127^2 * T per cell - both orders of magnitude inside int32.
    Returns raw logits (B, ny_pad), bias not yet added.
    """
    _, t_pad, n_pad = j_seq.shape
    ny_pad = w3q.shape[0]
    p, sx, sL, sw = scales[0], scales[1], scales[2], scales[3]
    col = jnp.arange(n_pad)
    LqT = Lq.astype(jnp.int8).T

    def one(jb, length):
        def step(carry, inp):
            xq_prev, acc = carry
            j_k, k = inp
            x_prev = xq_prev.astype(jnp.float32) * sx
            a = p * f(j_k + x_prev)
            aq = jnp.clip(jnp.round(a / sx), -127, 127).astype(jnp.int8)
            y = jax.lax.dot_general(
                aq[None, :], LqT,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )[0]
            x_k = y.astype(jnp.float32) * (sx * sL) + x_prev[-1] * qpow
            xq_k = jnp.clip(jnp.round(x_k / sx), -127, 127).astype(jnp.int32)
            live = k < length
            xq_k = jnp.where(live, xq_k, xq_prev)
            x1m = jnp.where((col < n_nodes) & live, xq_k, 0)
            x0_aug = jnp.where(col < n_nodes, xq_prev,
                               jnp.where(col == n_nodes, 1, 0))
            acc = acc + jax.lax.dot_general(
                x1m.astype(jnp.int8)[:, None], x0_aug.astype(jnp.int8)[:, None],
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            return (xq_k, acc), None

        carry0 = (jnp.zeros((n_pad,), jnp.int32),
                  jnp.zeros((n_pad, n_pad), jnp.int32))
        (_, acc), _ = jax.lax.scan(
            step, carry0, (jb, jnp.arange(t_pad, dtype=jnp.int32))
        )
        # per-column dequant: x columns carry sx^2, the ones column sx
        colscale = jnp.where(col == n_nodes, sx, sx * sx)
        racc = acc.astype(jnp.float32) * colscale[None, :]
        w = w3q.reshape(ny_pad, n_pad * n_pad).astype(jnp.float32) * sw
        return racc.reshape(n_pad * n_pad) @ w.T

    return jax.vmap(one)(j_seq, lengths.astype(jnp.int32))


def train_forward_ref(
    j_seq: jax.Array,      # (B, T_pad, n_pad) f32 masked inputs, zero padded
    L: jax.Array,          # (n_pad, n_pad) ring matrix, zero padded + mirrored
    qpow: jax.Array,       # (n_pad,) f32 ring powers
    lengths: jax.Array,    # (B,) int32
    p: jax.Array,
    n_nodes: int,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
):
    """Oracle of kernels.train.train_forward_pallas on padded shapes.

    Mirrors the kernel's per-step op sequence exactly (same dots on the
    same padded operands, same masking, same boundary latch order), so the
    interpret-mode kernel agrees with it bit for bit.  Returns
    ``(acc, x_last, x_prev, j_last)`` in the kernel's padded layout.
    """
    t_pad, n_pad = j_seq.shape[1], j_seq.shape[2]
    Lt = L.T
    col = jnp.arange(n_pad)[None, :]

    def one(jb, length):
        def step(carry, inp):
            x_prev, acc, x_bnd, j_bnd = carry
            j_k, k = inp
            a = p.astype(jnp.float32) * f(j_k + x_prev)
            x_k = jax.lax.dot(
                a, Lt, preferred_element_type=jnp.float32
            ) + x_prev[:, -1:] * qpow[None, :]
            is_bnd = k == length - 1
            x_bnd = jnp.where(is_bnd, x_prev, x_bnd)
            j_bnd = jnp.where(is_bnd, j_k, j_bnd)
            live = k < length
            x_k = jnp.where(live, x_k, x_prev)
            x1m = jnp.where((col < n_nodes) & live, x_k, 0.0)
            x0_aug = jnp.where(
                col < n_nodes, x_prev, jnp.where(col == n_nodes, 1.0, 0.0)
            )
            acc = acc + jax.lax.dot_general(
                x1m, x0_aug,
                dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            return (x_k, acc, x_bnd, j_bnd), None

        z_row = jnp.zeros((1, n_pad), jnp.float32)
        carry0 = (z_row, jnp.zeros((n_pad, n_pad), jnp.float32), z_row, z_row)
        (x_last, acc, x_bnd, j_bnd), _ = jax.lax.scan(
            step, carry0,
            (jb[:, None, :], jnp.arange(t_pad, dtype=jnp.int32)),
        )
        return acc, x_last[0], x_bnd[0], j_bnd[0]

    return jax.vmap(one)(j_seq, lengths.astype(jnp.int32))


def reservoir_ref(
    j_seq: jax.Array,      # (B, T_pad, n_pad)
    x0: jax.Array,         # (B, n_pad)
    lengths: jax.Array,    # (B,)
    p: jax.Array,
    q: jax.Array,
    n_nodes: int,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
) -> jax.Array:
    """Oracle of kernels.reservoir.reservoir_pallas (true-node lanes only).

    Runs the core scan on the unpadded node slice and re-pads with zeros
    (+ the replicated ring lane, see kernels.reservoir docstring).
    """
    n_pad = j_seq.shape[-1]
    x = core_res.run_reservoir(
        p, q, j_seq[..., :n_nodes], x0[..., :n_nodes], f=f, lengths=lengths
    )
    out = jnp.pad(x, ((0, 0), (0, 0), (0, n_pad - n_nodes)))
    # replicate the ring lane as the kernel does
    return out.at[..., -1].set(x[..., n_nodes - 1])
