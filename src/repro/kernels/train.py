"""Pallas TPU kernel: fused training-path forward (reservoir -> DPRR aux).

The training hot paths (population refinement, the serve step's truncated-BP
branch, the warm-pool autotuner's candidate scoring) need exactly four
things from a forward pass — the DPRR feature vector ``r`` plus the three
truncation boundary tensors ``x(T)``, ``x(T-1)``, ``j(T)`` — yet the unfused
composition (``kernels.reservoir`` then ``kernels.dprr``, or the core
``run_reservoir`` scan then ``compute_dprr``) materializes the full state
sequence X (B, T, Nx) in HBM between the two passes just so the reduction
and the boundary gathers can re-read it.  That is precisely the recursive
memory expansion the paper's truncated backpropagation exists to eliminate
(Sec. 3.4, Table 7: the FPGA keeps only x(T-1), x(T)).

This kernel is the serving kernel's training twin (``kernels.streaming``):
one ``pallas_call`` runs the whole time loop with the recurrent state block
(block_b, n_pad) and the per-sample DPRR accumulator tiles
(block_b, n_pad, n_pad) resident in VMEM, and instead of contracting the
accumulator against readout weights it emits the accumulator itself plus
the truncation boundary rows:

    per sample:  acc    (n_pad, n_pad)   DPRR accumulator (r in tile layout)
                 x_last (n_pad,)         x(T)   — final frozen state
                 x_prev (n_pad,)         x(T-1) — state *entering* step T
                 j_last (n_pad,)         j(T)   — input row of step T

X never exists anywhere: per-sample activation memory is O(Nx^2) for the
accumulator and O(Nx) for the state/boundary rows, independent of T —
mirroring the FPGA dataflow where the DPRR MACs are wired directly to the
reservoir ring and only the two boundary states are latched for training.

Boundary capture: step k = length-1 is recognized inside the time loop
(``k_global == length - 1``) and latches (x_prev, j_k) into VMEM scratch
rows before the state update, so ``x_prev`` is exactly the ``forward()``
gather ``x[length-2]`` (zero when length == 1, because the latched value is
then the initial state).  Dead steps (k >= length) freeze the state and
contribute zero to the accumulator, matching ``compute_dprr``'s row
masking bit for bit.

Grid: (batch_blocks, time_chunks), time minor/sequential so the scratch
carries across chunks (re-initialized at chunk 0 of every batch block).
Same ring-padding contract as the other kernels (``ops._ring_padded``):
L/qpow are built for the padded node count with the true last node
mirrored into the last padded lane so the in-kernel ring wrap
``x_prev[:, -1:]`` reads node Nx-1.

``train_forward_scan`` is the XLA fallback with the same fusion: an outer
``lax.scan`` over fixed-size time chunks carries (state, accumulator,
boundary latches); each outer step runs the recurrence for one chunk and
folds its DPRR contributions into the accumulator with a single K=chunk
contraction.  Chunks that provably precede every sample's boundary take a
mask-free fast path (``lax.cond``), so the steady-state inner step is
exactly the bare ring recurrence.  Per-sample activation memory is
O(Nx^2 + chunk*Nx) — bounded by the fixed chunk, independent of T — so
the no-X property holds on every backend.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import reservoir as core_res


def _train_forward_kernel(
    j_ref,       # (chunk_t, block_b, n_pad) masked inputs for this block
    L_ref,       # (n_pad, n_pad) ring matrix (zero padded, ring lane mirrored)
    qpow_ref,    # (1, n_pad) ring powers
    len_ref,     # (block_b, 1) int32 valid lengths
    pq_ref,      # (1, 2) f32: [p, q] (q folded into L/qpow)
    acc_ref,     # out (block_b, n_pad, n_pad) DPRR accumulators
    xlast_ref,   # out (block_b, n_pad) x(T)
    xprev_ref,   # out (block_b, n_pad) x(T-1)
    jlast_ref,   # out (block_b, n_pad) j(T)
    state,       # VMEM scratch (block_b, n_pad) recurrent state
    acc,         # VMEM scratch (block_b, n_pad, n_pad) DPRR accumulators
    bnd_x,       # VMEM scratch (block_b, n_pad) boundary latch x(T-1)
    bnd_j,       # VMEM scratch (block_b, n_pad) boundary latch j(T)
    *,
    f: Callable[[jax.Array], jax.Array],
    chunk_t: int,
    n_nodes: int,
):
    tc = pl.program_id(1)
    n_pad = state.shape[-1]

    @pl.when(tc == 0)
    def _init():
        state[...] = jnp.zeros_like(state)   # x(0) = 0 (paper Sec. 2.2)
        acc[...] = jnp.zeros_like(acc)
        bnd_x[...] = jnp.zeros_like(bnd_x)
        bnd_j[...] = jnp.zeros_like(bnd_j)

    p = pq_ref[0, 0]
    Lt = L_ref[...].T
    qpow = qpow_ref[...]
    lens = len_ref[...]                           # (block_b, 1)
    col = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1)

    def step(t, _):
        x_prev = state[...]
        j_k = j_ref[t, :, :]                      # (block_b, n_pad)
        a = p * f(j_k + x_prev)
        x_k = jax.lax.dot(a, Lt, preferred_element_type=jnp.float32) \
            + x_prev[:, -1:] * qpow
        k_global = tc * chunk_t + t
        # latch the truncation boundary BEFORE the state update: at the
        # last live step, x_prev is x(T-1) and j_k is j(T)
        is_bnd = k_global == lens - 1
        bnd_x[...] = jnp.where(is_bnd, x_prev, bnd_x[...])
        bnd_j[...] = jnp.where(is_bnd, j_k, bnd_j[...])
        live = k_global < lens
        x_k = jnp.where(live, x_k, x_prev)        # freeze past valid length
        # DPRR contribution of step k: x(k) . [x(k-1), 1]^T per sample,
        # masked to the true nodes; a frozen (dead) step contributes
        # exactly zero, matching compute_dprr's row masking.
        x1m = jnp.where((col < n_nodes) & live, x_k, 0.0)
        x0_aug = jnp.where(
            col < n_nodes, x_prev, jnp.where(col == n_nodes, 1.0, 0.0)
        )
        acc[...] += x1m[:, :, None] * x0_aug[:, None, :]
        state[...] = x_k
        return 0

    jax.lax.fori_loop(0, chunk_t, step, 0)

    @pl.when(tc == pl.num_programs(1) - 1)
    def _emit():
        acc_ref[...] = acc[...]
        xlast_ref[...] = state[...]
        xprev_ref[...] = bnd_x[...]
        jlast_ref[...] = bnd_j[...]


def train_forward_pallas(
    j_seq: jax.Array,     # (B, T_pad, n_pad) f32; node padding must be zero
    L: jax.Array,         # (n_pad, n_pad) ring matrix, zero padded + mirrored
    qpow: jax.Array,      # (n_pad,)
    lengths: jax.Array,   # (B,) int32
    p: jax.Array,         # scalar
    q: jax.Array,         # scalar
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    block_b: int = 8,
    chunk_t: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused training forward on padded shapes.

    Returns ``(acc, x_last, x_prev, j_last)`` with shapes
    ``(B, n_pad, n_pad)``, ``(B, n_pad)`` x3.  ``ops.train_forward`` owns
    the padding and the accumulator -> r conversion.
    """
    b, t_pad, n_pad = j_seq.shape
    assert t_pad % chunk_t == 0, (t_pad, chunk_t)
    assert b % block_b == 0, (b, block_b)
    assert n_pad % 128 == 0 and n_nodes < n_pad
    jt = jnp.swapaxes(j_seq, 0, 1)  # (T, B, N): time-major for the grid

    kernel = functools.partial(
        _train_forward_kernel, f=f, chunk_t=chunk_t, n_nodes=n_nodes
    )
    pq = jnp.stack([p.astype(jnp.float32), q.astype(jnp.float32)]).reshape(1, 2)
    grid = (b // block_b, t_pad // chunk_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk_t, block_b, n_pad), lambda bb, tc: (tc, bb, 0)),
            pl.BlockSpec((n_pad, n_pad), lambda bb, tc: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda bb, tc: (0, 0)),
            pl.BlockSpec((block_b, 1), lambda bb, tc: (bb, 0)),
            pl.BlockSpec((1, 2), lambda bb, tc: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, n_pad, n_pad), lambda bb, tc: (bb, 0, 0)),
            pl.BlockSpec((block_b, n_pad), lambda bb, tc: (bb, 0)),
            pl.BlockSpec((block_b, n_pad), lambda bb, tc: (bb, 0)),
            pl.BlockSpec((block_b, n_pad), lambda bb, tc: (bb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_pad, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, n_pad), jnp.float32),
            pltpu.VMEM((block_b, n_pad, n_pad), jnp.float32),
            pltpu.VMEM((block_b, n_pad), jnp.float32),
            pltpu.VMEM((block_b, n_pad), jnp.float32),
        ],
        interpret=interpret,
    )(jt, L, qpow.reshape(1, -1), lengths.astype(jnp.int32).reshape(-1, 1), pq)


#: time steps folded per accumulator contraction in the XLA fallback — a
#: bounded (T-independent) window, NOT a full-T materialization.  64 keeps
#: the per-chunk stack at 64*Nx floats per sample while turning the
#: accumulator update into one K=64 GEMM per chunk instead of 64 reads and
#: writes of the (Nx, Nx+1) carry (the per-step version doubled the HBM
#: traffic of the baseline and lost wall-clock on CPU at Nx=16).
SCAN_CHUNK = 64


def train_forward_scan(
    j_seq: jax.Array,               # (B, T, Nx) or (T, Nx) masked inputs
    lengths: Optional[jax.Array],   # (B,) int32, or scalar, or None
    p: jax.Array,
    q: jax.Array,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    chunk: int = SCAN_CHUNK,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """XLA twin of the fused kernel on logical shapes: a chunked lax.scan.

    The outer scan carries the recurrent state, the f32 DPRR accumulators
    (the (Nx, Nx) outer-product sum and the (Nx,) state sum — kept
    separate so no ones-column ever has to be concatenated) and the two
    boundary latches; each outer step runs ``chunk`` reservoir updates in
    an inner scan and folds their DPRR contributions into the
    accumulators with one contraction over the chunk axis.  The x(k) /
    x(k-1) pairing is expressed as shifted slices of the chunk-local
    stack plus one rank-1 term for the chunk's first step, so the fold
    allocates no shifted copy.  A chunk that ends strictly before every
    sample's boundary (k + chunk < min(lengths)) takes a ``lax.cond``
    fast path whose inner step is the bare ring recurrence — no
    live/boundary compares or wheres — so for long sequences the masking
    cost is confined to the boundary- and padding-holding chunks.
    Per-sample activation memory is O(Nx^2 + chunk*Nx) — bounded by the
    fixed chunk, independent of T: the full state sequence X is never
    stacked.  Returns logical ``(r, x_last, x_prev, j_last)``.
    """
    batched = j_seq.ndim == 3
    jt = jnp.swapaxes(j_seq, 0, 1) if batched else j_seq  # (T, [B,] Nx)
    t_len = jt.shape[0]
    n_nodes = jt.shape[-1]
    dt = jt.dtype
    if lengths is None:
        lengths = jnp.full(j_seq.shape[:-2], t_len, jnp.int32)
    L = core_res.ring_matrix(q, n_nodes, dt)
    qpow = core_res.ring_powers(q, n_nodes, dt)
    Lt = L.T

    # zero-pad T to a chunk multiple: padded steps have k >= lengths for
    # every sample, so the state freezes, the live mask zeroes their DPRR
    # rows and the boundary latch (k == length-1 < T) can never fire —
    # the pad is exactly dead compute, never a value change
    chunk = max(1, min(int(chunk), t_len))
    n_chunks = -(-t_len // chunk)
    if n_chunks * chunk != t_len:
        pad = jnp.zeros((n_chunks * chunk - t_len, *jt.shape[1:]), dt)
        jt = jnp.concatenate([jt, pad], axis=0)
    jc = jt.reshape(n_chunks, chunk, *jt.shape[1:])
    steps_idx = jnp.arange(chunk, dtype=jnp.int32)

    x0 = jnp.zeros_like(jt[0])
    out0 = jnp.zeros((*x0.shape, n_nodes), jnp.float32)
    sum0 = jnp.zeros(x0.shape, jnp.float32)
    carry0 = (x0, jnp.zeros((), jnp.int32), out0, sum0, x0,
              jnp.zeros_like(jt[0]))

    eq = "cbn,cbm->bnm" if batched else "cn,cm->nm"

    def fold(out_a, sum_a, x_in, xs, x1m):
        # sum_k x(k).x(k-1)^T over the chunk: the shifted pairing is
        # slices of the same stack (x1m[k] pairs with xs[k-1]) plus the
        # chunk-seam term x1m[0].x_in^T; the state sum rides separately
        out_a = out_a + jnp.einsum(eq, x1m[1:], xs[:-1].astype(jnp.float32))
        out_a = out_a + (x1m[0][..., :, None]
                         * x_in.astype(jnp.float32)[..., None, :])
        return out_a, sum_a + x1m.sum(axis=0)

    def chunk_step(carry, j_chunk):
        x_in, k0, out_a, sum_a, x_bnd, j_bnd = carry

        def fast(operand):
            # every step of the chunk is live for every sample and no
            # boundary can latch: the bare ring recurrence, mask-free
            x_in, out_a, sum_a, x_bnd, j_bnd = operand

            def step(x_prev, j_k):
                a = p * f(j_k + x_prev)
                x_k = a @ Lt + x_prev[..., -1:] * qpow
                return x_k, x_k

            x_out, xs = jax.lax.scan(step, x_in, j_chunk)
            out_a, sum_a = fold(out_a, sum_a, x_in, xs,
                                xs.astype(jnp.float32))
            return x_out, out_a, sum_a, x_bnd, j_bnd

        def slow(operand):
            x_in, out_a, sum_a, x_bnd, j_bnd = operand

            def step(c2, j_k):
                x_prev, k, x_bnd, j_bnd = c2
                a = p * f(j_k + x_prev)
                x_k = a @ Lt + x_prev[..., -1:] * qpow
                is_bnd = k == lengths - 1
                live = k < lengths
                if batched:
                    is_bnd, live = is_bnd[..., None], live[..., None]
                x_bnd = jnp.where(is_bnd, x_prev, x_bnd)
                j_bnd = jnp.where(is_bnd, j_k, j_bnd)
                x_k = jnp.where(live, x_k, x_prev)
                return (x_k, k + 1, x_bnd, j_bnd), x_k

            (x_out, _, x_bnd, j_bnd), xs = jax.lax.scan(
                step, (x_in, k0, x_bnd, j_bnd), j_chunk
            )
            ks = k0 + steps_idx
            if batched:
                live_c = (ks[:, None] < lengths[None, :])[..., None]
            else:
                live_c = (ks < lengths)[..., None]
            x1m = jnp.where(live_c, xs, jnp.zeros((), dt)).astype(jnp.float32)
            out_a, sum_a = fold(out_a, sum_a, x_in, xs, x1m)
            return x_out, out_a, sum_a, x_bnd, j_bnd

        # fast iff the whole chunk is strictly before every boundary
        # (k0 + chunk - 1 < lengths - 1 for all samples); the predicate
        # never touches vmapped member params, so cond survives vmap
        pred = k0 + chunk < jnp.min(lengths)
        x_out, out_a, sum_a, x_bnd, j_bnd = jax.lax.cond(
            pred, fast, slow, (x_in, out_a, sum_a, x_bnd, j_bnd)
        )
        return (x_out, k0 + chunk, out_a, sum_a, x_bnd, j_bnd), None

    (x_last, _, out_a, sum_a, x_bnd, j_bnd), _ = jax.lax.scan(
        chunk_step, carry0, jc
    )
    outer = out_a.reshape(*out_a.shape[:-2], n_nodes * n_nodes)
    r = jnp.concatenate([outer, sum_a], axis=-1).astype(dt)
    return r, x_last, x_bnd, j_bnd
