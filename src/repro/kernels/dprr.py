"""Pallas TPU kernel: fused DPRR accumulation (paper Eq. 27-28).

Computes, for one sample, the augmented dot-product reservoir representation

    ACC = sum_k  x(k) . [x(k-1), 1]^T        in one (pad, pad) MXU tile,

fusing (i) the k-1 shift (carried across T-blocks in a VMEM scratch row -
no shifted copy of X is ever materialized in HBM), (ii) the ones-column
append, and (iii) the valid-length row masking, with the T-blocked matmul
accumulation.  The FPGA implementation computes these sums element-wise;
the MXU does a (Nblk x Tb) @ (Tb x Nblk) per grid step instead.

Grid: (T // block_t,) sequential; the accumulator tile and the carry row
live in VMEM scratch across grid steps (TPU grids execute in order on a
core).  The time-padded tail and the node padding are masked inside the
kernel, so callers only pad with *any* values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dprr_kernel(
    len_ref,    # scalar prefetch: (1,) int32 valid length
    x_ref,      # (block_t, n_pad) f32 states block
    acc_out,    # (n_pad, n_pad) f32 output tile
    acc,        # VMEM scratch (n_pad, n_pad) accumulator
    carry,      # VMEM scratch (1, n_pad): last row of the previous block
    *,
    n_nodes: int,
    block_t: int,
):
    t = pl.program_id(0)
    n_pad = acc.shape[0]

    @pl.when(t == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        carry[...] = jnp.zeros_like(carry)  # x(0) = 0 (paper Sec. 2.2)

    x1 = x_ref[...]  # rows are x(k), k = t*block_t .. t*block_t+block_t-1

    # shifted stream x(k-1): previous block's last row, then our rows 0..Tb-2
    prev_last = carry[...]
    x0 = jnp.concatenate([prev_last, x1[:-1, :]], axis=0)

    # append the ones column at node index n_nodes (padding cols stay 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (block_t, n_pad), 1)
    x0_aug = jnp.where(col < n_nodes, x0, jnp.where(col == n_nodes, 1.0, 0.0))

    # valid-length row mask on the x(k) side kills padded contributions of
    # BOTH the outer-product block and the ones (row-sum) column
    row = jax.lax.broadcasted_iota(jnp.int32, (block_t, n_pad), 0) + t * block_t
    x1_masked = jnp.where(row < len_ref[0], x1, 0.0)
    # node padding on the x(k) side
    x1_masked = jnp.where(col < n_nodes, x1_masked, 0.0)

    acc[...] += jax.lax.dot_general(
        x1_masked, x0_aug,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over time
        preferred_element_type=jnp.float32,
    )
    carry[...] = x1[-1:, :]

    @pl.when(t == pl.num_programs(0) - 1)
    def _flush():
        acc_out[...] = acc[...]


def dprr_pallas(
    x: jax.Array,
    length: jax.Array,
    n_nodes: int,
    *,
    block_t: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """One sample: x (T_pad, n_pad) f32, length scalar int32.

    n_pad must be a multiple of 128 (lane width) and > n_nodes.
    Returns the (n_pad, n_pad) accumulator tile; rows/cols beyond
    (n_nodes, n_nodes+1) are zero.
    """
    t_pad, n_pad = x.shape
    assert t_pad % block_t == 0, (t_pad, block_t)
    assert n_pad % 128 == 0 and n_nodes < n_pad

    kernel = functools.partial(_dprr_kernel, n_nodes=n_nodes, block_t=block_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t_pad // block_t,),
        in_specs=[pl.BlockSpec((block_t, n_pad), lambda t, len_ref: (t, 0))],
        out_specs=pl.BlockSpec((n_pad, n_pad), lambda t, len_ref: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_pad, n_pad), jnp.float32),
            pltpu.VMEM((1, n_pad), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(length.reshape(1), x)
