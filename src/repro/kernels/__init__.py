"""Pallas TPU kernels for the DFR hot spots, with jnp oracles.

Layout (per kernel): <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the public jit'd wrappers (backend dispatch + padding contracts),
ref.py the pure-jnp oracles tests assert against.
"""
from repro.kernels import ops, ref  # noqa: F401
