"""Pallas TPU kernels: blocked Cholesky factorization + triangular solves.

TPU adaptation of the paper's in-place 1-D Cholesky (Alg. 2-4).  The packed
triangular addressing that suits FPGA BRAM defeats the MXU, so the *insight*
(exploit SPD symmetry; never form B^{-1}; share storage) is carried at tile
granularity instead:

  * ``chol_block``   - unblocked factorization of one (bs, bs) VMEM tile via
                       vectorized rank-1 updates (Alg. 2's update order,
                       column panels instead of scalars).
  * ``trsm_lower_t`` - X L^T = A (Alg. 3 on tiles: forward substitution over
                       columns, rows vectorized - the same row-parallelism
                       the paper's write-buffer/partitioned-Q trick buys).
  * ``trsm_lower``   - X L = D (Alg. 4 on tiles: backward substitution).

The inner dot products accumulate in VREGs and each output column is written
once - the TPU analogue of Alg. 5's RegSize write buffer (see DESIGN.md).

The blocked *driver* composing these into a full factorization lives in
``repro.kernels.ops`` (panel TRSM + SYRK trailing update between tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Tile Cholesky
# ---------------------------------------------------------------------------


def _chol_tile(a: jax.Array) -> jax.Array:
    """Factor one (bs, bs) SPD tile: returns L with A = L L^T (lower)."""
    n = a.shape[0]
    ridx = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cidx = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)

    def body(j, a):
        d = jnp.sqrt(a[j, j])
        colj = a[:, j] / d
        rowpos = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
        colj = jnp.where(rowpos > j, colj, 0.0).at[j].set(d)
        a = jnp.where((cidx == j) & (ridx >= j), colj[:, None], a)
        # rank-1 trailing update over the strictly-below-j square
        below = jnp.where(rowpos > j, colj, 0.0)
        return a - below[:, None] * below[None, :]

    a = jax.lax.fori_loop(0, n, body, a)
    return jnp.where(ridx >= cidx, a, 0.0)


def _chol_block_kernel(a_ref, o_ref):
    o_ref[...] = _chol_tile(a_ref[...])


def _chol_block_batched_kernel(a_ref, o_ref):
    # refs carry one population member per grid step: (1, bs, bs)
    o_ref[0] = _chol_tile(a_ref[0])


def chol_block(a: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Cholesky of a single (bs, bs) tile held in VMEM."""
    bs = a.shape[0]
    return pl.pallas_call(
        _chol_block_kernel,
        out_shape=jax.ShapeDtypeStruct((bs, bs), a.dtype),
        in_specs=[pl.BlockSpec((bs, bs), lambda: (0, 0))],
        out_specs=pl.BlockSpec((bs, bs), lambda: (0, 0)),
        interpret=interpret,
    )(a)


def chol_block_batched(a: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Population-axis tile Cholesky: a (K, bs, bs) -> L (K, bs, bs).

    One grid step per member; each factors its own VMEM tile, so the K
    independent factorizations of the population engine pipeline through
    the core without host round-trips.
    """
    k, bs, _ = a.shape
    return pl.pallas_call(
        _chol_block_batched_kernel,
        grid=(k,),
        out_shape=jax.ShapeDtypeStruct((k, bs, bs), a.dtype),
        in_specs=[pl.BlockSpec((1, bs, bs), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, bs), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(a)


# ---------------------------------------------------------------------------
# Tile TRSMs (rows of the right-hand side are gridded; L stays resident)
# ---------------------------------------------------------------------------


def _trsm_lower_t_tile(a: jax.Array, L: jax.Array) -> jax.Array:
    """Solve X L^T = A for one (bm, bs) row block: forward over columns."""
    n = L.shape[0]

    def body(j, x):
        # dot over columns k < j: x[:, k] holds finals, others are zero
        dot = x @ L[j, :]
        val = (a[:, j] - dot) / L[j, j]
        return x.at[:, j].set(val)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def _trsm_lower_tile(d: jax.Array, L: jax.Array) -> jax.Array:
    """Solve X L = D for one (bm, bs) row block: backward over columns."""
    n = L.shape[0]

    def body(t, x):
        j = n - 1 - t
        dot = x @ L[:, j]  # only columns k > j of x are non-zero
        val = (d[:, j] - dot) / L[j, j]
        return x.at[:, j].set(val)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(d))


def _trsm_lower_t_kernel(a_ref, l_ref, x_ref):
    x_ref[...] = _trsm_lower_t_tile(a_ref[...], l_ref[...])


def _trsm_lower_kernel(d_ref, l_ref, x_ref):
    x_ref[...] = _trsm_lower_tile(d_ref[...], l_ref[...])


def _trsm_lower_t_batched_kernel(a_ref, l_ref, x_ref):
    x_ref[0] = _trsm_lower_t_tile(a_ref[0], l_ref[0])


def _trsm_lower_batched_kernel(d_ref, l_ref, x_ref):
    x_ref[0] = _trsm_lower_tile(d_ref[0], l_ref[0])


def _trsm_call(kernel, rhs: jax.Array, L: jax.Array, block_m: int, interpret: bool):
    m, n = rhs.shape
    assert m % block_m == 0, (m, block_m)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        out_shape=jax.ShapeDtypeStruct((m, n), rhs.dtype),
        in_specs=[
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        interpret=interpret,
    )(rhs, L)


def _trsm_call_batched(kernel, rhs: jax.Array, L: jax.Array, block_m: int,
                       interpret: bool):
    k, m, n = rhs.shape
    assert m % block_m == 0, (m, block_m)
    return pl.pallas_call(
        kernel,
        grid=(k, m // block_m),
        out_shape=jax.ShapeDtypeStruct((k, m, n), rhs.dtype),
        in_specs=[
            pl.BlockSpec((1, block_m, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n, n), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, n), lambda b, i: (b, i, 0)),
        interpret=interpret,
    )(rhs, L)


def trsm_lower_t(a: jax.Array, L: jax.Array, *, block_m: int = 128,
                 interpret: bool = False) -> jax.Array:
    """X L^T = a;  a: (m, bs), L: (bs, bs) lower-triangular."""
    return _trsm_call(_trsm_lower_t_kernel, a, L, block_m, interpret)


def trsm_lower(d: jax.Array, L: jax.Array, *, block_m: int = 128,
               interpret: bool = False) -> jax.Array:
    """X L = d;  d: (m, bs), L: (bs, bs) lower-triangular."""
    return _trsm_call(_trsm_lower_kernel, d, L, block_m, interpret)


def trsm_lower_t_batched(a: jax.Array, L: jax.Array, *, block_m: int = 128,
                         interpret: bool = False) -> jax.Array:
    """Population-axis X L^T = a;  a: (K, m, bs), L: (K, bs, bs)."""
    return _trsm_call_batched(_trsm_lower_t_batched_kernel, a, L, block_m, interpret)


def trsm_lower_batched(d: jax.Array, L: jax.Array, *, block_m: int = 128,
                       interpret: bool = False) -> jax.Array:
    """Population-axis X L = d;  d: (K, m, bs), L: (K, bs, bs)."""
    return _trsm_call_batched(_trsm_lower_batched_kernel, d, L, block_m, interpret)
