"""Blocked Cholesky-ridge driver composing the Pallas tile kernels.

Implements  W~ = A B^{-1}  for SPD B exactly as the paper's Alg. 2-4, but at
tile granularity (right-looking blocked factorization):

    for k in diag blocks:   Lkk   = chol_block(Bkk)              (Alg. 2 core)
                            Lik   = trsm_lower_t(Bik, Lkk)       (Alg. 2 panel)
                            Bij  -= Lik @ Ljk^T                  (SYRK, MXU)
    D = A C^{-T}  by block forward substitution                  (Alg. 3)
    W = D C^{-1}  by block backward substitution                 (Alg. 4)

Only the lower triangle of tiles is read/written (the paper's storage
symmetry claim, tile-level); no inverse is ever materialized.  The SYRK and
block-combination matmuls run as plain XLA dots (they are MXU-shaped
already); the substitutions and tile factorizations are the Pallas kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.cholesky import (
    chol_block,
    chol_block_batched,
    trsm_lower,
    trsm_lower_batched,
    trsm_lower_t,
    trsm_lower_t_batched,
)


def _pad_spd(B: jax.Array, block: int):
    s = B.shape[0]
    pad = (-s) % block
    if pad:
        Bp = jnp.pad(B, ((0, pad), (0, pad)))
        diag_pad = jnp.pad(jnp.zeros((s,), B.dtype), (0, pad), constant_values=1.0)
        Bp = Bp + jnp.diag(diag_pad)
        return Bp, s + pad
    return B, s


def cholesky_blocked(B: jax.Array, *, block: int = 256, interpret: bool = False) -> jax.Array:
    """Blocked lower Cholesky C with B = C C^T; returns (s, s) tril."""
    s = B.shape[0]
    a, n = _pad_spd(B, block)
    nb = n // block
    for kb in range(nb):
        k0 = kb * block
        diag = jax.lax.dynamic_slice(a, (k0, k0), (block, block))
        Lkk = chol_block(diag, interpret=interpret)
        a = jax.lax.dynamic_update_slice(a, Lkk, (k0, k0))
        rest = n - k0 - block
        if rest:
            panel = jax.lax.dynamic_slice(a, (k0 + block, k0), (rest, block))
            Lp = trsm_lower_t(panel, Lkk, block_m=min(128, rest), interpret=interpret)
            a = jax.lax.dynamic_update_slice(a, Lp, (k0 + block, k0))
            trail = jax.lax.dynamic_slice(a, (k0 + block, k0 + block), (rest, rest))
            trail = trail - jax.lax.dot(Lp, Lp.T, preferred_element_type=jnp.float32)
            a = jax.lax.dynamic_update_slice(a, trail, (k0 + block, k0 + block))
    return jnp.tril(a)[:s, :s]


def _pad_rows(x: jax.Array, mult: int):
    m = x.shape[0]
    pad = (-m) % mult
    return (jnp.pad(x, ((0, pad), (0, 0))) if pad else x), m


def trsm_blocked_lower_t(A: jax.Array, C: jax.Array, *, block: int = 256,
                         interpret: bool = False) -> jax.Array:
    """D = A (C^T)^{-1}: block forward substitution (Alg. 3 at tile level)."""
    s = C.shape[0]
    pad = (-s) % block
    Cp, n = _pad_spd(C, block) if pad else (C, s)
    if pad:
        Cp = jnp.tril(Cp)
    Ap, m = _pad_rows(jnp.pad(A, ((0, 0), (0, pad))) if pad else A, 8)
    nb = n // block
    D = jnp.zeros_like(Ap)
    for jb in range(nb):
        j0 = jb * block
        rhs = jax.lax.dynamic_slice(Ap, (0, j0), (Ap.shape[0], block))
        if jb:
            # subtract contributions of solved blocks: D[:, <j] @ C[j, <j]^T
            Dleft = jax.lax.dynamic_slice(D, (0, 0), (Ap.shape[0], j0))
            Crow = jax.lax.dynamic_slice(Cp, (j0, 0), (block, j0))
            rhs = rhs - jax.lax.dot(Dleft, Crow.T, preferred_element_type=jnp.float32)
        Cjj = jax.lax.dynamic_slice(Cp, (j0, j0), (block, block))
        Dj = trsm_lower_t(rhs, Cjj, block_m=min(128, Ap.shape[0]), interpret=interpret)
        D = jax.lax.dynamic_update_slice(D, Dj, (0, j0))
    return D[:m, :s]


def trsm_blocked_lower(Dm: jax.Array, C: jax.Array, *, block: int = 256,
                       interpret: bool = False) -> jax.Array:
    """W = D C^{-1}: block backward substitution (Alg. 4 at tile level)."""
    s = C.shape[0]
    pad = (-s) % block
    Cp, n = _pad_spd(C, block) if pad else (C, s)
    if pad:
        Cp = jnp.tril(Cp)
    Dp, m = _pad_rows(jnp.pad(Dm, ((0, 0), (0, pad))) if pad else Dm, 8)
    nb = n // block
    W = jnp.zeros_like(Dp)
    for t in range(nb):
        jb = nb - 1 - t
        j0 = jb * block
        rhs = jax.lax.dynamic_slice(Dp, (0, j0), (Dp.shape[0], block))
        if t:
            right0 = j0 + block
            Wright = jax.lax.dynamic_slice(W, (0, right0), (Dp.shape[0], n - right0))
            Ccol = jax.lax.dynamic_slice(Cp, (right0, j0), (n - right0, block))
            rhs = rhs - jax.lax.dot(Wright, Ccol, preferred_element_type=jnp.float32)
        Cjj = jax.lax.dynamic_slice(Cp, (j0, j0), (block, block))
        Wj = trsm_lower(rhs, Cjj, block_m=min(128, Dp.shape[0]), interpret=interpret)
        W = jax.lax.dynamic_update_slice(W, Wj, (0, j0))
    return W[:m, :s]


def ridge_solve_blocked(A: jax.Array, B: jax.Array, *, block: int = 256,
                        interpret: bool = False) -> jax.Array:
    """Full paper pipeline on tiles: W~ = A B^{-1} via Cholesky + 2 TRSMs."""
    C = cholesky_blocked(B, block=block, interpret=interpret)
    D = trsm_blocked_lower_t(A, C, block=block, interpret=interpret)
    return trsm_blocked_lower(D, C, block=block, interpret=interpret)


# ---------------------------------------------------------------------------
# Population-axis (batched) drivers.  Same blocked schedule as above with a
# leading K axis on every tile: the K independent systems of the population
# engine (repro.core.population) factor/solve in one program, each tile
# kernel gridded over the members (kernels/cholesky.py *_batched variants).
#
# jax.vmap over the unbatched driver lifts to an equivalent program (vmap of
# pallas_call prepends a grid axis); the explicit grid form is kept so the
# population axis stays visible in the kernel launch - grid order, per-member
# block indexing, and VMEM residency are stated rather than derived from
# vmap batching rules, which is the form the TPU scheduling work builds on.
# ---------------------------------------------------------------------------


def _pad_spd_batched(B: jax.Array, block: int):
    k, s, _ = B.shape
    pad = (-s) % block
    if pad:
        Bp = jnp.pad(B, ((0, 0), (0, pad), (0, pad)))
        diag_pad = jnp.pad(jnp.zeros((s,), B.dtype), (0, pad), constant_values=1.0)
        Bp = Bp + jnp.diag(diag_pad)[None]
        return Bp, s + pad
    return B, s


def cholesky_blocked_batched(B: jax.Array, *, block: int = 256,
                             interpret: bool = False) -> jax.Array:
    """Blocked lower Cholesky per member: B (K, s, s) -> C (K, s, s) tril."""
    k, s, _ = B.shape
    a, n = _pad_spd_batched(B, block)
    nb = n // block
    for kb in range(nb):
        k0 = kb * block
        diag = jax.lax.dynamic_slice(a, (0, k0, k0), (k, block, block))
        Lkk = chol_block_batched(diag, interpret=interpret)
        a = jax.lax.dynamic_update_slice(a, Lkk, (0, k0, k0))
        rest = n - k0 - block
        if rest:
            panel = jax.lax.dynamic_slice(a, (0, k0 + block, k0), (k, rest, block))
            Lp = trsm_lower_t_batched(panel, Lkk, block_m=min(128, rest),
                                      interpret=interpret)
            a = jax.lax.dynamic_update_slice(a, Lp, (0, k0 + block, k0))
            trail = jax.lax.dynamic_slice(
                a, (0, k0 + block, k0 + block), (k, rest, rest))
            trail = trail - jnp.einsum(
                "kij,klj->kil", Lp, Lp, preferred_element_type=jnp.float32
            ).astype(a.dtype)
            a = jax.lax.dynamic_update_slice(a, trail, (0, k0 + block, k0 + block))
    return jnp.tril(a)[:, :s, :s]


def _pad_rows_batched(x: jax.Array, mult: int):
    m = x.shape[1]
    pad = (-m) % mult
    return (jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x), m


def trsm_blocked_lower_t_batched(A: jax.Array, C: jax.Array, *, block: int = 256,
                                 interpret: bool = False) -> jax.Array:
    """D = A (C^T)^{-1} per member: A (K, Ny, s), C (K, s, s)."""
    k, s, _ = C.shape
    pad = (-s) % block
    Cp, n = _pad_spd_batched(C, block) if pad else (C, s)
    if pad:
        Cp = jnp.tril(Cp)
    Ap, m = _pad_rows_batched(
        jnp.pad(A, ((0, 0), (0, 0), (0, pad))) if pad else A, 8)
    nb = n // block
    rows = Ap.shape[1]
    D = jnp.zeros_like(Ap)
    for jb in range(nb):
        j0 = jb * block
        rhs = jax.lax.dynamic_slice(Ap, (0, 0, j0), (k, rows, block))
        if jb:
            Dleft = jax.lax.dynamic_slice(D, (0, 0, 0), (k, rows, j0))
            Crow = jax.lax.dynamic_slice(Cp, (0, j0, 0), (k, block, j0))
            rhs = rhs - jnp.einsum(
                "kij,klj->kil", Dleft, Crow, preferred_element_type=jnp.float32
            ).astype(rhs.dtype)
        Cjj = jax.lax.dynamic_slice(Cp, (0, j0, j0), (k, block, block))
        Dj = trsm_lower_t_batched(rhs, Cjj, block_m=min(128, rows),
                                  interpret=interpret)
        D = jax.lax.dynamic_update_slice(D, Dj, (0, 0, j0))
    return D[:, :m, :s]


def trsm_blocked_lower_batched(Dm: jax.Array, C: jax.Array, *, block: int = 256,
                               interpret: bool = False) -> jax.Array:
    """W = D C^{-1} per member: Dm (K, Ny, s), C (K, s, s)."""
    k, s, _ = C.shape
    pad = (-s) % block
    Cp, n = _pad_spd_batched(C, block) if pad else (C, s)
    if pad:
        Cp = jnp.tril(Cp)
    Dp, m = _pad_rows_batched(
        jnp.pad(Dm, ((0, 0), (0, 0), (0, pad))) if pad else Dm, 8)
    nb = n // block
    rows = Dp.shape[1]
    W = jnp.zeros_like(Dp)
    for t in range(nb):
        jb = nb - 1 - t
        j0 = jb * block
        rhs = jax.lax.dynamic_slice(Dp, (0, 0, j0), (k, rows, block))
        if t:
            right0 = j0 + block
            Wright = jax.lax.dynamic_slice(W, (0, 0, right0), (k, rows, n - right0))
            Ccol = jax.lax.dynamic_slice(Cp, (0, right0, j0), (k, n - right0, block))
            rhs = rhs - jnp.einsum(
                "kij,kjl->kil", Wright, Ccol, preferred_element_type=jnp.float32
            ).astype(rhs.dtype)
        Cjj = jax.lax.dynamic_slice(Cp, (0, j0, j0), (k, block, block))
        Wj = trsm_lower_batched(rhs, Cjj, block_m=min(128, rows),
                                interpret=interpret)
        W = jax.lax.dynamic_update_slice(W, Wj, (0, 0, j0))
    return W[:, :m, :s]


def ridge_solve_blocked_batched(A: jax.Array, B: jax.Array, *, block: int = 256,
                                interpret: bool = False) -> jax.Array:
    """Population-axis tile pipeline: W~_k = A_k B_k^{-1} for every member k.

    A: (K, Ny, s), B: (K, s, s) -> (K, Ny, s).
    """
    C = cholesky_blocked_batched(B, block=block, interpret=interpret)
    D = trsm_blocked_lower_t_batched(A, C, block=block, interpret=interpret)
    return trsm_blocked_lower_batched(D, C, block=block, interpret=interpret)
