"""Pallas TPU kernel: fused single-window streaming step (paper Sec. 3.1).

The latency-critical operation of the serving path is infer-before-update:
for each freshly arrived window the system must produce predictions from
the *current* parameters before the training update touches them.  The
two-kernel composition (``kernels.reservoir`` then ``kernels.dprr``) round-
trips the full state sequence X (B, T, Nx) through HBM between the two
calls; this kernel fuses the whole read path

    reservoir scan -> DPRR accumulation -> readout logits

into ONE ``pallas_call``: the recurrent state (1, n_pad) and the DPRR
accumulator tile (n_pad, n_pad) both live in VMEM scratch for the whole
time loop, so X is never materialized anywhere - HBM traffic is one read
of the masked inputs J plus one (ny_pad,) logits write per sample.  That
is the TPU analogue of the paper's FPGA dataflow, where the reservoir,
DPRR and output MACs are wired back to back with no DRAM in between.

Grid: (batch, time_chunks); time is the minor (sequential) dimension so
the scratch carries across chunks, re-initialized at chunk 0 of every
sample.  The readout weights arrive pre-laid-out as a (ny_pad, n_pad,
n_pad) tile w3 matching the accumulator's layout (``ops.streaming_logits``
builds it): w3[y, i, j] = W[y, i*Nx + j] for the dot-product block and
w3[y, i, Nx] = W[y, Nx^2 + i] for the sum block, so the final logits are
one (1, n_pad^2) x (n_pad^2, ny_pad) MXU contraction of the flattened
accumulator.  The bias is added by the wrapper.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _streaming_kernel(
    len_ref,     # scalar prefetch: (B,) int32 valid lengths
    j_ref,       # (chunk_t, 1, n_pad) masked inputs for this sample
    L_ref,       # (n_pad, n_pad) ring matrix (zero padded, ring lane mirrored)
    qpow_ref,    # (1, n_pad) ring powers
    pq_ref,      # (1, 2) f32: [p, q] (q folded into L/qpow)
    w3_ref,      # (ny_pad, n_pad, n_pad) readout tile
    out_ref,     # (1, ny_pad) logits (written at the last time chunk)
    state,       # VMEM scratch (1, n_pad) recurrent state
    acc,         # VMEM scratch (n_pad, n_pad) DPRR accumulator
    *,
    f: Callable[[jax.Array], jax.Array],
    chunk_t: int,
    n_nodes: int,
):
    b = pl.program_id(0)
    tc = pl.program_id(1)
    n_pad = acc.shape[0]

    @pl.when(tc == 0)
    def _init():
        state[...] = jnp.zeros_like(state)   # x(0) = 0 (paper Sec. 2.2)
        acc[...] = jnp.zeros_like(acc)

    p = pq_ref[0, 0]
    Lt = L_ref[...].T
    qpow = qpow_ref[...]
    length = len_ref[b]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1)

    def step(t, _):
        x_prev = state[...]
        j_k = j_ref[t, :, :]                      # (1, n_pad)
        a = p * f(j_k + x_prev)
        x_k = jax.lax.dot(a, Lt, preferred_element_type=jnp.float32) \
            + x_prev[:, -1:] * qpow
        k_global = tc * chunk_t + t
        live = k_global < length
        x_k = jnp.where(live, x_k, x_prev)        # freeze past valid length
        # DPRR contribution of step k: x(k) . [x(k-1), 1]^T, masked to the
        # true nodes; a frozen (dead) step contributes exactly zero, matching
        # compute_dprr's row masking.
        x1m = jnp.where((col < n_nodes) & live, x_k, 0.0)
        x0_aug = jnp.where(
            col < n_nodes, x_prev, jnp.where(col == n_nodes, 1.0, 0.0)
        )
        acc[...] += jax.lax.dot_general(
            x1m, x0_aug,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        state[...] = x_k
        return 0

    jax.lax.fori_loop(0, chunk_t, step, 0)

    @pl.when(tc == pl.num_programs(1) - 1)
    def _readout():
        flat = acc[...].reshape(1, n_pad * n_pad)
        w = w3_ref[...].reshape(w3_ref.shape[0], n_pad * n_pad)
        out_ref[...] = jax.lax.dot_general(
            flat, w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def _streaming_kernel_q8(
    len_ref,     # scalar prefetch: (B,) int32 valid lengths
    j_ref,       # (chunk_t, 1, n_pad) masked inputs (fp32)
    Lq_ref,      # (n_pad, n_pad) int8 ring-matrix codes (scale sL)
    qpow_ref,    # (1, n_pad) f32 ring powers (the ring wrap stays fp32)
    scal_ref,    # (1, 4) f32: [p, sx, sL, sw]
    w3q_ref,     # (ny_pad, n_pad, n_pad) int8 readout codes (scale sw)
    out_ref,     # (1, ny_pad) f32 logits (written at the last time chunk)
    state,       # VMEM scratch (1, n_pad) int32 state *codes*
    acc,         # VMEM scratch (n_pad, n_pad) int32 DPRR code accumulator
    *,
    f: Callable[[jax.Array], jax.Array],
    chunk_t: int,
    n_nodes: int,
):
    """Int8 variant of ``_streaming_kernel``: the reservoir mix and the DPRR
    accumulation run int8 x int8 -> int32 on symmetric codes; only the
    nonlinearity, the ring wrap and the final readout dequant are fp32.
    Exact-math contract shared with ``ref.streaming_q8_sim`` (the oracle) -
    integer arithmetic carries no rounding, so the two agree bitwise on the
    codes and to fp rounding on the dequantized logits."""
    b = pl.program_id(0)
    tc = pl.program_id(1)
    n_pad = acc.shape[0]

    @pl.when(tc == 0)
    def _init():
        state[...] = jnp.zeros_like(state)
        acc[...] = jnp.zeros_like(acc)

    p = scal_ref[0, 0]
    sx = scal_ref[0, 1]
    sL = scal_ref[0, 2]
    LqT = Lq_ref[...].T
    qpow = qpow_ref[...]
    length = len_ref[b]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1)

    def step(t, _):
        xq_prev = state[...]                      # (1, n_pad) int32 codes
        x_prev = xq_prev.astype(jnp.float32) * sx
        j_k = j_ref[t, :, :]
        a = p * f(j_k + x_prev)
        aq = jnp.clip(jnp.round(a / sx), -127, 127).astype(jnp.int8)
        y = jax.lax.dot(
            aq, LqT, preferred_element_type=jnp.int32
        )
        x_k = y.astype(jnp.float32) * (sx * sL) + x_prev[:, -1:] * qpow
        xq_k = jnp.clip(jnp.round(x_k / sx), -127, 127).astype(jnp.int32)
        k_global = tc * chunk_t + t
        live = k_global < length
        xq_k = jnp.where(live, xq_k, xq_prev)     # freeze in the code domain
        x1m = jnp.where((col < n_nodes) & live, xq_k, 0)
        x0_aug = jnp.where(
            col < n_nodes, xq_prev, jnp.where(col == n_nodes, 1, 0)
        )
        acc[...] += jax.lax.dot_general(
            x1m.astype(jnp.int8), x0_aug.astype(jnp.int8),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        state[...] = xq_k
        return 0

    jax.lax.fori_loop(0, chunk_t, step, 0)

    @pl.when(tc == pl.num_programs(1) - 1)
    def _readout():
        sw = scal_ref[0, 3]
        # dequantize per accumulator column (x columns sx^2, ones column sx)
        colscale = jnp.where(
            col == n_nodes, sx, sx * sx).astype(jnp.float32)
        racc = acc[...].astype(jnp.float32) * colscale
        flat = racc.reshape(1, n_pad * n_pad)
        w = w3q_ref[...].reshape(
            w3q_ref.shape[0], n_pad * n_pad).astype(jnp.float32) * sw
        out_ref[...] = jax.lax.dot_general(
            flat, w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def streaming_step_pallas(
    j_seq: jax.Array,     # (B, T_pad, n_pad) f32; node padding must be zero
    L: jax.Array,         # (n_pad, n_pad) ring matrix, zero padded + mirrored
    qpow: jax.Array,      # (n_pad,)
    lengths: jax.Array,   # (B,) int32
    p: jax.Array,         # scalar
    q: jax.Array,         # scalar
    w3: jax.Array,        # (ny_pad, n_pad, n_pad) readout tile
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    chunk_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns raw logits (B, ny_pad) (bias not yet added).

    Same ring-padding contract as ``kernels.reservoir.reservoir_pallas``:
    L/qpow are built for the padded node count with the true last node
    mirrored into the last padded lane (``ops.streaming_logits`` does this),
    so the in-kernel ring wrap ``x_prev[:, -1:]`` reads node Nx-1.
    """
    b, t_pad, n_pad = j_seq.shape
    ny_pad = w3.shape[0]
    assert t_pad % chunk_t == 0, (t_pad, chunk_t)
    assert n_pad % 128 == 0 and n_nodes < n_pad
    jt = jnp.swapaxes(j_seq, 0, 1)  # (T, B, N): time-major for the grid

    kernel = functools.partial(
        _streaming_kernel, f=f, chunk_t=chunk_t, n_nodes=n_nodes
    )
    pq = jnp.stack([p.astype(jnp.float32), q.astype(jnp.float32)]).reshape(1, 2)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, t_pad // chunk_t),
        in_specs=[
            pl.BlockSpec((chunk_t, 1, n_pad), lambda bb, tc, len_ref: (tc, bb, 0)),
            pl.BlockSpec((n_pad, n_pad), lambda bb, tc, len_ref: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda bb, tc, len_ref: (0, 0)),
            pl.BlockSpec((1, 2), lambda bb, tc, len_ref: (0, 0)),
            pl.BlockSpec((ny_pad, n_pad, n_pad), lambda bb, tc, len_ref: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ny_pad), lambda bb, tc, len_ref: (bb, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, n_pad), jnp.float32),
            pltpu.VMEM((n_pad, n_pad), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, ny_pad), jnp.float32),
        interpret=interpret,
    )(lengths.astype(jnp.int32), jt, L, qpow.reshape(1, -1), pq, w3)


def streaming_step_pallas_q8(
    j_seq: jax.Array,     # (B, T_pad, n_pad) f32; node padding must be zero
    Lq: jax.Array,        # (n_pad, n_pad) int8 ring-matrix codes
    qpow: jax.Array,      # (n_pad,) f32
    lengths: jax.Array,   # (B,) int32
    w3q: jax.Array,       # (ny_pad, n_pad, n_pad) int8 readout codes
    scales: jax.Array,    # (4,) f32: [p, sx, sL, sw] (all > 0)
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    chunk_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Quantized fused step: returns raw fp32 logits (B, ny_pad).

    Same grid/padding contract as ``streaming_step_pallas``; the VMEM
    residents shrink to int32 code tiles and the two hot dots run on int8
    operands.  ``ops.streaming_logits_q8`` owns the code/scale prep (ring
    codes from the fp32 ring matrix, readout codes from ``QuantParams``).
    """
    b, t_pad, n_pad = j_seq.shape
    ny_pad = w3q.shape[0]
    assert t_pad % chunk_t == 0, (t_pad, chunk_t)
    assert n_pad % 128 == 0 and n_nodes < n_pad
    jt = jnp.swapaxes(j_seq, 0, 1)  # (T, B, N): time-major for the grid

    kernel = functools.partial(
        _streaming_kernel_q8, f=f, chunk_t=chunk_t, n_nodes=n_nodes
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, t_pad // chunk_t),
        in_specs=[
            pl.BlockSpec((chunk_t, 1, n_pad), lambda bb, tc, len_ref: (tc, bb, 0)),
            pl.BlockSpec((n_pad, n_pad), lambda bb, tc, len_ref: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda bb, tc, len_ref: (0, 0)),
            pl.BlockSpec((1, 4), lambda bb, tc, len_ref: (0, 0)),
            pl.BlockSpec((ny_pad, n_pad, n_pad), lambda bb, tc, len_ref: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ny_pad), lambda bb, tc, len_ref: (bb, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, n_pad), jnp.int32),
            pltpu.VMEM((n_pad, n_pad), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, ny_pad), jnp.float32),
        interpret=interpret,
    )(lengths.astype(jnp.int32), jt, Lq.astype(jnp.int8),
      qpow.astype(jnp.float32).reshape(1, -1),
      scales.astype(jnp.float32).reshape(1, 4), w3q.astype(jnp.int8))
