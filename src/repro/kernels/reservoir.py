"""Pallas TPU kernel: fused modular-DFR reservoir chunk (paper Eq. 14).

Per time step the modular DFR computes, batched over samples,

    a(k) = p * f(j(k) + x(k-1))                 # VPU elementwise
    x(k) = a(k) @ L(q)^T + x(k-1)_{Nx} * qpow   # (B, Nx) @ (Nx, Nx) MXU

where L(q)/qpow encode the ring recurrence in closed form (see
repro.core.reservoir).  The kernel runs a whole chunk of time steps with the
state resident in VMEM scratch - the TPU analogue of the FPGA's pipelined
node loop: HBM traffic is one read of J and one write of X per step, the
recurrent state never leaves VMEM.

Grid: (batch_blocks, time_chunks); time is the minor (sequential) dimension
so the state scratch carries across chunks, re-initialized at chunk 0 of
every batch block.  Per-sample valid lengths freeze the state (matching
``run_reservoir(lengths=...)``).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _reservoir_kernel(
    j_ref,       # (chunk_t, block_b, n_pad) masked inputs
    x0_ref,      # (block_b, n_pad) initial state
    L_ref,       # (n_pad, n_pad) ring matrix (zero-padded)
    qpow_ref,    # (1, n_pad) ring powers
    len_ref,     # (block_b, 1) int32 valid lengths
    pq_ref,      # (1, 2) f32: [p, q] (q unused here; folded into L)
    out_ref,     # (chunk_t, block_b, n_pad) states
    state,       # VMEM scratch (block_b, n_pad)
    *,
    f: Callable[[jax.Array], jax.Array],
    chunk_t: int,
):
    tc = pl.program_id(1)

    @pl.when(tc == 0)
    def _init():
        state[...] = x0_ref[...]

    p = pq_ref[0, 0]
    Lt = L_ref[...].T
    qpow = qpow_ref[...]
    lens = len_ref[...]  # (block_b, 1)

    def step(t, _):
        x_prev = state[...]
        j_k = j_ref[t, :, :]
        a = p * f(j_k + x_prev)
        ring_in = x_prev[:, -1:]
        x_k = jax.lax.dot(a, Lt, preferred_element_type=jnp.float32) + ring_in * qpow
        k_global = tc * chunk_t + t
        live = k_global < lens
        x_k = jnp.where(live, x_k, x_prev)
        state[...] = x_k
        out_ref[t, :, :] = x_k
        return 0

    jax.lax.fori_loop(0, chunk_t, step, 0)


def reservoir_pallas(
    j_seq: jax.Array,     # (B, T_pad, n_pad) f32; node padding must be zero
    x0: jax.Array,        # (B, n_pad)
    L: jax.Array,         # (n_pad, n_pad) ring matrix, zero padded
    qpow: jax.Array,      # (n_pad,)
    lengths: jax.Array,   # (B,) int32
    p: jax.Array,         # scalar
    q: jax.Array,         # scalar
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    block_b: int = 8,
    chunk_t: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns states X (B, T_pad, n_pad).

    NOTE on ring padding: L/qpow must be built for the *padded* node count
    with q-powers beyond Nx set to zero (ops.py does this), so the ring wrap
    reads the true node Nx-1, not padding.  The kernel itself reads
    x_prev[:, -1:]; ops.py therefore keeps the true last node replicated
    into the last padded lane (see ``ops.reservoir_states``).
    """
    b, t_pad, n_pad = j_seq.shape
    assert t_pad % chunk_t == 0 and b % block_b == 0
    jt = jnp.swapaxes(j_seq, 0, 1)  # (T, B, N): time-major for the grid

    kernel = functools.partial(_reservoir_kernel, f=f, chunk_t=chunk_t)
    pq = jnp.stack([p.astype(jnp.float32), q.astype(jnp.float32)]).reshape(1, 2)
    out = pl.pallas_call(
        kernel,
        grid=(b // block_b, t_pad // chunk_t),
        out_shape=jax.ShapeDtypeStruct((t_pad, b, n_pad), jnp.float32),
        in_specs=[
            pl.BlockSpec((chunk_t, block_b, n_pad), lambda bb, tc: (tc, bb, 0)),
            pl.BlockSpec((block_b, n_pad), lambda bb, tc: (bb, 0)),
            pl.BlockSpec((n_pad, n_pad), lambda bb, tc: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda bb, tc: (0, 0)),
            pl.BlockSpec((block_b, 1), lambda bb, tc: (bb, 0)),
            pl.BlockSpec((1, 2), lambda bb, tc: (0, 0)),
        ],
        out_specs=pl.BlockSpec((chunk_t, block_b, n_pad), lambda bb, tc: (tc, bb, 0)),
        scratch_shapes=[pltpu.VMEM((block_b, n_pad), jnp.float32)],
        interpret=interpret,
    )(jt, x0, L, qpow.reshape(1, -1), lengths.reshape(-1, 1), pq)
    return jnp.swapaxes(out, 0, 1)
