"""Pallas TPU kernel: flash attention (online softmax, causal + window, GQA).

Grid (B, H, NQ, NK): the KV-block axis is minor (sequential on a TPU core),
so the softmax statistics (m, l) and the output accumulator live in VMEM
scratch across KV blocks; scores never touch HBM.  HBM traffic is exactly
q + k + v reads and one out write - this is the kernel the roofline memory
term models via the 'flashattn_vmem' scope (see launch/hlo_cost.py).

Causal/window masking is applied at tile granularity; fully-masked KV tiles
are skipped via pl.when on the block indices (halves the causal FLOPs vs
the XLA fallback, which must mask a dense product).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

FLASH_SCOPE = "flashattn_vmem"


def _flash_kernel(
    q_ref,    # (1, 1, bq, D)
    k_ref,    # (1, 1, bk, D)
    v_ref,    # (1, 1, bk, D)
    o_ref,    # (1, 1, bq, D)
    m_scr,    # VMEM (bq, 1)
    l_scr,    # VMEM (bq, 1)
    acc_scr,  # VMEM (bq, D)
    *,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    window: int,
    causal: bool,
    scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # tile-level skip: causal tiles entirely above the diagonal, or entirely
    # outside the window, contribute nothing
    first_q = iq * block_q
    last_q = first_q + block_q - 1
    first_k = ik * block_k
    last_k = first_k + block_k - 1
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, first_k <= last_q)
    if window > 0:
        live = jnp.logical_and(live, last_k > first_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        mask = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (B, H, Tq, D)
    k: jax.Array,   # (B, KV, Tk, D)
    v: jax.Array,   # (B, KV, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 1024,
    softmax_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, h, tq, d = q.shape
    _, kv, tk, _ = k.shape
    g = h // kv
    scale = softmax_scale if softmax_scale is not None else d**-0.5
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pq = (-tq) % block_q
    pk = (-tk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (tq + pq) // block_q
    nk = (tk + pk) // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_q=tq, seq_k=tk,
        window=window, causal=causal, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :tq, :]
