"""Public jit'd wrappers around the Pallas kernels, with dispatch.

Backend selection per call:
  * ``backend='tpu'``       - compile the Pallas kernel for TPU (production).
  * ``backend='interpret'`` - run the kernel body in Python on CPU (tests).
  * ``backend='xla'``       - pure-jnp fallback (this container's default;
                              identical math via repro.kernels.ref).
  * ``backend=None``        - auto: 'tpu' on TPU hosts else 'xla'.

All wrappers own the padding/layout contracts documented on the kernels, so
callers deal only in logical shapes.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import reservoir as core_res
from repro.kernels import ref as kref
from repro.kernels.dprr import dprr_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.reservoir import reservoir_pallas
from repro.kernels.ridge_solve import ridge_solve_blocked, cholesky_blocked
from repro.kernels.streaming import (streaming_step_pallas,
                                     streaming_step_pallas_q8)
from repro.kernels.train import train_forward_pallas, train_forward_scan


def _auto_backend(backend: Optional[str]) -> str:
    if backend is not None:
        return backend
    return "tpu" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# Symmetric int8 quantization primitives (the serving fast path's contract;
# same convention as optim.compression's gradient codec: scale = absmax/127
# with an epsilon floor, codes clipped to +-127, zero-point-free)
# ---------------------------------------------------------------------------


def symmetric_scale(absmax: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Symmetric int8 scale from an absolute maximum: ``max(|v|)/127``.

    The epsilon floor keeps an all-zero operand (e.g. a zero-range
    reservoir window) quantizing to all-zero codes instead of NaNs -
    dequantization then reproduces the zeros exactly."""
    return jnp.maximum(absmax.astype(jnp.float32), eps) / 127.0


def quantize_symmetric(v: jax.Array, scale: jax.Array) -> jax.Array:
    """fp -> int8 codes: ``clip(round(v / scale), -127, 127)``."""
    return jnp.clip(
        jnp.round(v.astype(jnp.float32) / scale), -127, 127
    ).astype(jnp.int8)


def dequantize_symmetric(q: jax.Array, scale: jax.Array,
                         dtype=jnp.float32) -> jax.Array:
    """int8 codes -> fp: ``q * scale``."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _ring_padded(q: jax.Array, nx: int, n_pad: int):
    """Ring-padded (L, qpow) for the reservoir/streaming kernels: zero-pad
    to n_pad and mirror the true last node into the last padded lane so the
    in-kernel ring wrap ``x_prev[:, -1:]`` reads node Nx-1 (see
    kernels/reservoir.py docstring)."""
    Lq = core_res.ring_matrix(q, nx, jnp.float32)
    qpow = core_res.ring_powers(q, nx, jnp.float32)
    Lp = jnp.zeros((n_pad, n_pad), jnp.float32).at[:nx, :nx].set(Lq)
    Lp = Lp.at[n_pad - 1, :nx].set(Lq[nx - 1])
    qp = jnp.zeros((n_pad,), jnp.float32).at[:nx].set(qpow)
    qp = qp.at[n_pad - 1].set(qpow[nx - 1])
    return Lp, qp


# ---------------------------------------------------------------------------
# DPRR features
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_nodes", "block_t", "backend"))
def dprr_features(
    x: jax.Array,          # (B, T, Nx) reservoir states
    lengths: jax.Array,    # (B,) int32
    n_nodes: int,
    *,
    block_t: int = 256,
    backend: Optional[str] = None,
) -> jax.Array:
    """Batched DPRR r vectors: (B, Nx*(Nx+1)), kernel-accelerated."""
    backend = _auto_backend(backend)
    b, t, nx = x.shape
    assert nx == n_nodes
    n_pad = max(128, -(-nx // 128) * 128)
    xp = _pad_to(_pad_to(x, 2, n_pad), 1, block_t)

    if backend == "xla":
        acc = jax.vmap(lambda xi, li: kref.dprr_ref(xi, li, n_nodes))(
            xp, lengths
        )
    else:
        interp = backend == "interpret"
        acc = jax.vmap(
            lambda xi, li: dprr_pallas(
                xi, li, n_nodes, block_t=block_t, interpret=interp
            )
        )(xp, lengths.astype(jnp.int32))
    outer = acc[:, :n_nodes, :n_nodes].reshape(b, n_nodes * n_nodes)
    sums = acc[:, :n_nodes, n_nodes]
    return jnp.concatenate([outer, sums], axis=-1)


# ---------------------------------------------------------------------------
# Reservoir states
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "f", "block_b", "chunk_t", "backend")
)
def reservoir_states(
    j_seq: jax.Array,      # (B, T, Nx) masked inputs
    lengths: jax.Array,    # (B,)
    p: jax.Array,
    q: jax.Array,
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    block_b: int = 8,
    chunk_t: int = 128,
    backend: Optional[str] = None,
) -> jax.Array:
    """Batched reservoir states X (B, T, Nx), kernel-accelerated."""
    backend = _auto_backend(backend)
    b, t, nx = j_seq.shape
    if backend == "xla":
        return core_res.run_reservoir(p, q, j_seq, f=f, lengths=lengths)

    n_pad = max(128, -(-nx // 128) * 128)
    jp = _pad_to(_pad_to(_pad_to(j_seq, 2, n_pad), 1, chunk_t), 0, block_b)
    bp, tp = jp.shape[0], jp.shape[1]
    Lp, qp = _ring_padded(q, nx, n_pad)
    x0 = jnp.zeros((bp, n_pad), jnp.float32)
    lens = _pad_to(lengths.astype(jnp.int32), 0, block_b)
    xs = reservoir_pallas(
        jp, x0, Lp, qp, lens, p, q,
        f=f, block_b=block_b, chunk_t=chunk_t,
        interpret=(backend == "interpret"),
    )
    return xs[:b, :t, :nx]


# ---------------------------------------------------------------------------
# Fused training forward (reservoir -> DPRR aux, no materialized X)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "f", "block_b", "chunk_t", "backend")
)
def train_forward(
    j_seq: jax.Array,      # (B, T, Nx) or (T, Nx) masked inputs
    lengths: Optional[jax.Array],  # (B,) int32 (or None = full length)
    p: jax.Array,
    q: jax.Array,
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    block_b: int = 8,
    chunk_t: Optional[int] = None,
    backend: Optional[str] = None,
) -> tuple:
    """Fused training forward: ``(r, x_last, x_prev, j_last)`` in logical
    shapes, with the state sequence X never materialized (see
    kernels.train).  These are exactly the data-dependent ``ForwardAux``
    fields of ``core.backprop`` — the truncated-BP production path
    (``backprop.forward_fused`` wraps this in the custom-VJP layer).

    ``chunk_t=None`` sizes the sequential time chunk to the window (capped
    at 128) like ``streaming_logits``; ``block_b`` tiles the batch axis of
    the Pallas grid.  The XLA backend ignores both (its single fused scan
    has no tiling).
    """
    backend = _auto_backend(backend)
    nx = j_seq.shape[-1]
    assert nx == n_nodes
    if backend == "xla" or j_seq.ndim == 2:
        # the Pallas grid is batched; the unbatched (T, Nx) form only
        # occurs on host-side call sites, which the scan serves directly
        return train_forward_scan(j_seq, lengths, p, q, f=f)

    b, t = j_seq.shape[0], j_seq.shape[1]
    if lengths is None:
        lengths = jnp.full((b,), t, jnp.int32)
    if chunk_t is None:
        chunk_t = min(128, -(-t // 8) * 8)
    n_pad = max(128, -(-nx // 128) * 128)
    jp = _pad_to(_pad_to(_pad_to(j_seq.astype(jnp.float32), 2, n_pad),
                         1, chunk_t), 0, block_b)
    Lp, qp = _ring_padded(q, nx, n_pad)
    lens = _pad_to(jnp.clip(lengths.astype(jnp.int32), 0, t), 0, block_b)
    acc, x_last, x_prev, j_last = train_forward_pallas(
        jp, Lp, qp, lens, p, q, nx,
        f=f, block_b=block_b, chunk_t=chunk_t,
        interpret=(backend == "interpret"),
    )
    dt = j_seq.dtype
    outer = acc[:b, :nx, :nx].reshape(b, nx * nx)
    sums = acc[:b, :nx, nx]
    r = jnp.concatenate([outer, sums], axis=-1).astype(dt)
    return (r, x_last[:b, :nx].astype(dt), x_prev[:b, :nx].astype(dt),
            j_last[:b, :nx].astype(dt))


# ---------------------------------------------------------------------------
# Fused streaming step (reservoir -> DPRR -> readout, one kernel call)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "f", "chunk_t", "backend")
)
def streaming_logits(
    j_seq: jax.Array,      # (B, T, Nx) masked inputs
    lengths: jax.Array,    # (B,) int32
    p: jax.Array,
    q: jax.Array,
    W: jax.Array,          # (Ny, Nr) readout weights
    b: jax.Array,          # (Ny,) readout bias
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    chunk_t: Optional[int] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Batched readout logits (B, Ny) in one fused kernel call.

    The serving path's infer-before-update: reservoir scan, DPRR
    accumulation and the readout contraction run back to back with the
    recurrent state and the accumulator tile resident in VMEM - the state
    sequence X is never materialized (see kernels.streaming).

    ``chunk_t=None`` sizes the sequential time chunk to the window (capped
    at 128), so short serving windows don't pay for zero-padded kernel
    steps; pass an explicit value to pin the chunking.
    """
    backend = _auto_backend(backend)
    if backend == "xla":
        return kref.streaming_logits_ref(j_seq, lengths, p, q, W, b, f=f)

    bsz, t, nx = j_seq.shape
    assert nx == n_nodes
    if chunk_t is None:
        chunk_t = min(128, -(-t // 8) * 8)
    ny = W.shape[0]
    n_pad = max(128, -(-nx // 128) * 128)
    ny_pad = max(8, -(-ny // 8) * 8)
    jp = _pad_to(_pad_to(j_seq, 2, n_pad), 1, chunk_t)
    Lp, qp = _ring_padded(q, nx, n_pad)
    # readout tile w3 in the accumulator's (i, j) layout: dot-product block
    # at [:nx, :nx], sum block down the ones column j = nx
    Wblk = W[:, : nx * nx].reshape(ny, nx, nx).astype(jnp.float32)
    Wsum = W[:, nx * nx :].astype(jnp.float32)
    w3 = jnp.zeros((ny_pad, n_pad, n_pad), jnp.float32)
    w3 = w3.at[:ny, :nx, :nx].set(Wblk)
    w3 = w3.at[:ny, :nx, nx].set(Wsum)
    out = streaming_step_pallas(
        jp, Lp, qp, lengths.astype(jnp.int32), p, q, w3, nx,
        f=f, chunk_t=chunk_t, interpret=(backend == "interpret"),
    )
    return out[:, :ny] + b


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "f", "chunk_t", "backend")
)
def streaming_logits_slots(
    j_seq: jax.Array,      # (S, B, T, Nx) masked inputs, slot axis leading
    lengths: jax.Array,    # (S, B) int32
    p: jax.Array,          # (S,) per-slot reservoir gains
    q: jax.Array,          # (S,)
    W: jax.Array,          # (S, Ny, Nr) per-slot readout weights
    b: jax.Array,          # (S, Ny)
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    chunk_t: Optional[int] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Slot-axis batched ``streaming_logits``: (S, B, Ny) in one dispatch.

    The stream server's fused-infer path serves S independent slots, each
    with its own (p, q, W, b); this wrapper owns the slot-axis batching
    contract (one vmapped program over the fused kernel dispatch) so the
    serving loop issues a single call instead of vmapping the public
    single-system API at every call site.

    Under the slot-sharded server (``StreamServer(devices=n)``) this runs
    *inside* ``shard_map``, so S here is the device-LOCAL slot count
    (global S / n) and the vmap stays collective-free - per-slot batching
    composes with slot sharding with no change to this wrapper."""
    return jax.vmap(
        lambda j_s, len_s, p_s, q_s, W_s, b_s: streaming_logits(
            j_s, len_s, p_s, q_s, W_s, b_s, n_nodes,
            f=f, chunk_t=chunk_t, backend=backend,
        )
    )(j_seq, lengths, p, q, W, b)


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "f", "chunk_t", "backend")
)
def streaming_logits_q8(
    j_seq: jax.Array,      # (B, T, Nx) masked inputs (any float dtype)
    lengths: jax.Array,    # (B,) int32
    p: jax.Array,          # scalar reservoir gain
    q: jax.Array,          # scalar ring gain (quantized into ring codes here)
    Wq: jax.Array,         # (Ny, Nr) int8 readout codes
    w_scale: jax.Array,    # scalar f32 readout scale (0 = unarmed)
    x_scale: jax.Array,    # scalar f32 reservoir-state scale (0 = unarmed)
    b: jax.Array,          # (Ny,) fp readout bias (stays fp)
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    chunk_t: Optional[int] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Quantized fused serving logits (B, Ny): the int8 fast path.

    Owns the whole code/scale prep so callers deal only in ``QuantParams``
    leaves: the ring matrix is built fp32 (ring-padded exactly like the
    fp32 kernel) and coded per call with its own scale - it depends only on
    the frozen ``q``, so XLA hoists the coding out of the serving loop -
    while the readout codes arrive pre-folded from the refresh boundary.

    Unarmed scales (0, i.e. no refresh has folded codes yet) are replaced
    by 1.0 so the program stays NaN-free; the serving caller must discard
    those slots' logits (``StreamServer`` selects fp32 logits until the
    slot arms).  Inputs are cast to fp32: the quantized path defines its
    own precision end to end, so bf16 configs feed it unchanged.
    """
    backend = _auto_backend(backend)
    bsz, t, nx = j_seq.shape
    assert nx == n_nodes
    if chunk_t is None:
        chunk_t = min(128, -(-t // 8) * 8)
    ny = Wq.shape[0]
    n_pad = max(128, -(-nx // 128) * 128)
    ny_pad = max(8, -(-ny // 8) * 8)
    jp = _pad_to(_pad_to(j_seq.astype(jnp.float32), 2, n_pad), 1, chunk_t)
    Lp, qp = _ring_padded(q, nx, n_pad)
    sL = symmetric_scale(jnp.max(jnp.abs(Lp)))
    Lq8 = quantize_symmetric(Lp, sL)
    sx = jnp.where(x_scale > 0, x_scale, 1.0).astype(jnp.float32)
    sw = jnp.where(w_scale > 0, w_scale, 1.0).astype(jnp.float32)
    # readout codes in the accumulator's (i, j) layout (the int8 twin of
    # the fp32 w3 tile): dot-product block at [:nx, :nx], sums at j = nx
    Wblk = Wq[:, : nx * nx].reshape(ny, nx, nx)
    Wsum = Wq[:, nx * nx:]
    w3q = jnp.zeros((ny_pad, n_pad, n_pad), jnp.int8)
    w3q = w3q.at[:ny, :nx, :nx].set(Wblk)
    w3q = w3q.at[:ny, :nx, nx].set(Wsum)
    scales = jnp.stack([p.astype(jnp.float32), sx, sL, sw])
    if backend == "xla":
        out = kref.streaming_q8_sim(
            jp, Lq8, qp, lengths.astype(jnp.int32), w3q, scales, nx, f=f
        )
    else:
        out = streaming_step_pallas_q8(
            jp, Lq8, qp, lengths.astype(jnp.int32), w3q, scales, nx,
            f=f, chunk_t=chunk_t, interpret=(backend == "interpret"),
        )
    return out[:, :ny] + b.astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "f", "chunk_t", "backend")
)
def streaming_logits_slots_q8(
    j_seq: jax.Array,      # (S, B, T, Nx) masked inputs, slot axis leading
    lengths: jax.Array,    # (S, B) int32
    p: jax.Array,          # (S,) per-slot reservoir gains
    q: jax.Array,          # (S,)
    Wq: jax.Array,         # (S, Ny, Nr) int8 per-slot readout codes
    w_scale: jax.Array,    # (S,) f32
    x_scale: jax.Array,    # (S,) f32
    b: jax.Array,          # (S, Ny)
    n_nodes: int,
    *,
    f: Callable[[jax.Array], jax.Array] = lambda z: z,
    chunk_t: Optional[int] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Slot-axis batched ``streaming_logits_q8``: (S, B, Ny) f32 in one
    dispatch - the int8 twin of ``streaming_logits_slots``, same
    slot-local contract under the sharded server (S is device-local inside
    ``shard_map``, no collectives)."""
    return jax.vmap(
        lambda j_s, len_s, p_s, q_s, Wq_s, ws_s, xs_s, b_s:
        streaming_logits_q8(
            j_s, len_s, p_s, q_s, Wq_s, ws_s, xs_s, b_s, n_nodes,
            f=f, chunk_t=chunk_t, backend=backend,
        )
    )(j_seq, lengths, p, q, Wq, w_scale, x_scale, b)


# ---------------------------------------------------------------------------
# Ridge solve
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def ridge_solve(
    A: jax.Array,
    B: jax.Array,
    *,
    block: int = 256,
    backend: Optional[str] = None,
) -> jax.Array:
    """W~ = A B^{-1} via blocked Cholesky + TRSMs, kernel-accelerated."""
    backend = _auto_backend(backend)
    if backend == "xla":
        return kref.ridge_solve_ref(A, B)
    return ridge_solve_blocked(A, B, block=block, interpret=(backend == "interpret"))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "backend"),
)
def flash_attention(
    q: jax.Array,   # (B, H, Tq, D)
    k: jax.Array,   # (B, KV, Tk, D)
    v: jax.Array,   # (B, KV, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 1024,
    backend: Optional[str] = None,
) -> jax.Array:
    backend = _auto_backend(backend)
    if backend == "xla":
        return kref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=(backend == "interpret"),
    )


@functools.partial(jax.jit, static_argnames=("block", "backend"))
def cholesky(
    B: jax.Array, *, block: int = 256, backend: Optional[str] = None
) -> jax.Array:
    backend = _auto_backend(backend)
    if backend == "xla":
        return kref.chol_ref(B)
    return cholesky_blocked(B, block=block, interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("sign", "backend"))
def cholupdate_window(
    L: jax.Array,          # (s, s) or (K, s, s) live lower factor(s)
    X: jax.Array,          # (W, s) or (K, W, s) sample rows, stream order
    *,
    sign: float = 1.0,
    backend: Optional[str] = None,
) -> jax.Array:
    """Rank-1 rotate a window of sample rows into live Cholesky factor(s).

    Padding contract: s pads to the 128-lane tile with an identity diagonal
    on the factor and zero sample columns - zero rotations are exact no-ops,
    so the logical block is bit-equivalent to the unpadded sweep.
    """
    backend = _auto_backend(backend)
    batched = L.ndim == 3
    if backend == "xla":
        from repro.core import ridge as core_ridge

        if batched:
            return jax.vmap(
                lambda l, x: core_ridge.cholupdate_window(l, x, sign)
            )(L, X)
        return core_ridge.cholupdate_window(L, X, sign)

    from repro.core.ridge import pad_factor_identity
    from repro.kernels.cholupdate import cholupdate_block, cholupdate_block_batched

    s = L.shape[-1]
    n_pad = max(128, -(-s // 128) * 128)
    pad = n_pad - s
    if pad:
        L = pad_factor_identity(L, pad)
        X = _pad_to(X, X.ndim - 1, n_pad)
    interp = backend == "interpret"
    if batched:
        out = cholupdate_block_batched(L, X, sign=sign, interpret=interp)
        return out[:, :s, :s]
    out = cholupdate_block(L, X, sign=sign, interpret=interp)
    return out[:s, :s]
