"""Shared model layers: params-with-axes, norms, dense, embeddings, RoPE.

Parameters are plain jnp arrays; every init returns a ``PV`` (param + logical
axes) leaf.  ``split_tree`` separates values from axes so the runtime can
derive PartitionSpecs (see repro.distributed.sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PV:
    """A parameter leaf: value + logical axes.

    Registered as a pytree node (axes ride as static aux data) so PV trees
    survive jax.eval_shape - the dry-run derives parameter shapes AND
    logical axes without ever allocating.
    """

    value: Array
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), tuple(self.axes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], tuple(aux))


def is_pv(x) -> bool:
    return isinstance(x, PV)


def split_tree(tree):
    """Tree of PV -> (tree of arrays, tree of axes tuples)."""
    vals = jax.tree_util.tree_map(lambda pv: pv.value, tree, is_leaf=is_pv)
    axes = jax.tree_util.tree_map(lambda pv: pv.axes, tree, is_leaf=is_pv)
    return vals, axes


def dense_init(
    key: jax.Array,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype=jnp.bfloat16,
    scale: Optional[float] = None,
    fan_in: Optional[int] = None,
) -> PV:
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    w = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return PV(w, axes)


def zeros_init(shape, axes, dtype=jnp.bfloat16) -> PV:
    return PV(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.bfloat16) -> PV:
    return PV(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Norms (f32 accumulation)
# ---------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: Array, weight: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (classic + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: (B, T, H, D); positions: (B, T) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(
    x: Array, positions: Array, theta: float = 1e4, sections=(16, 24, 24)
) -> Array:
    """Multimodal RoPE (Qwen2-VL): positions (B, T, 3) = (t, h, w) ids.

    The head_dim/2 frequency slots are split into ``sections`` groups, each
    rotated by one positional stream.  For text tokens the three streams are
    equal and M-RoPE degenerates to 1-D RoPE.
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    n_half = d // 2
    secs = jnp.asarray(sections)
    assert int(sum(sections)) == n_half, (sections, n_half)
    # section id of each frequency slot
    bounds = jnp.cumsum(secs)
    slot = jnp.arange(n_half)
    sec_id = jnp.sum(slot[:, None] >= bounds[None, :], axis=-1)  # (D/2,) in 0..2
    pos = positions.astype(jnp.float32)[..., sec_id]  # (B, T, D/2)
    angles = pos * freqs  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embed_init(key: jax.Array, vocab: int, d_model: int, dtype=jnp.bfloat16) -> PV:
    w = (jax.random.normal(key, (vocab, d_model), jnp.float32) * (d_model**-0.5))
    return PV(w.astype(dtype), ("vocab", "embed_no_shard"))


def embed_lookup(table: Array, ids: Array) -> Array:
    return jnp.take(table, ids, axis=0)


def unembed(x: Array, table: Array) -> Array:
    """Logits in f32 (stable CE)."""
    return jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32), table.astype(jnp.float32)
    )
