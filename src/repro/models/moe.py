"""Top-1 (Switch-style) Mixture of Experts with grouped einsum dispatch.

TPU-native formulation (GShard/Switch lineage): tokens are grouped; dispatch
and combine are einsums against a (G, S, E, C) one-hot tensor, so the
expert-parallel resharding (G sharded over 'data'  ->  E sharded over
'model') lowers to an all-to-all under XLA SPMD, and the expert FFN itself
is a dense batched matmul on the MXU.

Group sizing (auto):
  * long sequences (T >= 1024): groups of 1024 tokens *within* a sequence -
    groups inherit the batch's data sharding, dispatch stays local;
  * decode / tiny batches (B*T <= 4096): one global group - the routing
    tensors are a few MB, and capacity stays ~cf x tokens/E so expert FLOPs
    don't balloon (slots = max(E, S*cf));
  * otherwise: largest power-of-two divisor of T up to 2048.

Capacity: C = ceil(S / E * capacity_factor); overflow tokens fall through
the residual connection (standard Switch behaviour).  Router: f32 logits,
switch load-balance aux loss + router z-loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.layers import PV, dense_init

Array = jax.Array


def moe_init(key, d_model: int, d_ff: int, n_experts: int, dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, n_experts), ("embed_no_shard", None),
                             jnp.float32, scale=d_model**-0.5),
        "w_gate": dense_init(ks[1], (n_experts, d_model, d_ff),
                             ("expert", "embed", "expert_mlp"), dtype),
        "w_up": dense_init(ks[2], (n_experts, d_model, d_ff),
                           ("expert", "embed", "expert_mlp"), dtype),
        "w_down": dense_init(ks[3], (n_experts, d_ff, d_model),
                             ("expert", "expert_mlp", "embed"), dtype),
    }


def _group_size(b: int, t: int) -> int:
    if t >= 1024 and t % 1024 == 0:
        return 1024
    if b * t <= 4096:
        return b * t  # single global group
    s = 1
    for cand in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if t % cand == 0:
            return cand
    return s


def moe_apply(
    p: Dict,
    x: Array,                    # (B, T, d_model)
    *,
    capacity_factor: float = 1.25,
    activation: str = "silu",
) -> Tuple[Array, Dict]:
    """Returns (output, aux) with aux = {lb_loss, z_loss, fraction_dropped}."""
    b, t, d = x.shape
    e = p["router"].shape[-1]
    s_g = _group_size(b, t)
    g = (b * t) // s_g
    xg = x.reshape(g, s_g, d)
    if g > 1:
        xg = shard_act(xg, ("batch", None, None))
    cap = max(1, int(s_g / e * capacity_factor))

    # ---- routing (bf16 inputs, f32 accumulation: an explicit f32 cast of
    # xg here makes XLA share a gathered-f32 copy of the WHOLE token tensor
    # with the dispatch einsum - 2x the collective bytes; see EXPERIMENTS S4)
    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    expert = jnp.argmax(probs, axis=-1)                     # (G, S)
    gate = jnp.max(probs, axis=-1)                          # (G, S)
    onehot = jax.nn.one_hot(expert, e, dtype=jnp.float32)   # (G, S, E)

    # switch load-balance loss: E * sum_e fraction_tokens_e * mean_prob_e
    frac = jnp.mean(onehot, axis=1)                         # (G, E)
    mean_p = jnp.mean(probs, axis=1)                        # (G, E)
    lb_loss = e * jnp.mean(jnp.sum(frac * mean_p, axis=-1))
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- capacity assignment ----
    pos = jnp.cumsum(onehot, axis=1) - 1.0                  # (G, S, E) slot id
    pos = jnp.sum(pos * onehot, axis=-1)                    # (G, S) slot of token
    keep = (pos < cap).astype(jnp.float32)
    gate = gate * keep
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)  # (G,S,C)
    disp = onehot.astype(x.dtype)[..., None] * slot_oh[..., None, :]     # (G,S,E,C)
    disp = disp * keep.astype(x.dtype)[..., None, None]

    # ---- dispatch: (G,S,D) x (G,S,E,C) -> (G,E,C,D); a2a under SPMD ----
    # the E dim adopts the expert weights' sharding ('expert' -> data axis);
    # XLA realizes the G->E resharding as an all-to-all over 'data'.
    # checkpoint_name lets the save_moe remat policy keep xe so the backward
    # pass never re-runs the dispatch collective.
    xe = jnp.einsum("gsd,gsec->gecd", xg, disp)
    xe = shard_act(xe, (None, "expert", None, None))
    xe = jax.ad_checkpoint.checkpoint_name(xe, "moe_xe")

    # ---- expert FFN (batched over E, sharded over 'model') ----
    gate_h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
    up_h = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    act = jax.nn.silu(gate_h.astype(jnp.float32)).astype(x.dtype) if activation == "silu" \
        else jax.nn.gelu(gate_h.astype(jnp.float32)).astype(x.dtype)
    h = act * up_h
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    ye = shard_act(ye, (None, "expert", None, None))

    # ---- combine: weight by gate prob, a2a back ----
    comb = disp * gate.astype(x.dtype)[..., None, None]
    y = jnp.einsum("gecd,gsec->gsd", ye, comb)
    y = y.reshape(b, t, d)

    aux = {
        "lb_loss": lb_loss,
        "z_loss": z_loss,
        "fraction_dropped": 1.0 - jnp.mean(keep),
    }
    return y, aux
