"""RWKV-6 "Finch" block: token shift + data-dependent-decay linear attention.

Recurrence per head (state S in R^{dk x dv}):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = q_t (diag(u) k_t^T v_t + S_{t-1})        (bonus u on current token)

with per-channel data-dependent decay w_t = exp(-exp(lambda_t)) produced by
a low-rank MLP from the token-shifted input (the Finch contribution).

TPU adaptation: the training/prefill path uses the *chunkwise-parallel*
formulation (flash-linear-attention family): within chunks of length C the
contribution is computed with dense (C x C) matmuls on the MXU; across
chunks the state is carried by a lax.scan with cumulative decay products.
Cost O(T/C * (C^2 d + C d^2)) and O(d^2) state - this is what makes the
long_500k cell tractable (constant-size state at decode).

Decode: single-token recurrence on the (H, dk, dv) state.

Simplifications vs the reference implementation (documented): the low-rank
"ddlerp" token-shift interpolation is applied to the decay path only; other
projections use plain token shift.  Head layout (B, T, H, D).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import fsdp_gather, shard_act
from repro.models.layers import PV, dense_init, ones_init, zeros_init, rms_norm

Array = jax.Array


def rwkv_block_init(key, d_model: int, head_dim: int = 64, lora_dim: int = 64,
                    dtype=jnp.bfloat16) -> Dict:
    n_heads = d_model // head_dim
    ks = jax.random.split(key, 10)
    return {
        "w_r": dense_init(ks[0], (d_model, d_model), ("embed", "heads"), dtype),
        "w_k": dense_init(ks[1], (d_model, d_model), ("embed", "heads"), dtype),
        "w_v": dense_init(ks[2], (d_model, d_model), ("embed", "heads"), dtype),
        "w_g": dense_init(ks[3], (d_model, d_model), ("embed", "heads"), dtype),
        "w_o": dense_init(ks[4], (d_model, d_model), ("heads", "embed"), dtype),
        # data-dependent decay: low-rank lambda(x) = (tanh(x A)) B + bias
        "w_dec_a": dense_init(ks[5], (d_model, lora_dim), ("embed", None), dtype),
        "w_dec_b": dense_init(ks[6], (lora_dim, d_model), (None, "heads"), dtype),
        "dec_bias": PV(jnp.full((d_model,), -6.0, dtype), ("heads",)),
        "bonus": zeros_init((n_heads, head_dim), ("heads", "head_dim"), dtype),
        # token-shift mixing coefficients
        "mix": PV(0.5 * jnp.ones((5, d_model), dtype), (None, "embed_no_shard")),
        "ln_x": zeros_init((d_model,), ("embed_no_shard",), dtype),
    }


def _token_shift(x: Array, x_prev: Array) -> Array:
    """shifted(x)[t] = x[t-1]; x_prev fills t = 0. x: (B, T, D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


class RwkvState(NamedTuple):
    s: Array       # (B, H, dk, dv) linear-attention state
    x_last: Array  # (B, D) last token input (for token shift)


def _projections(p: Dict, x: Array, x_prev: Array, n_heads: int, head_dim: int):
    b, t, d = x.shape
    xs = _token_shift(x, x_prev)
    mix = p["mix"].astype(x.dtype)
    xr = x * mix[0] + xs * (1 - mix[0])
    xk = x * mix[1] + xs * (1 - mix[1])
    xv = x * mix[2] + xs * (1 - mix[2])
    xg = x * mix[3] + xs * (1 - mix[3])
    xd = x * mix[4] + xs * (1 - mix[4])
    w_r = fsdp_gather(p["w_r"], ("embed", "heads"))
    w_k = fsdp_gather(p["w_k"], ("embed", "heads"))
    w_v = fsdp_gather(p["w_v"], ("embed", "heads"))
    w_g = fsdp_gather(p["w_g"], ("embed", "heads"))
    r = (xr @ w_r.astype(x.dtype)).reshape(b, t, n_heads, head_dim)
    k = (xk @ w_k.astype(x.dtype)).reshape(b, t, n_heads, head_dim)
    v = (xv @ w_v.astype(x.dtype)).reshape(b, t, n_heads, head_dim)
    g = jax.nn.silu((xg @ w_g.astype(x.dtype)).astype(jnp.float32))
    lam = jnp.tanh(xd @ p["w_dec_a"].astype(x.dtype)) @ p["w_dec_b"].astype(x.dtype)
    lam = lam.astype(jnp.float32) + p["dec_bias"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(lam)).reshape(b, t, n_heads, head_dim)  # decay in (0,1)
    return r, k, v, g, w


def rwkv_attention_chunked(
    r: Array, k: Array, v: Array, w: Array, bonus: Array,
    s0: Array, chunk: int = 128,
) -> Tuple[Array, Array]:
    """Chunkwise-parallel RWKV6 linear attention.

    r/k/v/w: (B, T, H, D) with decay w in (0, 1); bonus: (H, D).
    s0: (B, H, D, D) initial state.  Returns (out (B,T,H,D), s_T).

    Within a chunk (f32 math):
      decay products  W_t = prod_{u<=t} w_u   (cumprod, exclusive of s0 step)
      intra           o_t += sum_{u<t} [q_t (W_t/W_u) . k_u] v_u + q_t diag(u) k_t v_t
      inter           o_t += (q_t * W_t^excl) @ S_prev
      state           S   = diag(W_C) S_prev + sum_u (k_u W_C/W_u)^T v_u
    """
    b, t, h, d = r.shape
    assert t % chunk == 0, (t, chunk)
    n_ch = t // chunk
    # keep the scanned xs in the input dtype (bf16 on the LM path): any
    # resharding the chunking induces then moves half the bytes; each chunk
    # is cast to f32 LOCALLY inside the step (recurrence stays f32-exact)
    rc = r.reshape(b, n_ch, chunk, h, d)
    kc = k.reshape(b, n_ch, chunk, h, d)
    vc = v.reshape(b, n_ch, chunk, h, d)
    wc = w.reshape(b, n_ch, chunk, h, d)
    rc, kc, vc, wc = (jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, wc))

    def step(s, inp):
        rc_, kc_, vc_, wc_ = (a.astype(jnp.float32) for a in inp)
        log_w = jnp.log(jnp.maximum(wc_, 1e-38))
        cum_ = jnp.cumsum(log_w, axis=1)         # inclusive cumulative decay
        cume_ = cum_ - log_w                     # exclusive
        total_ = cum_[:, -1:, :, :]              # (B, 1, H, D)
        # inter-chunk: q decayed to chunk start attends the carried state
        q_dec = rc_ * jnp.exp(cume_)             # (B, C, H, D)
        o_inter = jnp.einsum("bchd,bhde->bche", q_dec, s)
        # intra-chunk: causal (C x C) scores with relative decay
        # score[t, u] = sum_d q[t,d] k[u,d] exp(cum_excl[t,d] - cum[u,d]), u < t
        q_s = rc_ * jnp.exp(cume_)
        k_s = kc_ * jnp.exp(-cum_)
        scores = jnp.einsum("bchd,buhd->bhcu", q_s, k_s)
        c_idx = jnp.arange(rc_.shape[1])
        causal = c_idx[:, None] > c_idx[None, :]
        scores = jnp.where(causal[None, None], scores, 0.0)
        o_intra = jnp.einsum("bhcu,buhe->bche", scores, vc_)
        # current-token bonus term: q_t diag(u) k_t^T v_t
        qk = jnp.einsum("bchd,bchd->bch", rc_ * bonus[None, None], kc_)
        o_bonus = qk[..., None] * vc_
        # state update: S = diag(exp(total)) S + sum_u (k_u exp(total-cum_u))^T v_u
        k_dec = kc_ * jnp.exp(total_ - cum_)
        s_new = jnp.exp(total_[:, 0, :, :, None]) * s + jnp.einsum(
            "bchd,bche->bhde", k_dec, vc_
        )
        return s_new, (o_inter + o_intra + o_bonus).astype(r.dtype)

    s_final, outs = jax.lax.scan(
        step, s0.astype(jnp.float32), (rc, kc, vc, wc)
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(b, t, h, d)
    return out, s_final


def rwkv_block_apply(
    p: Dict, x: Array, state: RwkvState, *, head_dim: int = 64,
    chunk: int = 128, eps: float = 1e-5,
) -> Tuple[Array, RwkvState]:
    """Full RWKV6 time-mix block over a sequence. x: (B, T, D)."""
    b, t, d = x.shape
    n_heads = d // head_dim
    r, k, v, g, w = _projections(p, x, state.x_last, n_heads, head_dim)
    bonus = p["bonus"].astype(jnp.float32)
    out, s_new = rwkv_attention_chunked(r, k, v, w, bonus, state.s, chunk=min(chunk, t))
    # per-head group norm (ln_x)
    out = rms_norm(out.reshape(b, t, d), p["ln_x"], eps)
    out = out * g.astype(out.dtype)
    out = shard_act(out, ("batch", None, "act_model"))
    w_o = fsdp_gather(p["w_o"], ("heads", "embed"))
    y = out.astype(x.dtype) @ w_o.astype(x.dtype)
    return y, RwkvState(s=s_new.astype(state.s.dtype), x_last=x[:, -1, :])


def rwkv_decode_step(
    p: Dict, x: Array, state: RwkvState, *, head_dim: int = 64, eps: float = 1e-5,
) -> Tuple[Array, RwkvState]:
    """Single token: x (B, 1, D); recurrent state update (O(d^2))."""
    b, _, d = x.shape
    n_heads = d // head_dim
    r, k, v, g, w = _projections(p, x, state.x_last, n_heads, head_dim)
    rf, kf, vf, wf = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
    bonus = p["bonus"].astype(jnp.float32)
    s = state.s.astype(jnp.float32)  # (B, H, dk, dv)
    # o = q (diag(u) k^T v + S):
    kv = jnp.einsum("bhd,bhe->bhde", kf, vf)
    o = jnp.einsum("bhd,bhde->bhe", rf * bonus[None], kv) + jnp.einsum(
        "bhd,bhde->bhe", rf, s
    )
    s_new = wf[..., None] * s + kv
    out = rms_norm(o.reshape(b, 1, d).astype(x.dtype), p["ln_x"], eps)
    out = out * g.astype(out.dtype)
    y = out.astype(x.dtype) @ p["w_o"].astype(x.dtype)
    return y, RwkvState(s=s_new.astype(state.s.dtype), x_last=x[:, -1, :])
