"""Feed-forward blocks: SwiGLU / GeLU MLPs with TP sharding annotations."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import fsdp_gather, shard_act
from repro.models.layers import PV, dense_init

Array = jax.Array


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16, gated: bool = True) -> Dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), ("embed", "mlp"), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), ("mlp", "embed"), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), ("embed", "mlp"), dtype)
    return p


def mlp_apply(p: Dict, x: Array, activation: str = "silu") -> Array:
    """x: (B, T, d_model); TP over the d_ff dimension; FSDP gathers the
    weights at use (see sharding.fsdp_gather)."""
    w_up = fsdp_gather(p["w_up"], ("embed", "mlp"))
    w_down = fsdp_gather(p["w_down"], ("mlp", "embed"))
    up = jnp.einsum("btd,df->btf", x, w_up.astype(x.dtype))
    if "w_gate" in p:
        w_gate = fsdp_gather(p["w_gate"], ("embed", "mlp"))
        gate = jnp.einsum("btd,df->btf", x, w_gate.astype(x.dtype))
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) if activation == "silu" \
            else jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
        h = act * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype) if activation == "gelu" \
            else jax.nn.silu(up.astype(jnp.float32)).astype(x.dtype)
    h = shard_act(h, ("batch", None, "act_model"))
    return jnp.einsum("btf,fd->btd", h, w_down.astype(x.dtype))
