"""Attention: GQA with blockwise online-softmax (flash-style in XLA),
sliding windows (gemma3's local:global schedule), cross-attention, and a
KV-cache decode path.

The training/prefill path never materializes the (T, T) score matrix: a scan
over query chunks with an inner scan over KV chunks keeps the live working
set at (block_q, block_k) per head - the memory-roofline behaviour a Pallas
flash kernel would have, expressed so XLA can fuse it (this container cannot
run TPU Pallas, see DESIGN.md).

Layout: q (B, T, H, D), k/v (B, S, KV, D) with H = G * KV (GQA groups).
Softmax statistics are f32; matmuls accumulate f32 via
preferred_element_type.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def _chunk(x: Array, axis: int, size: int) -> Array:
    n = x.shape[axis]
    assert n % size == 0, (n, size)
    shape = list(x.shape)
    shape[axis : axis + 1] = [n // size, size]
    return x.reshape(shape)


def blockwise_attention(
    q: Array,                 # (B, Tq, H, D)
    k: Array,                 # (B, Tk, KV, D)
    v: Array,                 # (B, Tk, KV, D)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = global; w > 0 = only attend to last w keys
    q_offset: int = 0,        # absolute position of q[0] (for prefill chunks)
    block_q: int = 512,
    block_k: int = 1024,
    softmax_scale: Optional[float] = None,
) -> Array:
    """Online-softmax attention over KV chunks; O(Tq*D + bq*bk) live memory."""
    b, tq, h, d = q.shape
    _, tk, kv, _ = k.shape
    assert h % kv == 0
    g = h // kv
    scale = softmax_scale if softmax_scale is not None else d**-0.5

    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    # pad to block multiples (masked out below)
    pq = (-tq) % block_q
    pk = (-tk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    tqp, tkp = tq + pq, tk + pk

    # (nq, B, bq, KV, G, D) query chunks; keys (nk, B, bk, KV, D)
    qc = jnp.moveaxis(_chunk(q.reshape(b, tqp, kv, g, d), 1, block_q), 1, 0)
    kc = jnp.moveaxis(_chunk(k, 1, block_k), 1, 0)
    vc = jnp.moveaxis(_chunk(v, 1, block_k), 1, 0)

    q_pos_in = jnp.arange(block_q)
    k_pos_in = jnp.arange(block_k)

    def q_step(_, qi_pack):
        qi, iq = qi_pack  # qi: (B, bq, KV, G, D)
        q_pos = q_offset + iq * block_q + q_pos_in  # (bq,)

        def kv_step(carry, kj_pack):
            acc, m, l = carry
            kj, vj, jk = kj_pack
            k_pos = jk * block_k + k_pos_in  # (bk,)
            # scores (B, KV, G, bq, bk), f32
            s = jax.lax.dot_general(
                qi.astype(jnp.float32),
                kj.astype(jnp.float32),
                dimension_numbers=((((4,), (3,))), (((0, 2), (0, 2)))),
                preferred_element_type=jnp.float32,
            )  # (B, KV, bq, G, bk) -> fix ordering below
            # dims: batch (B, KV), contracting D: result (B, KV, bq, G, bk)
            s = s * scale
            mask = (k_pos[None, :] <= q_pos[:, None]) if causal else jnp.ones(
                (block_q, block_k), bool
            )
            # window may be a traced per-layer scalar; <= 0 means global
            w_eff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), tkp + tqp)
            mask = mask & (k_pos[None, :] > q_pos[:, None] - w_eff)
            mask = mask & (k_pos[None, :] < tk)  # kv padding
            s = jnp.where(mask[None, None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))  # (B, KV, bq, G)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            # p @ v: (B, KV, bq, G, bk) x (B, bk, KV, D) -> (B, KV, bq, G, D)
            pv = jax.lax.dot_general(
                p,
                vj.astype(jnp.float32),
                dimension_numbers=(((4,), (1,)), ((0, 1), (0, 2))),
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kv, block_q, g, d), jnp.float32)
        m0 = jnp.full((b, kv, block_q, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, block_q, g), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kc, vc, jnp.arange(kc.shape[0])),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, KV, bq, G, D)
        return None, out

    iq = jnp.arange(qc.shape[0])
    # reorder qc to (nq, B, bq, KV, G, D) -> kernel wants (B, bq, KV, G, D)
    _, outs = jax.lax.scan(q_step, None, (qc, iq))
    # outs: (nq, B, KV, bq, G, D) -> (B, T, KV*G, D)
    outs = jnp.moveaxis(outs, 0, 1)  # (B, nq, KV, bq, G, D)
    outs = jnp.moveaxis(outs, 3, 2)  # (B, nq, bq, KV, G, D)
    outs = outs.reshape(b, tqp, kv * g, d)
    return outs[:, :tq].astype(q.dtype)


def make_flash_scoped(causal: bool, block_q: int, block_k: int,
                      use_kernel: bool = False):
    """Flash attention with VMEM-scoped fwd AND bwd.

    The backward pass is the standard flash-attention backward: recompute
    scores blockwise from (q, k, v) - one extra forward's FLOPs, interior
    traffic VMEM-resident.  Expressed as a custom_vjp whose fwd and bwd both
    run inside the ``flashattn_vmem`` named scope, so the roofline walker
    models both directions as kernels (on TPU the fwd IS the Pallas kernel;
    the bwd kernel falls back to the scoped XLA recompute path).
    """
    from repro.kernels.flash_attention import FLASH_SCOPE

    def _fwd_math(q, k, v, window):
        if use_kernel and jax.default_backend() == "tpu":
            from repro.kernels import ops as kops

            out = kops.flash_attention(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                jnp.swapaxes(v, 1, 2), causal=causal, window=0,
                block_q=block_q, block_k=block_k,
            )
            return jnp.swapaxes(out, 1, 2)
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k)

    @jax.custom_vjp
    def f(q, k, v, window):
        with jax.named_scope(FLASH_SCOPE):
            return _fwd_math(q, k, v, window)

    def f_fwd(q, k, v, window):
        with jax.named_scope(FLASH_SCOPE):
            out = _fwd_math(q, k, v, window)
        return out, (q, k, v, window)

    def f_bwd(res, ct):
        q, k, v, window = res
        with jax.named_scope(FLASH_SCOPE):
            # recompute-based flash backward: checkpoint(nothing_saveable)
            # makes the transposed scan recompute scores PER BLOCK instead
            # of stacking per-iteration residuals - exactly the real flash
            # backward kernel's dataflow (and its FLOP count)
            fn = jax.checkpoint(
                lambda q_, k_, v_: blockwise_attention(
                    q_, k_, v_, causal=causal, window=window,
                    block_q=block_q, block_k=block_k,
                ),
                policy=jax.checkpoint_policies.nothing_saveable,
            )
            _, vjp = jax.vjp(fn, q, k, v)
            dq, dk, dv = vjp(ct)
        return dq, dk, dv, jnp.zeros_like(window)

    f.defvjp(f_fwd, f_bwd)
    return f


def decode_attention(
    q: Array,            # (B, 1, H, D)
    k_cache: Array,      # (B, S, KV, D)
    v_cache: Array,      # (B, S, KV, D)
    cache_len: Array,    # (B,) or scalar: number of valid cache entries
    *,
    window: int = 0,
    softmax_scale: Optional[float] = None,
) -> Array:
    """Single-token decode attention against a (padded) KV cache."""
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    scale = softmax_scale if softmax_scale is not None else d**-0.5
    qg = q.reshape(b, kv, g, d)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.broadcast_to(jnp.reshape(cache_len, (-1, 1)), (b, s))
    w_eff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), s + 1)
    valid = valid & (pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - w_eff)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


class KVCache(NamedTuple):
    k: Array  # (B, S_max, KV, D)
    v: Array  # (B, S_max, KV, D)
    length: Array  # (B,) int32 valid entries

    @classmethod
    def zeros(cls, batch: int, max_len: int, n_kv: int, head_dim: int, dtype=jnp.bfloat16):
        shape = (batch, max_len, n_kv, head_dim)
        return cls(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            length=jnp.zeros((batch,), jnp.int32),
        )

    def append(self, k_new: Array, v_new: Array) -> "KVCache":
        """Append T_new tokens per row.

        T_new == 1 (decode): per-row write at each row's own length
        (continuous batching - rows are at different positions).
        T_new > 1 (chunked prefill): uniform position (length[0]).
        """
        if k_new.shape[1] == 1:
            def put(buf, upd, pos):
                return jax.lax.dynamic_update_slice(buf, upd, (pos, 0, 0))

            k = jax.vmap(put)(self.k, k_new.astype(self.k.dtype), self.length)
            v = jax.vmap(put)(self.v, v_new.astype(self.v.dtype), self.length)
        else:
            pos = self.length[0]
            k = jax.lax.dynamic_update_slice(
                self.k, k_new.astype(self.k.dtype), (0, pos, 0, 0)
            )
            v = jax.lax.dynamic_update_slice(
                self.v, v_new.astype(self.v.dtype), (0, pos, 0, 0)
            )
        return KVCache(k=k, v=v, length=self.length + k_new.shape[1])
