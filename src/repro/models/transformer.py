"""Generic multi-family LM stack: dense / MoE / RWKV6 / Mamba2-hybrid /
encoder-decoder, built from one ArchConfig.

Layer stacks are parameter-stacked and driven by ``lax.scan`` (compile time
O(1) in depth; per-layer remat policy), with per-layer scanned scalars for
heterogeneous schedules (gemma3's 5:1 local:global windows).

Decode paths operate on explicit cache pytrees so `serve_step` lowers with
ShapeDtypeStruct caches in the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd_mod
from repro.distributed.sharding import shard_act
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache
from repro.models.layers import (
    PV,
    apply_m_rope,
    apply_rope,
    dense_init,
    embed_init,
    embed_lookup,
    is_pv,
    layer_norm,
    ones_init,
    rms_norm,
    split_tree,
    unembed,
    zeros_init,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def stack_layer_trees(trees):
    """Stack per-layer PV trees into one tree with a leading 'layers' axis."""

    def stack(*pvs):
        return PV(
            jnp.stack([pv.value for pv in pvs]), ("layers",) + tuple(pvs[0].axes)
        )

    return jax.tree_util.tree_map(stack, *trees, is_leaf=is_pv)


def _norm(cfg: ArchConfig, p: Dict, x: Array, name: str) -> Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p[f"{name}_w"], p[f"{name}_b"], cfg.rms_eps)
    return rms_norm(x, p[f"{name}_w"], cfg.rms_eps)


def _norm_init(cfg: ArchConfig, d: int, name: str) -> Dict:
    if cfg.norm == "layernorm":
        return {
            f"{name}_w": ones_init((d,), ("embed_no_shard",), cfg.dtype),
            f"{name}_b": zeros_init((d,), ("embed_no_shard",), cfg.dtype),
        }
    return {f"{name}_w": zeros_init((d,), ("embed_no_shard",), cfg.dtype)}


def _remat(cfg: ArchConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    elif cfg.remat_policy == "save_moe":
        # keep the dispatched expert inputs: backward re-runs only the local
        # expert FFN, never the dispatch collective (EXPERIMENTS S4)
        pol = jax.checkpoint_policies.save_only_these_names("moe_xe")
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# attention sub-block
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ArchConfig, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nq), ("embed", "heads"), cfg.dtype),
        "wk": dense_init(ks[1], (d, nkv), ("embed", "kv"), cfg.dtype),
        "wv": dense_init(ks[2], (d, nkv), ("embed", "kv"), cfg.dtype),
        "wo": dense_init(ks[3], (nq, d), ("heads", "embed"), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((nq,), ("heads",), cfg.dtype)
        p["bk"] = zeros_init((nkv,), ("kv",), cfg.dtype)
        p["bv"] = zeros_init((nkv,), ("kv",), cfg.dtype)
    return p


def _qkv(cfg: ArchConfig, p: Dict, xq: Array, xkv: Array):
    b, tq, d = xq.shape
    tk = xkv.shape[1]
    hd = cfg.head_dim
    q = xq @ shd_mod.fsdp_gather(p["wq"], ("embed", "heads")).astype(xq.dtype)
    k = xkv @ shd_mod.fsdp_gather(p["wk"], ("embed", "kv")).astype(xq.dtype)
    v = xkv @ shd_mod.fsdp_gather(p["wv"], ("embed", "kv")).astype(xq.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, tq, cfg.n_heads, hd)
    k = k.reshape(b, tk, cfg.n_kv_heads, hd)
    v = v.reshape(b, tk, cfg.n_kv_heads, hd)
    q = shard_act(q, ("batch", None, "heads", None))
    k = shard_act(k, ("batch", None, "kv", None))
    v = shard_act(v, ("batch", None, "kv", None))
    return q, k, v


def attn_apply_full(
    cfg: ArchConfig,
    p: Dict,
    x: Array,
    window,
    *,
    causal: bool = True,
    positions: Optional[Array] = None,
    kv_x: Optional[Array] = None,       # cross attention source
) -> Array:
    """Training/prefill attention over a full sequence."""
    b, t, d = x.shape
    xkv = kv_x if kv_x is not None else x
    q, k, v = _qkv(cfg, p, x, xkv)
    if cfg.pos == "rope" and kv_x is None:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        if cfg.m_rope:
            pos3 = positions if positions.ndim == 3 else jnp.repeat(
                positions[..., None], 3, axis=-1
            )
            q = apply_m_rope(q, pos3, cfg.rope_theta, cfg.m_rope_sections)
            k = apply_m_rope(k, pos3, cfg.rope_theta, cfg.m_rope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_impl == "pallas":
        # NOTE: per-layer scanned windows (gemma3) carry a traced window
        # scalar; the Pallas kernel needs it static, so windowed archs keep
        # the (scoped) XLA path on TPU until the scan is split by window kind.
        use_kernel = not cfg.window_pattern
        flash = attn_mod.make_flash_scoped(
            causal, cfg.block_q, cfg.block_k, use_kernel=use_kernel
        )
        out = flash(q, k, v, jnp.asarray(window, jnp.int32))
    else:
        out = attn_mod.blockwise_attention(
            q, k, v, causal=causal, window=window,
            block_q=cfg.block_q, block_k=cfg.block_k,
        )
    out = out.reshape(b, t, cfg.n_heads * cfg.head_dim)
    wo = shd_mod.fsdp_gather(p["wo"], ("heads", "embed"))
    return out @ wo.astype(x.dtype)


def attn_apply_decode(
    cfg: ArchConfig,
    p: Dict,
    x: Array,             # (B, 1, d)
    cache: KVCache,
    window,
    *,
    cross: bool = False,
) -> Tuple[Array, KVCache]:
    b, _, d = x.shape
    hd = cfg.head_dim
    if cross:
        # cross-attention at decode: cache holds precomputed enc K/V
        q = (x @ p["wq"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(q.dtype)
        q = q.reshape(b, 1, cfg.n_heads, hd)
        out = attn_mod.decode_attention(q, cache.k, cache.v, cache.length, window=0)
        out = out.reshape(b, 1, cfg.n_heads * hd)
        return out @ p["wo"].astype(x.dtype), cache
    q, k, v = _qkv(cfg, p, x, x)
    pos = cache.length[0]
    if cfg.pos == "rope":
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
        if cfg.m_rope:
            pos3 = jnp.repeat(positions[..., None], 3, axis=-1)
            q = apply_m_rope(q, pos3, cfg.rope_theta, cfg.m_rope_sections)
            k = apply_m_rope(k, pos3, cfg.rope_theta, cfg.m_rope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    cache = cache.append(k, v)
    out = attn_mod.decode_attention(q, cache.k, cache.v, cache.length, window=window)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return out @ p["wo"].astype(x.dtype), cache


# ---------------------------------------------------------------------------
# decoder layer (dense / moe)
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ArchConfig, cross: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"attn": attn_init(ks[0], cfg)}
    p.update(_norm_init(cfg, d, "ln_attn"))
    if cross:
        p["cross"] = attn_init(ks[3], cfg, cross=True)
        p.update(_norm_init(cfg, d, "ln_cross"))
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], d, cfg.d_ff, cfg.n_experts, cfg.dtype)
    else:
        p["mlp"] = ffn_mod.mlp_init(ks[1], d, cfg.d_ff, cfg.dtype,
                                    gated=(cfg.act == "silu"))
    p.update(_norm_init(cfg, d, "ln_mlp"))
    return p


def _barrier(cfg: ArchConfig, h: Array) -> Array:
    return jax.lax.optimization_barrier(h) if cfg.act_barrier else h


def layer_apply_full(
    cfg: ArchConfig, p: Dict, x: Array, window, *,
    causal: bool = True, positions=None, enc_out: Optional[Array] = None,
) -> Tuple[Array, Dict]:
    aux = {}
    h = attn_apply_full(cfg, p["attn"], _norm(cfg, p, x, "ln_attn"), window,
                        causal=causal, positions=positions)
    h = _barrier(cfg, h)
    x = x + h
    if "cross" in p and enc_out is not None:
        h = attn_apply_full(cfg, p["cross"], _norm(cfg, p, x, "ln_cross"),
                            0, causal=False, kv_x=enc_out)
        x = x + h
    if cfg.family == "moe":
        h, aux = moe_mod.moe_apply(p["moe"], _norm(cfg, p, x, "ln_mlp"),
                                   capacity_factor=cfg.capacity_factor,
                                   activation=cfg.act)
    else:
        h = ffn_mod.mlp_apply(p["mlp"], _norm(cfg, p, x, "ln_mlp"), cfg.act)
    x = x + _barrier(cfg, h)
    x = shard_act(x, ("batch", None, None))
    return x, aux


def layer_apply_decode(
    cfg: ArchConfig, p: Dict, x: Array, cache: KVCache, window,
    cross_cache: Optional[KVCache] = None,
) -> Tuple[Array, KVCache]:
    h, cache = attn_apply_decode(cfg, p["attn"], _norm(cfg, p, x, "ln_attn"),
                                 cache, window)
    x = x + h
    if "cross" in p and cross_cache is not None:
        h, _ = attn_apply_decode(cfg, p["cross"], _norm(cfg, p, x, "ln_cross"),
                                 cross_cache, 0, cross=True)
        x = x + h
    if cfg.family == "moe":
        h, _ = moe_mod.moe_apply(p["moe"], _norm(cfg, p, x, "ln_mlp"),
                                 capacity_factor=2.0, activation=cfg.act)
    else:
        h = ffn_mod.mlp_apply(p["mlp"], _norm(cfg, p, x, "ln_mlp"), cfg.act)
    x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# rwkv / ssm layers (attention-free families)
# ---------------------------------------------------------------------------


def rwkv_layer_init(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    p = {"time_mix": rwkv_mod.rwkv_block_init(ks[0], d, cfg.rwkv_head_dim,
                                              dtype=cfg.dtype)}
    p.update(_norm_init(cfg, d, "ln_attn"))
    p["mlp"] = ffn_mod.mlp_init(ks[1], d, cfg.d_ff, cfg.dtype, gated=True)
    p.update(_norm_init(cfg, d, "ln_mlp"))
    return p


def ssm_layer_init(key, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    p = {"ssm": ssm_mod.ssm_block_init(key, d, cfg.ssm_state, cfg.ssm_head_dim,
                                       cfg.ssm_expand, cfg.dtype)}
    p.update(_norm_init(cfg, d, "ln_attn"))
    return p


# ---------------------------------------------------------------------------
# the model facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Transformer:
    cfg: ArchConfig

    # ---- init -------------------------------------------------------------

    def init_pv(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        p: Dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, cfg.dtype)
        }
        p.update(_norm_init(cfg, cfg.d_model, "ln_f"))
        if not cfg.tie_embeddings:
            p["unembed"] = dense_init(
                keys[1], (cfg.padded_vocab, cfg.d_model), ("vocab", "embed_no_shard"),
                cfg.dtype, fan_in=cfg.d_model,
            )
        if cfg.is_encdec:
            lk = jax.random.split(keys[2], cfg.enc_layers)
            p["enc_layers"] = stack_layer_trees([layer_init(k, cfg) for k in lk])
            lk = jax.random.split(keys[3], cfg.dec_layers)
            p["dec_layers"] = stack_layer_trees(
                [layer_init(k, cfg, cross=True) for k in lk]
            )
            p.update(_norm_init(cfg, cfg.d_model, "ln_enc"))
            # absolute positions for whisper-style models
            p["pos_embed"] = PV(
                _sinusoidal(cfg.max_abs_pos, cfg.d_model).astype(cfg.dtype),
                ("seq", "embed_no_shard"),
            )
        elif cfg.rwkv:
            lk = jax.random.split(keys[2], cfg.n_layers)
            p["layers"] = stack_layer_trees([rwkv_layer_init(k, cfg) for k in lk])
        elif cfg.family == "hybrid":
            lk = jax.random.split(keys[2], cfg.n_layers)
            p["layers"] = stack_layer_trees([ssm_layer_init(k, cfg) for k in lk])
            p["shared_attn"] = layer_init(keys[4], cfg)  # ONE shared block
        else:
            lk = jax.random.split(keys[2], cfg.n_layers)
            p["layers"] = stack_layer_trees([layer_init(k, cfg) for k in lk])
        return p

    def init(self, key) -> Tuple[Dict, Dict]:
        """Returns (params values tree, logical axes tree)."""
        return split_tree(self.init_pv(key))

    def axes(self) -> Dict:
        """Logical axes tree without allocating (via eval_shape)."""
        pv = jax.eval_shape(lambda: self.init_pv(jax.random.PRNGKey(0)))
        return jax.tree_util.tree_map(
            lambda leaf: leaf.axes, pv, is_leaf=lambda x: isinstance(x, PV)
        )

    def param_shapes(self) -> Dict:
        pv = jax.eval_shape(lambda: self.init_pv(jax.random.PRNGKey(0)))
        return jax.tree_util.tree_map(
            lambda leaf: jax.ShapeDtypeStruct(leaf.value.shape, leaf.value.dtype),
            pv, is_leaf=lambda x: isinstance(x, PV),
        )

    # ---- layer-window schedule ---------------------------------------------

    def window_schedule(self, n_layers: int) -> Array:
        cfg = self.cfg
        if not cfg.window_pattern:
            return jnp.zeros((n_layers,), jnp.int32)
        pat = [cfg.window_for_layer(i) for i in range(n_layers)]
        return jnp.asarray(pat, jnp.int32)

    # ---- forward (train / prefill trunk) ------------------------------------

    def _trunk(self, params: Dict, x: Array, *, causal=True,
               positions=None, enc_out=None, collect_aux=False):
        cfg = self.cfg
        if cfg.rwkv:
            return self._trunk_rwkv(params, x)
        if cfg.family == "hybrid":
            return self._trunk_hybrid(params, x)
        key_layers = "dec_layers" if cfg.is_encdec else "layers"
        windows = self.window_schedule(
            cfg.dec_layers if cfg.is_encdec else cfg.n_layers
        )

        def body(carry, inp):
            x = carry
            lp, w = inp
            x, aux = layer_apply_full(cfg, lp, x, w, causal=causal,
                                      positions=positions, enc_out=enc_out)
            stats = (aux.get("lb_loss", jnp.zeros((), jnp.float32)),
                     aux.get("z_loss", jnp.zeros((), jnp.float32)))
            return x, stats

        body = _remat(cfg, body)
        x, stats = jax.lax.scan(body, x, (params[key_layers], windows))
        aux = {"lb_loss": jnp.mean(stats[0]), "z_loss": jnp.mean(stats[1])}
        return x, aux

    def _trunk_rwkv(self, params: Dict, x: Array):
        cfg = self.cfg
        b = x.shape[0]
        hd = cfg.rwkv_head_dim
        nh = cfg.d_model // hd

        def body(carry, lp):
            x = carry
            st = rwkv_mod.RwkvState(
                s=jnp.zeros((b, nh, hd, hd), jnp.float32),
                x_last=jnp.zeros((b, cfg.d_model), x.dtype),
            )
            h, _ = rwkv_mod.rwkv_block_apply(
                lp["time_mix"], _norm(cfg, lp, x, "ln_attn"), st,
                head_dim=hd, chunk=cfg.scan_chunk, eps=cfg.rms_eps,
            )
            x = x + _barrier(cfg, h)
            h = ffn_mod.mlp_apply(lp["mlp"], _norm(cfg, lp, x, "ln_mlp"), cfg.act)
            x = x + _barrier(cfg, h)
            return shard_act(x, ("batch", None, None)), None

        body = _remat(cfg, body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, {}

    def _trunk_hybrid(self, params: Dict, x: Array):
        cfg = self.cfg
        b = x.shape[0]

        def ssm_body(carry, lp):
            x = carry
            st = ssm_mod.ssm_state_init(b, cfg.d_model, cfg.ssm_state,
                                        cfg.ssm_head_dim, cfg.ssm_expand)
            h, _ = ssm_mod.ssm_block_apply(
                lp["ssm"], _norm(cfg, lp, x, "ln_attn"), st,
                ssm_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, chunk=cfg.scan_chunk, eps=cfg.rms_eps,
            )
            return shard_act(x + h, ("batch", None, None)), None

        ssm_body = _remat(cfg, ssm_body)
        per = cfg.attn_every
        n_seg, rem = divmod(cfg.n_layers, per)
        layers = params["layers"]

        def seg_slice(lo, ln):
            return jax.tree_util.tree_map(lambda a: a[lo : lo + ln], layers)

        shared_fn = _remat(
            cfg,
            lambda x: layer_apply_full(cfg, params["shared_attn"], x, 0)[0],
        )
        for s in range(n_seg):
            x, _ = jax.lax.scan(ssm_body, x, seg_slice(s * per, per))
            x = shared_fn(x)
        if rem:
            x, _ = jax.lax.scan(ssm_body, x, seg_slice(n_seg * per, rem))
        return x, {}

    # ---- public entry points -------------------------------------------------

    def train_logits(self, params: Dict, tokens=None, embeds=None,
                     enc_embeds=None) -> Tuple[Array, Dict]:
        cfg = self.cfg
        if cfg.is_encdec:
            return self._encdec_logits(params, tokens, enc_embeds)
        if embeds is not None:
            x = embeds
        else:
            x = embed_lookup(params["embed"], tokens)
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        x = shard_act(x, ("batch", None, None))
        x, aux = self._trunk(params, x, collect_aux=True)
        x = _norm(cfg, params, x, "ln_f")
        logits = unembed(x, params.get("unembed", params["embed"]))
        return logits, aux

    def _encdec_logits(self, params: Dict, tokens, enc_embeds):
        cfg = self.cfg
        enc = enc_embeds + params["pos_embed"][: enc_embeds.shape[1]][None]
        windows = self.window_schedule(cfg.enc_layers)

        def enc_body(carry, inp):
            lp, w = inp
            h, _ = layer_apply_full(cfg, lp, carry, w, causal=False)
            return h, None

        enc_body = _remat(cfg, enc_body)
        enc, _ = jax.lax.scan(enc_body, enc, (params["enc_layers"], windows))
        enc = _norm(cfg, params, enc, "ln_enc")

        x = embed_lookup(params["embed"], tokens)
        x = x + params["pos_embed"][: x.shape[1]][None].astype(x.dtype)
        x, aux = self._trunk(params, x, enc_out=enc)
        x = _norm(cfg, params, x, "ln_f")
        logits = unembed(x, params.get("unembed", params["embed"]))
        return logits, aux

    # ---- caches ---------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0):
        cfg = self.cfg
        if cfg.rwkv:
            hd = cfg.rwkv_head_dim
            nh = cfg.d_model // hd
            return {
                "s": jnp.zeros((cfg.n_layers, batch, nh, hd, hd), jnp.float32),
                "x_last": jnp.zeros((cfg.n_layers, batch, cfg.d_model), cfg.dtype),
                "len": jnp.zeros((batch,), jnp.int32),
            }
        if cfg.family == "hybrid":
            d_in = cfg.ssm_expand * cfg.d_model
            nh = d_in // cfg.ssm_head_dim
            conv_dim = d_in + 2 * cfg.ssm_state
            n_sites = cfg.n_layers // cfg.attn_every
            return {
                "s": jnp.zeros((cfg.n_layers, batch, nh, cfg.ssm_state,
                                cfg.ssm_head_dim), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch, ssm_mod.CONV_K - 1,
                                   conv_dim), cfg.dtype),
                "attn_k": jnp.zeros((n_sites, batch, max_len, cfg.n_kv_heads,
                                     cfg.head_dim), cfg.dtype),
                "attn_v": jnp.zeros((n_sites, batch, max_len, cfg.n_kv_heads,
                                     cfg.head_dim), cfg.dtype),
                "len": jnp.zeros((batch,), jnp.int32),
            }
        n_layers = cfg.dec_layers if cfg.is_encdec else cfg.n_layers
        cache = {
            "k": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), cfg.dtype),
            "v": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), cfg.dtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }
        if cfg.is_encdec:
            cache["cross_k"] = jnp.zeros(
                (n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype
            )
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
            cache["enc_len"] = jnp.zeros((batch,), jnp.int32)
        return cache

    def cache_specs(self, batch: int, max_len: int, enc_len: int = 0):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len, enc_len))

    def cache_axes(self, batch: int, max_len: int, enc_len: int = 0):
        """Logical axes for the cache pytree (for sharding)."""
        cache = self.cache_specs(batch, max_len, enc_len)

        def ax(path_leaf):
            name, leaf = path_leaf
            if name in ("len", "enc_len"):
                return (None,)
            if name in ("s",):
                return (None, "batch", "heads", None, None)
            if name == "conv":
                return (None, "batch", None, "mlp")
            if name == "x_last":
                return (None, "batch", None)
            # k/v caches: (L, B, S, KV, D) - shard batch over data, kv heads
            # over model when divisible, else head_dim over model ("kv_alt";
            # the divisibility guard keeps the first axis that fits)
            return (None, "batch", None, "kv", "kv_alt")

        return {k: ax((k, v)) for k, v in cache.items()}

    # ---- decode -----------------------------------------------------------------

    def decode_step(self, params: Dict, token: Array, cache) -> Tuple[Array, Any]:
        cfg = self.cfg
        if cfg.rwkv:
            return self._decode_rwkv(params, token, cache)
        if cfg.family == "hybrid":
            return self._decode_hybrid(params, token, cache)
        x = embed_lookup(params["embed"], token)
        if not cfg.is_encdec:  # matches train_logits' scaling convention
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        if cfg.pos == "absolute":
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], cache["len"][0], 1, axis=0
            )[None].astype(x.dtype)
        n_layers = cfg.dec_layers if cfg.is_encdec else cfg.n_layers
        windows = self.window_schedule(n_layers)

        def body(x, inp):
            if cfg.is_encdec:
                lp, w, kc, vc, ck, cv = inp
            else:
                lp, w, kc, vc = inp
                ck = cv = None
            cache_l = KVCache(k=kc, v=vc, length=cache["len"])
            cross_l = (
                KVCache(k=ck, v=cv, length=cache["enc_len"]) if cfg.is_encdec else None
            )
            x, new_cache = layer_apply_decode(cfg, lp, x, cache_l, w, cross_l)
            return x, (new_cache.k, new_cache.v)

        key_layers = "dec_layers" if cfg.is_encdec else "layers"
        xs = (params[key_layers], windows, cache["k"], cache["v"])
        if cfg.is_encdec:
            xs = xs + (cache["cross_k"], cache["cross_v"])
        x, (new_k, new_v) = jax.lax.scan(body, x, xs)
        x = _norm(cfg, params, x, "ln_f")
        logits = unembed(x, params.get("unembed", params["embed"]))
        new_cache = dict(cache)
        new_cache.update(k=new_k, v=new_v, len=cache["len"] + 1)
        return logits[:, -1], new_cache

    def _decode_rwkv(self, params, token, cache):
        cfg = self.cfg
        x = embed_lookup(params["embed"], token)
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        hd = cfg.rwkv_head_dim

        def body(x, inp):
            lp, s, x_last = inp
            st = rwkv_mod.RwkvState(s=s, x_last=x_last)
            h, st2 = rwkv_mod.rwkv_decode_step(
                lp["time_mix"], _norm(cfg, lp, x, "ln_attn"), st,
                head_dim=hd, eps=cfg.rms_eps,
            )
            x = x + h
            h = ffn_mod.mlp_apply(lp["mlp"], _norm(cfg, lp, x, "ln_mlp"), cfg.act)
            x = x + h
            return x, (st2.s, st2.x_last)

        x, (new_s, new_xl) = jax.lax.scan(
            body, x, (params["layers"], cache["s"], cache["x_last"])
        )
        x = _norm(cfg, params, x, "ln_f")
        logits = unembed(x, params.get("unembed", params["embed"]))
        return logits[:, -1], {"s": new_s, "x_last": new_xl, "len": cache["len"] + 1}

    def _decode_hybrid(self, params, token, cache):
        cfg = self.cfg
        x = embed_lookup(params["embed"], token)
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)

        def ssm_body(x, inp):
            lp, s, conv = inp
            st = ssm_mod.SsmState(s=s, conv=conv)
            h, st2 = ssm_mod.ssm_block_apply(
                lp["ssm"], _norm(cfg, lp, x, "ln_attn"), st,
                ssm_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, chunk=1, eps=cfg.rms_eps,
            )
            return x + h, (st2.s, st2.conv)

        per = cfg.attn_every
        n_seg, rem = divmod(cfg.n_layers, per)
        new_s, new_conv, new_k, new_v = [], [], [], []
        layers = params["layers"]

        def seg(lo, ln, x):
            xs = (
                jax.tree_util.tree_map(lambda a: a[lo : lo + ln], layers),
                cache["s"][lo : lo + ln],
                cache["conv"][lo : lo + ln],
            )
            x, (s2, c2) = jax.lax.scan(ssm_body, x, xs)
            return x, s2, c2

        for si in range(n_seg):
            x, s2, c2 = seg(si * per, per, x)
            new_s.append(s2)
            new_conv.append(c2)
            cache_l = KVCache(k=cache["attn_k"][si], v=cache["attn_v"][si],
                              length=cache["len"])
            x, cl = layer_apply_decode(cfg, params["shared_attn"], x, cache_l, 0)
            new_k.append(cl.k)
            new_v.append(cl.v)
        if rem:
            x, s2, c2 = seg(n_seg * per, rem, x)
            new_s.append(s2)
            new_conv.append(c2)
        x = _norm(cfg, params, x, "ln_f")
        logits = unembed(x, params.get("unembed", params["embed"]))
        return logits[:, -1], {
            "s": jnp.concatenate(new_s, 0),
            "conv": jnp.concatenate(new_conv, 0),
            "attn_k": jnp.stack(new_k, 0),
            "attn_v": jnp.stack(new_v, 0),
            "len": cache["len"] + 1,
        }

    # ---- prefill -------------------------------------------------------------

    def prefill(self, params: Dict, tokens=None, embeds=None, enc_embeds=None):
        """Full-sequence forward returning last-position logits.

        NOTE: returns logits only; cache construction during prefill is the
        serving runtime's job (runtime/server.py appends chunk-wise).  The
        dry-run's prefill cell measures this trunk, which dominates cost.
        """
        logits, _ = self.train_logits(params, tokens=tokens, embeds=embeds,
                                      enc_embeds=enc_embeds)
        return logits[:, -1]


def _sinusoidal(max_len: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(max_len)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]
