"""LM step functions: loss, microbatched train_step, prefill/decode serve
steps - the units the launcher jits onto the production mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.transformer import Transformer
from repro.optim.optimizers import Optimizer, clip_by_global_norm

Array = jax.Array


def softmax_xent(logits: Array, targets: Array) -> Array:
    """Mean next-token CE; logits f32 (B, T, V), targets int32 (B, T).

    The (B, T, V) logits are constrained vocab-sharded over 'model' so the
    f32 CE working set is 1/TP of the naive layout (the logsumexp reduction
    and the one-hot gather both SPMD-shard cleanly).
    """
    from repro.distributed.sharding import shard_act

    logits = shard_act(logits, ("batch", None, "vocab"))
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    # target logit via a masked local reduction (NOT take_along_axis, which
    # would all-gather the vocab-sharded logits)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, shifted.shape, shifted.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_ids == targets[..., None], shifted, 0.0), axis=-1)
    return jnp.mean(lse - ll)


def loss_fn(model: Transformer, params, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
    cfg = model.cfg
    kwargs = {}
    if cfg.is_encdec:
        # stub frontend supplies encoder frame embeddings; decoder is teacher
        # forced on the target token stream
        kwargs = dict(tokens=batch["targets"], enc_embeds=batch["embeds"])
    elif cfg.input_mode == "embeds":
        kwargs = dict(embeds=batch["embeds"])
    else:
        kwargs = dict(tokens=batch["tokens"])
    logits, aux = model.train_logits(params, **kwargs)
    targets = batch["targets"]
    # next-token objective: shift targets left for decoder-only token models
    if not cfg.is_encdec and "tokens" in batch:
        logits = logits[:, :-1]
        targets = targets[:, 1:]
    loss = softmax_xent(logits, targets)
    metrics = {"xent": loss}
    if aux:
        lb = aux.get("lb_loss", 0.0)
        zl = aux.get("z_loss", 0.0)
        loss = loss + 0.01 * lb + 1e-3 * zl
        metrics.update(lb_loss=lb, z_loss=zl)
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(
    model: Transformer,
    optimizer: Optimizer,
    lr_fn: Callable[[Array], Array],
    accum: int = 1,
    grad_clip: float = 1.0,
) -> Callable:
    """Builds train_step(params, opt_state, step, batch) -> (params, opt_state, metrics).

    Gradient accumulation: the global batch is split into ``accum``
    microbatches scanned sequentially; grads accumulate in f32 (sharded like
    their parameters, ZeRO-style), so peak activation memory is one
    microbatch deep.
    """

    def split_mb(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])

    def train_step(params, opt_state, step, batch):
        micro = jax.tree_util.tree_map(split_mb, batch)

        def one(carry, mb):
            gsum, lsum = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(model, p, mb), has_aux=True
            )(params)
            gsum = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads
            )
            return (gsum, lsum + loss), None

        gzero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (gsum, lsum), _ = jax.lax.scan(
            one, (gzero, jnp.zeros((), jnp.float32)), micro
        )
        grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        new_params, new_state = optimizer.update(grads, opt_state, params, lr)
        metrics = {
            "loss": lsum / accum,
            "grad_norm": gnorm,
            "lr": lr,
        }
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model: Transformer) -> Callable:
    def eval_step(params, batch):
        loss, metrics = loss_fn(model, params, batch)
        return metrics

    return eval_step


def make_prefill_step(model: Transformer) -> Callable:
    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.is_encdec:
            # prefill = encode the (stub) frames; decoder starts from BOS
            bos = jnp.zeros((batch["embeds"].shape[0], 1), jnp.int32)
            return model.prefill(params, tokens=bos, enc_embeds=batch["embeds"])
        if cfg.input_mode == "embeds":
            return model.prefill(params, embeds=batch["embeds"])
        return model.prefill(params, tokens=batch["tokens"])

    return prefill_step


def make_decode_step(model: Transformer) -> Callable:
    def decode_step(params, token, cache):
        return model.decode_step(params, token, cache)

    return decode_step
