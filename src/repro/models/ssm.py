"""Mamba-2 / SSD block (zamba2's backbone layer).

State-space duality recurrence per head (state S in R^{N x P}, N = ssm_state,
P = head dim):

    S_t = a_t S_{t-1} + b_t^T (dt_t x_t)        a_t = exp(-dt_t * A)  (scalar/head)
    y_t = c_t S_t + D x_t

with input-dependent (dt, b, c) projections, depthwise causal conv on the
(x, b, c) stream, gated output.  This is the scalar-decay special case of the
RWKV6 recurrence, and we reuse the same chunkwise-parallel scan pattern
(MXU-dense within chunks, lax.scan across chunks, O(N*P) state at decode).

Layout: x (B, T, D); heads H = d_inner / P.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import fsdp_gather, shard_act
from repro.models.layers import PV, dense_init, ones_init, zeros_init, rms_norm

Array = jax.Array

CONV_K = 4  # depthwise conv kernel width


class SsmState(NamedTuple):
    s: Array        # (B, H, N, P) SSD state
    conv: Array     # (B, CONV_K - 1, conv_dim) conv tail


def ssm_block_init(key, d_model: int, ssm_state: int = 64, head_dim: int = 64,
                   expand: int = 2, dtype=jnp.bfloat16) -> Dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * ssm_state
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [x (d_inner), z gate (d_inner), b (N), c (N), dt (H)]
        "w_in": dense_init(ks[0], (d_model, 2 * d_inner + 2 * ssm_state + n_heads),
                           ("embed", "mlp"), dtype),
        "conv_w": dense_init(ks[1], (CONV_K, conv_dim), ("conv", "mlp"), dtype,
                             scale=CONV_K**-0.5),
        "conv_b": zeros_init((conv_dim,), ("mlp",), dtype),
        "a_log": PV(jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
                    ("heads",)),
        "dt_bias": PV(jnp.full((n_heads,), -4.6, jnp.float32), ("heads",)),  # softplus^-1(0.01)
        "d_skip": ones_init((n_heads,), ("heads",), jnp.float32),
        "norm_w": zeros_init((d_inner,), ("mlp",), dtype),
        "w_out": dense_init(ks[2], (d_inner, d_model), ("mlp", "embed"), dtype),
    }


def _depthwise_conv(x: Array, w: Array, b: Array, tail: Array) -> Tuple[Array, Array]:
    """Causal depthwise conv along T.  x: (B, T, C), tail: (B, K-1, C)."""
    k = w.shape[0]
    xt = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, T+K-1, C)
    out = sum(
        xt[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k)
    ) + b.astype(x.dtype)
    new_tail = xt[:, -(k - 1):, :] if k > 1 else tail
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), new_tail


def ssd_chunked(
    xh: Array,    # (B, T, H, P) inputs (dt-scaled)
    a_log: Array,  # (B, T, H) log-decay per step (negative)
    bm: Array,    # (B, T, N) input matrix
    cm: Array,    # (B, T, N) output matrix
    s0: Array,    # (B, H, N, P)
    chunk: int = 128,
) -> Tuple[Array, Array]:
    """Chunkwise-parallel SSD scan (Mamba-2).  Returns (y (B,T,H,P), s_T)."""
    b, t, h, p = xh.shape
    n = bm.shape[-1]
    assert t % chunk == 0
    nc = t // chunk
    xc = jnp.moveaxis(xh.reshape(b, nc, chunk, h, p), 1, 0).astype(jnp.float32)
    ac = jnp.moveaxis(a_log.reshape(b, nc, chunk, h), 1, 0).astype(jnp.float32)
    bc = jnp.moveaxis(bm.reshape(b, nc, chunk, n), 1, 0).astype(jnp.float32)
    cc = jnp.moveaxis(cm.reshape(b, nc, chunk, n), 1, 0).astype(jnp.float32)

    cum = jnp.cumsum(ac, axis=2)        # inclusive (B? no: (nc,B,C,H))
    cum_excl = cum - ac
    total = cum[:, :, -1:, :]

    def step(s, inp):
        x_, a_, b_, c_, cum_, cume_, tot_ = inp
        # inter-chunk: y_t += c_t exp(cum_t) S_prev      (decay from chunk start)
        c_dec = c_[:, :, None, :] * jnp.exp(cum_)[..., None]  # (B,C,H,N)
        y_inter = jnp.einsum("bchn,bhnp->bchp", c_dec, s)
        # intra-chunk: y_t += sum_{u<=t} exp(cum_t - cum_u) (c_t . b_u) x_u
        scores = jnp.einsum("bcn,bun->bcu", c_, b_)  # (B, C, U)
        c_idx = jnp.arange(x_.shape[1])
        causal = c_idx[:, None] >= c_idx[None, :]
        decay = jnp.exp(cum_[:, :, None, :] - cum_[:, None, :, :])  # (B,C,U,H)
        scores = jnp.where(causal[None, :, :, None], scores[..., None] * decay, 0.0)
        y_intra = jnp.einsum("bcuh,buhp->bchp", scores, x_)
        # state: S = exp(total) S + sum_u exp(total - cum_u) b_u^T x_u
        b_dec = b_[:, :, None, :] * jnp.exp(tot_ - cum_)[..., None]  # (B,C,H,N)
        s_new = jnp.exp(tot_)[:, 0, :, None, None] * s + jnp.einsum(
            "bchn,bchp->bhnp", b_dec, x_
        )
        return s_new, y_inter + y_intra

    s_fin, ys = jax.lax.scan(step, s0.astype(jnp.float32),
                             (xc, ac, bc, cc, cum, cum_excl, total))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, p)
    return y, s_fin


def ssm_block_apply(
    p: Dict, x: Array, state: SsmState, *, ssm_state: int = 64,
    head_dim: int = 64, expand: int = 2, chunk: int = 128, eps: float = 1e-5,
) -> Tuple[Array, SsmState]:
    """Mamba-2 block over a sequence (prefill/train) or one step (T=1)."""
    b, t, d = x.shape
    d_inner = expand * d
    n_heads = d_inner // head_dim
    n = ssm_state

    proj = x @ fsdp_gather(p["w_in"], ("embed", "mlp")).astype(x.dtype)
    xz, z, bm, cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xz, bm, cm], axis=-1)
    conv_out, new_tail = _depthwise_conv(conv_in, p["conv_w"], p["conv_b"], state.conv)
    xz, bm, cm = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a = -jnp.exp(p["a_log"])                                      # (H,) negative
    a_log_step = dt * a                                           # (B,T,H) log decay
    xh = xz.reshape(b, t, n_heads, head_dim).astype(jnp.float32) * dt[..., None]

    chunk = min(chunk, t)
    y, s_new = ssd_chunked(xh, a_log_step, bm, cm, state.s, chunk=chunk)
    y = y + p["d_skip"][None, None, :, None] * xz.reshape(b, t, n_heads, head_dim).astype(jnp.float32)
    y = y.reshape(b, t, d_inner).astype(x.dtype)
    y = rms_norm(y, p["norm_w"], eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = shard_act(y, ("batch", None, "act_model"))
    out = y @ fsdp_gather(p["w_out"], ("mlp", "embed")).astype(x.dtype)
    return out, SsmState(s=s_new.astype(state.s.dtype), conv=new_tail.astype(state.conv.dtype))


def ssm_state_init(batch: int, d_model: int, ssm_state: int = 64,
                   head_dim: int = 64, expand: int = 2, dtype=jnp.float32) -> SsmState:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * ssm_state
    return SsmState(
        s=jnp.zeros((batch, n_heads, ssm_state, head_dim), dtype),
        conv=jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
    )
