"""Optimizers (pure JAX, no optax in this container).

* sgd       - plain SGD (+momentum), the paper's reservoir trainer uses the
              specialized variant in repro.core.backprop.apply_sgd.
* adamw     - decoupled weight decay Adam, f32 states.
* adafactor - factored second moment (T5X-style): the optimizer of choice for
              the 100B+ configs (state = O(rows + cols) instead of O(n)).

State trees mirror the param tree; optimizer state sharding follows the
parameter's logical axes (ZeRO-style: states inherit the FSDP sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Array], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def update(grads, state, params, lr):
        if momentum == 0.0:
            new = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads,
            )
            return new, state
        vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g.astype(jnp.float32), state, grads
        )
        new = jax.tree_util.tree_map(
            lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype), params, vel
        )
        return new, vel

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: Array


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, lr):
        c = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def step(p, m, n):
            upd = (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new = jax.tree_util.tree_map(step, params, mu, nu)
        return new, AdamState(mu=mu, nu=nu, count=c)

    return Optimizer(init, update)


class FactorState(NamedTuple):
    row: Any     # per-param row accumulator (or full nu for <2D params)
    col: Any
    count: Array


def adafactor(eps: float = 1e-30, decay: float = 0.8,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018, simplified).

    For params with >= 2 dims: keeps row/col mean-square accumulators over
    the last two axes (O(rows+cols) memory).  For 0/1-D params: full
    accumulator.  No first moment (as in T5X defaults for LLM pretraining).
    """

    def init(params):
        def rows(p):
            if p.ndim < 2:
                return jnp.zeros(p.shape, jnp.float32)
            return jnp.zeros(p.shape[:-1], jnp.float32)

        def cols(p):
            if p.ndim < 2:
                return jnp.zeros((1,), jnp.float32)
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

        return FactorState(
            row=jax.tree_util.tree_map(rows, params),
            col=jax.tree_util.tree_map(cols, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, lr):
        c = state.count + 1
        beta = 1.0 - (c.astype(jnp.float32)) ** -decay

        def upd_one(p, g, r, cl):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if p.ndim < 2:
                r2 = beta * r + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(r2 + eps)
                new_r, new_c = r2, cl
            else:
                row_mean = jnp.mean(g2, axis=-1)
                col_mean = jnp.mean(g2, axis=-2)
                r2 = beta * r + (1 - beta) * row_mean
                c2 = beta * cl + (1 - beta) * col_mean
                r_factor = jax.lax.rsqrt(
                    r2 / jnp.maximum(jnp.mean(r2, axis=-1, keepdims=True), eps) + eps
                )
                c_factor = jax.lax.rsqrt(c2 + eps)
                u = gf * r_factor[..., None] * c_factor[..., None, :]
                new_r, new_c = r2, c2
            # relative update clipping
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_r, new_c

        flat, treedef = jax.tree_util.tree_flatten(params)
        gflat = treedef.flatten_up_to(grads)
        rflat = treedef.flatten_up_to(state.row)
        cflat = treedef.flatten_up_to(state.col)
        out = [upd_one(p, g, r, cl) for p, g, r, cl in zip(flat, gflat, rflat, cflat)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_r = treedef.unflatten([o[1] for o in out])
        new_c = treedef.unflatten([o[2] for o in out])
        return new_p, FactorState(row=new_r, col=new_c, count=c)

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(**kw)
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name}")
