"""Gradient compression for cross-pod reduction (int8 + error feedback).

The pod axis crosses the data-center interconnect - the slowest link in the
production mesh.  Per-tensor symmetric int8 quantization cuts those bytes 4x
(vs f32) / 2x (vs bf16); the residual is fed back into the next step's
gradient (error feedback keeps SGD unbiased to first order).

Usage (runtime/trainer.py): quantize -> psum over 'pod' -> dequantize; the
all-reduce payload is int8 (XLA reduces int8 by widening to int32 partial
sums, still 4x fewer wire bytes than f32).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def compress_int8(g: Array) -> Tuple[Array, Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    absmax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(g: Array, axis_name, residual: Array) -> Tuple[Array, Array]:
    """Error-feedback compressed all-reduce of one tensor over ``axis_name``.

    residual carries the quantization error into the next step.
    Returns (reduced mean gradient, new residual).
    """
    g_ef = g.astype(jnp.float32) + residual
    q, scale = compress_int8(g_ef)
    new_residual = g_ef - decompress_int8(q, scale)
    # sum int8 payloads (widened accumulations) and the tiny scales
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale = jax.lax.pmean(scale, axis_name)  # shared scale approximation
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    reduced = q_sum.astype(jnp.float32) * scale / n
    return reduced.astype(g.dtype), new_residual


def tree_compressed_psum(grads, axis_name, residuals):
    flat, treedef = jax.tree_util.tree_flatten(grads)
    rflat = treedef.flatten_up_to(residuals)
    outs = [compressed_psum(g, axis_name, r) for g, r in zip(flat, rflat)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
