from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    sgd,
    clip_by_global_norm,
    make_optimizer,
)
from repro.optim.schedule import cosine_schedule, constant_schedule  # noqa: F401
from repro.optim.compression import compress_int8, decompress_int8  # noqa: F401
