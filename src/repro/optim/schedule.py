"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return fn


def paper_step_schedule(base: float, drops: tuple, steps_per_epoch: int):
    """The paper's x0.1-at-epoch schedule, expressed per optimizer step."""
    def fn(step):
        epoch = step // jnp.maximum(steps_per_epoch, 1)
        mult = 1.0
        out = jnp.asarray(base, jnp.float32)
        for e in drops:
            out = jnp.where(epoch >= e, out * 0.1, out)
        return out

    return fn
