import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.
#
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  For each cell this driver:

#     1. builds the sharded step program (launch/steps.py),
#     2. .lower().compile() on the production mesh,
#     3. records memory_analysis(), cost_analysis() and the collective wire
#        bytes parsed from the optimized HLO,
#     4. writes one JSON artifact under artifacts/dryrun/.
#
# Usage:
#     PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
#         --shape train_4k --mesh single           # one cell
#     PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both   # sweep
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, SHAPES, get_config
from repro.distributed import sharding as shd
from repro.launch import analysis
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell, pick_optimizer

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: dict | None = None, tag: str = "baseline",
             overrides: dict | None = None,
             accum_override: int | None = None) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "tag": tag,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "optimizer": pick_optimizer(cfg) if shape.kind == "train" else None,
    }
    if shape_name in cfg.skip_shapes:
        record["status"] = "skipped"
        record["reason"] = (
            "full-attention architecture at 524k context (sub-quadratic "
            "required); see DESIGN.md Arch-applicability"
        )
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape, mesh, rules=rules,
                          accum_override=accum_override)
        lowered = lower_cell(cell, mesh, rules=rules)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        from repro.kernels.flash_attention import FLASH_SCOPE
        walk = hlo_cost.analyze(hlo, vmem_scopes=(FLASH_SCOPE,))
        del hlo
        flops = walk.flops
        bytes_acc = walk.mem_bytes

        # grad-accumulation correction is NOT needed: the accumulation scan
        # is a while loop with known_trip_count, already multiplied in.
        terms = analysis.roofline_terms(flops, bytes_acc, walk.wire_bytes)
        mflops = analysis.model_flops(cfg, shape)
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            chips=n_chips,
            flops_per_device=flops,
            bytes_per_device=bytes_acc,
            collective={
                "wire_bytes": walk.wire_bytes,
                "op_bytes": walk.coll_bytes,
                "op_counts": walk.coll_counts,
                "n_while_unknown_trip": walk.n_while_unknown,
            },
            cost_analysis_raw={
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            memory={
                "argument_size": getattr(mem, "argument_size_in_bytes", None),
                "output_size": getattr(mem, "output_size_in_bytes", None),
                "temp_size": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
            },
            roofline=terms,
            model_flops_total=mflops,
            model_flops_per_device=mflops / n_chips,
            useful_flops_ratio=(mflops / n_chips) / flops if flops else None,
        )
    except Exception as ex:  # noqa: BLE001 - record the failure, keep sweeping
        record.update(
            status="error",
            error=f"{type(ex).__name__}: {ex}",
            trace=traceback.format_exc()[-4000:],
        )
    return record


def artifact_path(arch: str, shape_name: str, mesh_name: str, tag: str) -> pathlib.Path:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    return ART_DIR / f"{arch}__{shape_name}__{mesh_name}__{tag}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. attn_impl=pallas)")
    ap.add_argument("--accum", type=int, default=None,
                    help="grad-accumulation override for train cells")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    archs = ALL_ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for arch in archs:
            for shape_name in shapes:
                path = artifact_path(arch, shape_name, mesh_name, args.tag)
                if args.skip_existing and path.exists():
                    print(f"[skip-existing] {path.name}")
                    continue
                rec = run_cell(arch, shape_name, multi, tag=args.tag,
                               overrides=overrides or None,
                               accum_override=args.accum)
                rec["overrides"] = dict(overrides, accum=args.accum)
                path.write_text(json.dumps(rec, indent=1))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f" compile={rec['compile_s']}s dom={r['dominant']}"
                        f" frac={r['roofline_fraction']:.3f}"
                    )
                    print(compiled_summary(rec))
                elif status == "error":
                    failures += 1
                    extra = " " + rec["error"][:160]
                print(f"[{status}] {arch} x {shape_name} x {mesh_name}{extra}",
                      flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


def compiled_summary(rec: dict) -> str:
    mem = rec.get("memory", {})
    return (
        f"    mem/device: args={_gb(mem.get('argument_size'))} "
        f"temp={_gb(mem.get('temp_size'))} out={_gb(mem.get('output_size'))} | "
        f"flops/dev={rec['flops_per_device']:.3e} "
        f"bytes/dev={rec['bytes_per_device']:.3e} "
        f"wire/dev={rec['collective']['wire_bytes']:.3e}"
    )


def _gb(v):
    return f"{v/2**30:.2f}GiB" if v else "?"


if __name__ == "__main__":
    main()
