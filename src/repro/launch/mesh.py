"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device counts are locked on first jax init).
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_slot_mesh(n_slot: Optional[int] = None, member: int = 1):
    """Serving mesh for the slot-sharded stream server.

    A 1-D ``("slot",)`` mesh over ``n_slot`` devices (default: all of
    them), or a 2-D ``("slot", "member")`` mesh when ``member > 1`` (an
    ensemble-of-slots serving fleet: both axes are embarrassingly
    parallel).  The axis names are what the ``slot`` / ``member`` logical
    rules in ``repro.distributed.sharding.DEFAULT_RULES`` resolve to, so
    ``guarded_spec(..., ("slot", ...))`` shards state trees over this mesh
    with no extra rule plumbing.

    Uses the first ``n_slot * member`` devices, so a sweep over device
    counts (the scaling bench) can build 1/2/4/8-device meshes inside one
    process with ``--xla_force_host_platform_device_count=8``.
    """
    avail = jax.device_count()
    if n_slot is None:
        n_slot = avail // member
    need = n_slot * member
    if need > avail:
        raise ValueError(
            f"make_slot_mesh: {n_slot} slot x {member} member devices "
            f"requested but only {avail} available"
        )
    devices = jax.devices()[:need]
    if member > 1:
        import numpy as _np

        return jax.sharding.Mesh(
            _np.asarray(devices).reshape(n_slot, member), ("slot", "member")
        )
    return jax.sharding.Mesh(devices, ("slot",))
