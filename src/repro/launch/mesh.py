"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device counts are locked on first jax init).
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
