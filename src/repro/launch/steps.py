"""Step-function assembly shared by dryrun.py / train.py / serve.py:
builds the jitted, fully-sharded train/prefill/decode programs for one
(arch x shape x mesh) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.distributed import sharding as shd
from repro.models.lm import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models.transformer import Transformer
from repro.optim.optimizers import make_optimizer
from repro.optim.schedule import cosine_schedule


def pick_optimizer(cfg: ArchConfig) -> str:
    """Adafactor for 50B+ params (factored state is what fits HBM)."""
    return "adafactor" if cfg.param_count() > 5e10 else "adamw"


def _batch_axes(batch_specs: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, tuple]:
    out = {}
    for k, v in batch_specs.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def _opt_axes(opt_state, params_shapes, params_axes):
    """Optimizer-state logical axes: inherit the parameter's axes where the
    shapes match (mu/nu), drop factored dims (adafactor row/col)."""
    pflat, ptree = jax.tree_util.tree_flatten(params_shapes)
    aflat = ptree.flatten_up_to(params_axes)
    shape_to_axes = {}
    for ps, ax in zip(pflat, aflat):
        shape_to_axes.setdefault(tuple(ps.shape), tuple(ax))

    by_row = {}
    by_col = {}
    for ps, ax in zip(pflat, aflat):
        s = tuple(ps.shape)
        if len(s) >= 2:
            by_row.setdefault(s[:-1], tuple(ax[:-1]))
            by_col.setdefault(s[:-2] + s[-1:], tuple(ax[:-2] + ax[-1:]))

    def axes_of(leaf):
        s = tuple(leaf.shape)
        if s in shape_to_axes:
            return shape_to_axes[s]
        if s in by_row:
            return by_row[s]
        if s in by_col:
            return by_col[s]
        return (None,) * len(s)

    return jax.tree_util.tree_map(axes_of, opt_state)


@dataclasses.dataclass
class CellPrograms:
    """Everything needed to lower one (arch x shape) cell on a mesh."""

    kind: str
    fn: Any                  # the step callable
    args: Tuple              # ShapeDtypeStruct pytrees (lower(*args))
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...]


def build_cell(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh: Mesh,
    *,
    rules: Optional[dict] = None,
    accum_override: Optional[int] = None,
) -> CellPrograms:
    model = Transformer(cfg)
    rules = rules or dict(shd.DEFAULT_RULES)

    with shd.use_mesh(mesh, rules):
        params_shapes = model.param_shapes()
        params_axes = model.axes()
        p_shard = shd.guarded_shardings(params_shapes, params_axes, mesh, rules)
        batch_specs = input_specs(cfg, shape)
        b_shard = shd.guarded_shardings(batch_specs, _batch_axes(batch_specs),
                                        mesh, rules)
        repl = NamedSharding(mesh, P())

        if shape.kind == "train":
            # decoder-only token models learn next-token on the same stream;
            # embed-stub models get target tokens alongside
            opt = make_optimizer(pick_optimizer(cfg))
            accum = accum_override or cfg.grad_accum.get(shape.name, 1)
            lr_fn = cosine_schedule(3e-4, 100, 10000)
            step_fn = make_train_step(model, opt, lr_fn, accum=accum)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            opt_axes = _opt_axes(opt_shapes, params_shapes, params_axes)
            o_shard = shd.guarded_shardings(opt_shapes, opt_axes, mesh, rules)
            args = (params_shapes, opt_shapes,
                    jax.ShapeDtypeStruct((), jnp.int32), batch_specs)
            in_sh = (p_shard, o_shard, repl, b_shard)
            out_sh = (p_shard, o_shard, None)
            return CellPrograms("train", step_fn, args, in_sh, out_sh, (0, 1))

        if shape.kind == "prefill":
            fn = make_prefill_step(model)
            args = (params_shapes, batch_specs)
            return CellPrograms("prefill", fn, args, (p_shard, b_shard), None, ())

        # decode: one token against a seq_len cache
        fn = make_decode_step(model)
        enc_len = shape.seq_len if cfg.is_encdec else 0
        cache_shapes = model.cache_specs(shape.global_batch, shape.seq_len,
                                         enc_len=enc_len)
        cache_axes = model.cache_axes(shape.global_batch, shape.seq_len,
                                      enc_len=enc_len)
        c_shard = shd.guarded_shardings(cache_shapes, cache_axes, mesh, rules)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_shard = shd.guarded_shardings(
            {"t": tok}, {"t": ("batch", None)}, mesh, rules
        )["t"]
        args = (params_shapes, tok, cache_shapes)
        out_sh = (None, c_shard)
        return CellPrograms("decode", fn, args, (p_shard, tok_shard, c_shard),
                            out_sh, (2,))


def lower_cell(cell: CellPrograms, mesh: Mesh, rules: Optional[dict] = None):
    """jit + lower one cell under the mesh context (no compile)."""
    rules = rules or dict(shd.DEFAULT_RULES)
    with shd.use_mesh(mesh, rules):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        return jitted.lower(*cell.args)
