"""Serving driver: batched requests through the continuous-batching server.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --requests 16 --prompt-len 32 --max-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.transformer import Transformer
from repro.runtime.server import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, max_batch=args.max_batch, max_len=args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_tokens=args.max_tokens))
    done = server.run_until_drained()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    lat = [r.finish_t - r.submit_t for r in done]
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s); p50 latency {np.median(lat):.2f}s "
          f"p99 {np.percentile(lat, 99):.2f}s")


if __name__ == "__main__":
    main()
