"""Roofline-term extraction from compiled dry-run artifacts.

Sources:
  * compiled.cost_analysis()  -> HLO flops / bytes accessed (per device for
    SPMD-partitioned modules - verified in tests/test_dryrun_small.py)
  * HLO text                  -> per-collective wire-byte estimates

Hardware constants: TPU v5e (target platform).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# --- TPU v5e constants (per chip) -------------------------------------------
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (assume one active link/collective)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# e.g.  %ag = bf16[2,4096,5120]{2,1,0} all-gather(...), replica_groups=...
_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float                   # estimated per-chip wire traffic
    op_bytes: Dict[str, float]          # raw result bytes per op kind
    op_counts: Dict[str, int]

    def to_json(self):
        return dataclasses.asdict(self)


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str, default_group: int = 16) -> CollectiveStats:
    """Scan (possibly very large) HLO text line-by-line, summing collective
    wire bytes with ring-model formulas:

        all-reduce:          2 * B * (k-1)/k
        all-gather:          B * (k-1)/k          (B = result bytes)
        reduce-scatter:      B * (k-1)            (operand = k * result)
        all-to-all:          B * (k-1)/k
        collective-permute:  B
    """
    wire = 0.0
    op_bytes: Dict[str, float] = {}
    op_counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(dtype, dims)
        gm = _GROUPS_RE.search(line)
        if gm:
            k = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            k = len(gb.group(1).split(",")) if gb else default_group
        k = max(k, 2)
        if kind == "all-reduce":
            w = 2.0 * b * (k - 1) / k
        elif kind == "all-gather":
            w = b * (k - 1) / k
        elif kind == "reduce-scatter":
            w = b * (k - 1)
        elif kind == "all-to-all":
            w = b * (k - 1) / k
        else:  # collective-permute
            w = b
        wire += w
        op_bytes[kind] = op_bytes.get(kind, 0.0) + b
        op_counts[kind] = op_counts.get(kind, 0) + 1
    return CollectiveStats(wire_bytes=wire, op_bytes=op_bytes, op_counts=op_counts)


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
) -> Dict[str, float]:
    compute_s = flops_per_device / PEAK_FLOPS_BF16
    memory_s = bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": compute_s / total if total > 0 else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """6 N D (dense) / 6 N_active D (MoE); decode counts one token/row."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: fwd only, 1 token per row
