"""Loop-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, so scanned layer
stacks / grad-accumulation loops / chunked attention are undercounted by
their trip counts.  XLA records ``known_trip_count`` on every scan-lowered
while loop, and every instruction is defined with its shape, so an exact
walker is possible from the HLO text alone:

  * build the computation call graph (while bodies/conds x trip counts,
    fusions, conditionals),
  * per computation: dot FLOPs (2 * prod(result) * K, K from
    lhs_contracting_dims via the local symbol table), collective wire bytes
    (ring model), fusion HBM bytes (operands + results; in-place
    dynamic-update-slice roots counted at update size),
  * totals = per-computation costs weighted by path multiplier from ENTRY.

Flops are dot/conv only (elementwise is noise next to MXU work at these
shapes - documented).  All numbers are PER DEVICE (the HLO is the SPMD
per-device program).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "tf32": 4, "bf16": 2, "f16": 2,
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    # sub-byte element types are storage-padded to one byte outside packed
    # custom calls; HBM accounting charges the padded width
    "s4": 1, "u4": 1, "s2": 1, "u2": 1, "s1": 1, "u1": 1,
    # opaque control/token values occupy no HBM
    "token": 0, "opaque": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+([a-z0-9\-]+)\("
)
_TUPLE_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*\(\s*.*\)\s+([a-z0-9\-]+)\("
)
_OPERANDS = re.compile(r"\(([^)]*)\)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_REFS = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_PARAM_DECL = re.compile(r"%?([\w.\-]+):\s*([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    width = _DTYPE_BYTES.get(dtype)
    if width is None:
        # a silent 4-byte default mis-prices every narrow-dtype buffer by
        # 4x (the int8 serving path hit exactly this) - fail loudly so new
        # HLO dtypes get an explicit entry instead of a wrong guess
        raise ValueError(
            f"unrecognized HLO element type {dtype!r} (dims=[{dims}]); add "
            f"its byte width to launch.hlo_cost._DTYPE_BYTES"
        )
    return n * width


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    mem_fusion: float = 0.0   # HBM traffic at fusion call sites
    mem_plain: float = 0.0    # HBM traffic of top-level (unfused) ops;
    #                           dropped when this comp is itself a fusion body
    wire_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # edges: (callee, multiplier)
    edges: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    fusion_callees: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloCost:
    flops: float
    mem_bytes: float
    wire_bytes: float
    coll_bytes: Dict[str, float]
    coll_counts: Dict[str, float]
    n_while_unknown: int

    def to_json(self):
        return dataclasses.asdict(self)


def _parse_computations(text: str):
    comps: Dict[str, List[str]] = {}
    headers: Dict[str, str] = {}
    entry = None
    name = None
    for line in text.splitlines():
        if line.endswith("{") and ("->" in line) and ("(" in line):
            m = _COMP_HEADER.match(line.strip())
            if m:
                name = m.group(1)
                comps[name] = []
                headers[name] = line
                if line.lstrip().startswith("ENTRY"):
                    entry = name
                continue
        if line.strip() == "}":
            name = None
            continue
        if name is not None:
            comps[name].append(line)
    return comps, headers, entry


def _symbols(header: str, lines: List[str]) -> Dict[str, Tuple[str, str]]:
    """name -> (dtype, dims) for params + instruction results."""
    syms: Dict[str, Tuple[str, str]] = {}
    for m in _PARAM_DECL.finditer(header):
        syms[m.group(1)] = (m.group(2), m.group(3))
    for line in lines:
        m = _INSTR.match(line)
        if m:
            syms[m.group(1)] = (m.group(2), m.group(3))
    return syms


def _operand_names(line: str) -> List[str]:
    m = _OPERANDS.search(line[line.index("(") :] if "(" in line else line)
    # find the operand list of the op call: first "(...)" after op name
    # robust approach: take text between the first '(' following '= ... op'
    try:
        start = line.index("(", line.index(" = ") if " = " in line else 0)
    except ValueError:
        return []
    depth = 0
    buf = []
    for ch in line[start:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            buf.append(ch)
    inner = "".join(buf)
    names = re.findall(r"%([\w.\-]+)", inner)
    return names


def _dus_aliased_param(comp_lines: List[str]) -> Optional[int]:
    """If the computation's ROOT is dynamic-update-slice, return the index of
    the fusion parameter that is updated in place (operand 0), else None."""
    for line in comp_lines:
        if "ROOT" in line and "dynamic-update-slice(" in line:
            ops = _operand_names(line)
            if ops:
                m = re.match(r"param_(\d+)", ops[0])
                if m:
                    return int(m.group(1))
    return None


_GTE_INDEX = re.compile(r"index=(\d+)")


def _compute_scoped(comps: Dict[str, List[str]], vmem_scopes: tuple) -> Dict[str, set]:
    """Per-computation sets of VMEM-scoped instruction names.

    Seeds: instructions whose op_name metadata carries a scope tag.
    Closure 1 (intra-comp): an op ALL of whose array operands are scoped is
    scoped (XLA re-wraps interior ops - reduce-window, copy - dropping
    metadata; anything computed purely from scoped values is interior).
    Closure 2 (across loop carries): if a while's init-tuple element is
    scoped in the parent, the body/cond get-tuple-elements at that index are
    scoped (online-softmax carries cross scan boundaries).
    Iterated to a global fixpoint.
    """
    if not vmem_scopes:
        return {}
    defs_by_comp: Dict[str, Dict[str, Tuple[List[str], bool, Optional[int]]]] = {}
    tuples: Dict[str, Dict[str, List[str]]] = {}
    while_calls: List[Tuple[str, str, str, str]] = []  # parent, body, cond, init
    for name, lines in comps.items():
        defs = {}
        tups = {}
        for line in lines:
            m = _INSTR.match(line)
            tm = None if m else _TUPLE_INSTR.match(line)
            if not m and not tm:
                continue
            iname = m.group(1) if m else tm.group(1)
            op = m.group(4) if m else tm.group(2)
            ops = _operand_names(line)
            tagged = any(s in line for s in vmem_scopes)
            gidx = None
            if op == "get-tuple-element":
                gm = _GTE_INDEX.search(line)
                gidx = int(gm.group(1)) if gm else None
            defs[iname] = (ops, tagged, gidx)
            if op == "tuple":
                tups[iname] = ops
            if op == "while":
                wm = _WHILE_REFS.search(line)
                if wm and ops:
                    while_calls.append((name, wm.group(2), wm.group(1), ops[0]))
        defs_by_comp[name] = defs
        tuples[name] = tups

    scoped: Dict[str, set] = {
        n: {i for i, (_, tag, _) in d.items() if tag} for n, d in defs_by_comp.items()
    }
    for _ in range(6):  # global fixpoint (nesting depth bound)
        changed = False
        # intra-computation closure (constants/iota are neutral operands)
        for name, defs in defs_by_comp.items():
            sc = scoped[name]
            neutral = {
                i for i, (ops_, _, _) in defs.items() if not ops_
            }  # constant(...), iota, parameter-like leaves have no operands
            local = True
            while local:
                local = False
                for iname, (ops, _, _) in defs.items():
                    if iname in sc:
                        continue
                    arr = [o for o in ops if o in defs and o not in neutral]
                    if arr and all(o in sc for o in arr):
                        sc.add(iname)
                        local = changed = True
        # loop-carry seeding
        for parent, body, cond, init in while_calls:
            init_ops = tuples.get(parent, {}).get(init)
            if not init_ops:
                continue
            scoped_pos = {
                i for i, o in enumerate(init_ops) if o in scoped.get(parent, set())
            }
            if not scoped_pos:
                continue
            for target in (body, cond):
                defs = defs_by_comp.get(target)
                if not defs:
                    continue
                sc = scoped[target]
                for iname, (_, _, gidx) in defs.items():
                    if gidx in scoped_pos and iname not in sc:
                        sc.add(iname)
                        changed = True
        if not changed:
            break
    return scoped


def analyze(text: str, default_group: int = 16,
            vmem_scopes: tuple = ()) -> HloCost:
    """``vmem_scopes``: op_name substrings whose instructions' HBM traffic is
    NOT counted (they model Pallas-kernel interiors that stay in VMEM on the
    TPU target; FLOPs and collectives are still counted)."""
    comps, headers, entry = _parse_computations(text)
    costs: Dict[str, CompCost] = {}
    unknown_trips = 0

    scoped_by_comp = _compute_scoped(comps, vmem_scopes)

    for name, lines in comps.items():
        syms = _symbols(headers[name], lines)
        scoped = scoped_by_comp.get(name, set())
        cc = CompCost()

        # --- CPU-lowering artifact correction -------------------------------
        # XLA CPU upcasts bf16 dot operands to f32 (no native bf16 matmul);
        # on the TPU target (MXU) those values stay bf16 and the converts do
        # not exist.  Track instructions that are f32 converts of bf16 values
        # so (a) their own traffic is skipped and (b) collectives/dots that
        # consume them are costed at bf16 width.
        upcast: set = set()
        for line in lines:
            m = _INSTR.match(line)
            if not m:
                continue
            iname, dtype, op = m.group(1), m.group(2), m.group(4)
            if dtype != "f32":
                continue
            ops_ = _operand_names(line)
            if op == "convert" and ops_ and syms.get(ops_[0], ("",))[0] == "bf16":
                upcast.add(iname)
            elif op in ("copy", "bitcast", "reshape", "transpose", "all-gather",
                        "all-reduce", "broadcast") and ops_ and (
                ops_[0] in upcast
            ):
                upcast.add(iname)
            elif op == "fusion" and "convert" in line and ops_ and all(
                syms.get(o, ("",))[0] == "bf16" for o in ops_ if o in syms
            ):
                upcast.add(iname)

        def eff_bytes(dtype: str, dims: str, iname: Optional[str] = None) -> float:
            b = _shape_bytes(dtype, dims)
            if iname is not None and iname in upcast:
                return b / 2.0  # bf16 on the TPU target
            return b

        def operand_bytes(oname: str) -> float:
            s = syms.get(oname)
            if not s:
                return 0.0
            return eff_bytes(s[0], s[1], oname)

        def in_vmem_scope(line: str, _scoped=scoped) -> bool:
            if any(s in line for s in vmem_scopes):
                return True
            m = _INSTR.match(line)
            return bool(m and m.group(1) in _scoped)

        for line in lines:
            m = _INSTR.match(line)
            tuple_m = None if m else _TUPLE_INSTR.match(line)
            op = m.group(4) if m else (tuple_m.group(2) if tuple_m else None)
            if op is None:
                continue
            dtype, dims = (m.group(2), m.group(3)) if m else ("f32", "")

            if op == "while":
                wm = _WHILE_REFS.search(line)
                tm = _TRIP.search(line)
                trip = float(tm.group(1)) if tm else 1.0
                if not tm:
                    unknown_trips += 1
                if wm:
                    cc.edges.append((wm.group(2), trip))       # body x trip
                    cc.edges.append((wm.group(1), trip + 1.0))  # cond
                continue
            if op in ("fusion", "call", "custom-call"):
                fm = _CALLS.search(line)
                if fm:
                    cc.edges.append((fm.group(1), 1.0))
                    cc.fusion_callees.append(fm.group(1))
                # HBM traffic: operands + result (in-place DUS at update size)
                rb = _shape_bytes(dtype, dims) if m else 0.0
                onames = _operand_names(line)
                aliased = None
                if fm and fm.group(1) in comps:
                    aliased = _dus_aliased_param(comps[fm.group(1)])
                    if aliased is not None and fm.group(1) in comps:
                        # write = update size; use callee's operand-1 shape
                        upd = _update_bytes(comps[fm.group(1)])
                        if upd is not None:
                            rb = upd
                if not in_vmem_scope(line) and (m and m.group(1)) not in upcast:
                    for idx, on in enumerate(onames):
                        if aliased is not None and idx == aliased:
                            continue  # aliased buffer: not fully read/written
                        cc.mem_fusion += operand_bytes(on)
                    cc.mem_fusion += (
                        eff_bytes(dtype, dims, m.group(1)) if m and rb == _shape_bytes(dtype, dims) else rb
                    )
                continue
            if op == "conditional":
                bm = _BRANCHES.search(line)
                if bm:
                    for ref in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                        cc.edges.append((ref, 1.0))
                continue
            if op in COLLECTIVES or any(
                op == c + sfx for c in COLLECTIVES for sfx in ("-start",)
            ):
                base = op.replace("-start", "")
                b = eff_bytes(dtype, dims, m.group(1)) if m else 0.0
                gm = _GROUPS.search(line)
                if gm:
                    k = int(gm.group(2))
                else:
                    gb = _GROUPS_BRACE.search(line)
                    k = len(gb.group(1).split(",")) if gb else default_group
                k = max(k, 2)
                if base == "all-reduce":
                    w = 2.0 * b * (k - 1) / k
                elif base == "all-gather":
                    w = b * (k - 1) / k
                elif base == "reduce-scatter":
                    w = b * (k - 1)
                elif base == "all-to-all":
                    w = b * (k - 1) / k
                else:
                    w = b
                cc.wire_bytes += w
                cc.coll_bytes[base] = cc.coll_bytes.get(base, 0.0) + b
                cc.coll_counts[base] = cc.coll_counts.get(base, 0) + 1
                continue
            if op in ("dot", "convolution"):
                res_elems = _shape_elems(dims)
                k = 1
                lhs = _operand_names(line)
                cd = _LHS_CDIMS.search(line)
                if lhs and cd:
                    s = syms.get(lhs[0])
                    if s:
                        ldims = [int(d) for d in s[1].split(",")] if s[1] else []
                        for ci in cd.group(1).split(","):
                            if ci != "" and int(ci) < len(ldims):
                                k *= ldims[int(ci)]
                cc.flops += 2.0 * res_elems * k
                # dot HBM traffic (only charged when this comp is unfused)
                if not in_vmem_scope(line):
                    for on in lhs[:2]:
                        cc.mem_plain += operand_bytes(on)
                    cc.mem_plain += eff_bytes(dtype, dims, m.group(1) if m else None)
                continue
            if op in ("copy", "transpose", "reshape", "broadcast",
                      "dynamic-slice", "dynamic-update-slice", "slice",
                      "concatenate", "reduce", "pad", "gather", "scatter",
                      "iota", "convert", "select", "compare", "add",
                      "multiply", "subtract", "divide", "exponential",
                      "tanh", "rsqrt", "log", "maximum", "minimum"):
                # unfused top-level op: result write + operand reads
                # (pure bf16->f32 upcasts do not exist on the TPU target)
                if m and not in_vmem_scope(line) and m.group(1) not in upcast:
                    cc.mem_plain += eff_bytes(dtype, dims, m.group(1))
                    for on in _operand_names(line)[:3]:
                        cc.mem_plain += operand_bytes(on)
                continue
        costs[name] = cc

    # propagate multipliers from ENTRY through the call graph
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in costs or m <= 0:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, em in costs[name].edges:
            visit(callee, m * em)

    if entry:
        visit(entry, 1.0)

    fused_comps = set()
    for cc in costs.values():
        fused_comps.update(cc.fusion_callees)

    total = HloCost(0.0, 0.0, 0.0, {}, {}, unknown_trips)
    for name, m in mult.items():
        cc = costs[name]
        total.flops += cc.flops * m
        total.mem_bytes += cc.mem_fusion * m
        if name not in fused_comps:
            total.mem_bytes += cc.mem_plain * m
        total.wire_bytes += cc.wire_bytes * m
        for k, v in cc.coll_bytes.items():
            total.coll_bytes[k] = total.coll_bytes.get(k, 0.0) + v * m
        for k, v in cc.coll_counts.items():
            total.coll_counts[k] = total.coll_counts.get(k, 0.0) + v * m
    return total


def _update_bytes(comp_lines: List[str]) -> Optional[float]:
    """Bytes of the DUS update operand (operand 1) in a fusion computation."""
    syms: Dict[str, Tuple[str, str]] = {}
    for line in comp_lines:
        m = _INSTR.match(line)
        if m:
            syms[m.group(1)] = (m.group(2), m.group(3))
    for line in comp_lines:
        if "ROOT" in line and "dynamic-update-slice(" in line:
            ops = _operand_names(line)
            if len(ops) >= 2 and ops[1] in syms:
                return _shape_bytes(*syms[ops[1]])
    return None
