"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --batch 8 --seq 512 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (mesh auto-sized); on a real pod point it at
``--mesh production``.  Integrates: synthetic token pipeline, sharded
train_step, checkpoint/restart, straggler watchdog, optional DFR online
readout probe (--dfr-readout) demonstrating the paper's technique as a
first-class feature of the trainer.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.lm import make_train_step
from repro.models.transformer import Transformer
from repro.optim.optimizers import make_optimizer
from repro.optim.schedule import cosine_schedule
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Transformer(cfg)
    mesh = (
        make_production_mesh() if args.mesh == "production"
        else make_host_mesh(model=args.model_parallel)
    )
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    stream = TokenStream(TokenStreamConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    ))

    opt = make_optimizer(args.optimizer)
    lr_fn = cosine_schedule(args.lr, warmup=min(100, args.steps // 10 + 1),
                            total=args.steps)
    step_fn = make_train_step(model, opt, lr_fn, accum=args.accum)

    with shd.use_mesh(mesh):
        params, axes = model.init(jax.random.PRNGKey(0))
        p_shard = shd.guarded_shardings(params, axes, mesh)
        params = jax.device_put(params, p_shard)
        opt_state = jax.jit(
            opt.init, out_shardings=None
        )(params)
        n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
        print(f"arch {cfg.name}: {n_params/1e6:.1f}M params")

        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        def batch_fn(step):
            b = stream.batch(step)
            return {k: jnp.asarray(v) for k, v in b.items()}

        def wrapped_step(params, opt_state, step, batch):
            return jit_step(params, opt_state, jnp.asarray(step), batch)

        trainer = Trainer(
            TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
            wrapped_step,
            batch_fn,
        )
        params, opt_state, start = trainer.restore(params, opt_state)
        if start:
            print(f"resumed from step {start}")
        t0 = time.time()
        last = t0

        orig_log = trainer.metrics_log

        class LogList(list):
            def append(self, rec):  # live progress printing
                super().append(rec)
                if rec["step"] % args.log_every == 0:
                    print(
                        f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                        f"({rec['sec']:.2f}s/step)", flush=True,
                    )

        trainer.metrics_log = LogList(orig_log)
        params, opt_state, step = trainer.run(params, opt_state, args.steps,
                                              start_step=start)
        dt = time.time() - t0
        toks = (args.steps - start) * args.batch * args.seq
        print(f"done: {step} steps, {toks/dt/1e3:.1f}k tok/s, "
              f"final loss {trainer.metrics_log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
