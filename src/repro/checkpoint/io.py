"""Checkpoint I/O: numpy shards + JSON manifest, resharding-capable restore.

Design (no orbax in this container):
  * every param leaf is saved as one .npy per *host-local shard row*, keyed
    by the leaf's tree path and the global offset of the shard - NOT by
    device id.  Restore can therefore re-slice onto ANY mesh/device count
    (elastic restart after losing a pod is a restore onto a smaller mesh).
  * manifest.json records tree structure, global shapes/dtypes, shard
    offsets and data files + a step counter and user metadata.
  * writes are atomic: tmp dir + os.replace.

For the CPU container everything is addressable so save gathers per-leaf
shards trivially; on a real multi-host pod each host writes only its
addressable shards (the code paths are the same - addressable_shards).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out) or "<root>"


def save_checkpoint(
    directory: str | os.PathLike,
    tree: Any,
    step: int,
    metadata: Optional[Dict] = None,
) -> pathlib.Path:
    """Atomically save a pytree of jax/np arrays."""
    directory = pathlib.Path(directory)
    directory.parent.mkdir(parents=True, exist_ok=True)
    tmp = pathlib.Path(tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=directory.parent))
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    manifest: Dict[str, Any] = {
        "step": int(step),
        "time": time.time(),
        "metadata": metadata or {},
        "treedef": str(treedef),
        "leaves": [],
    }
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = _path_str(path)
        entry: Dict[str, Any] = {
            "key": key,
            "index": i,
            "shape": list(np.shape(leaf)),
            "dtype": None,
            "shards": [],
        }
        if isinstance(leaf, jax.Array):
            entry["dtype"] = str(leaf.dtype)
            for si, shard in enumerate(leaf.addressable_shards):
                # skip replicated duplicates: keep only replica 0
                if shard.replica_id != 0:
                    continue
                fname = f"leaf{i:05d}_shard{si:05d}.npy"
                data = np.asarray(shard.data)
                if entry["dtype"] == "bfloat16":
                    data = data.astype(np.float32)  # npy-portable (lossless)
                np.save(tmp / fname, data)
                entry["shards"].append(
                    {
                        "file": fname,
                        "offset": [int(idx.start or 0) for idx in shard.index],
                    }
                )
        else:
            arr = np.asarray(leaf)
            entry["dtype"] = str(arr.dtype)
            fname = f"leaf{i:05d}_shard00000.npy"
            np.save(tmp / fname, arr)
            entry["shards"].append({"file": fname, "offset": [0] * arr.ndim})
        manifest["leaves"].append(entry)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if directory.exists():
        shutil.rmtree(directory)
    os.replace(tmp, directory)
    return directory


def load_manifest(directory: str | os.PathLike) -> Dict:
    return json.loads((pathlib.Path(directory) / "manifest.json").read_text())


def restore_checkpoint(
    directory: str | os.PathLike,
    target_tree: Any,
    shardings: Optional[Any] = None,
) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``target_tree`` (shapes must match
    globally; sharding may be entirely different - elastic restart).

    Returns (tree, step, metadata).
    """
    directory = pathlib.Path(directory)
    manifest = load_manifest(directory)
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target expects {len(leaves)}"
        )
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    out: List[Any] = []
    for i, (target, entry) in enumerate(zip(leaves, manifest["leaves"])):
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"]) if entry["dtype"] != "bfloat16" else jax.numpy.bfloat16
        if tuple(np.shape(target)) != shape:
            raise ValueError(
                f"leaf {entry['key']}: checkpoint shape {shape} != target "
                f"{np.shape(target)}"
            )
        full = np.zeros(shape, dtype=np.float32 if str(dtype) == "bfloat16" else dtype)
        for sh in entry["shards"]:
            data = np.load(directory / sh["file"]).astype(full.dtype)
            idx = tuple(
                slice(off, off + dim) for off, dim in zip(sh["offset"], data.shape)
            )
            full[idx] = data
        arr = jax.numpy.asarray(full, dtype=dtype)
        if shard_leaves[i] is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, int(manifest["step"]), manifest.get("metadata", {})
