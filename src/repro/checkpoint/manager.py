"""Checkpoint manager: keep-last-k + best, auto-resume, failure recovery."""
from __future__ import annotations

import dataclasses
import pathlib
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.io import load_manifest, restore_checkpoint, save_checkpoint

_STEP_RE = re.compile(r"^step_(\d+)$")


@dataclasses.dataclass
class CheckpointManager:
    root: pathlib.Path
    keep: int = 3

    def __init__(self, root, keep: int = 3):
        self.root = pathlib.Path(root)
        self.keep = keep
        self.root.mkdir(parents=True, exist_ok=True)

    # -- catalogue -----------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir():
            m = _STEP_RE.match(p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def path_for(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step}"

    # -- save/restore ----------------------------------------------------------

    def save(self, tree: Any, step: int, metadata: Optional[Dict] = None):
        save_checkpoint(self.path_for(step), tree, step, metadata)
        self._gc()

    def restore_latest(
        self, target_tree: Any, shardings: Optional[Any] = None
    ) -> Optional[Tuple[Any, int, Dict]]:
        """Restore the newest valid checkpoint; fall back to older ones if a
        checkpoint is corrupt (partial write from a dying host)."""
        for step in reversed(self.steps()):
            try:
                return restore_checkpoint(self.path_for(step), target_tree, shardings)
            except Exception:  # noqa: BLE001 - corrupt ckpt: try the previous
                continue
        return None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.path_for(s), ignore_errors=True)
