from repro.checkpoint.io import restore_checkpoint, save_checkpoint, load_manifest  # noqa: F401
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
