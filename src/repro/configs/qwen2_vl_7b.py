"""Qwen2-VL-7B backbone: M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision
frontend is a STUB: input_specs provide precomputed patch embeddings; text
tokens use degenerate (t,t,t) M-RoPE streams.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    m_rope=True,
    m_rope_sections=(16, 24, 24),
    input_mode="embeds",
    skip_shapes=("long_500k",),
    grad_accum={"train_4k": 4, "prefill_32k": 1},
)
