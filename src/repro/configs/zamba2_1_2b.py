"""Zamba2-1.2B: Mamba2 backbone + shared attention block [arXiv:2411.15242; hf].

38L d_model=2048 (GQA kv=32 on the shared block) d_ff=8192 vocab=32000,
ssm_state=64.  Constant-size SSD state => runs long_500k (the shared-attn
call sites keep a KV cache, sharded over 'model').
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    grad_accum={"train_4k": 4, "prefill_32k": 1},
)
