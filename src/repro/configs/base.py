"""ArchConfig: one dataclass describing every supported architecture, plus
the four assigned input shapes and ``input_specs()`` (ShapeDtypeStruct
stand-ins - never allocates).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned shape set (identical for all 10 LM-family archs).
SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # --- attention ---
    qkv_bias: bool = False
    window_pattern: Tuple[int, ...] = ()   # cycled per layer; 0 = global
    rope_theta: float = 1e4
    m_rope: bool = False                   # qwen2-vl 3-stream RoPE
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- recurrent families ---
    rwkv: bool = False
    rwkv_head_dim: int = 64
    ssm_state: int = 0                     # mamba2 state size (hybrid/ssm)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 6                    # zamba2: shared attn block period
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- frontend stubs ---
    input_mode: str = "tokens"             # tokens | embeds (audio/vision stub)
    # --- numerics / misc ---
    norm: str = "rms"                      # rms | layernorm
    act: str = "silu"                      # silu | gelu
    pos: str = "rope"                      # rope | absolute
    max_abs_pos: int = 32800               # absolute-pos table size (encdec)
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    # --- training-time knobs (overridable per run) ---
    remat_policy: str = "nothing"          # nothing | dots | none
    scan_chunk: int = 128                  # rwkv/ssd chunk length
    block_q: int = 512
    block_k: int = 1024
    # attention implementation: 'xla' (fallback, scores spill to HBM) or
    # 'pallas' (flash kernel on TPU; on CPU the fallback runs inside the
    # flashattn_vmem scope so the roofline walker models VMEM residency)
    attn_impl: str = "xla"
    # pin block outputs with an optimization barrier so XLA cannot hoist
    # f32 converts across the TP all-reduces (keeps collectives in bf16)
    act_barrier: bool = False
    # shape-dependent skips, e.g. long_500k for full-attention archs
    skip_shapes: Tuple[str, ...] = ()
    # microbatch split per shape name (grad accumulation steps)
    grad_accum: Any = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (shardable by model axis)."""
        return -(-self.vocab // 256) * 256

    def window_for_layer(self, i: int) -> int:
        if not self.window_pattern:
            return 0
        return self.window_pattern[i % len(self.window_pattern)]

    # ----- parameter count (for 6ND model-flops accounting) ------------------

    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        embed = v * d * (1 if self.tie_embeddings else 2)
        if self.rwkv:
            per_layer = 5 * d * d + d * 64 + 64 * d + 2 * d  # rwkv6 approx
            ffn = 2 * d * ff
            return self.n_layers * (per_layer + ffn) + embed
        attn = d * n_q + 2 * d * n_kv + n_q * d
        dense_ffn = 3 * d * ff if self.act == "silu" else 2 * d * ff
        if self.family == "moe":
            moe_ffn = self.n_experts * 3 * d * ff + d * self.n_experts
            layers = self.n_layers * (attn + moe_ffn)
        elif self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            ssm = d * (2 * d_in + 2 * self.ssm_state + nh) + d_in * d
            n_attn = self.n_layers // self.attn_every if self.family == "hybrid" else 0
            layers = self.n_layers * ssm + max(n_attn, 1 if self.family == "hybrid" else 0) * 0
            # zamba2 shares ONE attn+ffn block across call sites
            shared = (attn + dense_ffn) if self.family == "hybrid" else 0
            layers += shared
        else:
            layers = self.n_layers * (attn + dense_ffn)
        if self.is_encdec:
            # encoder + decoder stacks + cross attention
            cross = d * n_q + 2 * d * n_kv + n_q * d
            layers = (self.enc_layers + self.dec_layers) * (attn + dense_ffn)
            layers += self.dec_layers * cross
        return layers + embed

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k active experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        total = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * d * ff
        moe_active = self.n_layers * self.top_k * 3 * d * ff
        return total - moe_all + moe_active


def input_specs(cfg: ArchConfig, shape: ShapeSpec, *, batch_override: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a step function.

    train:   tokens + targets (B, T)
    prefill: tokens (B, T)
    decode:  token (B, 1) + cache (built separately by the step fn factory)
    For input_mode='embeds' the token stream is replaced by precomputed
    frame/patch embeddings (B, T, d_model) - the assigned frontend stub.
    """
    b = batch_override or shape.global_batch
    t = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.input_mode == "embeds":
            return {
                "embeds": jax.ShapeDtypeStruct((b, t, cfg.d_model), cfg.dtype),
                "targets": jax.ShapeDtypeStruct((b, t), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, t), i32),
            "targets": jax.ShapeDtypeStruct((b, t), i32),
        }
    if shape.kind == "prefill":
        if cfg.input_mode == "embeds":
            return {"embeds": jax.ShapeDtypeStruct((b, t, cfg.d_model), cfg.dtype)}
        return {"tokens": jax.ShapeDtypeStruct((b, t), i32)}
    # decode: one new token against a cache of seq_len
    return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
