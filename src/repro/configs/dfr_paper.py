"""The paper's own DFR configurations (Sec. 4.1): Nx=30, linear f,
p=q=0.01 init, 25 epochs, the beta sweep - one preset per Table 4 dataset.
"""
from repro.core.types import DFRConfig
from repro.data.timeseries import PAPER_DATASETS


def paper_dfr_config(dataset: str, n_nodes: int = 30) -> DFRConfig:
    spec = PAPER_DATASETS[dataset.upper()]
    return DFRConfig(
        n_in=spec.n_in,
        n_classes=spec.n_classes,
        n_nodes=n_nodes,
        nonlinearity="linear",
    )
