"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published configuration;
``get_reduced(arch_id)`` returns the same family scaled down for CPU smoke
tests (few layers, narrow widths, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, input_specs  # noqa: F401

from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b
from repro.configs.llama4_maverick_400b_a17b import CONFIG as llama4_maverick
from repro.configs.llama4_scout_17b_a16e import CONFIG as llama4_scout
from repro.configs.minitron_8b import CONFIG as minitron_8b
from repro.configs.gemma3_4b import CONFIG as gemma3_4b
from repro.configs.qwen15_110b import CONFIG as qwen15_110b
from repro.configs.smollm_135m import CONFIG as smollm_135m
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b
from repro.configs.whisper_small import CONFIG as whisper_small
from repro.configs.qwen2_vl_7b import CONFIG as qwen2_vl_7b

REGISTRY: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        rwkv6_7b,
        llama4_maverick,
        llama4_scout,
        minitron_8b,
        gemma3_4b,
        qwen15_110b,
        smollm_135m,
        zamba2_1_2b,
        whisper_small,
        qwen2_vl_7b,
    ]
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def get_reduced(arch_id: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(arch_id)
    updates = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=128,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        scan_chunk=32,
        block_q=64,
        block_k=64,
        max_abs_pos=512,
    )
    if cfg.family == "moe":
        updates.update(n_experts=4)
    if cfg.m_rope:
        updates.update(m_rope_sections=(4, 6, 6))  # head_dim 32 -> 16 half-slots
    if cfg.rwkv:
        updates.update(rwkv_head_dim=32)
    if cfg.ssm_state:
        updates.update(ssm_state=16, ssm_head_dim=32, attn_every=2)
    if cfg.is_encdec:
        updates.update(enc_layers=2, dec_layers=2)
    if cfg.window_pattern:
        updates.update(window_pattern=(32, 32, 0))
    return dataclasses.replace(cfg, **updates)


ALL_ARCHS = sorted(REGISTRY)
