"""RWKV-6 'Finch' 7B: attention-free, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=4096 d_ff=14336 vocab=65536; linear-time recurrent state =>
runs the long_500k cell (constant-size state at decode).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="dense",
    rwkv=True,
    rwkv_head_dim=64,
    n_layers=32,
    d_model=4096,
    n_heads=64,        # d_model / rwkv_head_dim (bookkeeping only)
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    act="silu",
    grad_accum={"train_4k": 8, "prefill_32k": 1},
)
