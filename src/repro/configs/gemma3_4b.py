"""Gemma-3 4B: 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; local window 1024.
Global layers are full-attention => long_500k skipped (see DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),  # 5 local : 1 global
    rope_theta=1e6,
    act="gelu",
    skip_shapes=("long_500k",),
    grad_accum={"train_4k": 4, "prefill_32k": 1},
)
