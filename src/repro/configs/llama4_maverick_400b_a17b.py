"""Llama-4 Maverick 400B-A17B: MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
Full attention => long_500k skipped (documented in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    capacity_factor=1.25,
    rope_theta=5e5,
    skip_shapes=("long_500k",),
    grad_accum={"train_4k": 8, "prefill_32k": 2},
)
