"""Whisper-small: enc-dec audio, conv frontend STUB [arXiv:2212.04356; unverified].

12L enc + 12L dec, d_model=768 12H d_ff=3072 vocab=51865 (padded to 51968).
input_specs supply precomputed frame embeddings (the assigned stub).
Encoder has no decode step; decode cells exercise the decoder with
cross-attention to stub encoder states.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,       # stack depth bookkeeping (enc/dec below)
    enc_layers=12,
    dec_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    pos="absolute",
    input_mode="embeds",
    max_abs_pos=32800,
    skip_shapes=("long_500k",),
    grad_accum={"train_4k": 1, "prefill_32k": 1},
)
