"""Synthetic multivariate time-series classification data (paper Table 4).

The paper evaluates on the UEA-derived npz datasets of [6] (ARAB ... WALK),
which are not redistributable in this offline container.  We generate
class-separable synthetic series with *exactly* the Table 4 statistics
(#V channels, #C classes, Train/Test sizes, Tmin/Tmax lengths) so every
system-level claim (bp vs grid-search time/accuracy, Cholesky exactness,
memory/op ratios) is exercised at the paper's true scales.

Generator: each class c owns a random stable 2nd-order AR filter bank and a
class-specific sinusoidal carrier per channel; samples are filtered noise +
carrier + observation noise, then z-normalized per channel.  Class
information lives in both the spectrum and the cross-channel mixing - the
kind of structure a reservoir readout can separate but a linear model on raw
means cannot.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import numpy as np

from repro.core.types import RegressionBatch, TimeSeriesBatch

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_in: int       # V
    n_classes: int  # C
    n_train: int
    n_test: int
    t_min: int
    t_max: int


# Paper Table 4, verbatim.
PAPER_DATASETS: Dict[str, DatasetSpec] = {
    s.name: s
    for s in [
        DatasetSpec("ARAB", 13, 10, 6600, 2200, 4, 93),
        DatasetSpec("AUS", 22, 95, 1140, 1425, 45, 136),
        DatasetSpec("CHAR", 3, 20, 300, 2558, 109, 205),
        DatasetSpec("CMU", 62, 2, 29, 29, 127, 580),
        DatasetSpec("ECG", 2, 2, 100, 100, 39, 152),
        DatasetSpec("JPVOW", 12, 9, 270, 370, 7, 29),
        DatasetSpec("KICK", 62, 2, 16, 10, 274, 841),
        DatasetSpec("LIB", 2, 15, 180, 180, 45, 45),
        DatasetSpec("NET", 4, 13, 803, 534, 50, 994),
        DatasetSpec("UWAV", 3, 8, 200, 427, 315, 315),
        DatasetSpec("WAF", 6, 2, 298, 896, 104, 198),
        DatasetSpec("WALK", 62, 2, 28, 16, 128, 1918),
    ]
}


def _gen_class_params(rng: np.random.Generator, n_classes: int, n_in: int):
    """Per-class prototype curves: a small bank of sinusoidal harmonics per
    channel (class-specific amplitudes, cycle counts and phases).  Samples
    are time-warped, scaled renderings of the prototype plus AR(1) noise -
    shape-based classes like the UEA gesture/character sets, which require
    temporal integration (not just lag-1 statistics) to separate."""
    n_h = 4
    amp = rng.uniform(0.3, 1.0, (n_classes, n_in, n_h))
    cycles = rng.uniform(0.5, 4.0, (n_classes, n_in, n_h))
    phase = rng.uniform(0, 2 * np.pi, (n_classes, n_in, n_h))
    return amp, cycles, phase


def _synth_one(
    rng: np.random.Generator,
    t_len: int,
    amp: np.ndarray,     # (n_in, n_h)
    cycles: np.ndarray,  # (n_in, n_h)
    phase: np.ndarray,   # (n_in, n_h)
    noise: float,
) -> np.ndarray:
    n_in = amp.shape[0]
    warp = rng.uniform(0.85, 1.15)
    offs = rng.uniform(-0.05, 0.05)
    scale = rng.uniform(0.8, 1.25)
    frac = (np.arange(t_len) / max(t_len - 1, 1))[:, None, None]  # (T,1,1)
    curves = amp[None] * np.sin(
        2 * np.pi * cycles[None] * (warp * frac + offs) + phase[None]
    )
    x = scale * curves.sum(-1)  # (T, n_in)
    # AR(1) observation noise
    e = rng.normal(0, noise, (t_len, n_in))
    ar = np.zeros_like(e)
    for t in range(t_len):
        ar[t] = (0.6 * ar[t - 1] if t else 0.0) + e[t]
    x = x + ar
    # per-channel z-normalization (standard for the UEA sets)
    mu, sd = x.mean(0, keepdims=True), x.std(0, keepdims=True) + 1e-6
    return (x - mu) / sd


def make_dataset(
    spec: DatasetSpec,
    seed: int = 0,
    noise: float = 0.3,
    size_cap: int | None = None,
) -> Tuple[TimeSeriesBatch, TimeSeriesBatch]:
    """Generate (train, test) batches with the spec's exact statistics.

    ``size_cap`` optionally bounds Train/Test counts (for fast CI runs);
    class balance is preserved.
    """
    # zlib.crc32 is a stable digest: Python's str hash is randomized per
    # process (PYTHONHASHSEED), which silently made "deterministic per seed"
    # datasets differ across runs/CI machines.
    rng = np.random.default_rng(seed + zlib.crc32(spec.name.encode()) % (2**31))
    amp, cycles, phase = _gen_class_params(rng, spec.n_classes, spec.n_in)

    def gen_split(n: int, split_seed: int) -> TimeSeriesBatch:
        srng = np.random.default_rng(split_seed)
        labels = np.arange(n) % spec.n_classes  # balanced
        srng.shuffle(labels)
        lengths = srng.integers(spec.t_min, spec.t_max + 1, n)
        u = np.zeros((n, spec.t_max, spec.n_in), np.float32)
        for i in range(n):
            c = labels[i]
            u[i, : lengths[i]] = _synth_one(
                srng, int(lengths[i]), amp[c], cycles[c], phase[c], noise,
            )
        return TimeSeriesBatch(
            u=jnp.asarray(u),
            length=jnp.asarray(lengths.astype(np.int32)),
            label=jnp.asarray(labels.astype(np.int32)),
        )

    n_train, n_test = spec.n_train, spec.n_test
    if size_cap is not None:
        n_train = min(n_train, size_cap)
        n_test = min(n_test, size_cap)
        n_train = max(n_train, spec.n_classes)  # at least one per class
        n_test = max(n_test, spec.n_classes)
    return gen_split(n_train, seed * 2 + 1), gen_split(n_test, seed * 2 + 2)


def load(name: str, seed: int = 0, size_cap: int | None = None):
    """Load a paper dataset by Table 4 name (synthetic; see module doc)."""
    return make_dataset(PAPER_DATASETS[name.upper()], seed=seed, size_cap=size_cap)


# ---------------------------------------------------------------------------
# NARMA10: the standard reservoir-computing regression benchmark (used by the
# population engine's NRMSE fitness and its tests).
# ---------------------------------------------------------------------------


def narma10_series(n_steps: int, seed: int = 0, order: int = 10) -> Tuple[np.ndarray, np.ndarray]:
    """One NARMA-``order`` input/output sequence.

        y(t+1) = 0.3 y(t) + 0.05 y(t) sum_{i=0..9} y(t-i)
                 + 1.5 u(t-9) u(t) + 0.1,    u(t) ~ U[0, 0.5]

    Returns (u, y), both (n_steps,) float32.  The recurrence is run with
    zero history for t < order (the usual washout convention).
    """
    rng = np.random.default_rng(seed)
    u = rng.uniform(0.0, 0.5, n_steps).astype(np.float64)
    y = np.zeros(n_steps, np.float64)
    for t in range(n_steps - 1):
        window = y[max(0, t - order + 1): t + 1].sum()
        y[t + 1] = (0.3 * y[t] + 0.05 * y[t] * window
                    + 1.5 * u[max(0, t - order + 1)] * u[t] + 0.1)
    return u.astype(np.float32), y.astype(np.float32)


NARMA_COEFFS = (0.3, 0.05, 1.5, 0.1)
"""The standard NARMA10 recurrence coefficients (a, b, c, d) in
y(t+1) = a y(t) + b y(t) sum_i y(t-i) + c u(t-9) u(t) + d."""


def narma_series_coeffs(
    n_steps: int,
    seed: int = 0,
    order: int = 10,
    coeffs: np.ndarray | Tuple[float, float, float, float] = NARMA_COEFFS,
) -> Tuple[np.ndarray, np.ndarray]:
    """``narma10_series`` with per-step recurrence coefficients.

    ``coeffs`` is either one (a, b, c, d) tuple (stationary - identical to
    ``narma10_series`` for the default coefficients) or an (n_steps, 4)
    array giving the coefficients used to *produce* each y[t] - the
    piecewise-stationary drift hook.  Raises ``ValueError`` if the chosen
    coefficients drive the recurrence non-finite (unstable regime).
    """
    rng = np.random.default_rng(seed)
    u = rng.uniform(0.0, 0.5, n_steps).astype(np.float64)
    cf = np.broadcast_to(
        np.asarray(coeffs, np.float64), (n_steps, 4)
    )
    y = np.zeros(n_steps, np.float64)
    with np.errstate(over="ignore", invalid="ignore"):
        for t in range(n_steps - 1):
            a, b, c, d = cf[t + 1]
            window = y[max(0, t - order + 1): t + 1].sum()
            y[t + 1] = (a * y[t] + b * y[t] * window
                        + c * u[max(0, t - order + 1)] * u[t] + d)
    if not np.isfinite(y).all():
        raise ValueError("NARMA recurrence diverged for these coefficients")
    return u.astype(np.float32), y.astype(np.float32)


def make_narma10_drift(
    n_samples: int = 400,
    t_len: int = 32,
    seed: int = 0,
    switch_frac: float = 0.5,
    coeffs_a: Tuple[float, float, float, float] = NARMA_COEFFS,
    coeffs_b: Tuple[float, float, float, float] = (0.2, 0.04, 1.0, 0.3),
    order: int = 10,
) -> Tuple[RegressionBatch, Dict]:
    """One piecewise-stationary (drifting) NARMA stream, in serving order.

    The recurrence runs under ``coeffs_a`` up to the drift point and under
    ``coeffs_b`` after it: the exogenous input distribution never changes,
    only the input->output dynamics - the regime a deployed reservoir
    readout faces when the plant behind a sensor drifts.  Windows are cut
    stride-1 in time order (no shuffling: sample i is served before sample
    i+1), and the switch lands exactly at sample ``switch_sample =
    floor(n_samples * switch_frac)``: that window's target is the first
    value produced by the ``coeffs_b`` recurrence.

    Returns ``(batch, info)``: a ``RegressionBatch`` with u (N, t_len, 1) /
    length (N,) / y (N, 1), and an info dict with ``switch_sample``,
    ``switch_step`` (the underlying series index where the coefficients
    change) and both coefficient tuples.  Deterministic per ``seed``.
    """
    if not 0.0 < switch_frac < 1.0:
        raise ValueError(f"switch_frac must be in (0, 1), got {switch_frac!r}")
    n_steps = order + n_samples + t_len
    switch_sample = int(n_samples * switch_frac)
    # y[idx] is window i's target for idx = order + i + t_len - 1: regime B
    # from the switch sample's target onward
    switch_step = order + switch_sample + t_len - 1
    cf = np.empty((n_steps, 4), np.float64)
    cf[:switch_step] = coeffs_a
    cf[switch_step:] = coeffs_b
    u, y = narma_series_coeffs(n_steps, seed=seed, order=order, coeffs=cf)
    starts = order + np.arange(n_samples)
    uw = np.stack([u[s: s + t_len] for s in starts])[..., None]
    yw = y[starts + t_len - 1][:, None]
    batch = RegressionBatch(
        u=jnp.asarray(uw.astype(np.float32)),
        length=jnp.asarray(np.full(n_samples, t_len, np.int32)),
        y=jnp.asarray(yw.astype(np.float32)),
    )
    info = {
        "switch_sample": switch_sample,
        "switch_step": switch_step,
        "coeffs_a": tuple(coeffs_a),
        "coeffs_b": tuple(coeffs_b),
    }
    return batch, info


def quantize_targets(
    y: np.ndarray,
    n_classes: int,
    edges: np.ndarray | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Bin continuous targets into ``n_classes`` ordinal labels.

    ``edges`` defaults to the equal-mass quantile edges of ``y`` itself;
    pass edges computed on a reference segment (e.g. the pre-drift regime)
    to make a distribution shift visible as label-space movement.  Returns
    (labels int32 (N,), edges (n_classes - 1,)).
    """
    y = np.asarray(y).reshape(-1)
    if edges is None:
        qs = np.linspace(0, 1, n_classes + 1)[1:-1]
        edges = np.quantile(y, qs)
    edges = np.asarray(edges, y.dtype)
    return np.digitize(y, edges).astype(np.int32), edges


def make_drift_label_streams(
    n_streams: int,
    n_samples: int,
    t_len: int,
    n_classes: int,
    seed: int = 0,
    seed_stride: int = 17,
) -> Tuple[list, list]:
    """Drifting NARMA streams as classification-serving arrays.

    One ``make_narma10_drift`` stream per rid (seeds strided so streams are
    independent), targets quantized to ``n_classes`` ordinal labels with
    *full-stream* quantile edges - the edges span both regimes, so the
    drift shows up as the input->label mapping moving, not as unseen
    labels.  Returns (streams, switches): each stream is a dict with
    ``u`` (N, t_len, 1) f32, ``length`` (N,) i32 and ``label`` (N,) i32 -
    ready to wrap in a serving request - and ``switches`` the per-stream
    drift sample.  Shared by the drift benchmark and the drift example so
    both report on identical data.
    """
    streams, switches = [], []
    for rid in range(n_streams):
        batch, info = make_narma10_drift(
            n_samples=n_samples, t_len=t_len, seed=seed + seed_stride * rid
        )
        labels, _ = quantize_targets(np.asarray(batch.y), n_classes)
        streams.append({
            "u": np.asarray(batch.u),
            "length": np.asarray(batch.length),
            "label": labels.astype(np.int32),
        })
        switches.append(info["switch_sample"])
    return streams, switches


def drift_segment_bounds(
    n_samples: int, switch_sample: int, window: int
) -> Tuple[Tuple[int, int], Tuple[int, int], Tuple[int, int]]:
    """The shared (pre, at, post) index bounds for drift-recovery accuracy.

    ``seg = max(window, n_samples // 5)``: *pre* is the seg samples before
    the switch, *at* the seg/2 right after it (where every policy craters
    - no oracle knows the plant changed), *post* the stream tail (where
    retirement policies have had time to re-track).  One definition so the
    benchmark drift table and the example always report comparable
    segments.  Raises ``ValueError`` when the segments do not fit around
    the switch (e.g. an extreme ``switch_frac``): a silent negative bound
    would slice an empty range and report NaN accuracy downstream.
    """
    seg = max(window, n_samples // 5)
    if switch_sample < seg or switch_sample + seg // 2 > n_samples:
        raise ValueError(
            f"accuracy segments of {seg} samples do not fit around "
            f"switch_sample={switch_sample} in n_samples={n_samples}"
        )
    return (
        (switch_sample - seg, switch_sample),
        (switch_sample, switch_sample + seg // 2),
        (n_samples - seg, n_samples),
    )


def make_narma10(
    n_train: int = 200,
    n_test: int = 100,
    t_len: int = 32,
    seed: int = 0,
    order: int = 10,
) -> Tuple[RegressionBatch, RegressionBatch]:
    """NARMA10 framed as sequence -> scalar regression for the DFR pipeline.

    Overlapping windows of length ``t_len`` are cut from one long series;
    each window's target is the NARMA output aligned with its last input
    step.  Train windows precede test windows in time, with a ``t_len``-step
    gap between the last train window and the first test window so no test
    window shares any input step (or adjacent target) with a train window.
    """
    n_total = n_train + n_test
    u, y = narma10_series(order + n_total + 2 * t_len, seed=seed, order=order)
    starts = order + np.arange(n_total)
    starts[n_train:] += t_len  # leakage gap between the splits
    uw = np.stack([u[s: s + t_len] for s in starts])[..., None]  # (B, T, 1)
    yw = y[starts + t_len - 1][:, None]                          # (B, 1)
    lengths = np.full(n_total, t_len, np.int32)

    def split(lo: int, hi: int) -> RegressionBatch:
        return RegressionBatch(
            u=jnp.asarray(uw[lo:hi].astype(np.float32)),
            length=jnp.asarray(lengths[lo:hi]),
            y=jnp.asarray(yw[lo:hi].astype(np.float32)),
        )

    return split(0, n_train), split(n_train, n_total)
