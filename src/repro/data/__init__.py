from repro.data.timeseries import (  # noqa: F401
    NARMA_COEFFS,
    PAPER_DATASETS,
    DatasetSpec,
    drift_segment_bounds,
    load,
    make_dataset,
    make_drift_label_streams,
    make_narma10,
    make_narma10_drift,
    narma10_series,
    narma_series_coeffs,
    quantize_targets,
)
