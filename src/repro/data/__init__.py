from repro.data.timeseries import (  # noqa: F401
    PAPER_DATASETS,
    DatasetSpec,
    load,
    make_dataset,
    make_narma10,
    narma10_series,
)
