"""Synthetic LM token pipeline: deterministic, shardable, restart-safe.

Streams Zipf-distributed token sequences with local n-gram structure (so a
real LM can actually reduce loss on it).  Every batch is a pure function of
(seed, step, shard) - the fault-tolerant trainer replays any step after
restore and elastic restarts re-partition the stream by shard count.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_order: int = 3
    ngram_strength: float = 0.7   # prob of following the n-gram chain


class TokenStream:
    """Deterministic synthetic token batches."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # fixed random n-gram successor table: v -> successor (cheap chain)
        self.successor = base.integers(0, cfg.vocab, size=cfg.vocab)
        # precomputed Zipf normalization
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.probs = p / p.sum()

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        rows = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + shard
        )
        iid = rng.choice(cfg.vocab, size=(rows, cfg.seq_len), p=self.probs)
        follow = rng.random((rows, cfg.seq_len)) < cfg.ngram_strength
        toks = iid.copy()
        for t in range(1, cfg.seq_len):
            chained = self.successor[toks[:, t - 1]]
            toks[:, t] = np.where(follow[:, t], chained, iid[:, t])
        toks = toks.astype(np.int32)
        return {"tokens": toks, "targets": toks}

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
