"""Online edge training + inference (the paper's deployment scenario),
served through the continuous-batching stream server.

    PYTHONPATH=src python examples/online_edge.py [--size-cap 100]
        [--nodes 30] [--streams 4] [--window 4] [--max-streams 2]

Simulates a fleet of predictive-maintenance sensors (Sec. 1): several
independent streams submit labeled sample windows; the server packs them
into fixed slots and advances every live stream with ONE fused jitted step
per window round - the 'everything on the FPGA' analogue, multi-tenant:

  * infer-before-update: each window is answered from the parameters the
    slot had before seeing the labels (the honest online metric),
  * phase 1 (slot-local): truncated-bp SGD adapts (p, q, W, b),
  * phase 2: the reservoir freezes and the slot accumulates the Ridge
    sufficient statistics (A, B) in place; ``reset_statistics`` semantics
    guarantee no stale phase-1 features leak into them,
  * every few rounds the server re-solves every live slot's output layer
    with one batched Cholesky (the paper's 1-D Cholesky, batched).

With fewer slots than streams, finished streams retire and the slots
refill (continuous batching).  The retired snapshot of each stream is a
complete ``OnlineState``: we pick the best stream's model, give it the
single-stream ``reset_statistics`` / ``refresh_output`` treatment on a
held-out pass, and report final accuracy.
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import OnlineDFR
from repro.core.types import DFRConfig
from repro.data import PAPER_DATASETS, load
from repro.runtime import StreamRequest, StreamServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ECG")
    ap.add_argument("--size-cap", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=30)
    ap.add_argument("--streams", type=int, default=4,
                    help="how many sensor streams to carve the data into")
    ap.add_argument("--max-streams", type=int, default=2,
                    help="server slots (< streams exercises refill)")
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--refresh-mode", choices=("recompute", "incremental"),
                    default="recompute",
                    help="periodic ridge refresh: re-factorize B (O(s^3)) "
                         "or keep a live rank-1-updated Cholesky factor per "
                         "slot (O(s^2) solves)")
    ap.add_argument("--refresh-cohorts", type=int, default=1,
                    help="stagger the refresh round over this many "
                         "round-robin slot cohorts (1 = global round)")
    args = ap.parse_args()

    spec = PAPER_DATASETS[args.dataset]
    train, test = load(args.dataset, size_cap=args.size_cap)
    cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes,
                    n_nodes=args.nodes)

    # carve the training set into independent streams (one per 'sensor');
    # array_split uses every sample and honors --streams exactly
    n = train.batch
    u, ln, lab = (np.asarray(train.u), np.asarray(train.length),
                  np.asarray(train.label))
    splits = [idx for idx in np.array_split(np.arange(n), args.streams)
              if len(idx)]
    streams = [
        StreamRequest(rid=i, u=u[idx], length=ln[idx], label=lab[idx])
        for i, idx in enumerate(splits)
    ]

    # phase 1 covers ~40% of each stream's windows, but always leaves at
    # least one phase-2 window so (A, B) accumulate and the refresh runs
    windows_per_stream = max(1, len(splits[0]) // args.window)
    phase_steps = max(1, min(int(windows_per_stream * 0.4) or 1,
                             windows_per_stream - 1))
    server = StreamServer(
        cfg, t_max=train.t_max, max_streams=args.max_streams,
        window=args.window, phase_steps=phase_steps, refresh_every=5,
        refresh_mode=args.refresh_mode, refresh_cohorts=args.refresh_cohorts,
    )
    print(f"serving {len(streams)} streams x ~{len(splits[0])} samples "
          f"({args.max_streams} slots, windows of {args.window}); phase 1 "
          f"(reservoir adaptation) for {phase_steps} windows/stream, then "
          f"phase 2 ((A,B) accumulation, {args.refresh_mode} ridge refresh "
          f"every 5 rounds over {server.cohorts.n_cohorts} cohort(s)) - "
          f"the paper's protocol, train-while-serve")
    for s in streams:
        server.submit(s)
    done = server.run_until_drained()

    for r in sorted(done, key=lambda r: r.rid):
        print(f"  stream {r.rid}: {r.n_samples} samples, rolling online acc "
              f"{r.online_accuracy:.3f} "
              f"({int(r.final_state.ridge.count)} samples in (A,B))")
    lat = server.latency_percentiles_ms()
    print(f"  window-round latency p50 {lat['p50_ms']:.1f} ms / "
          f"p99 {lat['p99_ms']:.1f} ms over {server.global_step} rounds")

    # held-out evaluation with the best stream's retired model: refresh the
    # readout from its streamed statistics, then classify the test split
    best = max(done, key=lambda r: (r.online_accuracy, -r.rid))
    system = OnlineDFR(cfg, mask=server.mask)
    state = best.final_state
    if int(state.ridge.count) > 0:
        state = system.refresh_output(state, jnp.float32(1e-2))
    else:
        print("  note: no phase-2 samples accumulated (stream too short for "
              "the phase split) - evaluating the SGD readout unrefreshed")
    preds = system.infer(state, test.u, test.length)
    acc = float(jnp.mean((preds == test.label).astype(jnp.float32)))
    print(f"final held-out accuracy (best stream {best.rid}'s model, "
          f"p={float(state.params.p):.4f} q={float(state.params.q):.4f}): "
          f"{acc:.3f}")


if __name__ == "__main__":
    main()
