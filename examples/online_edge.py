"""Online edge training + inference (the paper's deployment scenario).

    PYTHONPATH=src python examples/online_edge.py

Simulates the predictive-maintenance stream of Sec. 1: samples arrive a few
at a time; the system (one fused jitted step - the 'everything on the FPGA'
analogue) updates (p, q, W, b) by truncated backprop, accumulates the Ridge
sufficient statistics (A, B) in-place, periodically refreshes the output
layer with the 1-D Cholesky solve, and serves inference *while training* -
reporting rolling accuracy as it adapts.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import OnlineDFR
from repro.core.types import DFRConfig
from repro.data import PAPER_DATASETS, load


def main():
    name = "ECG"  # 2-channel sensor stream, 2 classes (fault / healthy)
    spec = PAPER_DATASETS[name]
    train, test = load(name, size_cap=100)
    cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes, n_nodes=30)
    system = OnlineDFR(cfg)
    state = system.init()

    import dataclasses
    from repro.core.types import RidgeState

    window, refresh_every = 4, 5
    n_windows = (train.batch - window + 1) // window + 1
    phase_switch = max(3, int(n_windows * 0.4))
    seen, correct = 0, 0
    print(f"streaming {train.batch} samples in windows of {window}; "
          f"phase 1 (reservoir adaptation) for {phase_switch} windows, then "
          f"phase 2 ((A,B) accumulation with frozen reservoir, ridge refresh "
          f"every {refresh_every} windows) - the paper's protocol, online")
    for i, lo in enumerate(range(0, train.batch - window + 1, window)):
        u = train.u[lo:lo + window]
        ln = train.length[lo:lo + window]
        lab = train.label[lo:lo + window]
        # inference-before-update: the honest online metric
        preds = system.infer(state, u, ln)
        correct += int(jnp.sum((preds == lab).astype(jnp.int32)))
        seen += window
        if i < phase_switch:
            lr = jnp.float32(0.2)       # adapt (p, q, W, b) by truncated bp
        else:
            lr = jnp.float32(0.0)       # reservoir frozen: consistent features
        state, metrics = system.step(state, u, ln, lab, lr, lr)
        if i == phase_switch - 1:
            # features change as (p, q) move - restart the sufficient stats
            state = dataclasses.replace(
                state, ridge=RidgeState.zeros(cfg.s, cfg.n_classes))
            print(f"  window {i+1:3d}: phase switch "
                  f"(p={float(state.params.p):.4f} q={float(state.params.q):.4f})")
        elif i >= phase_switch and (i + 1) % refresh_every == 0:
            state = system.refresh_output(state, jnp.float32(1e-2))
            print(f"  window {i+1:3d}: rolling online acc "
                  f"{correct/seen:.3f} (ridge refreshed, "
                  f"{int(state.ridge.count)} samples)")

    state = system.refresh_output(state, jnp.float32(1e-2))
    preds = system.infer(state, test.u, test.length)
    acc = float(jnp.mean((preds == test.label).astype(jnp.float32)))
    print(f"final held-out accuracy after online adaptation: {acc:.3f}")


if __name__ == "__main__":
    main()
