"""Online edge training + inference (the paper's deployment scenario),
served through the continuous-batching stream server.

    PYTHONPATH=src python examples/online_edge.py [--size-cap 100]
        [--nodes 30] [--streams 4] [--window 4] [--max-streams 2]

Simulates a fleet of predictive-maintenance sensors (Sec. 1): several
independent streams submit labeled sample windows; the server packs them
into fixed slots and advances every live stream with ONE fused jitted step
per window round - the 'everything on the FPGA' analogue, multi-tenant:

  * infer-before-update: each window is answered from the parameters the
    slot had before seeing the labels (the honest online metric),
  * phase 1 (slot-local): truncated-bp SGD adapts (p, q, W, b),
  * phase 2: the reservoir freezes and the slot accumulates the Ridge
    sufficient statistics (A, B) in place; ``reset_statistics`` semantics
    guarantee no stale phase-1 features leak into them,
  * every few rounds the server re-solves every live slot's output layer
    with one batched Cholesky (the paper's 1-D Cholesky, batched).

With fewer slots than streams, finished streams retire and the slots
refill (continuous batching).  The retired snapshot of each stream is a
complete ``OnlineState``: we pick the best stream's model, give it the
single-stream ``reset_statistics`` / ``refresh_output`` treatment on a
held-out pass, and report final accuracy.

Drift mode (``--drift``): serve piecewise-stationary NARMA streams
(``repro.data.make_narma10_drift``) instead of a dataset, and report the
online accuracy before / at / after each stream's drift point - the
regime where the sample-retirement policies (``--forget`` lambda, or
``--retire-window`` capacity with the guarded hyperbolic downdate) keep
tracking while the grow-only default stays anchored to the dead regime.
``--retirement adaptive`` (PR 9) needs neither knob: a per-slot loss-EMA
breakpoint detector anneals that slot's statistics only when its own
error rate breaks out, so it recovers like the hand-tuned policies
without being told lambda, the capacity, or that a drift exists.
``--autotune`` attaches the warm-pool background autotuner: a per-cohort
(p, q, beta) population re-evaluated on recent retained windows, with
winners hot-swapped into live slots at refresh boundaries.

Sharded serving (``--devices N``): shard the server's slot axis over N
devices (PR 6; ``--max-streams`` is rounded up to a multiple of N).  On a
CPU-only host the flag also forces the XLA host-device split, so
``--devices 8`` works out of the box - the episode is bitwise the
single-device one; only the placement changes.

Quantized serving (``--quantize int8``, PR 7): armed slots answer from the
int8 fused fast path (coded readout + reservoir state, integer compute,
fp32 dequantized logits); scales calibrate online and fold at the ridge
refresh boundaries, training stays fp32.  Step blocking
(``--step-block T``) fuses up to T window rounds per slot into one
dispatch; the served episode is exactly the ``--step-block 1`` one.  Both
compose with ``--devices``:

    PYTHONPATH=src python examples/online_edge.py --quantize int8
    PYTHONPATH=src python examples/online_edge.py --step-block 4 \
        --quantize int8 --devices 8
"""
import argparse
import os
import sys


def _sniff_devices() -> int:
    """--devices before jax initializes: device counts lock on first jax
    import, so the CPU host split must be forced from argv, pre-import."""
    argv = sys.argv
    for k, a in enumerate(argv):
        if a == "--devices" and k + 1 < len(argv):
            return int(argv[k + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 1


_DEVICES = _sniff_devices()
if _DEVICES > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_DEVICES}"
    ).strip()

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import OnlineDFR
from repro.core.types import DFRConfig
from repro.data import (
    PAPER_DATASETS,
    drift_segment_bounds,
    load,
    make_drift_label_streams,
)
from repro.runtime import StreamRequest, StreamServer


def _server_retirement_kw(args) -> dict:
    """Map --forget / --retire-window to StreamServer retirement kwargs.

    ``refresh_mode`` stays ``None`` when the flag was not given, so
    ``--config auto`` can plan it; the retirement policies still pin
    ``incremental`` explicitly (a semantic requirement, not a tuning
    choice - window retirement downdates a live factor)."""
    picked = [f for f, v in (("--forget", args.forget),
                             ("--retire-window", args.retire_window),
                             ("--retirement", args.retirement)) if v is not None]
    if len(picked) > 1:
        raise SystemExit(f"pick one of {' / '.join(picked)}")
    if args.retirement == "adaptive":
        # the self-adjusting policy: no lambda / capacity to supply - the
        # per-slot detector runs on the server's default thresholds
        return {"retirement": "adaptive",
                "refresh_mode": args.refresh_mode or "incremental"}
    if args.forget is not None:
        return {"retirement": "forget", "forget": args.forget,
                "refresh_mode": args.refresh_mode or "incremental"}
    if args.retire_window is not None:
        return {"retirement": "window", "retire_window": args.retire_window,
                "refresh_mode": args.refresh_mode or "incremental"}
    return {"refresh_mode": args.refresh_mode}


def _server_pipeline_kw(args) -> dict:
    """Map the serving-pipeline flags to StreamServer kwargs (PR 5/6/8).

    Unset knobs pass ``None`` through: the server resolves them to the
    historical defaults, or - under ``--config auto`` - to the calibrated
    planner's picks."""
    return {
        "pipeline_depth": args.pipeline_depth,
        "staging": "host" if args.host_staging else "device",
        "devices": args.devices,
        "quantize": args.quantize,
        "step_block": args.step_block,
        "config": args.config,
    }


def _attach_autotuner(server, args):
    """--autotune: hang the warm-pool (p, q, beta) autotuner off the server."""
    if not args.autotune:
        return None
    from repro.runtime import WarmPoolAutotuner
    tuner = WarmPoolAutotuner(server)
    server.attach_autotuner(tuner)
    return tuner


def _print_tuner(tuner) -> None:
    if tuner is not None:
        st = tuner.stats()
        print(f"  autotuner: {st['rounds_run']} tune round(s), "
              f"{st['swaps_applied']} hot-swap(s) applied "
              f"({st['swaps_pending']} still pending at drain)")


def _fmt_ms(v) -> str:
    """A latency percentile for humans: NaN means 'no records', never a
    fake 0.0 ms reading."""
    return "n/a" if np.isnan(v) else f"{v:.1f} ms"


def _print_plan(server) -> None:
    if server.plan is not None:
        pl = server.plan
        print(f"  auto config (calibrated planner): "
              f"refresh_mode={server.refresh_mode}, "
              f"refresh_cohorts={server.cohorts.n_cohorts}, "
              f"step_block={server.step_block} "
              f"(predicted {pl.predicted_samples_per_s:.0f} samples/s)")


def _effective_max_streams(args) -> int:
    """Round --max-streams up to a multiple of --devices (equal shards)."""
    ms = args.max_streams
    if args.devices > 1 and ms % args.devices:
        ms = -(-ms // args.devices) * args.devices
        print(f"note: rounding --max-streams up to {ms} "
              f"(multiple of --devices {args.devices})")
    return ms


def _print_mesh(server) -> None:
    if server.mesh is not None:
        print(f"  slot mesh: {server.devices} devices x "
              f"{server.max_streams // server.devices} slots each "
              f"({jax.device_count()} XLA devices visible)")
    if server.quantize != "none" or server.step_block > 1:
        print(f"  serving fast path: quantize={server.quantize}, "
              f"step_block={server.step_block} (training stays fp32; the "
              f"episode schedule matches the unblocked fp32 server)")


def run_drift(args) -> None:
    """Serve drifting NARMA streams and report drift-recovery accuracy."""
    n = 64 if args.smoke else 160
    t_len, n_classes = 16, 4
    nodes = min(args.nodes, 8) if args.smoke else args.nodes
    cfg = DFRConfig(n_in=1, n_classes=n_classes, n_nodes=nodes)
    arrays, switches = make_drift_label_streams(
        args.streams, n, t_len, n_classes)
    streams = [StreamRequest(rid=rid, **arr)
               for rid, arr in enumerate(arrays)]

    kw = _server_retirement_kw(args)
    server = StreamServer(
        cfg, t_max=t_len, max_streams=_effective_max_streams(args),
        window=args.window, phase_steps=3, refresh_every=2,
        refresh_cohorts=args.refresh_cohorts,
        **_server_pipeline_kw(args), **kw,
    )
    policy = kw.get("retirement", "none")
    print(f"serving {len(streams)} drifting NARMA streams x {n} samples "
          f"(switch at sample {switches[0]}; retirement={policy})")
    _print_mesh(server)
    _print_plan(server)
    tuner = _attach_autotuner(server, args)
    for s in streams:
        server.submit(s)
    done = server.run_until_drained()
    _print_tuner(tuner)

    for r in sorted(done, key=lambda r: r.rid):
        bounds = drift_segment_bounds(n, switches[r.rid], args.window)
        p = np.asarray(r.preds)
        pre, at, post = (float((p[lo:hi] == r.label[lo:hi]).mean())
                         for lo, hi in bounds)
        print(f"  stream {r.rid}: online acc pre-drift {pre:.3f} / at "
              f"{at:.3f} / post {post:.3f} "
              f"({int(r.final_state.ridge.count)} samples in (A,B))")
    lat = server.latency_percentiles_ms()
    print(f"  window-round latency p50 {_fmt_ms(lat['p50_ms'])} / "
          f"p99 {_fmt_ms(lat['p99_ms'])} over {server.global_step} rounds "
          f"(p99 absorbs the one-time jit compile at these few rounds; "
          f"bench_stream reports warmed steady-state latency)")
    if server.pipeline_depth > 0:
        print(f"  pipeline depth {server.pipeline_depth}: dispatch p50 "
              f"{_fmt_ms(lat['dispatch_p50_ms'])}, drain (sync) p50 "
              f"{_fmt_ms(lat['drain_p50_ms'])} / "
              f"p99 {_fmt_ms(lat['drain_p99_ms'])}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ECG")
    ap.add_argument("--size-cap", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=30)
    ap.add_argument("--streams", type=int, default=4,
                    help="how many sensor streams to carve the data into")
    ap.add_argument("--max-streams", type=int, default=2,
                    help="server slots (< streams exercises refill)")
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--refresh-mode", choices=("recompute", "incremental"),
                    default=None,
                    help="periodic ridge refresh: re-factorize B (O(s^3)) "
                         "or keep a live rank-1-updated Cholesky factor per "
                         "slot (O(s^2) solves); default recompute, or the "
                         "planner's pick under --config auto")
    ap.add_argument("--refresh-cohorts", type=int, default=None,
                    help="stagger the refresh round over this many "
                         "round-robin slot cohorts (default 1 = global "
                         "round, or the planner's pick under --config auto)")
    ap.add_argument("--forget", type=float, default=None, metavar="LAMBDA",
                    help="forgetting-factor retirement: decay (A, B) and "
                         "the live factor by lambda per accumulated sample "
                         "(implies --refresh-mode incremental; lambda=1.0 "
                         "is exactly the non-retiring path)")
    ap.add_argument("--retire-window", type=int, default=None, metavar="W",
                    help="sliding-window retirement: keep only the last W "
                         "samples per slot in (A, B, Lt) via guarded "
                         "hyperbolic downdates (implies --refresh-mode "
                         "incremental; W >= stream length is exactly the "
                         "non-retiring path)")
    ap.add_argument("--retirement", choices=("adaptive",), default=None,
                    help="'adaptive' (PR 9): per-slot loss-EMA breakpoint "
                         "detector anneals a slot's (A, B, Lt) only when "
                         "that slot's own error rate breaks out - drift "
                         "recovery without hand-picking --forget or "
                         "--retire-window (implies --refresh-mode "
                         "incremental; bitwise the non-retiring path while "
                         "the detector stays silent)")
    ap.add_argument("--autotune", action="store_true",
                    help="attach the warm-pool background autotuner (PR 9): "
                         "a per-cohort (p, q, beta) population re-evaluated "
                         "on each slot's recent retained windows, winners "
                         "hot-swapped into live slots just after their "
                         "cohort's refresh boundary (factor invariant "
                         "re-seeded, quant scales re-arm)")
    ap.add_argument("--pipeline-depth", type=int, default=0, metavar="D",
                    help="async serving pipeline depth: predictions ride a "
                         "lag-D device ring while the host books step k "
                         "during device compute of k+1..k+D (0 = fully "
                         "synchronous; the served episode is bit-identical "
                         "at every depth)")
    ap.add_argument("--devices", type=int, default=1, metavar="N",
                    help="shard the server's slot axis over N devices "
                         "(PR 6; rounds --max-streams up to a multiple of "
                         "N; forces the XLA host-device split on CPU so "
                         "N > physical devices works; the episode is "
                         "bitwise the single-device one)")
    ap.add_argument("--quantize", choices=("none", "int8"), default="none",
                    help="serve armed slots from the int8 fused fast path "
                         "(PR 7): coded readout + reservoir state, integer "
                         "reservoir/DPRR/readout compute, fp32 dequantized "
                         "logits; scales fold at ridge-refresh boundaries "
                         "and training stays fp32 (requires device staging)")
    ap.add_argument("--step-block", type=int, default=None, metavar="T",
                    help="multi-sample step blocking: fuse up to T window "
                         "rounds per slot into ONE dispatch (PR 7); blocks "
                         "clamp at retirement boundaries so the served "
                         "episode is exactly the T=1 one (requires device "
                         "staging; default 1, or the planner's pick under "
                         "--config auto)")
    ap.add_argument("--config", choices=("auto",), default=None,
                    help="'auto': fill the unset performance knobs "
                         "(--refresh-mode / --refresh-cohorts / "
                         "--step-block) from the calibrated cost-model "
                         "planner (PR 8; first run on a host pays a few "
                         "seconds of micro-calibration, persisted to "
                         ".planner_calibration.json)")
    ap.add_argument("--host-staging", action="store_true",
                    help="use the PR-4 host-staged batch build instead of "
                         "the device-resident request pool (A/B baseline; "
                         "bit-identical, slower)")
    ap.add_argument("--drift", action="store_true",
                    help="serve piecewise-stationary NARMA streams and "
                         "report before/at/after-drift online accuracy")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI drift smoke lane)")
    args = ap.parse_args()

    if args.drift:
        run_drift(args)
        return

    spec = PAPER_DATASETS[args.dataset]
    train, test = load(args.dataset, size_cap=args.size_cap)
    cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes,
                    n_nodes=args.nodes)

    # carve the training set into independent streams (one per 'sensor');
    # array_split uses every sample and honors --streams exactly
    n = train.batch
    u, ln, lab = (np.asarray(train.u), np.asarray(train.length),
                  np.asarray(train.label))
    splits = [idx for idx in np.array_split(np.arange(n), args.streams)
              if len(idx)]
    streams = [
        StreamRequest(rid=i, u=u[idx], length=ln[idx], label=lab[idx])
        for i, idx in enumerate(splits)
    ]

    # phase 1 covers ~40% of each stream's windows, but always leaves at
    # least one phase-2 window so (A, B) accumulate and the refresh runs
    windows_per_stream = max(1, len(splits[0]) // args.window)
    phase_steps = max(1, min(int(windows_per_stream * 0.4) or 1,
                             windows_per_stream - 1))
    kw = _server_retirement_kw(args)
    server = StreamServer(
        cfg, t_max=train.t_max, max_streams=_effective_max_streams(args),
        window=args.window, phase_steps=phase_steps, refresh_every=5,
        refresh_cohorts=args.refresh_cohorts,
        **_server_pipeline_kw(args), **kw,
    )
    print(f"serving {len(streams)} streams x ~{len(splits[0])} samples "
          f"({server.max_streams} slots, windows of {args.window}); phase 1 "
          f"(reservoir adaptation) for {phase_steps} windows/stream, then "
          f"phase 2 ((A,B) accumulation, {server.refresh_mode} ridge refresh "
          f"every 5 rounds over {server.cohorts.n_cohorts} cohort(s), "
          f"retirement={server.retirement}) - the paper's protocol, "
          f"train-while-serve")
    _print_mesh(server)
    _print_plan(server)
    tuner = _attach_autotuner(server, args)
    for s in streams:
        server.submit(s)
    done = server.run_until_drained()
    _print_tuner(tuner)

    for r in sorted(done, key=lambda r: r.rid):
        print(f"  stream {r.rid}: {r.n_samples} samples, rolling online acc "
              f"{r.online_accuracy:.3f} "
              f"({int(r.final_state.ridge.count)} samples in (A,B))")
    lat = server.latency_percentiles_ms()
    print(f"  window-round latency p50 {_fmt_ms(lat['p50_ms'])} / "
          f"p99 {_fmt_ms(lat['p99_ms'])} over {server.global_step} rounds")
    if server.pipeline_depth > 0:
        print(f"  pipeline depth {server.pipeline_depth}: dispatch p50 "
              f"{_fmt_ms(lat['dispatch_p50_ms'])}, drain (sync) p50 "
              f"{_fmt_ms(lat['drain_p50_ms'])} / "
              f"p99 {_fmt_ms(lat['drain_p99_ms'])}")

    # held-out evaluation with the best stream's retired model: refresh the
    # readout from its streamed statistics, then classify the test split
    best = max(done, key=lambda r: (r.online_accuracy, -r.rid))
    system = OnlineDFR(cfg, mask=server.mask)
    state = best.final_state
    if int(state.ridge.count) > 0:
        state = system.refresh_output(state, jnp.float32(1e-2))
    else:
        print("  note: no phase-2 samples accumulated (stream too short for "
              "the phase split) - evaluating the SGD readout unrefreshed")
    preds = system.infer(state, test.u, test.length)
    acc = float(jnp.mean((preds == test.label).astype(jnp.float32)))
    print(f"final held-out accuracy (best stream {best.rid}'s model, "
          f"p={float(state.params.p):.4f} q={float(state.params.q):.4f}): "
          f"{acc:.3f}")


if __name__ == "__main__":
    main()
