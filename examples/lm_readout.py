"""DFR readout at scale: the paper's online trainer as an LM adaptation head.

    PYTHONPATH=src python examples/lm_readout.py

A frozen LM backbone (reduced smollm here; any of the 10 archs via --arch)
emits hidden-state streams; the modular DFR + DPRR + streaming Ridge solve
adapts a classification head ONLINE, with (A, B) reduced across data shards
by one psum (exact, because Eq. 38 is an associative sum) - the edge system
of the paper, lifted to a pod.  Demonstrated here on a synthetic sequence
classification task with a shard_map over the host mesh.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.core.readout import DistributedDFRReadout, ReadoutConfig
from repro.models.transformer import Transformer


def synth_task(key, n, t, vocab, n_classes):
    """Class c = sequences biased toward token block c (linearly separable
    in occupancy, but only through temporal features here)."""
    ks = jax.random.split(key, 3)
    labels = jax.random.randint(ks[0], (n,), 0, n_classes)
    block = vocab // n_classes
    base = jax.random.randint(ks[1], (n, t), 0, vocab)
    biased = block * labels[:, None] + jax.random.randint(ks[2], (n, t), 0, block)
    pick = jax.random.bernoulli(ks[0], 0.6, (n, t))
    toks = jnp.where(pick, biased, base)
    return toks.astype(jnp.int32), labels.astype(jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--classes", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"frozen backbone: {cfg.name} (reduced, d_model={cfg.d_model})")

    toks, labels = synth_task(jax.random.PRNGKey(1), args.n, args.seq,
                              cfg.vocab, args.classes)

    @jax.jit
    def hidden(toks):
        """Frozen-backbone features: the trunk output before unembedding."""
        from repro.models.layers import embed_lookup
        x = embed_lookup(params["embed"], toks)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        h, _ = model._trunk(params, x)
        return h.astype(jnp.float32)  # (B, T, d_model)

    h = hidden(toks)

    rc = ReadoutConfig(feature_dim=cfg.d_model, n_classes=args.classes,
                       n_nodes=30)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    ro = DistributedDFRReadout(rc, axis_names=("data",))
    dfr_params, ridge_state = ro.init()

    def fit_shard(h, labels):
        st = ro.accumulate(ridge_state, dfr_params, h, labels)
        fitted = ro.solve(st, dfr_params, jnp.float32(1e-2))
        return fitted.W, fitted.b

    W, b = jax.shard_map(fit_shard, mesh=mesh,
                         in_specs=(P("data"), P("data")), out_specs=P())(h, labels)
    fitted = type(dfr_params)(p=dfr_params.p, q=dfr_params.q, W=W, b=b)
    preds = ro.predict(fitted, h)
    acc = float(jnp.mean((preds == labels).astype(jnp.float32)))
    print(f"DFR readout (one distributed ridge solve, {args.n} sequences): "
          f"train acc {acc:.3f} over {args.classes} classes")
    print("the same code path scales: (A,B) psum crosses 'data' (+'pod') "
          "axes; the Cholesky system is s x s = "
          f"{rc.n_nodes**2 + rc.n_nodes + 1}^2 regardless of stream length")


if __name__ == "__main__":
    main()
