"""End-to-end driver: train the full 135M smollm-135m for a few hundred
steps on the synthetic token pipeline, with checkpointing + fault tolerance.

    PYTHONPATH=src python examples/train_smollm.py --steps 300

This is a thin preset around repro.launch.train (the production driver);
on a TPU pod the same command with --mesh production shards over
(data=16, model=16).  On this CPU container a full-135M step at seq 256 is
~10 s; pass --steps 30 for a quick demonstration (loss drops from ~10.8
toward the n-gram entropy of the synthetic stream).
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    preset = [
        "--arch", "smollm-135m",
        "--steps", "300",
        "--batch", "4",
        "--seq", "256",
        "--lr", "1e-3",
        "--ckpt-dir", "/tmp/smollm_ckpt",
        "--ckpt-every", "20",
        "--log-every", "5",
    ]
    # user args override the preset (argparse last-wins)
    sys.argv = [sys.argv[0]] + preset + sys.argv[1:]
    main()
