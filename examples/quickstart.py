"""Quickstart: train a modular DFR classifier end to end (paper pipeline).

    PYTHONPATH=src python examples/quickstart.py [--dataset JPVOW] [--full]
                                                 [--population]

Runs the paper's recipe - truncated-backprop SGD on the two reservoir
parameters (p, q) + output layer, then a Ridge refit via the in-place
Cholesky solver - on a synthetic stand-in of the chosen Table-4 dataset,
and compares against the grid-search baseline (itself a single vmapped
program over all candidates).  ``--population`` additionally runs the
population engine: grid-seeded candidates refined with truncated-BP and
culled by fitness, all population-parallel (repro.core.population).
"""
import argparse
import time

import jax.numpy as jnp

from repro.core import DFRModel, train_population_classification
from repro.core.grid_search import grid_search
from repro.core.types import DFRConfig
from repro.data import PAPER_DATASETS, load


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="JPVOW", choices=sorted(PAPER_DATASETS))
    ap.add_argument("--full", action="store_true", help="full Table-4 sizes")
    ap.add_argument("--nodes", type=int, default=30)
    ap.add_argument("--population", action="store_true",
                    help="also run the population-parallel search engine")
    args = ap.parse_args()

    spec = PAPER_DATASETS[args.dataset]
    train, test = load(args.dataset, size_cap=None if args.full else 120)
    print(f"{args.dataset}: {train.batch} train / {test.batch} test, "
          f"{spec.n_in} channels, {spec.n_classes} classes, "
          f"T in [{spec.t_min}, {spec.t_max}] (synthetic stand-in)")

    cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes,
                    n_nodes=args.nodes)
    model = DFRModel.create(cfg)

    t0 = time.time()
    params = model.fit(train, minibatch=4)
    bp_t = time.time() - t0
    acc = float(model.accuracy(test, params))
    print(f"[backprop]    test acc {acc:.3f}  ({bp_t:.1f}s)  "
          f"p={float(params.p):.4f} q={float(params.q):.4f}")

    t0 = time.time()
    gs = grid_search(cfg, train, test, divs=4)
    gs_t = time.time() - t0
    print(f"[grid search] test acc {gs['acc']:.3f}  ({gs_t:.1f}s over "
          f"{gs['n_points']} points)  p={gs['p']:.4f} q={gs['q']:.4f}")
    print(f"speed ratio (gs/bp at 4 divisions): {gs_t / bp_t:.1f}x "
          f"(paper protocol grows divisions until accuracy parity; "
          f"see benchmarks/bench_backprop.py)")

    if args.population:
        t0 = time.time()
        divs = 4
        res = train_population_classification(
            cfg, train, test, divs=divs, rounds=2, steps_per_round=2,
            minibatch=4,
        )
        print(f"[population]  test acc {res.best_acc:.3f}  "
              f"({time.time() - t0:.1f}s, {divs * divs} members x "
              f"{len(res.history) - 1} rounds)  "
              f"p={res.best_p:.4f} q={res.best_q:.4f} beta={res.best_beta:g}")


if __name__ == "__main__":
    main()
