"""Data fixtures: the drifting (piecewise-stationary) NARMA stream.

Contract under test (``repro.data.make_narma10_drift`` /
``narma_series_coeffs`` / ``quantize_targets``):

  * deterministic per seed, shapes match the ``RegressionBatch`` layout,
  * stationary coefficients reproduce ``narma10_series`` exactly,
  * the switch-point metadata is sharp: every target before
    ``switch_sample`` is identical to the undrifted stream, the switch
    sample's target is the first produced under the drifted coefficients,
    and the exogenous input never changes,
  * unstable coefficient choices raise instead of returning NaNs,
  * ``quantize_targets`` is deterministic and respects provided edges.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import (
    NARMA_COEFFS,
    make_narma10_drift,
    narma10_series,
    narma_series_coeffs,
    quantize_targets,
)


def test_drift_fixture_shapes_and_determinism():
    b, info = make_narma10_drift(n_samples=90, t_len=12, seed=7)
    assert b.u.shape == (90, 12, 1)
    assert b.length.shape == (90,)
    assert b.y.shape == (90, 1)
    assert b.u.dtype == np.float32 and b.y.dtype == np.float32
    assert np.all(np.asarray(b.length) == 12)

    b2, info2 = make_narma10_drift(n_samples=90, t_len=12, seed=7)
    np.testing.assert_array_equal(np.asarray(b.u), np.asarray(b2.u))
    np.testing.assert_array_equal(np.asarray(b.y), np.asarray(b2.y))
    assert info == info2

    b3, _ = make_narma10_drift(n_samples=90, t_len=12, seed=8)
    assert not np.array_equal(np.asarray(b.y), np.asarray(b3.y))


def test_stationary_coeffs_reproduce_narma10_series():
    u1, y1 = narma10_series(300, seed=3)
    u2, y2 = narma_series_coeffs(300, seed=3)
    np.testing.assert_array_equal(u1, u2)
    np.testing.assert_array_equal(y1, y2)


def test_switch_point_metadata_is_sharp():
    """Targets match the undrifted stream exactly up to switch_sample and
    diverge exactly there; the exogenous input is regime-independent."""
    kw = dict(n_samples=80, t_len=16, seed=11, switch_frac=0.4)
    drift, info = make_narma10_drift(**kw)
    flat, _ = make_narma10_drift(coeffs_b=NARMA_COEFFS, **kw)

    sw = info["switch_sample"]
    assert sw == 32
    assert info["switch_step"] == 10 + sw + 16 - 1  # order + sw + t_len - 1
    assert info["coeffs_a"] == NARMA_COEFFS
    y_d = np.asarray(drift.y).ravel()
    y_f = np.asarray(flat.y).ravel()
    np.testing.assert_array_equal(y_d[:sw], y_f[:sw])
    assert y_d[sw] != y_f[sw]  # first target produced by the new regime
    np.testing.assert_array_equal(np.asarray(drift.u), np.asarray(flat.u))


def test_switch_frac_validation_and_divergence_guard():
    with pytest.raises(ValueError):
        make_narma10_drift(n_samples=40, switch_frac=0.0)
    with pytest.raises(ValueError):
        make_narma10_drift(n_samples=40, switch_frac=1.0)
    # wildly unstable regime-B coefficients must raise, not emit NaNs
    with pytest.raises(ValueError):
        make_narma10_drift(n_samples=60, t_len=16, seed=0,
                           coeffs_b=(1.5, 1.0, 1.5, 1.0))


def test_drift_segment_bounds_fit_or_raise():
    from repro.data import drift_segment_bounds

    pre, at, post = drift_segment_bounds(160, 80, 4)
    assert pre == (48, 80) and at == (80, 96) and post == (128, 160)
    with pytest.raises(ValueError):  # switch too early: pre would wrap
        drift_segment_bounds(160, 16, 4)
    with pytest.raises(ValueError):  # switch too late: at overruns the end
        drift_segment_bounds(160, 150, 4)


def test_quantize_targets_edges_and_determinism():
    rng = np.random.default_rng(0)
    y = rng.normal(size=500)
    lab, edges = quantize_targets(y, 4)
    assert lab.dtype == np.int32 and edges.shape == (3,)
    assert set(np.unique(lab)) == {0, 1, 2, 3}
    # equal-mass quantile bins on the defining sample
    counts = np.bincount(lab, minlength=4)
    assert counts.max() - counts.min() <= 2
    # provided edges are respected verbatim (labels from a shifted segment
    # land in the top bins - how the drift bench makes the shift visible)
    lab_hi, edges2 = quantize_targets(y + 10.0, 4, edges)
    np.testing.assert_array_equal(edges, edges2)
    assert np.all(lab_hi == 3)
    lab_rep, _ = quantize_targets(y, 4, edges)
    np.testing.assert_array_equal(lab, lab_rep)


def test_make_dataset_stable_across_hash_randomization():
    """Regression: ``make_dataset`` once mixed ``hash(spec.name)`` into its
    RNG seed; Python randomizes str hashes per process (PYTHONHASHSEED), so
    "deterministic per seed" datasets silently differed across runs and CI
    machines.  The digest is now ``zlib.crc32`` - two subprocesses forced
    to DIFFERENT hash seeds must produce byte-identical datasets."""
    prog = (
        "import numpy as np, sys\n"
        "from repro.data import load\n"
        "tr, te = load('JPVOW', size_cap=12)\n"
        "for a in (tr.u, tr.length, tr.label, te.u, te.length, te.label):\n"
        "    sys.stdout.write(np.asarray(a).tobytes().hex())\n"
    )
    outs = []
    for hash_seed in ("1", "2"):
        r = subprocess.run(
            [sys.executable, "-c", prog],
            capture_output=True, text=True, timeout=600,
            env={**os.environ, "PYTHONHASHSEED": hash_seed,
                 "JAX_PLATFORMS": "cpu"},
        )
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1] and len(outs[0]) > 0
