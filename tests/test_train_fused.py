"""Fused training forward (kernels.train / backprop.forward_fused, PR 10).

Three layers of parity pin the production training path:

* the custom-VJP backward (paper Eq. 33-36 in closed form) against BOTH
  ``grads_truncated_manual`` (the paper equations, literally) and
  ``grads_truncated`` (autodiff of the stop_gradient objective) - a
  hypothesis battery over shapes, signs of q, ragged lengths and dtypes;
* the interpret-backend Pallas kernel BITWISE against the ``kernels.ref``
  oracle (same op order on padded shapes);
* the call-site contracts: ``online_serve_step(fused=True)``,
  ``refine_population(fused=...)`` and the jit-cache (retrace) regression
  for the identity-cached ``DFRConfig.f()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backprop as bp
from repro.core import masking, online, population
from repro.core.types import DFRConfig, DFRParams
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.train import train_forward_pallas, train_forward_scan

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:          # the CI property lane installs hypothesis;
    HAVE_HYP = False         # bare hosts still run the deterministic sweep

SETTINGS = dict(max_examples=25, deadline=None)


def _setup(nx=6, ny=4, t=9, b=2, seed=0, nonlinearity="tanh",
           dtype=jnp.float32):
    cfg = DFRConfig(n_in=3, n_classes=ny, n_nodes=nx,
                    nonlinearity=nonlinearity)
    key = jax.random.PRNGKey(seed)
    params = DFRParams(
        p=jnp.float32(0.15), q=jnp.float32(0.45),
        W=(0.05 * jax.random.normal(key, (ny, cfg.n_rep))).astype(dtype),
        b=0.01 * jnp.ones(ny, dtype),
    )
    j_seq = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (b, t, nx)
    ).astype(dtype)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 2), (b,), 0, ny)
    onehot = jax.nn.one_hot(labels, ny, dtype=dtype)
    return cfg, params, j_seq, onehot


def _grad_close(g1, g2, rtol, atol):
    for name in ("p", "q", "W", "b"):
        np.testing.assert_allclose(
            np.asarray(getattr(g1, name), np.float32),
            np.asarray(getattr(g2, name), np.float32),
            rtol=rtol, atol=atol, err_msg=name,
        )


# ---------------------------------------------------------------------------
# forward parity
# ---------------------------------------------------------------------------


def test_forward_fused_matches_forward():
    cfg, params, j_seq, _ = _setup(t=17, b=3)
    lengths = jnp.asarray([5, 17, 1], jnp.int32)
    f = cfg.f()
    ref = bp.forward(params, j_seq, f, lengths)
    got = bp.forward_fused(params, j_seq, f, lengths)
    for name in ("logits", "probs", "r", "x_last", "x_prev", "j_last"):
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(ref, name)),
            rtol=1e-5, atol=1e-6, err_msg=name,
        )


# ---------------------------------------------------------------------------
# the hypothesis gradient-parity battery (fused VJP vs manual vs autodiff)
# ---------------------------------------------------------------------------


def _check_grad_parity(seed, nx, t, b, q, ragged):
    cfg, params, j_seq, onehot = _setup(nx=nx, t=t, b=b, seed=seed)
    params = DFRParams(p=params.p, q=jnp.float32(q), W=params.W, b=params.b)
    lengths = None
    if ragged:
        lengths = jax.random.randint(
            jax.random.PRNGKey(seed + 3), (b,), 1, t + 1
        ).astype(jnp.int32)
    f = cfg.f()
    fp = lambda z: 1 - jnp.tanh(z) ** 2  # noqa: E731 (unused by the math)
    lm, gm = bp.grads_truncated_manual(params, j_seq, onehot, f, fp, lengths)
    la, ga = bp.grads_truncated(params, j_seq, onehot, f, lengths)
    lf, gf = bp.grads_truncated_fused(params, j_seq, onehot, f, lengths)
    assert float(abs(lf - lm)) < 1e-4 * max(1.0, float(abs(lm)))
    assert float(abs(lf - la)) < 1e-4 * max(1.0, float(abs(la)))
    _grad_close(gf, gm, rtol=2e-4, atol=1e-5)
    _grad_close(gf, ga, rtol=2e-4, atol=1e-5)


if HAVE_HYP:
    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**16),
        nx=st.integers(2, 8),
        t=st.integers(1, 24),
        b=st.integers(1, 4),
        q=st.floats(-0.9, 0.9, allow_nan=False),
        ragged=st.booleans(),
    )
    def test_fused_grads_match_manual_and_autodiff(seed, nx, t, b, q,
                                                   ragged):
        _check_grad_parity(seed, nx, t, b, q, ragged)
else:
    @pytest.mark.parametrize(
        "seed,nx,t,b,q,ragged",
        [(0, 2, 1, 1, 0.4, False), (1, 6, 9, 2, -0.55, True),
         (2, 8, 24, 4, 0.9, True), (3, 3, 16, 3, -0.9, False),
         (4, 5, 12, 4, 0.0, True), (5, 7, 2, 2, 0.7, True)],
    )
    def test_fused_grads_match_manual_and_autodiff(seed, nx, t, b, q,
                                                   ragged):
        _check_grad_parity(seed, nx, t, b, q, ragged)


def test_fused_grads_bf16_track_scan_autodiff():
    """bf16 activations: the closed-form backward and the autodiff path
    share the f32-accumulated forward, so they agree to bf16 resolution."""
    cfg, params, j_seq, onehot = _setup(t=12, b=3, dtype=jnp.bfloat16)
    params = DFRParams(p=jnp.bfloat16(0.15), q=jnp.bfloat16(0.45),
                       W=params.W, b=params.b)
    lengths = jnp.asarray([4, 12, 7], jnp.int32)
    f = cfg.f()
    la, ga = bp.grads_truncated(params, j_seq, onehot, f, lengths)
    lf, gf = bp.grads_truncated_fused(params, j_seq, onehot, f, lengths)
    assert float(abs(lf - la)) < 3e-2 * max(1.0, float(abs(la)))
    _grad_close(gf, ga, rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("t", [7, 8, 9])
def test_fused_grads_at_chunk_boundaries_interpret(t):
    """T = chunk_t - 1 / chunk_t / chunk_t + 1 through the interpret-mode
    Pallas kernel: the boundary latch and the padded-chunk freeze must not
    leak into the gradients."""
    cfg, params, j_seq, onehot = _setup(nx=4, t=t, b=3, seed=t)
    lengths = jnp.asarray([t, max(1, t - 1), 1], jnp.int32)
    f = cfg.f()
    la, ga = bp.grads_truncated(params, j_seq, onehot, f, lengths)
    lf, gf = bp.grads_truncated_fused(
        params, j_seq, onehot, f, lengths,
        backend="interpret", chunk_t=8, block_b=2,
    )
    assert float(abs(lf - la)) < 1e-4 * max(1.0, float(abs(la)))
    _grad_close(gf, ga, rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel-level parity: interpret backend vs the ref.py oracle (bitwise)
# ---------------------------------------------------------------------------


def _padded_operands(nx=5, t=11, b=3, seed=7, q=0.4):
    j_seq = jax.random.normal(jax.random.PRNGKey(seed), (b, t, nx),
                              jnp.float32)
    lengths = jnp.asarray([t, 4, 1][:b], jnp.int32)
    p, qv = jnp.float32(0.3), jnp.float32(q)
    block_b, chunk_t, n_pad = 4, 8, 128
    jp = kops._pad_to(kops._pad_to(kops._pad_to(j_seq, 2, n_pad),
                                   1, chunk_t), 0, block_b)
    Lp, qp = kops._ring_padded(qv, nx, n_pad)
    lens = kops._pad_to(lengths, 0, block_b)
    return jp, Lp, qp, lens, p, qv, nx, block_b, chunk_t


@pytest.mark.parametrize("q", [0.4, -0.55])
def test_interpret_kernel_bitwise_matches_ref_oracle(q):
    jp, Lp, qp, lens, p, qv, nx, block_b, chunk_t = _padded_operands(q=q)
    f = jnp.tanh
    got = train_forward_pallas(jp, Lp, qp, lens, p, qv, nx, f=f,
                               block_b=block_b, chunk_t=chunk_t,
                               interpret=True)
    ref = kref.train_forward_ref(jp, Lp, qp, lens, p, nx, f=f)
    for g, r, name in zip(got, ref, ("acc", "x_last", "x_prev", "j_last")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                      err_msg=name)


def test_interpret_matches_scan_fallback():
    cfg, params, j_seq, _ = _setup(nx=4, t=13, b=5, seed=11)
    lengths = jnp.asarray([13, 1, 7, 13, 2], jnp.int32)
    f = cfg.f()
    scan = kops.train_forward(j_seq, lengths, params.p, params.q, 4,
                              f=f, backend="xla")
    pall = kops.train_forward(j_seq, lengths, params.p, params.q, 4,
                              f=f, backend="interpret", chunk_t=8,
                              block_b=4)
    for s, g, name in zip(scan, pall, ("r", "x_last", "x_prev", "j_last")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(s),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# retrace regression: DFRConfig.f() is identity-stable across calls
# ---------------------------------------------------------------------------


def test_cfg_f_identity_stable():
    cfg = DFRConfig(n_in=3, n_classes=4, n_nodes=4, nonlinearity="tanh")
    assert cfg.f() is cfg.f()
    twin = DFRConfig(n_in=5, n_classes=2, n_nodes=8, nonlinearity="tanh")
    assert cfg.f() is twin.f()          # same (nonlinearity, alpha) key


def test_jitted_entry_points_do_not_retrace_on_fresh_f():
    """The silent-retrace audit: repeated calls with ``cfg.f()`` built
    fresh each time must HIT the jit cache of every entry point that takes
    ``f`` statically (run_reservoir, ops.train_forward, ops.
    streaming_logits)."""
    from repro.core import reservoir

    cfg = DFRConfig(n_in=3, n_classes=4, n_nodes=4, nonlinearity="tanh")
    j = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 4), jnp.float32)
    lengths = jnp.asarray([6, 3], jnp.int32)
    p, q = jnp.float32(0.3), jnp.float32(0.4)
    W = jnp.zeros((4, 20), jnp.float32)
    b = jnp.zeros((4,), jnp.float32)

    entry_calls = [
        (reservoir.run_reservoir,
         lambda f: reservoir.run_reservoir(p, q, j, f=f, lengths=lengths)),
        (kops.train_forward,
         lambda f: kops.train_forward(j, lengths, p, q, 4, f=f)),
        (kops.streaming_logits,
         lambda f: kops.streaming_logits(j, lengths, p, q, W, b, 4, f=f)),
    ]
    for entry, call in entry_calls:
        call(DFRConfig(n_in=3, n_classes=4, n_nodes=4).f())
        size = entry._cache_size()
        for _ in range(3):
            call(DFRConfig(n_in=3, n_classes=4, n_nodes=4).f())
        assert entry._cache_size() == size, entry


# ---------------------------------------------------------------------------
# call-site contracts: serve step and population refinement
# ---------------------------------------------------------------------------


def test_online_serve_step_fused_matches_unfused():
    cfg = DFRConfig(n_in=3, n_classes=4, n_nodes=5, nonlinearity="tanh")
    mask = masking.make_mask(jax.random.PRNGKey(1), cfg.n_nodes, cfg.n_in,
                             cfg.dtype)
    state = online.init_state(cfg)
    u = jax.random.normal(jax.random.PRNGKey(2), (3, 9, cfg.n_in), cfg.dtype)
    length = jnp.asarray([9, 4, 1], jnp.int32)
    label = jnp.asarray([0, 2, 1], jnp.int32)
    lr = jnp.asarray(0.1, cfg.dtype)
    weight = jnp.ones((3,), cfg.dtype)
    acc = jnp.asarray(1.0, cfg.dtype)
    out = {}
    for fused in (False, True):
        st, logits, metrics = online.online_serve_step(
            cfg, mask, state, u, length, label, lr, weight, acc, fused=fused
        )
        out[fused] = (st, logits, metrics)
    np.testing.assert_allclose(np.asarray(out[True][1]),
                               np.asarray(out[False][1]),
                               rtol=1e-5, atol=1e-6)
    for leaf_t, leaf_f in zip(jax.tree_util.tree_leaves(out[True][0]),
                              jax.tree_util.tree_leaves(out[False][0])):
        np.testing.assert_allclose(np.asarray(leaf_t), np.asarray(leaf_f),
                                   rtol=1e-4, atol=1e-5)


def test_refine_population_fused_matches_scan_path():
    cfg = DFRConfig(n_in=3, n_classes=3, n_nodes=4, nonlinearity="tanh")
    mask = masking.make_mask(jax.random.PRNGKey(3), cfg.n_nodes, cfg.n_in,
                             cfg.dtype)
    k = jax.random.PRNGKey(4)
    pop = DFRParams(
        p=jnp.asarray([0.2, 0.6], cfg.dtype),
        q=jnp.asarray([0.4, -0.3], cfg.dtype),
        W=0.05 * jax.random.normal(k, (2, cfg.n_classes, cfg.n_rep),
                                   cfg.dtype),
        b=jnp.zeros((2, cfg.n_classes), cfg.dtype),
    )
    u = jax.random.normal(jax.random.PRNGKey(5), (6, 8, cfg.n_in), cfg.dtype)
    lengths = jnp.asarray([8, 5, 8, 2, 8, 8], jnp.int32)
    y = jax.nn.one_hot(jnp.asarray([0, 1, 2, 0, 1, 2]), cfg.n_classes,
                       dtype=cfg.dtype)
    kw = dict(lr_res=jnp.asarray(0.05, cfg.dtype),
              lr_out=jnp.asarray(0.05, cfg.dtype), steps=2, minibatch=3)
    ref_pop, ref_loss = population.refine_population(
        cfg, mask, pop, u, lengths, y, fused=False, **kw)
    got_pop, got_loss = population.refine_population(
        cfg, mask, pop, u, lengths, y, fused=True, **kw)
    np.testing.assert_allclose(np.asarray(got_loss), np.asarray(ref_loss),
                               rtol=1e-4, atol=1e-5)
    for name in ("p", "q", "W", "b"):
        np.testing.assert_allclose(
            np.asarray(getattr(got_pop, name)),
            np.asarray(getattr(ref_pop, name)),
            rtol=1e-4, atol=1e-5, err_msg=name,
        )


def test_scan_fallback_handles_unbatched_and_default_lengths():
    cfg, params, j_seq, _ = _setup(nx=3, t=6, b=1)
    f = cfg.f()
    r_b, xl_b, xp_b, jl_b = train_forward_scan(
        j_seq, None, params.p, params.q, f=f)
    r_s, xl_s, xp_s, jl_s = train_forward_scan(
        j_seq[0], None, params.p, params.q, f=f)
    np.testing.assert_allclose(np.asarray(r_s), np.asarray(r_b[0]),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(xp_s), np.asarray(xp_b[0]),
                               rtol=1e-6, atol=1e-7)
