"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests only")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dprr, reservoir, ridge
from repro.optim.compression import compress_int8, decompress_int8

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    s=st.integers(2, 24),
    ny=st.integers(1, 6),
    seed=st.integers(0, 10_000),
    beta=st.floats(1e-4, 10.0),
)
@settings(**SETTINGS)
def test_ridge_solves_the_normal_equations(s, ny, seed, beta):
    """W B == A for every SPD B (the defining property of Eq. 23)."""
    rng = np.random.default_rng(seed)
    R = rng.normal(size=(s, s + 8)).astype(np.float32)
    B = jnp.asarray(R @ R.T + beta * np.eye(s, dtype=np.float32))
    A = jnp.asarray(rng.normal(size=(ny, s)).astype(np.float32))
    W = ridge.ridge_cholesky_packed(A, B)
    resid = np.asarray(W @ B - A)
    assert np.max(np.abs(resid)) / (np.max(np.abs(np.asarray(A))) + 1e-6) < 5e-2


@given(
    seed=st.integers(0, 10_000),
    scale=st.floats(0.1, 3.0),
    nx=st.integers(2, 16),
    t=st.integers(1, 24),
)
@settings(**SETTINGS)
def test_reservoir_linear_f_is_homogeneous(seed, scale, nx, t):
    """With f linear, states are linear in the input stream."""
    key = jax.random.PRNGKey(seed)
    j = jax.random.normal(key, (t, nx))
    p, q = jnp.float32(0.2), jnp.float32(0.5)
    x1 = reservoir.run_reservoir(p, q, j)
    x2 = reservoir.run_reservoir(p, q, scale * j)
    np.testing.assert_allclose(np.asarray(x2), scale * np.asarray(x1),
                               rtol=5e-3, atol=5e-4)


@given(seed=st.integers(0, 10_000), nx=st.integers(2, 12), t=st.integers(2, 20))
@settings(**SETTINGS)
def test_dprr_additive_in_time(seed, nx, t):
    """r(T) - r(T-1 prefix) == the last outer-product contribution."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, nx))
    full = np.asarray(dprr.compute_dprr(x))
    prefix = np.asarray(dprr.compute_dprr(x[:-1]))
    last = np.outer(np.asarray(x[-1]), np.asarray(x[-2]) if t > 1 else np.zeros(nx))
    delta = np.concatenate([last.reshape(-1), np.asarray(x[-1])])
    np.testing.assert_allclose(full - prefix, delta, rtol=1e-3, atol=1e-4)


@given(s=st.integers(1, 40))
@settings(**SETTINGS)
def test_packed_index_bijection(s):
    """The paper's 1-D packing P[i(i+1)/2+j] is a bijection on the lower
    triangle."""
    seen = set()
    for i in range(s):
        for j in range(i + 1):
            idx = ridge.packed_index(i, j)
            assert 0 <= idx < ridge.packed_size(s)
            assert idx not in seen
            seen.add(idx)
    assert len(seen) == ridge.packed_size(s)


@given(seed=st.integers(0, 10_000), scale=st.floats(1e-3, 1e3))
@settings(**SETTINGS)
def test_int8_compression_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray((rng.normal(size=(64,)) * scale).astype(np.float32))
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    err = np.max(np.abs(np.asarray(back - g)))
    assert err <= float(s) * 0.5 + 1e-9  # half-ULP of the quantizer


@given(
    seed=st.integers(0, 1000),
    ny=st.integers(2, 5),
    n=st.integers(4, 30),
)
@settings(**SETTINGS)
def test_ab_accumulation_is_order_invariant(seed, ny, n):
    """Eq. 38: (A, B) are associative sums => any chunking/order agrees
    (the property that makes the distributed psum exact)."""
    rng = np.random.default_rng(seed)
    s = 9
    rt = jnp.asarray(rng.normal(size=(n, s)).astype(np.float32))
    oh = jax.nn.one_hot(jnp.asarray(rng.integers(0, ny, n)), ny)
    A1 = jnp.zeros((ny, s)); B1 = jnp.zeros((s, s))
    A1, B1 = ridge.accumulate_ab(A1, B1, rt, oh)
    perm = rng.permutation(n)
    A2 = jnp.zeros((ny, s)); B2 = jnp.zeros((s, s))
    for i in perm:
        A2, B2 = ridge.accumulate_ab(A2, B2, rt[i:i+1], oh[i:i+1])
    np.testing.assert_allclose(np.asarray(A1), np.asarray(A2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(B1), np.asarray(B2), rtol=1e-3, atol=1e-3)
