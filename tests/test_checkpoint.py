"""Checkpoint: roundtrip, keep-k GC, corrupt-fallback, bf16, manager."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "emb": jax.random.normal(k, (10, 4), jnp.bfloat16),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
        "scalar": jnp.asarray(3, jnp.int32),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path / "ck", tree, step=7, metadata={"note": "hi"})
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got, step, meta = restore_checkpoint(tmp_path / "ck", like)
    assert step == 7 and meta["note"] == "hi"
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_manager_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path / "ckpts", keep=2)
    tree = _tree()
    for s in (10, 20, 30):
        mgr.save(tree, s)
    assert mgr.steps() == [20, 30]
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    _, step, _ = mgr.restore_latest(like)
    assert step == 30


def test_manager_corrupt_fallback(tmp_path):
    mgr = CheckpointManager(tmp_path / "ckpts", keep=3)
    tree = _tree()
    mgr.save(tree, 10)
    mgr.save(tree, 20)
    # corrupt the newest checkpoint (partial write simulation)
    mani = mgr.path_for(20) / "manifest.json"
    m = json.loads(mani.read_text())
    m["leaves"][0]["shards"][0]["file"] = "missing.npy"
    mani.write_text(json.dumps(m))
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got = mgr.restore_latest(like)
    assert got is not None
    _, step, _ = got
    assert step == 10  # fell back past the corrupt one


def test_shape_mismatch_raises(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path / "ck", tree, step=1)
    bad = dict(tree)
    bad["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path / "ck", bad)


def test_atomic_overwrite(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path / "ck", tree, step=1)
    tree2 = jax.tree_util.tree_map(lambda x: x + 1 if x.dtype != jnp.bfloat16 else x, tree)
    save_checkpoint(tmp_path / "ck", tree2, step=2)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got, step, _ = restore_checkpoint(tmp_path / "ck", like)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]) + 1)
