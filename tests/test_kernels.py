"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.cholesky import (
    chol_block,
    chol_block_batched,
    trsm_lower,
    trsm_lower_batched,
    trsm_lower_t,
    trsm_lower_t_batched,
)
from repro.kernels.dprr import dprr_pallas
from repro.kernels.ridge_solve import ridge_solve_blocked_batched


@pytest.mark.parametrize("t,nx,block_t", [(128, 30, 64), (300, 30, 128),
                                          (64, 100, 64), (512, 17, 256)])
def test_dprr_kernel_sweep(t, nx, block_t):
    rng = np.random.default_rng(t + nx)
    b = 3
    x = jnp.asarray(rng.normal(size=(b, t, nx)).astype(np.float32))
    lens = jnp.asarray(rng.integers(1, t + 1, b), jnp.int32)
    got = ops.dprr_features(x, lens, nx, block_t=block_t, backend="interpret")
    want = ops.dprr_features(x, lens, nx, block_t=block_t, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("n", [16, 64, 128])
def test_chol_block_sweep(n):
    rng = np.random.default_rng(n)
    M = rng.normal(size=(n, 2 * n)).astype(np.float32)
    a = jnp.asarray(M @ M.T + n * np.eye(n, dtype=np.float32))
    got = chol_block(a, interpret=True)
    want = ref.chol_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("m,n", [(8, 32), (128, 64), (256, 128)])
def test_trsm_kernels_sweep(m, n):
    rng = np.random.default_rng(m * n)
    M = rng.normal(size=(n, 2 * n)).astype(np.float32)
    L = jnp.asarray(np.linalg.cholesky(M @ M.T + n * np.eye(n)).astype(np.float32))
    a = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    got = trsm_lower_t(a, L, block_m=min(128, m), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.trsm_lower_t_ref(a, L)),
                               rtol=2e-3, atol=2e-3)
    got2 = trsm_lower(a, L, block_m=min(128, m), interpret=True)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref.trsm_lower_ref(a, L)),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("k,n", [(2, 16), (4, 64)])
def test_chol_block_batched_matches_loop(k, n):
    rng = np.random.default_rng(k * n)
    tiles = []
    for _ in range(k):
        M = rng.normal(size=(n, 2 * n)).astype(np.float32)
        tiles.append(M @ M.T + n * np.eye(n, dtype=np.float32))
    a = jnp.asarray(np.stack(tiles))
    got = chol_block_batched(a, interpret=True)
    for i in range(k):
        want = chol_block(a[i], interpret=True)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("k,m,n", [(2, 8, 32), (3, 128, 64)])
def test_trsm_batched_kernels_match_loop(k, m, n):
    rng = np.random.default_rng(k + m + n)
    Ls, As = [], []
    for _ in range(k):
        M = rng.normal(size=(n, 2 * n)).astype(np.float32)
        Ls.append(np.linalg.cholesky(M @ M.T + n * np.eye(n)).astype(np.float32))
        As.append(rng.normal(size=(m, n)).astype(np.float32))
    L = jnp.asarray(np.stack(Ls))
    a = jnp.asarray(np.stack(As))
    bm = min(128, m)
    got_t = trsm_lower_t_batched(a, L, block_m=bm, interpret=True)
    got = trsm_lower_batched(a, L, block_m=bm, interpret=True)
    for i in range(k):
        np.testing.assert_allclose(
            np.asarray(got_t[i]),
            np.asarray(trsm_lower_t(a[i], L[i], block_m=bm, interpret=True)),
            rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(
            np.asarray(got[i]),
            np.asarray(trsm_lower(a[i], L[i], block_m=bm, interpret=True)),
            rtol=2e-3, atol=2e-3)


def test_ridge_solve_blocked_batched_vs_dense_ref():
    rng = np.random.default_rng(7)
    k, s, ny, block = 3, 100, 5, 64
    As, Bs = [], []
    for _ in range(k):
        R = rng.normal(size=(s, 2 * s)).astype(np.float32)
        Bs.append(R @ R.T + 0.1 * np.eye(s, dtype=np.float32))
        As.append(rng.normal(size=(ny, s)).astype(np.float32))
    A = jnp.asarray(np.stack(As))
    B = jnp.asarray(np.stack(Bs))
    got = ridge_solve_blocked_batched(A, B, block=block, interpret=True)
    for i in range(k):
        want = np.asarray(As[i]) @ np.linalg.inv(np.asarray(Bs[i], np.float64))
        scale = np.max(np.abs(want))
        np.testing.assert_allclose(np.asarray(got[i]) / scale, want / scale,
                                   rtol=0, atol=3e-4)


@pytest.mark.parametrize("s,block", [(100, 64), (300, 128), (257, 128)])
def test_ridge_solve_blocked_sweep(s, block):
    rng = np.random.default_rng(s)
    R = rng.normal(size=(s, 2 * s)).astype(np.float32)
    B = jnp.asarray(R @ R.T + 0.1 * np.eye(s, dtype=np.float32))
    A = jnp.asarray(rng.normal(size=(7, s)).astype(np.float32))
    got = ops.ridge_solve(A, B, block=block, backend="interpret")
    want = ref.ridge_solve_ref(A, B)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale,
                               rtol=0, atol=2e-4)


@pytest.mark.parametrize("t,chunk,f_name", [(64, 32, "linear"), (96, 32, "tanh"),
                                            (128, 128, "tanh")])
def test_reservoir_kernel_sweep(t, chunk, f_name):
    f = {"linear": (lambda z: z), "tanh": jnp.tanh}[f_name]
    rng = np.random.default_rng(t)
    b, nx = 8, 30
    j = jnp.asarray(rng.normal(size=(b, t, nx)).astype(np.float32))
    lens = jnp.asarray(rng.integers(1, t + 1, b), jnp.int32)
    p, q = jnp.float32(0.2), jnp.float32(0.5)
    got = ops.reservoir_states(j, lens, p, q, nx, f=f, chunk_t=chunk,
                               block_b=8, backend="interpret")
    want = ops.reservoir_states(j, lens, p, q, nx, f=f, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_dprr_kernel_single_sample_matches_manual():
    """Direct pallas_call contract (padding semantics) vs ref.dprr_ref."""
    rng = np.random.default_rng(5)
    t_pad, n_pad, nx = 256, 128, 30
    x = jnp.asarray(rng.normal(size=(t_pad, n_pad)).astype(np.float32))
    x = x.at[:, nx:].set(0.0)
    length = jnp.asarray(200, jnp.int32)
    got = dprr_pallas(x, length, nx, block_t=128, interpret=True)
    want = ref.dprr_ref(x, length, nx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("b,t,nx,ny,chunk,f_name",
                         [(3, 50, 30, 4, 64, "linear"),
                          (2, 130, 17, 9, 128, "linear"),
                          (4, 64, 8, 2, 64, "tanh")])
def test_streaming_kernel_matches_unfused(b, t, nx, ny, chunk, f_name):
    """Fused streaming step (reservoir -> DPRR -> readout in one kernel)
    vs the unfused XLA composition, across lengths/padding/nonlinearity."""
    f = {"linear": (lambda z: z), "tanh": jnp.tanh}[f_name]
    rng = np.random.default_rng(b * t + nx)
    j = jnp.asarray(rng.normal(size=(b, t, nx)).astype(np.float32))
    lens = jnp.asarray(rng.integers(1, t + 1, b), jnp.int32)
    p, q = jnp.float32(0.02), jnp.float32(0.3)
    W = jnp.asarray(0.01 * rng.normal(size=(ny, nx * (nx + 1))).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(ny,)).astype(np.float32))
    got = ops.streaming_logits(j, lens, p, q, W, bias, nx, f=f,
                               chunk_t=chunk, backend="interpret")
    want = ops.streaming_logits(j, lens, p, q, W, bias, nx, f=f,
                                backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)
