"""DPRR = the paper's Eq. 27/28 sums, computed as a GEMM."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dprr


def manual_dprr(x, length=None):
    t, nx = x.shape
    t_eff = int(length) if length is not None else t
    r_outer = np.zeros((nx, nx))
    r_sum = np.zeros(nx)
    xprev = np.zeros(nx)
    for k in range(t_eff):
        xk = np.asarray(x[k])
        r_outer += np.outer(xk, xprev)
        r_sum += xk
        xprev = xk
    return np.concatenate([r_outer.reshape(-1), r_sum])


def test_matches_paper_sums():
    x = jax.random.normal(jax.random.PRNGKey(0), (9, 5))
    got = np.asarray(dprr.compute_dprr(x))
    np.testing.assert_allclose(got, manual_dprr(x), rtol=1e-5, atol=1e-5)


def test_lengths_mask():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 11, 4))
    lengths = jnp.asarray([11, 3, 7], jnp.int32)
    got = np.asarray(dprr.compute_dprr(x, lengths=lengths))
    for b in range(3):
        np.testing.assert_allclose(
            got[b], manual_dprr(x[b], int(lengths[b])), rtol=1e-5, atol=1e-5
        )


def test_r_tilde_appends_one():
    r = jnp.ones((2, 6))
    rt = dprr.r_tilde(r)
    assert rt.shape == (2, 7)
    assert float(rt[0, -1]) == 1.0


def test_shifted_states_zero_prefix():
    x = jnp.arange(12.0).reshape(4, 3)
    x0 = dprr.shifted_states(x)
    assert float(jnp.sum(jnp.abs(x0[0]))) == 0.0
    np.testing.assert_allclose(np.asarray(x0[1:]), np.asarray(x[:-1]))
