"""Stream server: continuous batching retires/refills slots correctly,
per-slot state isolation, OnlineEnsemble(K=1) == OnlineDFR parity, and the
refresh-policy equivalences (staggered C=1 == global bit-for-bit,
incremental == recompute to solver tolerance over a full episode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import OnlineDFR, OnlineEnsemble, reset_statistics
from repro.core.types import DFRConfig
from repro.runtime import StreamRequest, StreamServer
from repro.runtime.scheduler import RefreshCohorts


CFG = DFRConfig(n_in=2, n_classes=3, n_nodes=8)


def _make_stream(rid, n, t=16, seed=0, n_in=2, n_classes=3):
    r = np.random.default_rng(seed)
    return StreamRequest(
        rid=rid,
        u=r.normal(size=(n, t, n_in)).astype(np.float32),
        length=r.integers(4, t + 1, n).astype(np.int32),
        label=r.integers(0, n_classes, n).astype(np.int32),
    )


# ---------------------------------------------------------------------------
# Continuous batching lifecycle
# ---------------------------------------------------------------------------


def test_retire_refill_serves_every_stream():
    """More streams than slots, lengths that are not window multiples:
    every stream completes with exactly one prediction per sample."""
    srv = StreamServer(CFG, t_max=16, max_streams=2, window=4,
                       phase_steps=2, refresh_every=3)
    sizes = [10, 7, 5, 12, 3]
    for i, n in enumerate(sizes):
        srv.submit(_make_stream(i, n, seed=i))
    done = srv.run_until_drained()
    assert sorted(r.rid for r in done) == list(range(len(sizes)))
    for r in done:
        assert r.done
        assert len(r.preds) == r.n_samples
        assert r.final_state is not None
        # retired snapshot is a single-system state (no slot axis)
        assert r.final_state.ridge.B.shape == (CFG.s, CFG.s)


def test_slot_reuse_resets_state():
    """A stream admitted into a reused slot starts from the fresh state:
    serving the same stream first or after another yields identical
    predictions (the refilled slot inherits nothing)."""

    def serve(streams, target_rid):
        srv = StreamServer(CFG, t_max=16, max_streams=1, window=4,
                           phase_steps=2, refresh_every=3)
        for s in streams:
            srv.submit(s)
        srv.run_until_drained()
        return next(r.preds for r in srv.completed if r.rid == target_rid)

    first = serve([_make_stream(7, 9, seed=3)], 7)
    second = serve([_make_stream(0, 8, seed=11), _make_stream(7, 9, seed=3)], 7)
    assert first == second


def test_rejects_mismatched_t_max():
    srv = StreamServer(CFG, t_max=16, max_streams=1, window=2)
    with pytest.raises(ValueError):
        srv.submit(_make_stream(0, 4, t=12))


# ---------------------------------------------------------------------------
# Per-slot state isolation
# ---------------------------------------------------------------------------


def test_per_slot_state_isolation_exact():
    """One stream's updates never leak into another slot: stream 0 served
    alone produces bit-identical predictions to stream 0 served alongside
    four co-tenant streams (including slot churn)."""

    def serve(streams):
        srv = StreamServer(CFG, t_max=16, max_streams=4, window=3,
                           phase_steps=3, refresh_every=2)
        for s in streams:
            srv.submit(s)
        srv.run_until_drained()
        return {r.rid: list(r.preds) for r in srv.completed}

    alone = serve([_make_stream(0, 11, seed=42)])
    crowd = serve([_make_stream(0, 11, seed=42)]
                  + [_make_stream(i, n, seed=20 + i)
                     for i, n in [(1, 9), (2, 14), (3, 6), (4, 10)]])
    assert alone[0] == crowd[0]


# ---------------------------------------------------------------------------
# Refresh policies: staggering and the incremental factor engine
# ---------------------------------------------------------------------------


def _serve_collect(streams, **kw):
    srv = StreamServer(CFG, t_max=16, max_streams=3, window=2,
                       phase_steps=2, refresh_every=3, **kw)
    for s in streams:
        srv.submit(s)
    done = srv.run_until_drained()
    return {r.rid: list(r.preds) for r in done}, srv


def _episode_streams(n_streams=4, seed0=0):
    return [_make_stream(i, n, seed=seed0 + i)
            for i, n in enumerate([8, 6, 10, 4][:n_streams])]


def test_refresh_cohorts_schedule():
    """C=1 reduces to the global round; staggering keeps the exact per-slot
    cadence (one refresh per refresh_every steps) with bounded cohorts."""
    glob = RefreshCohorts(8, 5, 1)
    assert [glob.due_cohort(t) for t in range(1, 11)] == \
        [None, None, None, None, 0, None, None, None, None, 0]
    assert glob.due_slots(5) == list(range(8))

    stag = RefreshCohorts(8, 5, 4)
    per_period = [stag.due_slots(t) or [] for t in range(5, 10)]
    # every slot refreshed exactly once per period, <= ceil(8/4) per step
    assert sorted(i for sl in per_period for i in sl) == list(range(8))
    assert max(len(sl) for sl in per_period) == 2
    # clamped: more cohorts than phases cannot keep the cadence
    assert RefreshCohorts(8, 3, 7).n_cohorts == 3


def test_staggered_cohort1_is_bitwise_the_global_refresh():
    """The cohort-row refresh path at C=1 serves bit-identical predictions
    and final states to the PR-2 global ``_stream_refresh``.  Pinned to the
    host-staged un-donated path: the device-staged pipeline folds the
    refresh into the fused step and never routes through this entry point
    (its own equivalence battery lives in test_stream_pipeline.py)."""
    import repro.runtime.stream_server as ss

    def serve(force_global):
        orig = ss._stream_refresh_rows
        if force_global:
            ss._stream_refresh_rows = (
                lambda states, beta, eligible, rows:
                    ss._stream_refresh(states, beta, eligible))
        try:
            return _serve_collect(_episode_streams(), staging="host",
                                  donate=False)
        finally:
            ss._stream_refresh_rows = orig

    preds_g, srv_g = serve(True)
    preds_r, srv_r = serve(False)
    assert preds_g == preds_r
    for a, b in zip(jax.tree_util.tree_leaves(srv_g.states),
                    jax.tree_util.tree_leaves(srv_r.states)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incremental_refresh_matches_recompute_over_episode():
    """A full run_until_drained episode under refresh_mode='incremental'
    (live rank-1-maintained factors, O(s^2) refresh solves) serves the same
    streams as global recompute with near-identical predictions, and the
    retired models agree to solver tolerance."""
    preds_rec, srv_rec = _serve_collect(_episode_streams())
    preds_inc, srv_inc = _serve_collect(_episode_streams(),
                                        refresh_mode="incremental")
    assert sorted(preds_rec) == sorted(preds_inc)
    total = agree = 0
    for rid in preds_rec:
        assert len(preds_rec[rid]) == len(preds_inc[rid])
        total += len(preds_rec[rid])
        agree += sum(int(a == b)
                     for a, b in zip(preds_rec[rid], preds_inc[rid]))
    assert agree / total >= 0.97  # float drift may flip a borderline argmax

    for r_rec, r_inc in zip(sorted(srv_rec.completed, key=lambda r: r.rid),
                            sorted(srv_inc.completed, key=lambda r: r.rid)):
        w_rec = np.asarray(r_rec.final_state.params.W)
        w_inc = np.asarray(r_inc.final_state.params.W)
        np.testing.assert_allclose(
            w_inc, w_rec, rtol=5e-3,
            atol=5e-3 * max(1.0, np.abs(w_rec).max()))
        # the incremental slot kept its factor live the whole episode
        assert float(r_inc.final_state.ridge.factor_beta) > 0


# ---------------------------------------------------------------------------
# Retirement policies: forgetting factor and sliding window
# ---------------------------------------------------------------------------


def _assert_states_bitwise_equal(sa, sb):
    for a, b in zip(jax.tree_util.tree_leaves(sa), jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forget_lambda1_is_bitwise_the_incremental_path():
    """retirement='forget' at lambda=1 serves a full episode bit-for-bit
    identically to the PR-3 incremental path: every scaling is a multiply
    by exactly 1.0 (the documented equivalence contract)."""
    preds_inc, srv_inc = _serve_collect(_episode_streams(),
                                        refresh_mode="incremental")
    preds_f, srv_f = _serve_collect(_episode_streams(),
                                    refresh_mode="incremental",
                                    retirement="forget", forget=1.0)
    assert preds_inc == preds_f
    _assert_states_bitwise_equal(srv_inc.states, srv_f.states)
    for a, b in zip(sorted(srv_inc.completed, key=lambda r: r.rid),
                    sorted(srv_f.completed, key=lambda r: r.rid)):
        _assert_states_bitwise_equal(a.final_state, b.final_state)


def test_window_capacity_geq_stream_is_bitwise_the_incremental_path():
    """retirement='window' with capacity >= every stream length serves a
    full episode bit-for-bit identically to the PR-3 incremental path:
    the ring never wraps, so every eviction is of a zero row - an exact
    no-op in (A, B) and in the factor downdate."""
    preds_inc, srv_inc = _serve_collect(_episode_streams(),
                                        refresh_mode="incremental")
    preds_w, srv_w = _serve_collect(_episode_streams(),
                                    refresh_mode="incremental",
                                    retirement="window", retire_window=16)
    assert preds_inc == preds_w
    _assert_states_bitwise_equal(srv_inc.states, srv_w.states)


def test_window_matches_from_scratch_ridge_on_last_w_samples():
    """After serving with retirement='window', a slot's (A, B, Lt) are the
    statistics of exactly the last W retained (frozen-phase) samples: they
    match a from-scratch recomputation of those samples' r~ rows, and the
    factor refresh matches a from-scratch ridge fit on them (fp32 tol)."""
    from repro.core import dprr, masking, reservoir, ridge

    n, window, phase_steps, cap = 24, 2, 3, 8
    beta = 1e-2
    req = _make_stream(0, n, seed=9)
    srv = StreamServer(CFG, t_max=16, max_streams=1, window=window,
                       phase_steps=phase_steps, refresh_every=4, beta=beta,
                       refresh_mode="incremental",
                       retirement="window", retire_window=cap)
    srv.submit(req)
    done = srv.run_until_drained()
    st = done[0].final_state
    assert int(st.ridge.count) == cap

    # the last `cap` accumulated samples (phase-2 only; lr=0 there so the
    # final (p, q) are exactly the ones that produced every retained row)
    acc_lo = phase_steps * window
    retained = np.arange(n)[acc_lo:][-cap:]
    u = jnp.asarray(req.u[retained])
    ln = jnp.asarray(req.length[retained])
    lab = jnp.asarray(req.label[retained])
    j_seq = masking.apply_mask(srv.mask, u)
    x = reservoir.run_reservoir(st.params.p, st.params.q, j_seq,
                                f=CFG.f(), lengths=ln)
    rt = np.asarray(dprr.r_tilde(dprr.compute_dprr(x, lengths=ln)))
    onehot = np.eye(CFG.n_classes, dtype=np.float32)[np.asarray(lab)]
    A_ref = onehot.T @ rt
    B_ref = rt.T @ rt

    tolA = dict(rtol=2e-3, atol=2e-3 * max(1.0, np.abs(A_ref).max()))
    np.testing.assert_allclose(np.asarray(st.ridge.A), A_ref, **tolA)
    tolB = dict(rtol=2e-3, atol=2e-3 * max(1.0, np.abs(B_ref).max()))
    np.testing.assert_allclose(np.asarray(st.ridge.B), B_ref, **tolB)

    W_win = np.asarray(ridge.ridge_solve_from_factor_t(st.ridge.A, st.ridge.Lt))
    W_ref = np.asarray(ridge.ridge_cholesky_blocked(
        jnp.asarray(A_ref), jnp.asarray(B_ref + beta * np.eye(CFG.s))))
    np.testing.assert_allclose(
        W_win, W_ref, rtol=5e-3, atol=5e-3 * max(1.0, np.abs(W_ref).max()))


def test_window_guard_refactorizes_on_indefinite_eviction():
    """An eviction downdate that would break the live factor (engineered
    by shrinking one slot's factor mid-episode so the retained rows carry
    more mass than it does) trips the numerical guard: the slot's factor
    is rebuilt from its retained B + beta I inside the same step, the
    state stays finite and SPD, and the stream still completes."""
    import dataclasses

    n, window, cap = 24, 2, 6
    beta = 1e-2
    req = _make_stream(0, n, seed=4)
    srv = StreamServer(CFG, t_max=16, max_streams=1, window=window,
                       phase_steps=2, refresh_every=4, beta=beta,
                       refresh_mode="incremental",
                       retirement="window", retire_window=cap)
    srv.submit(req)
    # run until the ring is full and evictions are real
    while srv.slot_pos[0] < (2 + cap // window + 2) * window:
        srv.step()
    # corrupt the live factor (NOT the statistics): a tiny factor makes the
    # next eviction's downdate indefinite w.r.t. it
    shrunk = srv.states.ridge.Lt * 0.05
    srv.states = dataclasses.replace(
        srv.states, ridge=dataclasses.replace(srv.states.ridge, Lt=shrunk))
    srv.run_until_drained()

    st = srv.sched.completed[0].final_state
    Lt = np.asarray(st.ridge.Lt)
    assert np.all(np.isfinite(Lt))
    assert np.all(np.diag(Lt) > 0)
    # the guard refactorized from (B + beta I): the invariant holds again
    rhs = np.asarray(st.ridge.B) + beta * np.eye(CFG.s)
    np.testing.assert_allclose(Lt.T @ Lt, rhs, rtol=5e-4,
                               atol=5e-4 * max(1.0, np.abs(rhs).max()))
    assert len(srv.sched.completed[0].preds) == n


def test_staggered_refresh_serves_every_stream_correctly():
    """C>1 staggering (both modes) still serves every sample of every
    stream; per-slot refresh cadence changes only latency, not coverage."""
    for kw in ({"refresh_cohorts": 3},
               {"refresh_cohorts": 3, "refresh_mode": "incremental"}):
        preds, srv = _serve_collect(_episode_streams(), **kw)
        assert sorted(preds) == [0, 1, 2, 3]
        for r in srv.completed:
            assert len(r.preds) == r.n_samples


# ---------------------------------------------------------------------------
# OnlineEnsemble(K=1) == OnlineDFR parity oracle
# ---------------------------------------------------------------------------


def test_ensemble_k1_matches_online_dfr_exactly():
    """K=1 ensemble is numerically identical to the single system across
    steps, infer, reset_statistics, and (to solver tolerance) refresh."""
    cfg = DFRConfig(n_in=2, n_classes=3, n_nodes=8)
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(4, 12, 2)).astype(np.float32))
    ln = jnp.asarray(rng.integers(4, 13, 4), jnp.int32)
    lab = jnp.asarray(rng.integers(0, 3, 4), jnp.int32)
    lr = jnp.float32(0.2)

    single = OnlineDFR(cfg)
    ens = OnlineEnsemble(cfg, 1)
    s1, se = single.init(), ens.init()

    for i in range(6):
        p1 = np.asarray(single.infer(s1, u, ln))
        np.testing.assert_array_equal(p1, np.asarray(ens.infer(se, u, ln)))
        np.testing.assert_array_equal(
            p1, np.asarray(ens.infer_members(se, u, ln))[0])
        s1, m1 = single.step(s1, u, ln, lab, lr, lr)
        se, me = ens.step(se, u, ln, lab, lr, lr)
        np.testing.assert_array_equal(np.asarray(m1["loss"]),
                                      np.asarray(me["loss"])[0])
        if i == 2:
            s1 = single.reset_statistics(s1)
            se = jax.vmap(reset_statistics)(se)
        for a, b in zip(jax.tree_util.tree_leaves(s1),
                        jax.tree_util.tree_leaves(se)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[0])

    # refresh: batched Cholesky vs single Cholesky agree to solver precision
    s1 = single.refresh_output(s1, jnp.float32(1e-2))
    se = ens.refresh_output(se, jnp.float32(1e-2))
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(se.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b)[0],
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(single.infer(s1, u, ln)), np.asarray(ens.infer(se, u, ln)))


def test_ensemble_cull_reseeds_losers():
    """Culling keeps the best members verbatim (state included), re-seeds
    losers near survivors with fresh statistics."""
    cfg = DFRConfig(n_in=2, n_classes=2, n_nodes=6)
    ens = OnlineEnsemble(cfg, 4, seed_jitter=0.2)
    st = ens.init()
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.normal(size=(4, 10, 2)).astype(np.float32))
    ln = jnp.asarray(rng.integers(3, 11, 4), jnp.int32)
    lab = jnp.asarray(rng.integers(0, 2, 4), jnp.int32)
    for _ in range(3):
        st, _ = ens.step(st, u, ln, lab, jnp.float32(0.2), jnp.float32(0.2))
    culled = ens.cull(st, jax.random.PRNGKey(0), survive_frac=0.5)

    order = np.argsort(np.asarray(st.loss_ema))
    # survivors: best two members, verbatim (params, stats, counters)
    for slot, parent in enumerate(order[:2]):
        for a, b in zip(jax.tree_util.tree_leaves(
                            jax.tree_util.tree_map(lambda l: l[parent], st)),
                        jax.tree_util.tree_leaves(
                            jax.tree_util.tree_map(lambda l: l[slot], culled))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # culled slots: jittered (p, q) near their parent, zeroed statistics
    assert float(jnp.sum(jnp.abs(culled.ridge.B[2:]))) == 0.0
    assert int(jnp.sum(culled.ridge.count[2:])) == 0
    p = np.asarray(culled.params.p)
    assert p[2] != p[0] and p[3] != p[1]  # jitter moved the clones


def test_ensemble_cull_reseeds_live_factor_not_zeros():
    """Regression: a culled member that inherited a LIVE incremental factor
    must restart with ``seed_factor`` (chol(0 + beta I) = sqrt(beta) I),
    not an all-zero Lt - zero would be a singular fake factor violating
    ``Lt^T Lt == B + factor_beta I`` and NaN on the next maintained fold.
    Survivors keep their factor verbatim."""
    import dataclasses as dc
    from repro.core import online

    cfg = DFRConfig(n_in=2, n_classes=2, n_nodes=6)
    ens = OnlineEnsemble(cfg, 4, seed_jitter=0.2)
    beta = 0.25
    st = jax.vmap(lambda s: online.reset_statistics(s, factor_beta=beta))(
        ens.init())
    # fold real samples through the maintained path so Lt is non-trivial
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.normal(size=(3, 10, 2)).astype(np.float32))
    ln = jnp.asarray(rng.integers(3, 11, 3), jnp.int32)
    lab = jnp.asarray(rng.integers(0, 2, 3), jnp.int32)
    lr0, w1, acc1 = (jnp.float32(0.0), jnp.ones(3, jnp.float32),
                     jnp.float32(1.0))
    st, _, _ = jax.vmap(
        lambda s: online.online_serve_step(
            cfg, ens.mask, s, u, ln, lab, lr0, w1, acc1,
            maintain_factor=True)
    )(st)
    st = dc.replace(st, loss_ema=jnp.asarray([0.0, 0.1, 0.9, 1.0]))
    culled = ens.cull(st, jax.random.PRNGKey(0), survive_frac=0.5)

    s = cfg.s
    Lt = np.asarray(culled.ridge.Lt)
    B = np.asarray(culled.ridge.B)
    fb = np.asarray(culled.ridge.factor_beta)
    np.testing.assert_allclose(fb, beta, rtol=1e-6)
    # survivors (ranks 0, 1 == members 0, 1): factor untouched
    np.testing.assert_array_equal(Lt[:2], np.asarray(st.ridge.Lt)[:2])
    # culled rows: fresh sqrt(beta) I seed, and the invariant holds on the
    # zeroed statistics (Lt^T Lt == 0 + beta I)
    for i in (2, 3):
        np.testing.assert_allclose(Lt[i], np.sqrt(beta) * np.eye(s),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(Lt[i].T @ Lt[i], B[i] + beta * np.eye(s),
                                   rtol=1e-5, atol=1e-6)
    # the re-seeded factor is non-singular: one more maintained fold stays
    # finite (the regression scenario was a NaN here)
    after, _, _ = jax.vmap(
        lambda s_: online.online_serve_step(
            cfg, ens.mask, s_, u, ln, lab, lr0, w1, acc1,
            maintain_factor=True)
    )(culled)
    assert np.isfinite(np.asarray(after.ridge.Lt)).all()


def test_online_step_weight_masks_dead_samples_exactly():
    """The 0/1 sample weight (the stream server's tail-window mechanism) is
    exact: a window padded with dead samples produces the same state as the
    live samples alone (loss, grads, (A, B), count all unpolluted)."""
    from repro.core import masking, online

    cfg = DFRConfig(n_in=2, n_classes=3, n_nodes=8)
    mask = masking.make_mask(jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes,
                             cfg.n_in, cfg.dtype)
    state = online.init_state(cfg)
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=(4, 12, 2)).astype(np.float32))
    ln = jnp.asarray(rng.integers(4, 13, 4), jnp.int32)
    lab = jnp.asarray(rng.integers(0, 3, 4), jnp.int32)
    lr = jnp.float32(0.2)
    w = jnp.asarray([1.0, 1.0, 0.0, 0.0], jnp.float32)

    padded, m_pad = online.online_step(cfg, mask, state, u, ln, lab, lr, lr,
                                       weight=w)
    live, m_live = online.online_step(cfg, mask, state, u[:2], ln[:2],
                                      lab[:2], lr, lr)
    for a, b in zip(jax.tree_util.tree_leaves(padded),
                    jax.tree_util.tree_leaves(live)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(m_pad["loss"]), float(m_live["loss"]),
                               rtol=1e-6)
    assert int(padded.ridge.count) == int(live.ridge.count) == 2
