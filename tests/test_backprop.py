"""Backprop: manual truncated Eq. 33-36 == autodiff; Table 7 storage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backprop as bp
from repro.core.types import DFRConfig, DFRParams


def _setup(batched=True, nx=6, ny=4, t=9, seed=0):
    cfg = DFRConfig(n_in=3, n_classes=ny, n_nodes=nx, nonlinearity="tanh")
    key = jax.random.PRNGKey(seed)
    params = DFRParams(
        p=jnp.float32(0.15), q=jnp.float32(0.45),
        W=0.05 * jax.random.normal(key, (ny, cfg.n_rep)),
        b=0.01 * jnp.ones(ny),
    )
    shape = (2, t, nx) if batched else (t, nx)
    j_seq = jax.random.normal(jax.random.PRNGKey(seed + 1), shape)
    labels = jnp.asarray([1, 3][: (2 if batched else 1)])
    onehot = jax.nn.one_hot(labels if batched else labels[0], ny)
    return cfg, params, j_seq, onehot


@pytest.mark.parametrize("batched", [False, True])
def test_manual_equals_autodiff_truncated(batched):
    cfg, params, j_seq, onehot = _setup(batched)
    f = cfg.f()
    fp = lambda z: 1 - jnp.tanh(z) ** 2
    l1, g1 = bp.grads_truncated_manual(params, j_seq, onehot, f, fp)
    l2, g2 = bp.grads_truncated(params, j_seq, onehot, f)
    assert float(abs(l1 - l2)) < 1e-5
    for name in ("p", "q", "W", "b"):
        a, b_ = np.asarray(getattr(g1, name)), np.asarray(getattr(g2, name))
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-5, err_msg=name)


def test_manual_equals_autodiff_with_lengths():
    cfg, params, j_seq, onehot = _setup(batched=True)
    lengths = jnp.asarray([5, 9], jnp.int32)
    f = cfg.f()
    fp = lambda z: 1 - jnp.tanh(z) ** 2
    l1, g1 = bp.grads_truncated_manual(params, j_seq, onehot, f, fp, lengths)
    l2, g2 = bp.grads_truncated(params, j_seq, onehot, f, lengths)
    for name in ("p", "q", "W", "b"):
        np.testing.assert_allclose(
            np.asarray(getattr(g1, name)), np.asarray(getattr(g2, name)),
            rtol=1e-4, atol=1e-5, err_msg=name,
        )


def test_truncated_output_layer_grads_equal_full():
    """Truncation only affects (p, q): W/b grads must match full BPTT."""
    cfg, params, j_seq, onehot = _setup(batched=True)
    f = cfg.f()
    _, gt = bp.grads_truncated(params, j_seq, onehot, f)
    _, gf = bp.grads_full_bptt(params, j_seq, onehot, f)
    np.testing.assert_allclose(np.asarray(gt.W), np.asarray(gf.W), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(gt.b), np.asarray(gf.b), rtol=1e-4,
                               atol=1e-6)


def test_truncated_sgd_step_descends_full_loss():
    """Truncated-gradient SGD descends the FULL-BPTT objective - the
    property the paper's training recipe relies on.

    (A per-batch sign comparison of the (p, q) components at *random*
    readout weights is statistically meaningless: with W drawn at random,
    dL/dr - and hence the tiny last-step truncated (p, q) term - points
    anywhere.  The manual == autodiff identity tests above already pin the
    truncated equations exactly; what matters operationally is that the
    joint truncated step is a descent direction for the true loss, which
    holds for every seed/LR probed here.)
    """
    for seed in range(6):
        cfg, params, j_seq, onehot = _setup(batched=True, t=16, seed=seed)
        f = cfg.f()
        p = params
        for _ in range(3):
            _, gt = bp.grads_truncated(p, j_seq, onehot, f)
            p = bp.apply_sgd(p, gt, jnp.float32(0.05), jnp.float32(0.05),
                             inv_batch=0.5)
        l_before = float(bp._full_loss(params, j_seq, onehot, f))
        l_after = float(bp._full_loss(p, j_seq, onehot, f))
        assert l_after < l_before, (seed, l_before, l_after)


def test_storage_words_table7():
    """Naive grows with T; truncated is T-independent; >= 50% cut at T=500."""
    cfg = DFRConfig(n_in=5, n_classes=3, n_nodes=30)
    t = 500
    naive = bp.storage_words_naive(cfg, t)
    trunc = bp.storage_words_truncated(cfg, t)
    assert trunc < naive
    assert bp.storage_words_truncated(cfg, 10_000) == trunc
    assert (naive - trunc) / naive > 0.5
    # reservoir-state storage alone drops to 2/(T+1) (paper: <2% for T>100)
    assert 2 * cfg.n_nodes / ((t + 1) * cfg.n_nodes) < 0.02


def test_apply_sgd_clamps_to_paper_box():
    cfg, params, j_seq, onehot = _setup()
    g = DFRParams(p=jnp.float32(-100.0), q=jnp.float32(100.0),
                  W=jnp.zeros_like(params.W), b=jnp.zeros_like(params.b))
    new = bp.apply_sgd(params, g, 1.0, 1.0, grad_clip=None)
    eps = 1e-6  # f32 rounding of the box bounds
    assert bp.P_RANGE[0] - eps <= float(new.p) <= bp.P_RANGE[1] + eps
    assert bp.Q_RANGE[0] - eps <= float(new.q) <= bp.Q_RANGE[1] + eps
