"""Slot-sharded stream serving (PR 6): bitwise-parity + placement battery.

The contract under test (see runtime/stream_server.py, "Slot-sharded
serving"): ``StreamServer(devices=n)`` shards the slot axis over a 1-D
("slot",) mesh and serves episodes BITWISE identical to the single-device
server - across every retirement mode (none/forget/window), pipeline
depths 0/1/2, staggered refresh cohorts, mid-service pool growth and
continuous admission/retire churn.  The tests also pin the device-local
invariant structurally: state trees stay P("slot")-sharded across steps, a
live slot never migrates between devices, and the per-device refresh work
is bounded by the cohort size.

Multi-device tests need >= 8 XLA devices.  The conftest honors
``REPRO_FORCE_DEVICES=8`` (forcing ``--xla_force_host_platform_device_
count`` before jax initializes), which the CI sharded lane sets; a plain
single-device tier-1 run still executes the battery through the slow
subprocess fallback at the bottom, and the scheduler/placement property
tests are host-only and always run.
"""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic grid variants below still run
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed (CI property lane installs it); the "
           "deterministic grid variants cover the same invariants",
)

from repro.core.types import DFRConfig
from repro.runtime import StreamRequest, StreamServer
from repro.runtime.scheduler import RefreshCohorts, SlotScheduler

NDEV = jax.device_count()
needs_devices = pytest.mark.skipif(
    NDEV < 8, reason="needs 8 XLA devices (REPRO_FORCE_DEVICES=8); the "
                     "subprocess fallback covers the single-device run"
)

CFG = DFRConfig(n_in=2, n_classes=3, n_nodes=6)

RETIREMENT_MODES = (
    ("none", {"refresh_mode": "incremental"}),
    ("forget", {"refresh_mode": "incremental", "retirement": "forget",
                "forget": 0.9}),
    ("window", {"refresh_mode": "incremental", "retirement": "window",
                "retire_window": 6}),
)


def _make_stream(rid, n, t=10, seed=0):
    r = np.random.default_rng(seed)
    return StreamRequest(
        rid=rid,
        u=r.normal(size=(n, t, CFG.n_in)).astype(np.float32),
        length=r.integers(3, t + 1, n).astype(np.int32),
        label=r.integers(0, CFG.n_classes, n).astype(np.int32),
    )


def _episode_streams(seed0=0):
    """More streams than slots, ragged lengths: admission, tail windows,
    retirement and refill all fire."""
    return [_make_stream(i, n, seed=seed0 + i)
            for i, n in enumerate([7, 5, 9, 4, 6, 8, 5, 4, 7, 6, 5, 9])]


def _serve(devices, depth=0, cohorts=1, streams=None, **kw):
    srv = StreamServer(CFG, t_max=10, max_streams=8, window=2,
                      phase_steps=3, refresh_every=4,
                      refresh_cohorts=cohorts, pipeline_depth=depth,
                      devices=devices, **kw)
    for s in (streams if streams is not None else _episode_streams()):
        srv.submit(s)
    done = srv.run_until_drained()
    return {r.rid: list(r.preds) for r in done}, srv


def _assert_bitwise(sa, sb):
    for a, b in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


_BASELINES = {}


def _baseline(mode, kw):
    """devices=1 depth-0 episode, computed once per retirement mode."""
    if mode not in _BASELINES:
        _BASELINES[mode] = _serve(1, **kw)
    return _BASELINES[mode]


# ---------------------------------------------------------------------------
# Bitwise parity: device counts x retirement modes x pipeline depths
# ---------------------------------------------------------------------------


@needs_devices
@pytest.mark.parametrize("mode,kw", RETIREMENT_MODES,
                         ids=[m for m, _ in RETIREMENT_MODES])
@pytest.mark.parametrize("devices", [2, 4, 8])
def test_sharded_episode_is_bitwise_single_device(devices, mode, kw):
    """The shard_map'd fused step serves the full admission/retire episode
    bit-for-bit like devices=1: predictions, the final batched state AND
    every retirement snapshot match exactly (the per-device cond gates are
    exact identities when untaken)."""
    preds_1, srv_1 = _baseline(mode, kw)
    preds_n, srv_n = _serve(devices, **kw)
    assert preds_1 == preds_n
    _assert_bitwise(srv_1.states, srv_n.states)
    if srv_1.win is not None:
        _assert_bitwise(srv_1.win, srv_n.win)
    for a, b in zip(sorted(srv_1.completed, key=lambda r: r.rid),
                    sorted(srv_n.completed, key=lambda r: r.rid)):
        assert a.correct == b.correct and b.done
        _assert_bitwise(a.final_state, b.final_state)
        for leaf in jax.tree_util.tree_leaves(b.final_state):
            assert np.all(np.isfinite(np.asarray(leaf, np.float64)))


@needs_devices
@pytest.mark.parametrize("mode,kw", RETIREMENT_MODES,
                         ids=[m for m, _ in RETIREMENT_MODES])
@pytest.mark.parametrize("depth", [1, 2])
def test_sharded_pipelined_is_bitwise_synchronous(depth, mode, kw):
    """Async pipelining composes with sharding: 8-device depth-1/2 episodes
    equal the single-device depth-0 schedule bit-for-bit (the lag-D ring
    defers only bookkeeping, sharded or not)."""
    preds_1, srv_1 = _baseline(mode, kw)
    preds_d, srv_d = _serve(8, depth=depth, **kw)
    assert preds_1 == preds_d
    _assert_bitwise(srv_1.states, srv_d.states)


@needs_devices
def test_sharded_staggered_cohorts_match():
    """Uneven refresh cohorts (C=3 over 8 slots: per-shard row lists need
    cross-shard padding to a common width) refresh the exact same slots on
    the exact same steps as the unsharded schedule."""
    for devices in (2, 8):
        preds_1, srv_1 = _serve(1, cohorts=3)
        preds_n, srv_n = _serve(devices, cohorts=3)
        assert preds_1 == preds_n
        _assert_bitwise(srv_1.states, srv_n.states)


@needs_devices
def test_sharded_pool_growth_mid_service():
    """A longer stream submitted mid-episode grows the staged pool; the
    re-pinned sharded pool keeps serving exactly (vs devices=1 under the
    same submission schedule)."""
    def run(devices):
        srv = StreamServer(CFG, t_max=10, max_streams=4, window=2,
                          phase_steps=2, refresh_every=3, devices=devices)
        for s in _episode_streams()[:4]:
            srv.submit(s)
        for _ in range(2):
            srv.step()
        srv.submit(_make_stream(99, 13, seed=42))   # forces _grow_pool
        done = srv.run_until_drained()
        return {r.rid: list(r.preds) for r in done}, srv

    preds_1, srv_1 = run(1)
    preds_4, srv_4 = run(4)
    assert srv_4.pool.capacity == srv_1.pool.capacity > 10
    assert preds_1 == preds_4
    _assert_bitwise(srv_1.states, srv_4.states)


@needs_devices
@pytest.mark.parametrize("devices", [2, 8])
def test_sharded_quantized_episode_is_bitwise_single_device(devices):
    """quantize='int8' (PR 7) composes with slot sharding: the sharded
    quantized episode - per-slot scale folds riding the shard-local cohort
    refresh, int8 serving logits per device block - is bitwise the
    single-device quantized episode, quant leaves included."""
    preds_1, srv_1 = _serve(1, quantize="int8")
    preds_n, srv_n = _serve(devices, quantize="int8")
    assert preds_1 == preds_n
    _assert_bitwise(srv_1.states, srv_n.states)   # includes states.quant


@needs_devices
def test_sharded_blocked_quantized_parity():
    """step_block (PR 7) composes with sharding and quantization: the
    8-device blocked quantized episode equals the single-device blocked
    quantized one bitwise, and both serve the unblocked quantized
    predictions exactly (the block clamp pins the schedule)."""
    preds_u, _ = _serve(1, quantize="int8")
    preds_1, srv_1 = _serve(1, quantize="int8", step_block=3)
    preds_8, srv_8 = _serve(8, quantize="int8", step_block=3)
    assert preds_u == preds_1 == preds_8
    _assert_bitwise(srv_1.states, srv_8.states)


# ---------------------------------------------------------------------------
# Placement: the device-local invariant, structurally
# ---------------------------------------------------------------------------


@needs_devices
def test_sharded_state_trees_stay_slot_sharded():
    """Every per-slot tree is NamedSharding-P('slot') after init AND after
    serving steps (out_specs pin it), the replicated operands replicate,
    and each device holds exactly its contiguous S/n slot block."""
    srv = StreamServer(CFG, t_max=10, max_streams=8, window=2,
                      phase_steps=2, refresh_every=3, devices=8,
                      refresh_mode="incremental", retirement="window",
                      retire_window=4)
    for s in _episode_streams()[:6]:
        srv.submit(s)
    for _ in range(3):
        srv.step()
    srv.drain()
    mesh = srv.mesh
    assert mesh.axis_names == ("slot",) and mesh.size == 8
    slot_sh = NamedSharding(mesh, P("slot"))
    for tree in (srv.states, srv.win, srv.pool):
        for leaf in jax.tree_util.tree_leaves(tree):
            assert leaf.sharding.is_equivalent_to(slot_sh, leaf.ndim), leaf
    assert srv.mask.sharding.is_equivalent_to(
        NamedSharding(mesh, P()), srv.mask.ndim)
    # contiguous ownership: shard d of the (S,) step counter is slot d
    shards = sorted(srv.states.step.addressable_shards,
                    key=lambda sh: sh.device.id)
    assert [sh.index for sh in shards] == [
        (slice(d, d + 1),) for d in range(8)
    ]


def test_sharded_validation():
    """Misconfigurations fail fast: host staging, indivisible S, devices<1
    (all raised before any mesh is built)."""
    with pytest.raises(ValueError, match="staging='device'"):
        StreamServer(CFG, t_max=10, devices=2, staging="host")
    with pytest.raises(ValueError, match="divisible"):
        StreamServer(CFG, t_max=10, max_streams=6, devices=4)
    with pytest.raises(ValueError, match="devices"):
        StreamServer(CFG, t_max=10, devices=0)


# ---------------------------------------------------------------------------
# Host-only properties: placement never migrates, refresh work is bounded
# ---------------------------------------------------------------------------


def _check_no_migration(rng, n_slots, n_shards, n_ops):
    """Random admit/retire schedule: a request's slot index - hence its
    owning device, the fixed map slot // (S/n) - never changes while the
    request is live."""
    s_loc = n_slots // n_shards
    sched = SlotScheduler(n_slots)
    placed = {}          # rid -> (slot, device) at admission
    next_rid = 0
    for _ in range(n_ops):
        op = rng.choice(["submit", "admit", "retire"])
        if op == "submit":
            sched.submit(next_rid)
            next_rid += 1
        elif op == "admit":
            sched.admit(lambda i, rid: placed.setdefault(
                rid, (i, i // s_loc)))
        else:
            live = sched.live()
            if live:
                i, rid = live[int(rng.integers(len(live)))]
                sched.retire(i)
                del placed[rid]
        for i, rid in sched.live():
            slot0, dev0 = placed[rid]
            assert i == slot0 and i // s_loc == dev0


def _check_cohort_schedule(n_slots, refresh_every, n_cohorts, n_shards):
    """The shard-local refresh schedule is the unsharded schedule, re-based:
    same due steps, local rows in range and distinct per shard, the union
    of ok'd global ids is exactly the due cohort, and per-device refresh
    work is bounded by the local cohort size ceil(S/n / C)."""
    s_loc = n_slots // n_shards
    coh = RefreshCohorts(n_slots, refresh_every, n_cohorts)
    c_eff = coh.n_cohorts
    for step in range(refresh_every):
        due_g, _, _ = coh.due_rows_fixed(step)
        due_s, rows, ok = coh.due_rows_fixed_sharded(step, n_shards)
        assert due_s == due_g
        assert rows.shape == ok.shape and rows.shape[0] % n_shards == 0
        r_loc = rows.shape[0] // n_shards
        global_ok = set()
        for d in range(n_shards):
            blk = rows[d * r_loc:(d + 1) * r_loc]
            okb = ok[d * r_loc:(d + 1) * r_loc]
            assert ((blk >= 0) & (blk < s_loc)).all()
            assert len(set(blk.tolist())) == r_loc   # scatter-safe
            assert int(okb.sum()) <= -(-s_loc // c_eff)
            global_ok |= {d * s_loc + int(j) for j, o in zip(blk, okb) if o}
        expect = coh.due_slots(step)
        assert global_ok == set(expect if due_g else [])


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_property_live_slot_never_changes_device(data):
        n_slots = data.draw(st.sampled_from([4, 8, 16]), label="n_slots")
        n_shards = data.draw(
            st.sampled_from([d for d in (1, 2, 4, 8) if n_slots % d == 0]),
            label="n_shards")
        seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
        n_ops = data.draw(st.integers(4, 30), label="ops")
        _check_no_migration(
            np.random.default_rng(seed), n_slots, n_shards, n_ops)

    @settings(max_examples=60, deadline=None)
    @given(
        n_slots=st.sampled_from([4, 8, 16, 24]),
        refresh_every=st.integers(1, 12),
        n_cohorts=st.integers(1, 6),
        n_shards=st.sampled_from([1, 2, 4, 8]),
    )
    def test_property_sharded_cohort_schedule(n_slots, refresh_every,
                                              n_cohorts, n_shards):
        if n_slots % n_shards:
            n_shards = 1
        _check_cohort_schedule(n_slots, refresh_every, n_cohorts, n_shards)


def test_grid_live_slot_never_changes_device():
    """Deterministic variant of the migration property (runs with or
    without hypothesis): 24 random schedules across shard widths."""
    for n_slots, n_shards in ((4, 1), (4, 2), (8, 4), (8, 8), (16, 4)):
        for seed in range(5):
            _check_no_migration(
                np.random.default_rng(1000 * n_slots + seed),
                n_slots, n_shards, n_ops=25)


def test_grid_sharded_cohort_schedule():
    """Deterministic variant of the schedule property: the full small grid
    of slots x period x cohorts x shards."""
    for n_slots in (4, 8, 16, 24):
        for refresh_every in (1, 3, 5, 8):
            for n_cohorts in (1, 2, 3, 5):
                for n_shards in (1, 2, 4, 8):
                    if n_slots % n_shards:
                        continue
                    _check_cohort_schedule(
                        n_slots, refresh_every, n_cohorts, n_shards)


def test_sharded_cohort_schedule_rejects_indivisible():
    with pytest.raises(ValueError, match="divisible"):
        RefreshCohorts(6, 4, 2).due_rows_fixed_sharded(0, 4)


# ---------------------------------------------------------------------------
# _sharded_fixed direct unit battery (the padding construction itself)
# ---------------------------------------------------------------------------


def _sharded_fixed_corners():
    """(n_slots, refresh_every, n_cohorts, n_shards) corner grid: single
    cohort (r_loc == s_loc, empty pad pool), one-slot shards, cohorts >
    period (clamped), misaligned cohort stride vs shard blocks, max
    padding (one giant cohort among many shards)."""
    return [
        (4, 3, 1, 1), (4, 3, 1, 2), (4, 3, 1, 4),      # r_loc == s_loc
        (8, 5, 2, 2), (8, 5, 2, 8),                     # s_loc == 1
        (8, 2, 5, 2),                                   # cohorts clamped
        (6, 4, 2, 2), (6, 6, 4, 3), (12, 5, 5, 4),     # misaligned strides
        (16, 8, 8, 2), (24, 12, 5, 8),
    ]


def test_sharded_fixed_blocks_are_duplicate_free_and_in_range():
    """Every (cohort, shard) block holds r_loc DISTINCT local indices in
    [0, s_loc) - the property that makes the traced refresh scatter safe
    (a duplicate index would make the padded no-op write race the real
    refresh write) - and the ok'd ones are exactly the cohort's local
    members."""
    for n_slots, refresh_every, n_cohorts, n_shards in _sharded_fixed_corners():
        coh = RefreshCohorts(n_slots, refresh_every, n_cohorts)
        s_loc = n_slots // n_shards
        r_loc, fixed = coh._sharded_fixed(n_shards)
        assert set(fixed) == set(coh.offsets)
        for c, phase in enumerate(coh.offsets):
            rows, ok = fixed[phase]
            assert rows.shape == ok.shape == (n_shards * r_loc,)
            for d in range(n_shards):
                blk = rows[d * r_loc:(d + 1) * r_loc].tolist()
                okb = ok[d * r_loc:(d + 1) * r_loc].tolist()
                assert all(0 <= j < s_loc for j in blk)
                assert len(set(blk)) == r_loc, (
                    f"duplicate local rows in shard {d} of cohort {c}: {blk}")
                want = {i - d * s_loc for i in range(n_slots)
                        if coh.cohort_of_slot[i] == c
                        and d * s_loc <= i < (d + 1) * s_loc}
                assert {j for j, o in zip(blk, okb) if o} == want


def test_sharded_fixed_pad_pool_never_exhausts():
    """r_loc (the common padded width) never exceeds s_loc, so the pad
    pool of non-member local indices always covers the demand - the
    ``pad_pool.pop(0) if pad_pool else 0`` fallback (which would introduce
    a duplicate row) is unreachable.  Checked structurally: padding demand
    r_loc - len(members) never exceeds the pool s_loc - len(members)."""
    for n_slots, refresh_every, n_cohorts, n_shards in _sharded_fixed_corners():
        coh = RefreshCohorts(n_slots, refresh_every, n_cohorts)
        s_loc = n_slots // n_shards
        r_loc, _ = coh._sharded_fixed(n_shards)
        assert 1 <= r_loc <= s_loc
        for c in range(coh.n_cohorts):
            for d in range(n_shards):
                m = sum(1 for i in range(n_slots)
                        if coh.cohort_of_slot[i] == c
                        and d * s_loc <= i < (d + 1) * s_loc)
                assert r_loc - m <= s_loc - m


def test_sharded_fixed_single_cohort_is_full_permutation():
    """n_cohorts=1 is the r_loc == s_loc corner: the one cohort owns every
    slot, the pad pool is empty AND no padding is needed - each shard
    block must be a full permutation of range(s_loc), all ok."""
    for n_slots, n_shards in ((4, 1), (4, 2), (8, 4), (8, 8), (24, 3)):
        coh = RefreshCohorts(n_slots, 5, 1)
        s_loc = n_slots // n_shards
        r_loc, fixed = coh._sharded_fixed(n_shards)
        assert r_loc == s_loc
        (rows, ok), = fixed.values()
        assert ok.all()
        for d in range(n_shards):
            assert sorted(rows[d * s_loc:(d + 1) * s_loc].tolist()) \
                == list(range(s_loc))


def test_sharded_fixed_misaligned_stride_flags():
    """n_slots=6, n_shards=2, n_cohorts=2: cohort 0 = {0, 2, 4} straddles
    both 3-slot shard blocks unevenly (2 members in shard 0, 1 in shard
    1), so shard 1's block needs one ok=False pad distinct from its
    member."""
    coh = RefreshCohorts(6, 4, 2)
    r_loc, fixed = coh._sharded_fixed(2)
    assert r_loc == 2
    rows, ok = fixed[coh.offsets[0]]          # cohort 0
    s0, o0 = rows[:2].tolist(), ok[:2].tolist()
    s1, o1 = rows[2:].tolist(), ok[2:].tolist()
    assert sorted(j for j, o in zip(s0, o0) if o) == [0, 2]
    assert sorted(j for j, o in zip(s1, o1) if o) == [1]   # global slot 4
    assert len(set(s1)) == 2                  # the pad is distinct


# ---------------------------------------------------------------------------
# Single-device fallback: run the battery under a forced-8-device subprocess
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.skipif(NDEV >= 8, reason="battery already ran in-process")
def test_forced_lane_subprocess():
    """Plain tier-1 runs (one device) still execute the full sharded parity
    battery: re-run this file's device-gated tests in a subprocess with
    REPRO_FORCE_DEVICES=8 (the conftest forces the XLA flag pre-init)."""
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "tests/test_stream_sharded.py",
         "-q", "-k", "sharded_", "-p", "no:cacheprovider"],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "JAX_PLATFORMS": "cpu", "HOME": os.environ.get("HOME", "/root"),
             "REPRO_FORCE_DEVICES": "8"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, (out.stdout[-2000:] + out.stderr[-2000:])
    assert "passed" in out.stdout
