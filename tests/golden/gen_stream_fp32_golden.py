"""Generate the fp32 serving golden fixture (PR-6 baseline).

Run from the repo root with the *pre-quantization* tree checked out:

    PYTHONPATH=src python tests/golden/gen_stream_fp32_golden.py

The fixture pins the exact predictions and final model state of a full
multi-admission/retire episode in every retirement mode, so later PRs can
prove the fp32 serving path stayed bitwise identical.  The episode shape
mirrors tests/test_stream_pipeline.py (more streams than slots, ragged
lengths, tail windows, refresh cohorts firing mid-episode).

Regenerate ONLY when a PR intentionally changes fp32 serving numerics --
and say so in the PR description.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax  # noqa: E402

from repro.core.types import DFRConfig  # noqa: E402
from repro.runtime import StreamRequest, StreamServer  # noqa: E402

CFG = DFRConfig(n_in=2, n_classes=3, n_nodes=8)

MODES = (
    ("none", {}),
    ("none-inc", {"refresh_mode": "incremental"}),
    ("forget", {"refresh_mode": "incremental", "retirement": "forget",
                "forget": 0.9}),
    ("window", {"refresh_mode": "incremental", "retirement": "window",
                "retire_window": 6}),
)

# (name, leaf getter) -- the PR-6 OnlineState leaves; later PRs may add
# leaves (e.g. quantization state) which are deliberately NOT pinned here
STATE_LEAVES = (
    ("params_p", lambda s: s.params.p),
    ("params_q", lambda s: s.params.q),
    ("params_W", lambda s: s.params.W),
    ("params_b", lambda s: s.params.b),
    ("ridge_A", lambda s: s.ridge.A),
    ("ridge_B", lambda s: s.ridge.B),
    ("ridge_count", lambda s: s.ridge.count),
    ("ridge_Lt", lambda s: s.ridge.Lt),
    ("ridge_factor_beta", lambda s: s.ridge.factor_beta),
    ("step", lambda s: s.step),
    ("loss_ema", lambda s: s.loss_ema),
)


def make_stream(rid, n, t=16, seed=0, n_in=2, n_classes=3):
    r = np.random.default_rng(seed)
    return StreamRequest(
        rid=rid,
        u=r.normal(size=(n, t, n_in)).astype(np.float32),
        length=r.integers(4, t + 1, n).astype(np.int32),
        label=r.integers(0, n_classes, n).astype(np.int32),
    )


def episode_streams(seed0=0):
    return [make_stream(i, n, seed=seed0 + i)
            for i, n in enumerate([8, 6, 10, 4, 7])]


def serve(mode_kw):
    srv = StreamServer(CFG, t_max=16, max_streams=3, window=2,
                       phase_steps=2, refresh_every=3, **mode_kw)
    for s in episode_streams():
        srv.submit(s)
    done = srv.run_until_drained()
    return done, srv


def main():
    out = {
        "jax_version": np.array(jax.__version__),
        "platform": np.array(jax.default_backend()),
    }
    for mode, kw in MODES:
        done, srv = serve(kw)
        for r in sorted(done, key=lambda r: r.rid):
            out[f"{mode}/preds/{r.rid}"] = np.asarray(r.preds, np.int32)
        for name, get in STATE_LEAVES:
            out[f"{mode}/state/{name}"] = np.asarray(get(srv.states))
        print(f"{mode}: {sum(len(r.preds) for r in done)} preds, "
              f"global_step={srv.global_step}")
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "stream_fp32_golden.npz")
    np.savez_compressed(path, **out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
