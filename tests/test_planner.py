"""The calibrated cost-model planner (PR 8): model structure, persistence,
the feasibility lattice, ``StreamServer(config='auto')`` wiring, and the
bench-replay validation gate.

Most tests run on a SYNTHETIC ``Calibration`` - the model's structural
claims (step blocking amortizes dispatch, rotation-heavy backends favor
recompute at large windows, window retirement doubles the rotation bill)
must hold for any positive coefficients, so no test here pays the real
micro-calibration run.  The true-coefficient end-to-end check lives in the
planner bench lane (``bench_stream.py --planner --smoke``), which measures
this host and fails on the 1.3x gate.
"""
import json
import math

import pytest

from repro.core.types import DFRConfig
from repro.runtime import StreamRequest, StreamServer, planner
from repro.runtime.planner import (
    Calibration,
    Plan,
    Planner,
    predict_step_cost,
    replay_bench_tables,
)


#: flat synthetic coefficients: every primitive 1ns/unit, dispatch 1us
def _cal(**over) -> Calibration:
    kw = dict(c_dispatch=1e-6, c_flop=1e-9, c_byte=1e-9, c_rot=1e-9,
              c_sub=1e-9, c_chol=1e-9, c_quant=1e-9, backend="cpu",
              fingerprint={"backend": "cpu"})
    kw.update(over)
    return Calibration(**kw)


# tiny shape so program_cost's one-time lower+compile stays cheap (and is
# shared by every test through the lru_cache)
NX, S, W, T = 4, 2, 1, 8


def _predict(cal, **over):
    kw = dict(Nx=NX, S=S, window=W, retirement="none",
              refresh_mode="recompute", cohorts=1, step_block=1,
              quantize="none", n_classes=3, t_len=T, refresh_every=5,
              cal=cal)
    kw.update(over)
    return predict_step_cost(**kw)


# -- the model's structural claims -------------------------------------------


def test_step_block_amortizes_dispatch():
    cal = _cal(c_dispatch=1e-3)         # dispatch-dominated backend
    t1 = _predict(cal, step_block=1)
    t4 = _predict(cal, step_block=4)
    t8 = _predict(cal, step_block=8)
    assert t8 < t4 < t1
    # with free dispatch, blocking cannot help (and must not hurt)
    free = _cal(c_dispatch=0.0)
    assert _predict(free, step_block=8) == pytest.approx(
        _predict(free, step_block=1))


def test_refresh_mode_winner_flips_with_rotation_cost():
    """The PR-3 table's structure: cheap rotations -> incremental wins;
    expensive rotations (large windows multiply them) -> recompute wins."""
    rot_cheap = _cal(c_rot=1e-12, c_chol=1e-8)
    assert _predict(rot_cheap, refresh_mode="incremental") < _predict(
        rot_cheap, refresh_mode="recompute")
    rot_dear = _cal(c_rot=1e-6, c_chol=1e-12)
    assert _predict(rot_dear, refresh_mode="recompute", window=8) < _predict(
        rot_dear, refresh_mode="incremental", window=8)


def test_window_retirement_doubles_rotations():
    cal = _cal(c_rot=1e-6)
    inc = _predict(cal, refresh_mode="incremental")
    win = _predict(cal, refresh_mode="incremental", retirement="window")
    assert win > inc


def test_quantize_costs_extra_on_calibrated_cpu():
    cal = _cal()
    assert _predict(cal, quantize="int8") > _predict(cal, quantize="none")


def test_backend_mismatch_raises():
    with pytest.raises(ValueError, match="backend"):
        _predict(_cal(backend="cpu"), backend="tpu")


def test_more_cohorts_shrink_predicted_refresh_spike():
    cal = _cal()
    spikes = [planner.predict_refresh_spike_s(8, 16, "recompute", c,
                                              n_classes=3, cal=cal)
              for c in (1, 2, 4)]
    assert spikes[0] > spikes[1] > spikes[2]


# -- the feasibility lattice and the search ----------------------------------


def _mk_planner(cal, **over):
    kw = dict(Nx=NX, S=S, window=W, t_len=T, n_classes=3, refresh_every=5,
              cal=cal)
    kw.update(over)
    return Planner(**kw)


def test_lattice_respects_window_retirement():
    pl = _mk_planner(_cal(), retirement="window")
    assert {m for m, _, _, _ in pl.lattice()} == {"incremental"}


def test_lattice_restricts_host_staging_to_unblocked():
    pl = _mk_planner(_cal(), staging="host")
    assert {b for _, _, b, _ in pl.lattice()} == {1}


def test_lattice_searches_chunk_t_only_where_it_lowers_differently():
    """Off-TPU the XLA path ignores chunk_t: the default lattice must not
    burn compiles pricing identical programs (ROADMAP note closed by the
    chunk_t lattice dimension).  An explicit chunk_ts always wins."""
    import jax

    pl = _mk_planner(_cal())
    cts = {ct for _, _, _, ct in pl.lattice()}
    if jax.default_backend() == "tpu":
        assert cts == set(planner.DEFAULT_CHUNK_TS)
    else:
        assert cts == {None}
    explicit = {ct for _, _, _, ct in pl.lattice(chunk_ts=(None, 32))}
    assert explicit == {None, 32}


def test_search_ties_resolve_chunk_t_to_none():
    """chunk_t costs tie on a backend where the knob is a lowering no-op,
    and the None-first ordering must keep the kernels' own heuristic -
    auto-config behavior is bitwise pre-knob."""
    pl = _mk_planner(_cal())
    plan = pl.search(chunk_ts=(None, 64, 128))
    assert plan.chunk_t is None


def test_search_returns_lattice_argmin():
    pl = _mk_planner(_cal(c_dispatch=1e-3))
    plan = pl.search()
    assert isinstance(plan, Plan)
    best = min(pl.predict(m, c, b, ct) for m, c, b, ct in pl.lattice())
    assert plan.predicted_s_per_sample == pytest.approx(best)
    assert plan.predicted_samples_per_s == pytest.approx(
        1.0 / plan.predicted_s_per_sample)
    assert plan.knobs().keys() == {"refresh_mode", "refresh_cohorts",
                                   "step_block", "chunk_t"}


# -- calibration persistence -------------------------------------------------


def test_calibration_json_roundtrip():
    cal = _cal(c_flop=3.25e-10)
    doc = json.loads(json.dumps(cal.to_json()))
    back = Calibration.from_json(doc)
    assert back == cal


def test_calibration_schema_mismatch_raises():
    doc = _cal().to_json()
    doc["schema"] = 999
    with pytest.raises(ValueError, match="schema"):
        Calibration.from_json(doc)


def test_get_calibration_reuses_matching_file(tmp_path, monkeypatch):
    """A persisted calibration with this host's fingerprint must be loaded
    verbatim - never re-measured."""
    path = tmp_path / "cal.json"
    cal = _cal(c_flop=1.25e-4,
               fingerprint=planner._host_fingerprint(),
               backend=planner._host_fingerprint()["backend"])
    path.write_text(json.dumps(cal.to_json()))
    monkeypatch.setattr(planner, "calibrate",
                        lambda *a, **k: pytest.fail("re-measured"))
    got = planner.get_calibration(str(path))
    assert got.c_flop == 1.25e-4
    # and the in-process cache serves repeats even if the file vanishes
    path.unlink()
    assert planner.get_calibration(str(path)).c_flop == 1.25e-4


def test_get_calibration_rejects_foreign_fingerprint(tmp_path, monkeypatch):
    path = tmp_path / "cal.json"
    foreign = _cal(fingerprint={"backend": "not-this-host", "cores": -1})
    path.write_text(json.dumps(foreign.to_json()))
    fresh = _cal(c_flop=7.5e-7, fingerprint=planner._host_fingerprint())
    monkeypatch.setattr(planner, "calibrate", lambda *a, **k: fresh)
    got = planner.get_calibration(str(path))
    assert got.c_flop == 7.5e-7
    # the re-measured result replaced the foreign file
    assert json.loads(path.read_text())["c_flop"] == 7.5e-7


def test_get_calibration_recovers_from_torn_file(tmp_path, monkeypatch):
    """Regression: a half-written calibration (a crashed writer before the
    publish was made atomic) must re-measure and overwrite, not crash."""
    path = tmp_path / "cal.json"
    good = json.dumps(_cal().to_json())
    path.write_text(good[: len(good) // 2])   # torn mid-document
    fresh = _cal(c_flop=3.5e-8, fingerprint=planner._host_fingerprint())
    monkeypatch.setattr(planner, "calibrate", lambda *a, **k: fresh)
    got = planner.get_calibration(str(path))
    assert got.c_flop == 3.5e-8
    assert json.loads(path.read_text())["c_flop"] == 3.5e-8
    # no stray temp files left behind by the atomic publish
    assert [p.name for p in tmp_path.iterdir()] == ["cal.json"]


def test_get_calibration_concurrent_writers_never_tear(tmp_path, monkeypatch):
    """The mkstemp + os.replace publish is atomic: with many concurrent
    calibrators hammering the same path, every read of the file - at any
    instant - parses as a complete calibration document."""
    import threading

    path = str(tmp_path / "cal.json")
    fresh = _cal(c_flop=9e-9, fingerprint=planner._host_fingerprint())
    monkeypatch.setattr(planner, "calibrate", lambda *a, **k: fresh)
    stop = threading.Event()
    errors = []

    def writer():
        for _ in range(50):
            planner._CAL_CACHE.clear()         # force the re-measure+publish
            try:
                planner.get_calibration(path)
            except Exception as e:             # pragma: no cover
                errors.append(e)

    def reader():
        while not stop.is_set():
            try:
                with open(path) as fh:
                    Calibration.from_json(json.load(fh))
            except FileNotFoundError:
                pass                           # not yet published
            except Exception as e:             # pragma: no cover
                errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(4)]
    rt = threading.Thread(target=reader)
    rt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rt.join()
    assert not errors
    assert json.loads(open(path).read())["c_flop"] == 9e-9


# -- StreamServer(config='auto') wiring --------------------------------------


CFG = DFRConfig(n_in=2, n_classes=3, n_nodes=4)


def _stream(rid=0, n=6, t=T, seed=0):
    import numpy as np

    r = np.random.default_rng(seed)
    return StreamRequest(
        rid=rid,
        u=r.normal(size=(n, t, 2)).astype(np.float32),
        length=r.integers(4, t + 1, n).astype(np.int32),
        label=r.integers(0, 3, n).astype(np.int32),
    )


@pytest.fixture()
def synthetic_host_cal(monkeypatch):
    cal = _cal(c_dispatch=1e-3)
    monkeypatch.setattr(planner, "get_calibration", lambda *a, **k: cal)
    return cal


def test_config_auto_fills_unset_knobs(synthetic_host_cal):
    srv = StreamServer(CFG, t_max=T, max_streams=S, window=W, config="auto")
    assert srv.plan is not None
    assert srv.refresh_mode == srv.plan.refresh_mode
    assert srv.step_block == srv.plan.step_block
    assert srv.cohorts.n_cohorts >= 1
    srv.submit(_stream())
    done = srv.run_until_drained()
    assert len(done) == 1 and done[0].done


def test_config_auto_explicit_knobs_override(synthetic_host_cal):
    # the dispatch-heavy synthetic cal makes the planner prefer blocking,
    # so explicit step_block=1 proves the override wins
    auto = StreamServer(CFG, t_max=T, max_streams=S, window=W,
                        config="auto")
    assert auto.plan.step_block > 1
    srv = StreamServer(CFG, t_max=T, max_streams=S, window=W, config="auto",
                       refresh_mode="recompute", refresh_cohorts=1,
                       step_block=1)
    assert (srv.refresh_mode, srv.cohorts.n_cohorts, srv.step_block) == (
        "recompute", 1, 1)


def test_config_auto_respects_window_retirement(synthetic_host_cal):
    srv = StreamServer(CFG, t_max=T, max_streams=S, window=W, config="auto",
                       retirement="window", retire_window=8)
    assert srv.refresh_mode == "incremental"


def test_default_config_keeps_historical_defaults():
    srv = StreamServer(CFG, t_max=T, max_streams=S, window=W)
    assert srv.plan is None
    assert (srv.refresh_mode, srv.cohorts.n_cohorts, srv.step_block) == (
        "recompute", 1, 1)


def test_unknown_config_raises():
    with pytest.raises(ValueError, match="config"):
        StreamServer(CFG, t_max=T, max_streams=S, window=W, config="fast")


# -- the bench-replay validation gate ----------------------------------------


def _bench_doc(rows):
    return {"bench": "stream_quant", "rows": rows}


def _quant_row(cell="S2/Nx4/W1", **sps):
    row = {"table": "stream-quant", "cell": cell, "t_len": T}
    for name, v in sps.items():
        row[f"{name}_samples_per_s"] = v
    return row


def test_replay_passes_when_model_ranks_like_the_bench(tmp_path):
    # flat coefficients predict fp32_b4 fastest (blocking amortizes
    # dispatch, int8 adds work) - the bench agrees, so the gate passes
    (tmp_path / "BENCH_stream_quant.json").write_text(json.dumps(_bench_doc(
        [_quant_row(fp32=1000.0, int8=300.0, fp32_b4=1400.0, int8_b4=350.0)]
    )))
    res = replay_bench_tables(str(tmp_path), cal=_cal(c_dispatch=1e-3))
    assert len(res) == 1
    assert res[0]["ok"] is True
    assert res[0]["pick"] == "fp32_b4" == res[0]["best"]
    assert res[0]["best_over_pick_ratio"] == pytest.approx(1.0)


def test_replay_fails_when_pick_misses_the_gate(tmp_path):
    # the bench says blocking is a disaster (>1.3x) on this 'host'; the
    # flat model still picks it, so the row must flag ok=False
    (tmp_path / "BENCH_stream_quant.json").write_text(json.dumps(_bench_doc(
        [_quant_row(fp32=1000.0, int8=300.0, fp32_b4=500.0, int8_b4=200.0)]
    )))
    res = replay_bench_tables(str(tmp_path), cal=_cal(c_dispatch=1e-3))
    assert res[0]["ok"] is False
    assert res[0]["pick"] == "fp32_b4"
    assert res[0]["best"] == "fp32"
    assert res[0]["best_over_pick_ratio"] == pytest.approx(2.0)


def test_replay_no_table_is_empty(tmp_path):
    assert replay_bench_tables(str(tmp_path), cal=_cal()) == []


def test_replay_parses_real_tracked_table_if_present():
    """The repo's own tracked table must replay without errors (the gate
    itself is enforced by the bench lane with the REAL calibration; here
    any calibration proves row parsing, policy mapping, and ratio math)."""
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if not os.path.exists(os.path.join(root, "BENCH_stream_quant.json")):
        pytest.skip("no tracked quant table")
    res = replay_bench_tables(root, cal=_cal(c_dispatch=1e-3))
    assert res, "tracked table produced no replay rows"
    for row in res:
        assert set(row) >= {"cell", "pick", "best", "best_over_pick_ratio",
                            "ok"}
        assert row["best_over_pick_ratio"] >= 1.0
        assert not math.isnan(row["best_over_pick_ratio"])
