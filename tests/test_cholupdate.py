"""Property battery for the incremental rank-1 Cholesky engine.

The contract under test (repro.core.ridge cholupdate_* + the Pallas tile
kernel in repro.kernels.cholupdate):

  * rank-1 update/downdate of a live factor matches re-factorization of
    ``B +/- x x^T + beta I`` across random SPD systems and scales,
  * downdate-after-update round-trips to the original factor,
  * all forms agree to per-dtype tolerances: packed numpy oracle ==
    packed jitted == dense == batched vmap == Pallas (interpret mode),
  * a refresh from a maintained factor equals the full O(s^3) re-solve,
  * the serve-step maintenance invariant  L L^T == B + beta I  holds,
  * *interleaved histories*: random sequences of updates, downdates and
    sqrt(lambda) forgetting scalings keep  L L^T == B_live  (the decayed
    sample sum plus the decayed beta prior) within tolerance, in both the
    f64 packed oracle and the f32 transposed in-state form,
  * the downdate guard: an indefinite downdate raises in the numpy oracle
    and clamp-skips with an ``ok=False`` flag (finite, positive-diagonal
    factor) in the jax forms - never NaNs.

Randomized sweeps are hypothesis-driven (the CI property lane installs it);
without hypothesis the same checks run on a small deterministic seed grid,
so the battery never reduces to a silent skip.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import online, ridge
from repro.core.types import DFRConfig
from repro.kernels import ops

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback grid below still runs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="optional dep: hypothesis property sweeps"
)
SETTINGS = dict(max_examples=25, deadline=None)


def _spd(rng, s, scale, beta):
    """Random SPD B (f64) + its lower factor, condition set by beta."""
    R = rng.normal(size=(s, s + 4)) * scale
    B = R @ R.T + beta * np.eye(s)
    return B, np.linalg.cholesky(B)


def _safe_downdate_vector(B, x, margin=0.9):
    """Scale x so B - x x^T stays SPD: x^T B^{-1} x = margin^2 < 1."""
    gamma = float(x @ np.linalg.solve(B, x))
    return x * (margin / np.sqrt(gamma))


# ---------------------------------------------------------------------------
# Core checks (shared by the hypothesis sweeps and the deterministic grid)
# ---------------------------------------------------------------------------


def check_update_matches_refactorization(s, seed, scale, beta):
    rng = np.random.default_rng(seed)
    B, L = _spd(rng, s, scale, beta)
    x = rng.normal(size=s) * scale

    # packed numpy oracle (f64): the paper-shaped in-place sweep
    P = np.asarray(ridge.pack_lower(L))
    up = ridge.cholupdate_packed_numpy(P, x, s, 1.0)
    ref_up = ridge.pack_lower(np.linalg.cholesky(B + np.outer(x, x)))
    np.testing.assert_allclose(up, np.asarray(ref_up), rtol=1e-9, atol=1e-9)

    # downdate against re-factorization of B - x x^T (kept SPD)
    xd = _safe_downdate_vector(B, x)
    dn = ridge.cholupdate_packed_numpy(P, xd, s, -1.0)
    ref_dn = ridge.pack_lower(np.linalg.cholesky(B - np.outer(xd, xd)))
    np.testing.assert_allclose(dn, np.asarray(ref_dn), rtol=1e-7, atol=1e-9)


def check_downdate_after_update_roundtrips(s, seed, scale, beta):
    rng = np.random.default_rng(seed)
    B, L = _spd(rng, s, scale, beta)
    x = rng.normal(size=s) * scale
    P = np.asarray(ridge.pack_lower(L))
    there = ridge.cholupdate_packed_numpy(P, x, s, 1.0)
    back = ridge.cholupdate_packed_numpy(there, x, s, -1.0)
    np.testing.assert_allclose(back, P, rtol=1e-9, atol=1e-9)

    # the dense f32 form round-trips to f32 tolerance
    L32, x32 = jnp.asarray(L, jnp.float32), jnp.asarray(x, jnp.float32)
    scale_ref = float(np.abs(L).max())
    there32 = ridge.cholupdate_dense(L32, x32, 1.0)
    back32 = ridge.cholupdate_dense(there32, x32, -1.0)
    np.testing.assert_allclose(
        np.asarray(back32), np.asarray(L32),
        atol=5e-4 * max(1.0, scale_ref), rtol=5e-4)


def check_forms_agree(s, seed, scale, beta):
    """packed jax == packed numpy == dense == batched == Pallas interpret."""
    rng = np.random.default_rng(seed)
    B, L = _spd(rng, s, scale, beta)
    x = rng.normal(size=s) * scale

    # oracle, pushed to f32 for comparison with the jitted f32 forms
    oracle = ridge.cholupdate_packed_numpy(
        np.asarray(ridge.pack_lower(L)), x, s, 1.0)
    tol = dict(rtol=2e-4, atol=2e-4 * max(1.0, float(np.abs(oracle).max())))

    P32 = jnp.asarray(ridge.pack_lower(L), jnp.float32)
    x32 = jnp.asarray(x, jnp.float32)
    packed = ridge.cholupdate_packed_jax(P32, x32, s, 1.0)
    np.testing.assert_allclose(np.asarray(packed), oracle.astype(np.float32), **tol)

    L32 = jnp.asarray(L, jnp.float32)
    dense = ridge.cholupdate_dense(L32, x32, 1.0)
    np.testing.assert_allclose(
        np.asarray(ridge.pack_lower(np.asarray(dense))), oracle, **tol)

    # transposed in-state form: bit-identical to the lower sweep, transposed
    dense_t = ridge.cholupdate_dense_t(L32.T, x32, 1.0)
    np.testing.assert_array_equal(np.asarray(dense_t).T, np.asarray(dense))

    # batched form: every member equals the single-system sweep bit-for-bit
    k = 3
    Lb = jnp.stack([L32] * k)
    xb = jnp.asarray(rng.normal(size=(k, s)).astype(np.float32) * scale)
    got = ridge.cholupdate_dense_batched(Lb, xb, 1.0)
    for i in range(k):
        np.testing.assert_array_equal(
            np.asarray(got[i]), np.asarray(ridge.cholupdate_dense(L32, xb[i], 1.0)))

    # Pallas tile kernel (interpret mode), identity-padded to the 128 lane:
    # bit-identical to the jnp window sweep it adapts
    win = ops.cholupdate_window(L32, x32[None, :], sign=1.0, backend="interpret")
    np.testing.assert_array_equal(
        np.asarray(win), np.asarray(ridge.cholupdate_window(L32, x32[None, :], 1.0)))


def check_refresh_from_factor_matches_full(s, seed, scale, beta, ny=3, n_upd=6):
    """A factor maintained by n_upd rank-1 sweeps refreshes to the same W~
    as re-factorizing the accumulated B from scratch."""
    rng = np.random.default_rng(seed)
    L = np.sqrt(beta) * np.eye(s)        # seed_factor: empty system
    B = np.zeros((s, s))
    X = rng.normal(size=(n_upd, s)) * scale
    for t in range(n_upd):
        B = B + np.outer(X[t], X[t])
    A = rng.normal(size=(ny, s)) * scale

    L32 = ridge.cholupdate_window(
        jnp.asarray(L, jnp.float32), jnp.asarray(X, jnp.float32), 1.0)
    W_inc = ridge.ridge_solve_from_factor(jnp.asarray(A, jnp.float32), L32)
    W_full = ridge.ridge_cholesky_blocked(
        jnp.asarray(A, jnp.float32),
        jnp.asarray(B + beta * np.eye(s), jnp.float32))
    scale_w = max(1.0, float(jnp.max(jnp.abs(W_full))))
    np.testing.assert_allclose(
        np.asarray(W_inc), np.asarray(W_full), rtol=2e-3, atol=2e-3 * scale_w)

    # the transposed maintenance path (what the stream server runs):
    # window_t on U = L^T, then the plain / blocked batched substitutions
    U32 = ridge.cholupdate_window_t(
        jnp.asarray(L.T, jnp.float32), jnp.asarray(X, jnp.float32), 1.0)
    np.testing.assert_array_equal(np.asarray(U32).T, np.asarray(L32))
    W_t = ridge.ridge_solve_from_factor_t(jnp.asarray(A, jnp.float32), U32)
    np.testing.assert_allclose(
        np.asarray(W_t), np.asarray(W_full), rtol=2e-3, atol=2e-3 * scale_w)
    W_tb = ridge.ridge_solve_from_factor_t_batched(
        jnp.asarray(A, jnp.float32)[None], U32[None])[0]
    np.testing.assert_allclose(
        np.asarray(W_tb), np.asarray(W_full), rtol=2e-3, atol=2e-3 * scale_w)


def check_interleaved_history(s, seed, n_ops, scale, beta, lam):
    """Random update / downdate / sqrt(lambda)-scaling sequences preserve
    the live-factor invariant  L L^T == B_live  (B_live tracks the decayed
    sample sum *including* the decayed beta prior - the forgetting-factor
    semantics of ``online_serve_step``).

    Downdates only ever remove a row currently in the system (decayed in
    lockstep with it), as the sliding-window retirement does; a removal
    that would leave the f32 form too close to indefinite is deterministic-
    ally re-drawn as an update instead (the guard path has its own test).
    """
    rng = np.random.default_rng(seed)
    B_ref = beta * np.eye(s)                      # f64 live reference
    P = np.asarray(ridge.pack_lower(np.sqrt(beta) * np.eye(s)))  # oracle
    U32 = jnp.asarray(np.sqrt(beta) * np.eye(s), jnp.float32)    # in-state
    stored = []
    for _ in range(n_ops):
        op = int(rng.integers(0, 3))
        if op == 1:
            if not stored:
                op = 0
            else:
                x = stored.pop(int(rng.integers(0, len(stored))))
                # keep the f32 form clear of the downdate guard: only
                # remove rows whose relative mass leaves margin (< 0.9)
                if float(x @ np.linalg.solve(B_ref, x)) > 0.81:
                    stored.append(x)
                    op = 0
        if op == 0:                               # update with a fresh row
            x = rng.normal(size=s) * scale
            P = ridge.cholupdate_packed_numpy(P, x, s, 1.0)
            U32, ok = ridge.cholupdate_dense_t_guarded(
                U32, jnp.asarray(x, jnp.float32), 1.0)
            assert bool(ok)
            B_ref = B_ref + np.outer(x, x)
            stored.append(x)
        elif op == 1:                             # downdate the popped row
            P = ridge.cholupdate_packed_numpy(P, x, s, -1.0)
            U32, ok = ridge.cholupdate_dense_t_guarded(
                U32, jnp.asarray(x, jnp.float32), -1.0)
            assert bool(ok)
            B_ref = B_ref - np.outer(x, x)
        else:                                     # forgetting scaling
            root = np.sqrt(lam)
            P = P * root
            U32 = U32 * jnp.asarray(root, jnp.float32)
            B_ref = B_ref * lam
            stored = [v * root for v in stored]

    L = np.zeros((s, s))          # unpack in f64 (jnp would downcast)
    L[np.tril_indices(s)] = P
    mag = max(1.0, float(np.abs(B_ref).max()))
    np.testing.assert_allclose(L @ L.T, B_ref, rtol=1e-8, atol=1e-8 * mag)
    U = np.asarray(U32)
    np.testing.assert_allclose(U.T @ U, B_ref, rtol=3e-3, atol=3e-3 * mag)
    # the factor stayed triangular with a strictly positive diagonal (SPD)
    assert np.all(np.diag(U) > 0)
    assert np.all(np.isfinite(U))


def check_downdate_guard(s, seed, scale, beta):
    """An indefinite downdate (x^T B^{-1} x > 1) raises in the numpy
    oracle and clamp-skips with ok=False in every jax form - the factor
    stays finite, triangular, positive-diagonal; no NaNs anywhere."""
    rng = np.random.default_rng(seed)
    B, L = _spd(rng, s, scale, beta)
    x = _safe_downdate_vector(B, rng.normal(size=s) * scale, margin=1.05)

    with pytest.raises(np.linalg.LinAlgError):
        ridge.cholupdate_packed_numpy(
            np.asarray(ridge.pack_lower(L)), x, s, -1.0)

    L32 = jnp.asarray(L, jnp.float32)
    x32 = jnp.asarray(x, jnp.float32)
    got, ok = ridge.cholupdate_dense_guarded(L32, x32, -1.0)
    assert not bool(ok)
    got = np.asarray(got)
    assert np.all(np.isfinite(got))
    assert np.all(np.diag(got) > 0)
    # the unflagged dense form clamps identically (documented, not NaN)
    np.testing.assert_array_equal(
        np.asarray(ridge.cholupdate_dense(L32, x32, -1.0)), got)
    # transposed in-state form: same clamp, transposed bit-for-bit
    got_t, ok_t = ridge.cholupdate_dense_t_guarded(L32.T, x32, -1.0)
    assert not bool(ok_t)
    np.testing.assert_array_equal(np.asarray(got_t).T, got)
    # packed jitted form clamps to the same finite factor
    packed = ridge.cholupdate_packed_jax(
        jnp.asarray(ridge.pack_lower(L), jnp.float32), x32, s, -1.0)
    np.testing.assert_array_equal(
        np.asarray(ridge.unpack_lower(packed, s)), np.tril(got))
    # Pallas tile kernel (interpret): same guard, bit-parity with the
    # jnp window sweep, both signs dispatched through one kernel
    win = ops.cholupdate_window(
        L32, x32[None, :], sign=-1.0, backend="interpret")
    np.testing.assert_array_equal(
        np.asarray(win),
        np.asarray(ridge.cholupdate_window(L32, x32[None, :], -1.0)))
    assert np.all(np.isfinite(np.asarray(win)))


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @given(s=st.integers(2, 24), seed=st.integers(0, 10_000),
           scale=st.floats(0.1, 3.0), beta=st.floats(1e-3, 10.0))
    @settings(**SETTINGS)
    def test_update_matches_refactorization(s, seed, scale, beta):
        check_update_matches_refactorization(s, seed, scale, beta)

    @needs_hypothesis
    @given(s=st.integers(2, 24), seed=st.integers(0, 10_000),
           scale=st.floats(0.1, 3.0), beta=st.floats(1e-3, 10.0))
    @settings(**SETTINGS)
    def test_downdate_after_update_roundtrips(s, seed, scale, beta):
        check_downdate_after_update_roundtrips(s, seed, scale, beta)

    @needs_hypothesis
    @given(s=st.integers(2, 20), seed=st.integers(0, 10_000),
           scale=st.floats(0.2, 2.0), beta=st.floats(1e-2, 10.0))
    @settings(max_examples=10, deadline=None)  # includes the Pallas interpret run
    def test_all_forms_agree(s, seed, scale, beta):
        check_forms_agree(s, seed, scale, beta)

    @needs_hypothesis
    @given(s=st.integers(4, 24), seed=st.integers(0, 10_000),
           scale=st.floats(0.2, 2.0), beta=st.floats(1e-2, 1.0))
    @settings(**SETTINGS)
    def test_refresh_from_factor_matches_full(s, seed, scale, beta):
        check_refresh_from_factor_matches_full(s, seed, scale, beta)

    @needs_hypothesis
    @given(s=st.integers(3, 16), seed=st.integers(0, 10_000),
           n_ops=st.integers(4, 16), scale=st.floats(0.3, 2.0),
           beta=st.floats(1e-2, 1.0), lam=st.floats(0.7, 1.0))
    @settings(**SETTINGS)
    def test_interleaved_history(s, seed, n_ops, scale, beta, lam):
        check_interleaved_history(s, seed, n_ops, scale, beta, lam)

    @needs_hypothesis
    @given(s=st.integers(3, 16), seed=st.integers(0, 10_000),
           scale=st.floats(0.3, 2.0), beta=st.floats(1e-2, 1.0))
    @settings(max_examples=10, deadline=None)  # includes a Pallas interpret run
    def test_downdate_guard(s, seed, scale, beta):
        check_downdate_guard(s, seed, scale, beta)


# ---------------------------------------------------------------------------
# Deterministic grid (runs with or without hypothesis)
# ---------------------------------------------------------------------------

GRID = [(5, 0, 1.0, 1e-2), (12, 1, 0.3, 1e-1), (21, 2, 2.0, 1.0)]


@pytest.mark.parametrize("s,seed,scale,beta", GRID)
def test_update_matches_refactorization_grid(s, seed, scale, beta):
    check_update_matches_refactorization(s, seed, scale, beta)


@pytest.mark.parametrize("s,seed,scale,beta", GRID)
def test_downdate_after_update_roundtrips_grid(s, seed, scale, beta):
    check_downdate_after_update_roundtrips(s, seed, scale, beta)


@pytest.mark.parametrize("s,seed,scale,beta", GRID)
def test_all_forms_agree_grid(s, seed, scale, beta):
    check_forms_agree(s, seed, scale, beta)


@pytest.mark.parametrize("s,seed,scale,beta", GRID)
def test_refresh_from_factor_matches_full_grid(s, seed, scale, beta):
    check_refresh_from_factor_matches_full(s, seed, scale, beta)


INTERLEAVED_GRID = [
    (5, 0, 12, 1.0, 1e-2, 0.9), (9, 1, 16, 0.5, 1e-1, 0.75),
    (13, 2, 10, 2.0, 1.0, 1.0), (7, 3, 16, 0.8, 5e-2, 0.95),
]


@pytest.mark.parametrize("s,seed,n_ops,scale,beta,lam", INTERLEAVED_GRID)
def test_interleaved_history_grid(s, seed, n_ops, scale, beta, lam):
    check_interleaved_history(s, seed, n_ops, scale, beta, lam)


@pytest.mark.parametrize("s,seed,scale,beta", GRID)
def test_downdate_guard_grid(s, seed, scale, beta):
    check_downdate_guard(s, seed, scale, beta)


def test_window_decay_fold_matches_sequential_and_ones_is_identity():
    """``cholupdate_window_t_decay``: per-row factor pre-scaling equals the
    explicit scale-then-rotate sequence; an all-ones scale vector is
    bit-for-bit ``cholupdate_window_t`` (the lambda=1 contract)."""
    rng = np.random.default_rng(11)
    s = 13
    _, L = _spd(rng, s, 1.0, 0.1)
    U = jnp.asarray(L.T, jnp.float32)
    X = jnp.asarray(rng.normal(size=(4, s)).astype(np.float32) * 0.5)
    X = X.at[1].set(0.0)  # a gated row: its scale must be 1.0 (no decay)
    scales = jnp.asarray([0.95, 1.0, 0.9, 0.95], jnp.float32) ** 0.5

    got = ridge.cholupdate_window_t_decay(U, X, scales)
    want = U
    for t in range(4):
        want = ridge.cholupdate_dense_t(want * scales[t], X[t], 1.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    ones = jnp.ones((4,), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ridge.cholupdate_window_t_decay(U, X, ones)),
        np.asarray(ridge.cholupdate_window_t(U, X)))


def test_soft_reset_scales_statistics_consistently():
    """``reset_statistics(forget=lam)`` scales (A, B, Lt, factor_beta) in
    lockstep: the live-factor invariant survives, and lam=1.0 is the exact
    identity."""
    cfg = DFRConfig(n_in=2, n_classes=3, n_nodes=5)
    from repro.core import masking

    mask = masking.make_mask(jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes,
                             cfg.n_in, cfg.dtype)
    beta = 0.1
    state = online.init_state(cfg, factor_beta=beta)
    rng = np.random.default_rng(5)
    u = jnp.asarray(rng.normal(size=(4, 8, 2)).astype(np.float32))
    ln = jnp.asarray(rng.integers(3, 9, 4), jnp.int32)
    lab = jnp.asarray(rng.integers(0, 3, 4), jnp.int32)
    w = jnp.ones((4,), jnp.float32)
    state, _, _ = online.online_serve_step(
        cfg, mask, state, u, ln, lab, jnp.float32(0.1), w,
        jnp.float32(1.0), maintain_factor=True)

    lam = 0.8
    soft = online.reset_statistics(state, forget=lam)
    np.testing.assert_allclose(np.asarray(soft.ridge.A),
                               lam * np.asarray(state.ridge.A), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(soft.ridge.B),
                               lam * np.asarray(state.ridge.B), rtol=1e-6)
    lhs = np.asarray(soft.ridge.Lt.T @ soft.ridge.Lt)
    rhs = np.asarray(soft.ridge.B) + float(soft.ridge.factor_beta) * np.eye(cfg.s)
    np.testing.assert_allclose(lhs, rhs, rtol=5e-4,
                               atol=5e-4 * max(1.0, np.abs(rhs).max()))
    assert float(soft.ridge.factor_beta) == pytest.approx(lam * beta)
    assert int(soft.ridge.count) == int(state.ridge.count)

    ident = online.reset_statistics(state, forget=1.0)
    for a, b in zip(jax.tree_util.tree_leaves(ident),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # misuse is loud: lambda outside (0, 1] would NaN the next maintained
    # fold (zeroed factor diagonal), and the hard/soft resets are exclusive
    with pytest.raises(ValueError):
        online.reset_statistics(state, forget=0.0)
    with pytest.raises(ValueError):
        online.reset_statistics(state, factor_beta=beta, forget=0.9)


def test_window_equals_sequential_singles_and_zero_rows_noop():
    rng = np.random.default_rng(7)
    s = 17
    _, L = _spd(rng, s, 1.0, 0.1)
    L32 = jnp.asarray(L, jnp.float32)
    X = jnp.asarray(rng.normal(size=(5, s)).astype(np.float32) * 0.5)
    X = X.at[2].set(0.0)  # a gated (dead/tail) sample inside the window
    got = ridge.cholupdate_window(L32, X, 1.0)
    want = L32
    for t in range(5):
        if t != 2:
            want = ridge.cholupdate_dense(want, X[t], 1.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the all-zero window is the exact identity
    Z = jnp.zeros((4, s), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ridge.cholupdate_window(L32, Z, 1.0)), np.asarray(L32))


def test_serve_step_maintains_factor_invariant():
    """online_serve_step(maintain_factor=True): after any mix of live/dead
    samples and adaptation/frozen phases,  L L^T == B + beta I  holds."""
    cfg = DFRConfig(n_in=2, n_classes=3, n_nodes=6)
    from repro.core import masking

    mask = masking.make_mask(jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes,
                             cfg.n_in, cfg.dtype)
    beta = 0.05
    state = online.init_state(cfg, factor_beta=beta)
    rng = np.random.default_rng(0)
    for i in range(4):
        u = jnp.asarray(rng.normal(size=(3, 10, 2)).astype(np.float32))
        ln = jnp.asarray(rng.integers(4, 11, 3), jnp.int32)
        lab = jnp.asarray(rng.integers(0, 3, 3), jnp.int32)
        w = jnp.asarray(rng.integers(0, 2, 3).astype(np.float32))
        acc = jnp.asarray(float(i > 0))  # step 0: adaptation phase (gated)
        state, _, _ = online.online_serve_step(
            cfg, mask, state, u, ln, lab, jnp.float32(0.1), w, acc,
            maintain_factor=True)
    lhs = np.asarray(state.ridge.Lt.T @ state.ridge.Lt)
    rhs = np.asarray(state.ridge.B + beta * jnp.eye(cfg.s, dtype=cfg.dtype))
    np.testing.assert_allclose(lhs, rhs, rtol=5e-4,
                               atol=5e-4 * max(1.0, np.abs(rhs).max()))
    assert float(state.ridge.factor_beta) == pytest.approx(beta)

    # refresh_output takes the fast path for the seeded beta and agrees
    # with the full re-factorization to solver tolerance
    fast = online.refresh_output(state, jnp.asarray(beta, cfg.dtype))
    import dataclasses
    dead = dataclasses.replace(
        state, ridge=dataclasses.replace(
            state.ridge, factor_beta=jnp.zeros_like(state.ridge.factor_beta)))
    full = online.refresh_output(dead, jnp.asarray(beta, cfg.dtype))
    np.testing.assert_allclose(np.asarray(fast.params.W),
                               np.asarray(full.params.W), rtol=2e-3, atol=2e-4)

    # a different beta must NOT use the live factor: it re-factorizes
    other = online.refresh_output(state, jnp.asarray(10.0 * beta, cfg.dtype))
    ref = online.refresh_output(dead, jnp.asarray(10.0 * beta, cfg.dtype))
    np.testing.assert_array_equal(np.asarray(other.params.W),
                                  np.asarray(ref.params.W))
