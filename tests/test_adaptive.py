"""Adaptive retirement (in-step drift detector) + warm-pool autotuner.

Contracts under test:

  * ``retirement='adaptive'``: a per-slot DDM-style error-rate detector
    inside the fused stream step anneals a tripped slot's Ridge statistics
    by the traced forget vector.  A detector that never fires leaves the
    episode BITWISE identical to ``retirement='none'`` (the anneal is
    cond-gated; only the two detector EMA leaves move) - across device
    staging, step blocking and int8 serving.  On the shared drift fixture
    it recovers post-switch accuracy without being told the drift point.
  * ``online.adaptive_anneal``: trip semantics (update/armed/init gating,
    slow-baseline re-arm), the anneal's ``Lt^T Lt == B + factor_beta I``
    preservation, and the high-ratio silence guarantee.
  * ``WarmPoolAutotuner``: background (p, q, beta) re-optimization on
    recent retained windows; hot swaps beat a deliberately bad
    hyperparameter init, keep the incremental factor invariant intact,
    and a tuner that never swaps is a bitwise no-op.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import online
from repro.core.types import DFRConfig
from repro.data import drift_segment_bounds, make_drift_label_streams
from repro.runtime import StreamRequest, StreamServer, WarmPoolAutotuner

NDEV = jax.device_count()
needs_devices = pytest.mark.skipif(
    NDEV < 8, reason="needs 8 XLA devices (REPRO_FORCE_DEVICES=8)"
)

CFG = DFRConfig(n_in=2, n_classes=3, n_nodes=8)


def _make_stream(rid, n, t=16, seed=0, n_in=2, n_classes=3):
    rng = np.random.default_rng(seed + rid)
    return StreamRequest(
        rid=rid,
        u=rng.normal(size=(n, t, n_in)).astype(np.float32),
        length=rng.integers(4, t + 1, n).astype(np.int32),
        label=rng.integers(0, n_classes, n).astype(np.int32),
    )


def _drift_requests(n_streams=4, n=160, t=16, n_classes=4, seed=0):
    arrays, switches = make_drift_label_streams(n_streams, n, t, n_classes,
                                                seed=seed)
    return ([StreamRequest(rid=r, **a) for r, a in enumerate(arrays)],
            switches)


def _run(streams, **kw):
    srv = StreamServer(**kw)
    for r in streams:
        srv.submit(r)
    srv.run_until_drained()
    return srv


def _all_preds(srv):
    done = sorted(srv.sched.completed, key=lambda r: r.rid)
    return np.concatenate([np.asarray(r.preds) for r in done])


def _state_leaves(srv):
    done = sorted(srv.sched.completed, key=lambda r: r.rid)
    return [np.asarray(leaf) for r in done
            for leaf in jax.tree_util.tree_leaves(
                dataclasses.replace(r.final_state,
                                    loss_fast=jnp.zeros(()),
                                    loss_slow=jnp.zeros(())))]


# ---------------------------------------------------------------------------
# Silence contract: a never-firing detector is bitwise 'none'
# ---------------------------------------------------------------------------


SILENCE_MODES = (
    ("plain", {}),
    ("blocked", {"step_block": 4}),
    ("int8", {"quantize": "int8"}),
    ("host", {"staging": "host"}),
)


@pytest.mark.parametrize("name,extra", SILENCE_MODES, ids=[m[0] for m in
                                                           SILENCE_MODES])
def test_adaptive_silent_is_bitwise_none(name, extra):
    """With a ratio no bounded error rate can reach (the slow-EMA floor
    guarantees ratio * slow >= ratio * eps > 1 for huge ratios), adaptive
    mode must reproduce retirement='none' bit for bit - predictions AND
    final states (detector EMA leaves excepted, the only ones allowed to
    move)."""
    kw = dict(cfg=CFG, t_max=16, max_streams=4, window=4, phase_steps=2,
              refresh_every=3, refresh_mode="incremental", **extra)
    streams = [_make_stream(r, 24 + 4 * r) for r in range(5)]
    base = _run(streams, retirement="none", **kw)
    streams = [_make_stream(r, 24 + 4 * r) for r in range(5)]
    adap = _run(streams, retirement="adaptive", adapt_ratio=1e9, **kw)
    np.testing.assert_array_equal(_all_preds(base), _all_preds(adap))
    for a, b in zip(_state_leaves(base), _state_leaves(adap)):
        np.testing.assert_array_equal(a, b)


@needs_devices
def test_adaptive_silent_is_bitwise_none_sharded():
    kw = dict(cfg=CFG, t_max=16, max_streams=8, window=4, phase_steps=2,
              refresh_every=3, refresh_mode="incremental", devices=8)
    streams = [_make_stream(r, 24 + 4 * r) for r in range(10)]
    base = _run(streams, retirement="none", **kw)
    streams = [_make_stream(r, 24 + 4 * r) for r in range(10)]
    adap = _run(streams, retirement="adaptive", adapt_ratio=1e9, **kw)
    np.testing.assert_array_equal(_all_preds(base), _all_preds(adap))
    for a, b in zip(_state_leaves(base), _state_leaves(adap)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Drift recovery (the detector is never told lambda, the window, or the
# switch point - it must find the drift on its own)
# ---------------------------------------------------------------------------


def test_adaptive_recovers_from_drift():
    streams, switches = _drift_requests()
    kw = dict(cfg=DFRConfig(n_in=1, n_classes=4, n_nodes=8), t_max=16,
              max_streams=4, window=4, phase_steps=2, refresh_every=3,
              refresh_mode="incremental")
    base = _run(streams, retirement="none", **kw)
    streams, _ = _drift_requests()
    adap = _run(streams, retirement="adaptive", **kw)

    def post_acc(srv):
        accs = []
        for req in sorted(srv.sched.completed, key=lambda r: r.rid):
            (_, _), (_, _), (lo, hi) = drift_segment_bounds(
                req.n_samples, switches[req.rid], 4)
            accs.append((np.asarray(req.preds[lo:hi])
                         == req.label[lo:hi]).mean())
        return float(np.mean(accs))

    # the anneal must clearly beat the frozen-statistics baseline after
    # the switch (hand-tuned forget/window land at ~0.52-0.56 vs ~0.33
    # frozen on this fixture; the untold detector must reach that band)
    assert post_acc(adap) > post_acc(base) + 0.10


# ---------------------------------------------------------------------------
# adaptive_anneal unit semantics
# ---------------------------------------------------------------------------


def _stacked_state(k=4, beta=0.25, seed=0):
    """Slot-batched state with non-trivial, invariant-satisfying stats."""
    cfg = CFG
    rng = np.random.default_rng(seed)
    single = online.init_state(cfg, factor_beta=beta)
    st = jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (k, *leaf.shape)).copy(), single)
    s = single.ridge.B.shape[-1]
    R = rng.normal(size=(k, 3, s)).astype(np.float32)
    B = jnp.asarray(np.einsum("kbs,kbt->kst", R, R))
    A = jnp.asarray(rng.normal(size=st.ridge.A.shape).astype(np.float32))
    Lt = jnp.linalg.cholesky(
        B + beta * jnp.eye(s)).transpose(0, 2, 1)
    ridge_state = dataclasses.replace(
        st.ridge, A=A, B=B, Lt=Lt, count=jnp.full((k,), 7, jnp.int32))
    return dataclasses.replace(st, ridge=ridge_state)


def test_adaptive_anneal_trip_semantics():
    k = 4
    st = _stacked_state(k)
    st = dataclasses.replace(
        st,
        loss_fast=jnp.asarray([0.1, 0.1, 0.8, 0.8], jnp.float32),
        loss_slow=jnp.asarray([0.1, 0.1, 0.1, 0.1], jnp.float32),
    )
    update = jnp.asarray([True, True, True, True])
    armed = jnp.asarray([True, True, True, False])
    step_err = jnp.asarray([0.1, 0.1, 0.9, 0.9], jnp.float32)
    out, trip = online.adaptive_anneal(st, step_err, update, armed,
                                       ratio=1.2, forget=0.1)
    trip = np.asarray(trip)
    # slot 2: fast EMA far above ratio*slow+margin -> trips; slot 3 is
    # identical but un-armed; slots 0/1 are stationary
    assert list(trip) == [False, False, True, False]
    lam = np.where(trip, 0.1, 1.0)
    np.testing.assert_allclose(np.asarray(out.ridge.A),
                               np.asarray(st.ridge.A) * lam[:, None, None],
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out.ridge.B),
                               np.asarray(st.ridge.B) * lam[:, None, None],
                               rtol=1e-6)
    # count survives (the anneal is soft: sample history is discounted,
    # not forgotten)
    np.testing.assert_array_equal(np.asarray(out.ridge.count),
                                  np.asarray(st.ridge.count))
    # the annealed factor still satisfies Lt^T Lt == B + factor_beta I
    s = np.asarray(st.ridge.B).shape[-1]
    for i in range(k):
        Lt = np.asarray(out.ridge.Lt)[i]
        np.testing.assert_allclose(
            Lt.T @ Lt,
            np.asarray(out.ridge.B)[i]
            + np.asarray(out.ridge.factor_beta)[i] * np.eye(s),
            rtol=1e-4, atol=1e-5)
    # tripping re-arms: the slow baseline snaps to the fast EMA
    assert np.asarray(out.loss_slow)[2] == np.asarray(out.loss_fast)[2]


def test_adaptive_anneal_first_update_seeds_and_never_trips():
    st = _stacked_state(2)   # loss EMAs start at zero -> init step
    update = jnp.asarray([True, False])
    armed = jnp.asarray([True, True])
    step_err = jnp.asarray([0.9, 0.9], jnp.float32)
    out, trip = online.adaptive_anneal(st, step_err, update, armed,
                                       ratio=1.2, forget=0.1)
    assert not np.asarray(trip).any()
    # seeded slot takes the observed error; non-updated slot is untouched
    assert np.asarray(out.loss_fast)[0] == pytest.approx(0.9)
    assert np.asarray(out.loss_slow)[0] == pytest.approx(0.9)
    assert np.asarray(out.loss_fast)[1] == 0.0
    # silent step: ridge is bit-for-bit untouched
    for a, b in zip(jax.tree_util.tree_leaves(st.ridge),
                    jax.tree_util.tree_leaves(out.ridge)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adaptive_validation():
    kw = dict(cfg=CFG, t_max=16, max_streams=2, window=4)
    with pytest.raises(ValueError):
        StreamServer(retirement="bogus", **kw)
    with pytest.raises(ValueError):
        StreamServer(retirement="adaptive", adapt_forget=0.0, **kw)
    with pytest.raises(ValueError):
        StreamServer(retirement="adaptive", adapt_forget=1.5, **kw)
    with pytest.raises(ValueError):
        StreamServer(retirement="adaptive", adapt_ratio=1.0, **kw)
    with pytest.raises(ValueError):
        StreamServer(retirement="adaptive", adapt_warmup=-1, **kw)


# ---------------------------------------------------------------------------
# Warm-pool autotuner
# ---------------------------------------------------------------------------

# deliberately bad hyperparameter init: far from the NARMA-friendly region
BAD_CFG = DFRConfig(n_in=1, n_classes=4, n_nodes=16, p_init=0.5, q_init=0.5)
TUNER_SERVER_KW = dict(cfg=BAD_CFG, t_max=16, max_streams=4, window=4,
                       refresh_mode="incremental", refresh_every=5,
                       refresh_cohorts=2)


def _tuned_run(tuner_kw=None, devices=1, seed=0):
    streams, _ = _drift_requests(seed=seed)
    srv = StreamServer(devices=devices, **TUNER_SERVER_KW)
    if tuner_kw is not None:
        srv.attach_autotuner(WarmPoolAutotuner(srv, **tuner_kw))
    for r in streams:
        srv.submit(r)
    srv.run_until_drained()
    acc = np.mean([(np.asarray(r.preds) == r.label).mean()
                   for r in srv.sched.completed])
    return srv, float(acc)


def test_autotuner_improves_bad_init_and_keeps_invariant():
    srv0, acc0 = _tuned_run(None)
    srv1, acc1 = _tuned_run(dict(population=8, history=32, interval=2,
                                 margin=0.02, seed=1))
    stats = srv1._autotuner.stats()
    assert stats["swaps_applied"] > 0
    assert acc1 > acc0 + 0.03
    # the incremental-factor invariant must survive every hot swap: check
    # every slot of the live server state (swapped or not)
    rs = jax.device_get(srv1.states.ridge)
    s = rs.B.shape[-1]
    for i in range(rs.B.shape[0]):
        np.testing.assert_allclose(
            rs.Lt[i].T @ rs.Lt[i],
            rs.B[i] + rs.factor_beta[i] * np.eye(s),
            rtol=2e-3, atol=2e-3)
    # swapped slots must have moved off the bad (p, q) anchor somewhere
    done = sorted(srv1.sched.completed, key=lambda r: r.rid)
    ps = np.asarray([float(r.final_state.params.p) for r in done])
    qs = np.asarray([float(r.final_state.params.q) for r in done])
    assert ((ps != BAD_CFG.p_init) | (qs != BAD_CFG.q_init)).any()


def test_autotuner_never_swapping_is_bitwise_noop():
    """margin=10 demands an 11x NRMSE win - unreachable, so the tuner only
    *reads* server state and the episode must be bit-for-bit unchanged."""
    srv0, _ = _tuned_run(None)
    srv2, _ = _tuned_run(dict(population=8, history=32, interval=2,
                              margin=10.0, seed=1))
    assert srv2._autotuner.stats()["swaps_applied"] == 0
    assert srv2._autotuner.stats()["rounds_run"] > 0
    np.testing.assert_array_equal(_all_preds(srv0), _all_preds(srv2))
    for a, b in zip(_state_leaves(srv0), _state_leaves(srv2)):
        np.testing.assert_array_equal(a, b)


@needs_devices
def test_autotuner_sharded_matches_unsharded():
    """Slot sharding must not perturb the tuner: evaluation inputs are
    bitwise equal (the PR-6 parity contract), so the same swaps fire and
    the tuned episodes match exactly."""
    srv1, acc1 = _tuned_run(dict(population=8, history=32, interval=2,
                                 margin=0.02, seed=1))
    srv8, acc8 = _tuned_run(dict(population=8, history=32, interval=2,
                                 margin=0.02, seed=1), devices=8)
    assert (srv8._autotuner.stats()["swaps_applied"]
            == srv1._autotuner.stats()["swaps_applied"])
    np.testing.assert_array_equal(_all_preds(srv1), _all_preds(srv8))


def test_autotuner_validation():
    srv = StreamServer(**TUNER_SERVER_KW)
    other = StreamServer(**TUNER_SERVER_KW)
    with pytest.raises(ValueError):
        srv.attach_autotuner(WarmPoolAutotuner(other))
    with pytest.raises(ValueError):
        WarmPoolAutotuner(srv, population=1)
    with pytest.raises(ValueError):
        WarmPoolAutotuner(srv, history=4)
    with pytest.raises(ValueError):
        WarmPoolAutotuner(srv, val_frac=1.0)
