"""Reservoir forward: GEMM closed form == paper-faithful per-node loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reservoir as res


@pytest.mark.parametrize("q", [0.0, 0.3, -0.4, 0.95])
def test_ring_matrix_closed_form(q):
    n = 6
    L = np.asarray(res.ring_matrix(jnp.float32(q), n))
    for i in range(n):
        for j in range(n):
            expect = q ** (i - j) if i >= j else 0.0
            assert np.allclose(L[i, j], expect, atol=1e-6), (i, j)


@pytest.mark.parametrize("f_name", ["linear", "tanh", "mg"])
def test_gemm_step_matches_naive(f_name):
    f = {
        "linear": lambda z: z,
        "tanh": jnp.tanh,
        "mg": lambda z: z / (1 + jnp.abs(z) ** 2),
    }[f_name]
    key = jax.random.PRNGKey(0)
    nx, t = 9, 13
    j_seq = jax.random.normal(key, (t, nx))
    p, q = jnp.float32(0.2), jnp.float32(0.55)
    xp = jnp.zeros(nx)
    naive = []
    for k in range(t):
        xp = res.reservoir_step_naive(p, q, f, j_seq[k], xp)
        naive.append(xp)
    naive = jnp.stack(naive)
    gemm = res.run_reservoir(p, q, j_seq, f=f)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(gemm), rtol=2e-5,
                               atol=2e-6)


def test_batched_matches_single():
    key = jax.random.PRNGKey(1)
    j = jax.random.normal(key, (4, 11, 7))
    p, q = jnp.float32(0.1), jnp.float32(0.4)
    batched = res.run_reservoir(p, q, j, f=jnp.tanh)
    for b in range(4):
        single = res.run_reservoir(p, q, j[b], f=jnp.tanh)
        np.testing.assert_allclose(np.asarray(batched[b]), np.asarray(single),
                                   rtol=1e-5, atol=1e-6)


def test_lengths_freeze_state():
    key = jax.random.PRNGKey(2)
    j = jax.random.normal(key, (2, 10, 5))
    lengths = jnp.asarray([4, 10], jnp.int32)
    x = res.run_reservoir(jnp.float32(0.2), jnp.float32(0.3), j, f=jnp.tanh,
                          lengths=lengths)
    # after t >= length the state must stay frozen at x(T)
    np.testing.assert_allclose(np.asarray(x[0, 3]), np.asarray(x[0, 9]))
    assert not np.allclose(np.asarray(x[1, 3]), np.asarray(x[1, 9]))


def test_legacy_digital_dfr_runs():
    key = jax.random.PRNGKey(3)
    j = jax.random.normal(key, (12, 6))
    f = lambda x, jj: 0.8 * (x + jj) / (1 + jnp.abs(x + jj) ** 2)
    x = res.run_reservoir_legacy(jnp.float32(0.8), jnp.float32(1.0), 0.2, j, f)
    assert x.shape == (12, 6)
    assert bool(jnp.all(jnp.isfinite(x)))
