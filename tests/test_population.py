"""Population-parallel hyperparameter engine (repro.core.population).

Covers the ISSUE 1 acceptance checklist:
  * grid-seeded population with zero refinement reproduces the serial
    grid-search ranking (bit-for-bit accs via the primal solver),
  * refined population achieves NRMSE <= the best grid point on NARMA10,
plus the engine's moving parts (dual/primal solver agreement, culling
semantics, vmapped refinement vs a per-member loop, the grid_search shim,
and the runtime wrapper).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backprop, masking, population
from repro.core.grid_search import _eval_pq, grid_search, grid_search_serial
from repro.core.types import DFRConfig, DFRParams
from repro.data import load, make_narma10


@pytest.fixture(scope="module")
def cls_setup():
    train, test = load("JPVOW", size_cap=36)
    cfg = DFRConfig(n_in=12, n_classes=9, n_nodes=8)
    mask = masking.make_mask(
        jax.random.PRNGKey(cfg.mask_seed), cfg.n_nodes, cfg.n_in, cfg.dtype
    )
    return cfg, mask, train, test


@pytest.fixture(scope="module")
def narma():
    return make_narma10(n_train=120, n_test=60, t_len=24, seed=0)


def _onehots(cfg, train, test):
    return (jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype),
            jax.nn.one_hot(test.label, cfg.n_classes, dtype=cfg.dtype))


# ---------------------------------------------------------------------------
# Grid parity (zero refinement == serial grid search)
# ---------------------------------------------------------------------------


def test_zero_refinement_reproduces_serial_grid_ranking(cls_setup):
    """Primal-solver evaluate over grid seeds == the serial per-candidate
    sweep: same (K, n_beta) accuracy table, hence the same ranking.

    Betas are restricted to values where the float32 primal factorization is
    numerically healthy for this rank-deficient fixture (n_train < s): in
    degenerate cells both paths produce garbage, and *different* garbage
    (batched vs single LAPACK), so there is no ranking to reproduce there.
    Tolerances are calibrated per beta column (see inline comments); the
    beta=1e-2 column is additionally subject to run-to-run threaded-
    reduction nondeterminism amplified by the near-singular factorization.

    Flake protocol (ROADMAP note, hardened in PR 3): if the noisy-column
    checks trip, the whole evaluation is rerun once on the same
    deterministic inputs and BOTH attempts are dumped to an .npz artifact.
    A rerun that passes means the trip was run-to-run threaded-reduction
    noise (diagnosable from the artifact, not a red lane); only a
    *reproducible* disagreement fails.
    """
    import dataclasses
    import os
    import tempfile
    import warnings

    cfg, mask, train, test = cls_setup
    cfg = dataclasses.replace(cfg, betas=(1e-2, 1e0))
    divs = 3
    ps, qs = population.grid_candidates(divs, dtype=cfg.dtype)
    y_tr, y_ev = _onehots(cfg, train, test)
    eval_j = jax.jit(lambda p, q: _eval_pq(cfg, mask, p, q, train, test, cfg.betas))

    def evaluate():
        ev = population.evaluate_population(
            cfg, mask, ps, qs, train.u, train.length, y_tr,
            test.u, test.length, y_ev, select="acc", solver="primal",
        )
        accs_serial = np.stack(
            [np.asarray(eval_j(ps[i], qs[i])[0]) for i in range(ps.shape[0])]
        )
        return np.asarray(ev.acc_all), accs_serial, np.asarray(ev.beta_idx)

    one_sample = 1.0 / test.batch

    def check(acc_pop, accs_serial, beta_idx):
        # cell-by-cell agreement, column-calibrated: at beta=1e0 the (s, s)
        # system is well regularized and at most one borderline sample
        # flips from float reassociation; at beta=1e-2 the rank-deficient
        # float32 factorization amplifies reduction-order noise (including
        # run-to-run threaded-reduction nondeterminism) by several samples,
        # so that column gets a correspondingly wider - but still tight -
        # band (6 samples; was 4 before the ROADMAP-noted trips)
        np.testing.assert_allclose(accs_serial[:, 1], acc_pop[:, 1],
                                   atol=one_sample + 1e-7)
        np.testing.assert_allclose(accs_serial[:, 0], acc_pop[:, 0],
                                   atol=6 * one_sample + 1e-7)
        # and the induced ranking agrees: same winning-cell value, same
        # winner best-beta per member wherever the margin is decisive
        # (beyond the noisy column's band)
        assert np.max(acc_pop) == pytest.approx(np.max(accs_serial),
                                                abs=2 * one_sample)
        top2 = np.sort(accs_serial.ravel())[-2:]
        if top2[1] - top2[0] > 6 * one_sample:  # winner decisive: same cell
            assert np.unravel_index(np.argmax(acc_pop), acc_pop.shape) == \
                np.unravel_index(np.argmax(accs_serial), accs_serial.shape)
        margins = np.abs(accs_serial[:, 0] - accs_serial[:, 1])
        decisive = margins > 7 * one_sample + 1e-7
        np.testing.assert_array_equal(
            np.argmax(accs_serial, axis=1)[decisive], beta_idx[decisive])

    first = evaluate()
    try:
        check(*first)
        return
    except AssertionError as trip:
        # deterministic-seed rerun: same inputs, fresh reductions
        second = evaluate()
        art_dir = os.environ.get("PYTEST_ARTIFACT_DIR", tempfile.gettempdir())
        path = os.path.join(art_dir, "population_grid_parity_trip.npz")
        np.savez(
            path,
            acc_pop_1=first[0], accs_serial_1=first[1], beta_idx_1=first[2],
            acc_pop_2=second[0], accs_serial_2=second[1], beta_idx_2=second[2],
            one_sample=one_sample,
        )
        try:
            check(*second)
        except AssertionError as again:
            raise AssertionError(
                f"grid-parity disagreement reproduced on the deterministic "
                f"rerun (both attempts dumped to {path}): {again}"
            ) from trip
        warnings.warn(
            f"grid-parity check tripped once and passed on the "
            f"deterministic rerun - run-to-run threaded-reduction noise; "
            f"both attempts dumped to {path} (first trip: {trip})"
        )


def test_grid_search_shim_matches_serial(cls_setup):
    import dataclasses

    cfg, _, train, test = cls_setup
    cfg = dataclasses.replace(cfg, betas=(1e-2, 1e0))  # healthy solves only
    g_ser = grid_search_serial(cfg, train, test, divs=3)
    g_pop = grid_search(cfg, train, test, divs=3)
    assert g_pop["acc"] == pytest.approx(g_ser["acc"], abs=1e-6)
    assert g_pop["p"] == pytest.approx(g_ser["p"], rel=1e-5)
    assert g_pop["q"] == pytest.approx(g_ser["q"], rel=1e-5)
    assert g_pop["beta"] == g_ser["beta"]
    assert g_pop["n_points"] == g_ser["n_points"]


def test_dual_solver_matches_primal_on_well_conditioned_betas(cls_setup):
    """Dual (kernel-form) and primal solves are the same ridge solution
    wherever the primal factorization is numerically healthy."""
    cfg, mask, train, test = cls_setup
    ps, qs = population.grid_candidates(2, dtype=cfg.dtype)
    y_tr, y_ev = _onehots(cfg, train, test)
    kwargs = dict(select="nrmse")
    ev_p = population.evaluate_population(
        cfg, mask, ps, qs, train.u, train.length, y_tr,
        test.u, test.length, y_ev, solver="primal", **kwargs)
    ev_d = population.evaluate_population(
        cfg, mask, ps, qs, train.u, train.length, y_tr,
        test.u, test.length, y_ev, solver="dual", **kwargs)
    # betas 1e-2 and 1 are far above the float32 noise floor for this B
    for bi in (2, 3):
        np.testing.assert_allclose(
            np.asarray(ev_d.nrmse_all[:, bi]), np.asarray(ev_p.nrmse_all[:, bi]),
            rtol=5e-2, atol=5e-3)


# ---------------------------------------------------------------------------
# NARMA10 regression: refinement never loses to the grid (elitism) and the
# fitted readout is a real predictor
# ---------------------------------------------------------------------------


def test_refined_population_nrmse_beats_grid_on_narma10(narma):
    train, test = narma
    cfg = DFRConfig(n_in=1, n_classes=1, n_nodes=8)
    grid_only = population.train_population_regression(
        cfg, train, test, divs=3, rounds=0)
    refined = population.train_population_regression(
        cfg, train, test, divs=3, rounds=2, steps_per_round=2, minibatch=8)
    assert np.isfinite(grid_only.best_nrmse)
    assert refined.best_nrmse <= grid_only.best_nrmse + 1e-9
    # and the search is doing something: the readout beats predicting the mean
    assert refined.best_nrmse < 1.0
    # elitist history is monotone non-increasing
    hist = [h["best_nrmse"] for h in refined.history]
    assert all(b <= a + 1e-9 for a, b in zip(hist, hist[1:]))


def test_narma10_fixture_shapes(narma):
    train, test = narma
    assert train.u.shape == (120, 24, 1)
    assert train.y.shape == (120, 1)
    assert test.batch == 60 and test.t_max == 24
    # targets live on the NARMA attractor (bounded, non-constant)
    y = np.asarray(train.y)
    assert np.all(np.isfinite(y)) and y.std() > 1e-3


# ---------------------------------------------------------------------------
# Engine mechanics
# ---------------------------------------------------------------------------


def test_cull_keeps_best_and_reseeds_worst():
    k = 8
    cfg = DFRConfig(n_in=1, n_classes=2, n_nodes=4)
    ps = jnp.linspace(0.01, 0.1, k)
    qs = jnp.linspace(0.02, 0.2, k)
    pop = population.init_population(cfg, ps, qs)
    fitness = jnp.arange(k, dtype=jnp.float32)  # member 0 best, 7 worst
    culled = population.cull_population(
        pop, fitness, jax.random.PRNGKey(0), survive_frac=0.5, jitter=0.2)
    # survivors (ranks 0..3) keep their exact (p, q)
    np.testing.assert_allclose(np.asarray(culled.p[:4]), np.asarray(ps[:4]))
    np.testing.assert_allclose(np.asarray(culled.q[:4]), np.asarray(qs[:4]))
    # culled slots are jittered clones of survivors, inside the search box
    p_lo, p_hi = 10.0 ** population.P_LOG_RANGE[0], 10.0 ** population.P_LOG_RANGE[1]
    q_lo, q_hi = 10.0 ** population.Q_LOG_RANGE[0], 10.0 ** population.Q_LOG_RANGE[1]
    assert np.all(np.asarray(culled.p) >= p_lo) and np.all(np.asarray(culled.p) <= p_hi)
    assert np.all(np.asarray(culled.q) >= q_lo) and np.all(np.asarray(culled.q) <= q_hi)
    assert not np.allclose(np.asarray(culled.p[4:]), np.asarray(ps[:4]))


def test_seed_candidates_anchor_exempt_from_clipping():
    """Regression: member 0 is the documented *exact* anchor - an
    out-of-search-box (p_init, q_init) must come back verbatim (the clip
    used to silently move it onto the box edge, breaking the K=1 ensemble
    == single-system parity contract for such configs).  Members 1..K-1
    still clip into the box."""
    from repro.core import candidates

    p0, q0 = 0.9, 0.9            # above both boxes' upper edge 10**-0.25
    ps, qs = candidates.seed_candidates(jax.random.PRNGKey(0), 6, p0, q0,
                                        jitter=0.5)
    assert float(ps[0]) == np.float32(p0) and float(qs[0]) == np.float32(q0)
    p_hi = 10.0 ** candidates.P_LOG_RANGE[1]
    q_hi = 10.0 ** candidates.Q_LOG_RANGE[1]
    assert np.all(np.asarray(ps[1:]) <= p_hi)
    assert np.all(np.asarray(qs[1:]) <= q_hi)
    # in-box anchors are exact too (the historical behavior)
    ps_in, qs_in = candidates.seed_candidates(jax.random.PRNGKey(1), 4,
                                              0.01, 0.01)
    assert float(ps_in[0]) == np.float32(0.01)
    assert float(qs_in[0]) == np.float32(0.01)


def test_adapted_clones_covariance_and_passthrough():
    """The CMA-ES-style cull upgrade: survivors pass through bitwise, culled
    slots step inside the clip box, and with a single survivor the sampler
    reduces to the isotropic jitter (covariance floor only)."""
    from repro.core import candidates

    coords = jnp.asarray([[0.01, 0.02, 0.05, 0.04],
                          [0.03, 0.01, 0.02, 0.06]], jnp.float32)
    keep = jnp.asarray([True, True, False, False])
    out = candidates.adapted_clones(
        jax.random.PRNGKey(0), coords, keep, jitter=0.3,
        ranges=(candidates.P_LOG_RANGE, candidates.Q_LOG_RANGE))
    np.testing.assert_array_equal(np.asarray(out[:, :2]),
                                  np.asarray(coords[:, :2]))
    assert not np.array_equal(np.asarray(out[:, 2:]),
                              np.asarray(coords[:, 2:]))
    for d, (lo, hi) in enumerate((candidates.P_LOG_RANGE,
                                  candidates.Q_LOG_RANGE)):
        assert np.all(np.asarray(out[d]) >= 10.0 ** lo - 1e-7)
        assert np.all(np.asarray(out[d]) <= 10.0 ** hi + 1e-7)
    # single survivor: L == jitter * I exactly (no covariance term)
    one = jnp.asarray([True, False, False, False])
    L = candidates.sampling_cov_chol(jnp.log(coords), one, 0.3)
    np.testing.assert_allclose(np.asarray(L), 0.3 * np.eye(2), atol=1e-6)


def test_refine_population_matches_per_member_sgd(cls_setup):
    """One vmapped refinement epoch == running each member's truncated-BP
    SGD loop individually."""
    cfg, mask, train, _ = cls_setup
    ps, qs = population.grid_candidates(2, dtype=cfg.dtype)
    pop = population.init_population(cfg, ps, qs)
    y_tr = jax.nn.one_hot(train.label, cfg.n_classes, dtype=cfg.dtype)
    lr = jnp.asarray(0.1, cfg.dtype)
    mb = 6
    refined, _ = population.refine_population(
        cfg, mask, pop, train.u, train.length, y_tr, lr, lr,
        steps=1, minibatch=mb)
    f = cfg.f()
    n = train.u.shape[0] // mb * mb
    for i in range(ps.shape[0]):
        params = DFRParams(p=pop.p[i], q=pop.q[i], W=pop.W[i], b=pop.b[i])
        for lo in range(0, n, mb):
            j_seq = masking.apply_mask(mask, train.u[lo:lo + mb])
            _, g = backprop.grads_truncated(
                params, j_seq, y_tr[lo:lo + mb], f,
                lengths=train.length[lo:lo + mb])
            params = backprop.apply_sgd(params, g, lr, lr, inv_batch=1.0 / mb)
        np.testing.assert_allclose(
            float(refined.p[i]), float(params.p), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(
            float(refined.q[i]), float(params.q), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(refined.W[i]), np.asarray(params.W), rtol=1e-3, atol=1e-4)


def test_classification_rounds_never_regress_grid(cls_setup):
    cfg, _, train, test = cls_setup
    grid_only = population.train_population_classification(
        cfg, train, test, divs=2, rounds=0)
    refined = population.train_population_classification(
        cfg, train, test, divs=2, rounds=1, steps_per_round=1, minibatch=6)
    assert refined.best_acc >= grid_only.best_acc - 1e-9
    assert refined.best_params.W.shape == (cfg.n_classes, cfg.n_rep)


def test_population_trainer_runtime_wrapper(tmp_path, narma):
    from repro.runtime import PopulationTrainer, PopulationTrainerConfig

    train, test = narma
    cfg = DFRConfig(n_in=1, n_classes=1, n_nodes=6)
    pt = PopulationTrainer(PopulationTrainerConfig(
        divs=2, rounds=1, steps_per_round=1, minibatch=16,
        ckpt_dir=str(tmp_path / "pop_ckpt")))
    result = pt.fit(cfg, train, test, seed=0)
    assert len(pt.metrics_log) == 2  # round 0 (grid) + 1 refinement round
    assert np.isfinite(result.best_nrmse)
    # winning member was checkpointed and restores to the same params
    from repro.checkpoint.manager import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path / "pop_ckpt"))
    restored = ckpt.restore_latest(result.best_params)
    assert restored is not None
    tree, _step, meta = restored
    np.testing.assert_allclose(float(tree.p), float(result.best_params.p))
    assert meta["best_nrmse"] == pytest.approx(result.best_nrmse)
