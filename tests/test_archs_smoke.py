"""Per-architecture smoke tests: every assigned arch instantiates (reduced
config, same family) and runs one forward + one train step + one decode step
on CPU with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_reduced
from repro.configs.base import SHAPES, input_specs
from repro.models.lm import loss_fn, make_train_step
from repro.models.transformer import Transformer
from repro.optim.optimizers import make_optimizer
from repro.optim.schedule import constant_schedule

B, T = 2, 64


def _batch(cfg, key):
    if cfg.is_encdec:
        return {
            "embeds": jax.random.normal(key, (B, T, cfg.d_model), cfg.dtype),
            "targets": jax.random.randint(key, (B, T), 0, cfg.vocab),
        }
    if cfg.input_mode == "embeds":
        return {
            "embeds": jax.random.normal(key, (B, T, cfg.d_model), cfg.dtype),
            "targets": jax.random.randint(key, (B, T), 0, cfg.vocab),
        }
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    return {"tokens": toks, "targets": toks}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    # every arch must declare a stance on all four assigned shapes
    for s in SHAPES.values():
        specs = input_specs(cfg, s, batch_override=2)
        assert specs


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    model = Transformer(cfg)
    key = jax.random.PRNGKey(0)
    params, axes = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = loss_fn(model, params, batch)
    assert np.isfinite(float(loss)), arch

    opt = make_optimizer("adamw")
    step_fn = make_train_step(model, opt, constant_schedule(1e-3), accum=2)
    opt_state = opt.init(params)
    new_params, new_opt, m = step_fn(params, opt_state, jnp.asarray(0), batch)
    assert np.isfinite(float(m["loss"])), arch
    # parameters actually moved
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params))
    )
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 96, enc_len=T if cfg.is_encdec else 0)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, tok, cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # a second step advances the cache length
    logits2, cache3 = model.decode_step(params, tok, cache2)
    assert int(cache3["len"][0]) == int(cache["len"][0]) + 2


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-7b", "zamba2-1.2b"])
def test_smoke_decode_matches_forward_prefix(arch):
    """Greedy decode logits == train-path logits at the same position (the
    strictest smoke property: cache path is numerically the forward path)."""
    cfg = get_reduced(arch)
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab)
    full_logits, _ = model.train_logits(params, tokens=toks)
    cache = model.init_cache(1, 16)
    for t in range(toks.shape[1]):
        dec_logits, cache = model.decode_step(params, toks[:, t:t+1], cache)
        np.testing.assert_allclose(
            np.asarray(dec_logits[0], np.float32),
            np.asarray(full_logits[0, t], np.float32),
            rtol=5e-2, atol=5e-2,
        )
