"""Top-1 MoE: dispatch/combine correctness vs a per-token dense reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.models.layers import is_pv


def _vals(tree):
    return jax.tree_util.tree_map(lambda pv: pv.value, tree, is_leaf=is_pv)


def dense_reference(p, x):
    """Route each token to its argmax expert, compute exactly (no capacity)."""
    b, t, d = x.shape
    logits = np.einsum("btd,de->bte", np.asarray(x, np.float32),
                       np.asarray(p["router"], np.float32))
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    expert = np.argmax(np.asarray(probs), -1)
    gate = np.max(np.asarray(probs), -1)
    out = np.zeros((b, t, d), np.float32)
    wg, wu, wd = (np.asarray(p[k], np.float32) for k in ("w_gate", "w_up", "w_down"))
    xn = np.asarray(x, np.float32)
    for bi in range(b):
        for ti in range(t):
            e = expert[bi, ti]
            g = xn[bi, ti] @ wg[e]
            u = xn[bi, ti] @ wu[e]
            h = (g / (1 + np.exp(-g))) * u  # silu
            out[bi, ti] = gate[bi, ti] * (h @ wd[e])
    return out


def test_moe_matches_dense_reference_with_big_capacity():
    key = jax.random.PRNGKey(0)
    d, ff, e = 16, 32, 4
    p = _vals(moe.moe_init(key, d, ff, e, dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d), jnp.float32)
    # capacity large enough that nothing drops
    y, aux = moe.moe_apply(p, x, capacity_factor=float(e))
    assert float(aux["fraction_dropped"]) == 0.0
    want = dense_reference(p, x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_tokens():
    key = jax.random.PRNGKey(2)
    d, ff, e = 8, 16, 4
    p = _vals(moe.moe_init(key, d, ff, e, dtype=jnp.float32))
    # skew router so everything lands on one expert -> capacity overflow
    p["router"] = p["router"].at[:, 0].set(10.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, d), jnp.float32)
    y, aux = moe.moe_apply(p, x, capacity_factor=0.5)
    assert float(aux["fraction_dropped"]) > 0.4
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_aux_losses_finite_and_balanced_router_lower():
    key = jax.random.PRNGKey(4)
    d, ff, e = 8, 16, 4
    p = _vals(moe.moe_init(key, d, ff, e, dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, d), jnp.float32)
    _, aux_bal = moe.moe_apply(p, x)
    p_skew = dict(p)
    p_skew["router"] = p["router"].at[:, 0].set(10.0)
    _, aux_skew = moe.moe_apply(p_skew, x)
    assert float(aux_bal["lb_loss"]) < float(aux_skew["lb_loss"])


def test_moe_decode_single_group_path():
    """B*T <= 4096 => single global group; output stays finite + correct
    shape for a decode-like (B, 1, d) call."""
    key = jax.random.PRNGKey(6)
    d, ff, e = 8, 16, 4
    p = _vals(moe.moe_init(key, d, ff, e, dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 1, d), jnp.float32)
    y, aux = moe.moe_apply(p, x, capacity_factor=2.0)
    assert y.shape == (8, 1, d)
    assert bool(jnp.all(jnp.isfinite(y)))
