"""Blockwise online-softmax attention == naive full-matrix reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as att


def naive_attention(q, k, v, causal=True, window=0, q_offset=0):
    b, tq, h, d = q.shape
    _, tk, kv, _ = k.shape
    g = h // kv
    qg = q.reshape(b, tq, kv, g, d).astype(np.float32)
    scores = np.einsum("btkgd,bskd->bkgts", qg, np.asarray(k, np.float32))
    scores = scores / np.sqrt(d)
    q_pos = q_offset + np.arange(tq)[:, None]
    k_pos = np.arange(tk)[None, :]
    mask = np.ones((tq, tk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    scores = np.where(mask[None, None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bkgts,bskd->btkgd", p, np.asarray(v, np.float32))
    return out.reshape(b, tq, h, d)


@pytest.mark.parametrize("causal,window,tq,tk,h,kv", [
    (True, 0, 64, 64, 4, 4),
    (True, 0, 96, 96, 8, 2),       # GQA
    (True, 16, 64, 64, 4, 2),      # sliding window
    (False, 0, 32, 80, 4, 4),      # cross attention
])
def test_blockwise_matches_naive(causal, window, tq, tk, h, kv):
    key = jax.random.PRNGKey(tq + tk)
    d = 16
    q = jax.random.normal(key, (2, tq, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, tk, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, tk, kv, d))
    got = att.blockwise_attention(q, k, v, causal=causal, window=window,
                                  block_q=32, block_k=32)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_blockwise_odd_lengths_padding():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 37, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 53, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 53, 2, 8))
    got = att.blockwise_attention(q, k, v, causal=False, block_q=16, block_k=16)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_decode_matches_full_recompute():
    """decode_attention on a cache == last-row of full blockwise attention."""
    key = jax.random.PRNGKey(3)
    b, t, h, kv, d = 2, 24, 4, 2, 8
    q_all = jax.random.normal(key, (b, t, h, d))
    k_all = jax.random.normal(jax.random.PRNGKey(4), (b, t, kv, d))
    v_all = jax.random.normal(jax.random.PRNGKey(5), (b, t, kv, d))
    full = naive_attention(q_all, k_all, v_all, causal=True)
    cache = att.KVCache.zeros(b, 32, kv, d, dtype=jnp.float32)
    cache = cache.append(k_all, v_all)
    got = att.decode_attention(q_all[:, -1:], cache.k, cache.v, cache.length)
    np.testing.assert_allclose(np.asarray(got[:, 0]), full[:, -1], rtol=2e-3,
                               atol=2e-3)


def test_kv_cache_per_row_append():
    cache = att.KVCache.zeros(2, 8, 1, 4, dtype=jnp.float32)
    cache = att.KVCache(k=cache.k, v=cache.v, length=jnp.asarray([0, 3]))
    k_new = jnp.ones((2, 1, 1, 4))
    c2 = cache.append(k_new, k_new)
    assert float(c2.k[0, 0, 0, 0]) == 1.0   # row 0 wrote at 0
    assert float(c2.k[1, 3, 0, 0]) == 1.0   # row 1 wrote at 3
    assert list(np.asarray(c2.length)) == [1, 4]
