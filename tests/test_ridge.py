"""Ridge regression: all five implementations agree; paper Tables 2/3/8."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ridge


def test_all_implementations_agree(spd_system):
    A, B = spd_system
    ref = np.asarray(A) @ np.linalg.inv(np.asarray(B, np.float64))
    tol = dict(rtol=2e-3, atol=2e-3)
    outs = {
        "gauss_np": ridge.ridge_gaussian_numpy(np.asarray(A), np.asarray(B)),
        "gauss_jax": np.asarray(ridge.ridge_gaussian(A, B)),
        "chol_packed_np": ridge.ridge_cholesky_packed_numpy(np.asarray(A), np.asarray(B)),
        "chol_packed_jax": np.asarray(ridge.ridge_cholesky_packed(A, B)),
        "chol_blocked": np.asarray(ridge.ridge_cholesky_blocked(A, B, block=16)),
    }
    for name, W in outs.items():
        np.testing.assert_allclose(W, ref, err_msg=name, **tol)


def test_cholesky_equals_gaussian_exactly_in_accuracy(spd_system):
    """Paper Table 8: 'same accuracy as the naive method'."""
    A, B = spd_system
    Wg = ridge.ridge_gaussian_numpy(np.asarray(A), np.asarray(B))
    Wc = ridge.ridge_cholesky_packed_numpy(np.asarray(A), np.asarray(B))
    # identical argmax decisions on random probes
    probes = np.random.default_rng(1).normal(size=(200, A.shape[1])).astype(np.float32)
    assert (np.argmax(probes @ Wg.T, -1) == np.argmax(probes @ Wc.T, -1)).mean() > 0.99


def test_packed_roundtrip():
    s = 10
    rng = np.random.default_rng(0)
    M = rng.normal(size=(s, s)).astype(np.float32)
    B = M @ M.T + np.eye(s, dtype=np.float32)
    P = ridge.pack_lower(jnp.asarray(B))
    assert P.shape == (ridge.packed_size(s),)
    D = np.asarray(ridge.unpack_lower(P, s))
    np.testing.assert_allclose(np.tril(B), D, rtol=1e-6)


def test_packed_cholesky_matches_lapack(spd_system):
    _, B = spd_system
    s = B.shape[0]
    P = ridge.pack_lower(B)
    Pc = ridge.cholesky_packed_jax(P, s)
    C = np.asarray(ridge.unpack_lower(Pc, s))
    ref = np.linalg.cholesky(np.asarray(B, np.float64))
    np.testing.assert_allclose(C, ref, rtol=2e-3, atol=2e-3)


def test_memory_words_table2():
    """Table 2 formulas + the paper's 'about 1/4' claim."""
    for s, ny in [(931, 9), (931, 2), (241, 5)]:
        naive = ridge.memory_words_naive(s, ny)
        prop = ridge.memory_words_proposed(s, ny)
        assert naive == 2 * s * (s + ny) + 1
        assert prop == (s * (s + 2 * ny) + s) // 2
        assert 3.3 < naive / prop < 4.01


def test_op_counts_table3_closed_form_vs_enumeration():
    """Closed-form Table 3 counts vs exact loop enumeration of Alg 2-4.

    The paper's closed forms are leading-order in s (the Ny cross terms are
    kept at 1/6 scale); at the paper's operating point (s = 931) exact
    enumeration agrees within ~10%.
    """
    s, ny = 931, 9
    counted = ridge.count_ops_packed(s, ny)
    closed = ridge.op_counts_proposed(s, ny)
    for op in ("add", "mul"):
        assert abs(counted[op] - closed[op]) / counted[op] < 0.15, (op, s)
    assert counted["sqrt"] == closed["sqrt"]
    assert counted["div"] == pytest.approx(closed["div"], rel=0.05)


def test_op_ratio_naive_over_proposed_approx_12():
    """Paper: ~1/12 the adds+muls when Ny << s."""
    s, ny = 931, 2
    naive = ridge.op_counts_naive(s, ny)
    prop = ridge.op_counts_proposed(s, ny)
    ratio = (naive["add"] + naive["mul"]) / (prop["add"] + prop["mul"])
    assert 10.0 < ratio < 13.0


def _spd(rng, s, dtype, jitter=0.1):
    R = rng.normal(size=(s, 2 * s)).astype(dtype)
    return (R @ R.T + jitter * s * np.eye(s, dtype=dtype)).astype(dtype)


@pytest.mark.parametrize("s,ny", [(13, 2), (57, 5), (111, 9)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_parity_across_sizes_and_dtypes(s, ny, dtype):
    """ridge_gaussian_numpy == ridge_cholesky_packed_numpy ==
    ridge_cholesky_packed_jax == ridge_cholesky_blocked, every size/dtype."""
    if dtype == np.float64 and not jax.config.read("jax_enable_x64"):
        # JAX arrays downcast to f32 without x64 mode; compare in f32 there
        jdtype = np.float32
    else:
        jdtype = dtype
    rng = np.random.default_rng(s * ny)
    B = _spd(rng, s, dtype)
    A = rng.normal(size=(ny, s)).astype(dtype)
    ref = (A.astype(np.float64)
           @ np.linalg.inv(B.astype(np.float64))).astype(np.float64)
    scale = np.max(np.abs(ref)) + 1e-12
    tol = 2e-3 if jdtype == np.float32 else 1e-9
    outs = {
        "gauss_np": ridge.ridge_gaussian_numpy(A, B),
        "chol_packed_np": ridge.ridge_cholesky_packed_numpy(A, B),
        "chol_packed_jax": np.asarray(
            ridge.ridge_cholesky_packed(jnp.asarray(A, jdtype), jnp.asarray(B, jdtype))
        ),
        "chol_blocked": np.asarray(
            ridge.ridge_cholesky_blocked(jnp.asarray(A, jdtype), jnp.asarray(B, jdtype))
        ),
    }
    for name, W in outs.items():
        np.testing.assert_allclose(W / scale, ref / scale, rtol=0, atol=tol,
                                   err_msg=f"{name} s={s} ny={ny} {dtype}")


@pytest.mark.parametrize("k,s,ny", [(1, 21, 3), (4, 57, 5), (7, 30, 2)])
def test_batched_solve_matches_per_member_loop(k, s, ny):
    """The population-axis solve == a loop of single-member solves."""
    rng = np.random.default_rng(k + s)
    A = jnp.asarray(np.stack([rng.normal(size=(ny, s)).astype(np.float32)
                              for _ in range(k)]))
    B = jnp.asarray(np.stack([_spd(rng, s, np.float32) for _ in range(k)]))
    got = np.asarray(ridge.ridge_cholesky_batched(A, B))
    assert got.shape == (k, ny, s)
    for i in range(k):
        want = np.asarray(ridge.ridge_cholesky_blocked(A[i], B[i]))
        np.testing.assert_allclose(got[i], want, rtol=2e-3, atol=2e-3)
    got_gauss = np.asarray(ridge.ridge_solve_batched(A, B, method="gaussian"))
    for i in range(k):
        want = np.asarray(ridge.ridge_gaussian(A[i], B[i]))
        np.testing.assert_allclose(got_gauss[i], want, rtol=2e-3, atol=2e-3)


def test_batched_solve_rejects_unknown_method(spd_system):
    A, B = spd_system
    with pytest.raises(ValueError):
        ridge.ridge_solve_batched(A[None], B[None], method="nope")


def test_regularize_broadcasts_over_population_axis(spd_system):
    _, B = spd_system
    stack = jnp.stack([B, 2.0 * B])
    out = ridge.regularize(stack, jnp.float32(0.5))
    np.testing.assert_allclose(
        np.asarray(out[1]), np.asarray(2.0 * B + 0.5 * jnp.eye(B.shape[0])),
        rtol=1e-6)


def test_accumulate_ab_streaming(spd_system, rng):
    s = 13
    n = 40
    rt = jnp.asarray(rng.normal(size=(n, s)).astype(np.float32))
    onehot = jax.nn.one_hot(jnp.asarray(rng.integers(0, 3, n)), 3)
    A = jnp.zeros((3, s)); B = jnp.zeros((s, s))
    for lo in range(0, n, 7):  # stream in uneven chunks
        A, B = ridge.accumulate_ab(A, B, rt[lo:lo+7], onehot[lo:lo+7])
    np.testing.assert_allclose(np.asarray(B), np.asarray(rt.T @ rt), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(A), np.asarray(onehot.T @ rt),
                               rtol=1e-4, atol=1e-4)
