"""Ridge regression: all five implementations agree; paper Tables 2/3/8."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ridge


def test_all_implementations_agree(spd_system):
    A, B = spd_system
    ref = np.asarray(A) @ np.linalg.inv(np.asarray(B, np.float64))
    tol = dict(rtol=2e-3, atol=2e-3)
    outs = {
        "gauss_np": ridge.ridge_gaussian_numpy(np.asarray(A), np.asarray(B)),
        "gauss_jax": np.asarray(ridge.ridge_gaussian(A, B)),
        "chol_packed_np": ridge.ridge_cholesky_packed_numpy(np.asarray(A), np.asarray(B)),
        "chol_packed_jax": np.asarray(ridge.ridge_cholesky_packed(A, B)),
        "chol_blocked": np.asarray(ridge.ridge_cholesky_blocked(A, B, block=16)),
    }
    for name, W in outs.items():
        np.testing.assert_allclose(W, ref, err_msg=name, **tol)


def test_cholesky_equals_gaussian_exactly_in_accuracy(spd_system):
    """Paper Table 8: 'same accuracy as the naive method'."""
    A, B = spd_system
    Wg = ridge.ridge_gaussian_numpy(np.asarray(A), np.asarray(B))
    Wc = ridge.ridge_cholesky_packed_numpy(np.asarray(A), np.asarray(B))
    # identical argmax decisions on random probes
    probes = np.random.default_rng(1).normal(size=(200, A.shape[1])).astype(np.float32)
    assert (np.argmax(probes @ Wg.T, -1) == np.argmax(probes @ Wc.T, -1)).mean() > 0.99


def test_packed_roundtrip():
    s = 10
    rng = np.random.default_rng(0)
    M = rng.normal(size=(s, s)).astype(np.float32)
    B = M @ M.T + np.eye(s, dtype=np.float32)
    P = ridge.pack_lower(jnp.asarray(B))
    assert P.shape == (ridge.packed_size(s),)
    D = np.asarray(ridge.unpack_lower(P, s))
    np.testing.assert_allclose(np.tril(B), D, rtol=1e-6)


def test_packed_cholesky_matches_lapack(spd_system):
    _, B = spd_system
    s = B.shape[0]
    P = ridge.pack_lower(B)
    Pc = ridge.cholesky_packed_jax(P, s)
    C = np.asarray(ridge.unpack_lower(Pc, s))
    ref = np.linalg.cholesky(np.asarray(B, np.float64))
    np.testing.assert_allclose(C, ref, rtol=2e-3, atol=2e-3)


def test_memory_words_table2():
    """Table 2 formulas + the paper's 'about 1/4' claim."""
    for s, ny in [(931, 9), (931, 2), (241, 5)]:
        naive = ridge.memory_words_naive(s, ny)
        prop = ridge.memory_words_proposed(s, ny)
        assert naive == 2 * s * (s + ny) + 1
        assert prop == (s * (s + 2 * ny) + s) // 2
        assert 3.3 < naive / prop < 4.01


def test_op_counts_table3_closed_form_vs_enumeration():
    """Closed-form Table 3 counts vs exact loop enumeration of Alg 2-4.

    The paper's closed forms are leading-order in s (the Ny cross terms are
    kept at 1/6 scale); at the paper's operating point (s = 931) exact
    enumeration agrees within ~10%.
    """
    s, ny = 931, 9
    counted = ridge.count_ops_packed(s, ny)
    closed = ridge.op_counts_proposed(s, ny)
    for op in ("add", "mul"):
        assert abs(counted[op] - closed[op]) / counted[op] < 0.15, (op, s)
    assert counted["sqrt"] == closed["sqrt"]
    assert counted["div"] == pytest.approx(closed["div"], rel=0.05)


def test_op_ratio_naive_over_proposed_approx_12():
    """Paper: ~1/12 the adds+muls when Ny << s."""
    s, ny = 931, 2
    naive = ridge.op_counts_naive(s, ny)
    prop = ridge.op_counts_proposed(s, ny)
    ratio = (naive["add"] + naive["mul"]) / (prop["add"] + prop["mul"])
    assert 10.0 < ratio < 13.0


def test_accumulate_ab_streaming(spd_system, rng):
    s = 13
    n = 40
    rt = jnp.asarray(rng.normal(size=(n, s)).astype(np.float32))
    onehot = jax.nn.one_hot(jnp.asarray(rng.integers(0, 3, n)), 3)
    A = jnp.zeros((3, s)); B = jnp.zeros((s, s))
    for lo in range(0, n, 7):  # stream in uneven chunks
        A, B = ridge.accumulate_ab(A, B, rt[lo:lo+7], onehot[lo:lo+7])
    np.testing.assert_allclose(np.asarray(B), np.asarray(rt.T @ rt), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(A), np.asarray(onehot.T @ rt),
                               rtol=1e-4, atol=1e-4)
