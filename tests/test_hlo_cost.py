"""The loop-aware HLO cost walker: exact on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    comp = _compile(lambda x, y: x @ y, a, b)
    cost = hlo_cost.analyze(comp.as_text())
    assert cost.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_flops():
    """A scanned matmul must be counted trip_count times (the thing
    cost_analysis gets wrong)."""
    w = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)

    def fn(w, x):
        return jax.lax.scan(lambda c, wi: (jnp.tanh(c @ wi), ()), x, w)[0]

    comp = _compile(fn, w, x)
    cost = hlo_cost.analyze(comp.as_text())
    want = 7 * 2 * 16 * 32 * 32
    assert cost.flops == pytest.approx(want, rel=0.05)
    assert cost.n_while_unknown == 0
    # and the built-in analysis is indeed wrong (sanity of our premise);
    # cost_analysis() returns a dict in newer JAX, a one-per-program list
    # of dicts in older versions
    xla = comp.cost_analysis()
    if isinstance(xla, (list, tuple)):
        xla = xla[0] if xla else {}
    assert xla.get("flops", 0.0) < 0.5 * want


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((3, 4, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)

    def inner(c, wi):
        return jnp.tanh(c @ wi), ()

    def outer(c, ws):
        c2, _ = jax.lax.scan(inner, c, ws)
        return c2, ()

    comp = _compile(lambda w, x: jax.lax.scan(outer, x, w)[0], w, x)
    cost = hlo_cost.analyze(comp.as_text())
    want = 3 * 4 * 2 * 8 * 16 * 16
    assert cost.flops == pytest.approx(want, rel=0.05)


def test_grad_counts_forward_and_backward():
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)

    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    comp = _compile(jax.grad(loss), w, x)
    cost = hlo_cost.analyze(comp.as_text())
    fwd = 2 * 16 * 32 * 32
    # fwd + dW (x^T @ ct) = 2 matmuls minimum (dx not needed for grad wrt w)
    assert cost.flops >= 2 * fwd * 0.95


def test_memory_bytes_scale_with_shapes():
    big = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    small = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f = lambda x: jnp.tanh(x) * 2.0 + 1.0
    c_big = hlo_cost.analyze(_compile(f, big).as_text())
    c_small = hlo_cost.analyze(_compile(f, small).as_text())
    assert c_big.mem_bytes > 100 * c_small.mem_bytes


# -- dtype byte accounting (the _shape_bytes 4-byte-default bugfix) ----------


def test_shape_bytes_narrow_dtypes_exact():
    """int8/pred buffers must be priced at 1 byte/elem, f32 at 4 - the old
    silent 4-byte default overpriced every narrow buffer 4x (the quant
    path's planner calibration reads these numbers)."""
    assert hlo_cost._shape_bytes("s8", "16,32") == 16 * 32
    assert hlo_cost._shape_bytes("u8", "8") == 8
    assert hlo_cost._shape_bytes("pred", "64") == 64
    assert hlo_cost._shape_bytes("f32", "16,32") == 4 * 16 * 32
    assert hlo_cost._shape_bytes("bf16", "10,10") == 2 * 100
    assert hlo_cost._shape_bytes("f64", "3") == 24
    assert hlo_cost._shape_bytes("s32", "") == 4        # scalar
    assert hlo_cost._shape_bytes("token", "") == 0      # no HBM footprint
    assert hlo_cost._shape_bytes("f32", "0,7") == 0     # empty tensor


def test_shape_bytes_unknown_dtype_raises():
    with pytest.raises(ValueError, match="unrecognized HLO element type"):
        hlo_cost._shape_bytes("f640", "4,4")
    with pytest.raises(ValueError, match="nosuch"):
        hlo_cost._shape_bytes("nosuch", "")


def test_int8_vs_f32_program_bytes():
    """End-to-end through analyze(): the same elementwise program on int8
    operands must cost ~4x fewer HBM bytes than on f32 ones."""
    n = 4096
    i8 = jax.ShapeDtypeStruct((n,), jnp.int8)
    f32 = jax.ShapeDtypeStruct((n,), jnp.float32)
    c8 = hlo_cost.analyze(_compile(lambda x: x + x, i8).as_text())
    c32 = hlo_cost.analyze(_compile(lambda x: x + x, f32).as_text())
    assert c8.mem_bytes > 0
    # read + write of (n,) at 1 vs 4 bytes/elem; allow fusion-shape slack
    assert c32.mem_bytes == pytest.approx(4.0 * c8.mem_bytes, rel=0.25)
    assert c8.mem_bytes <= 3 * n          # never the old 4-byte default
