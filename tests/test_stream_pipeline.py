"""Device-resident serving pipeline (PR 5): equivalence + safety battery.

The contracts under test (see runtime/stream_server.py module docstring):

  * device staging (pool gather + folded cohort refresh, one dispatch) is
    bit-for-bit the PR-4 host-staged path over a full multi-admission /
    retire episode, in every retirement mode;
  * async pipelining (depth 1/2, donated) is bit-for-bit the synchronous
    depth-0 schedule (the lag only defers metric bookkeeping);
  * buffer donation never changes numerics, and the retirement snapshot
    (``_snapshot_row``) stays valid after later donated steps consume the
    batched state it was gathered from (no use-after-donate);
  * ``cfg.dtype`` is honored end to end (the PR-4 host staging hardcoded
    float32, silently upcasting bf16 configs);
  * ``run_until_drained(max_steps)`` truncation is never silent;
  * latency records ride bounded ring buffers and split dispatch (host
    enqueue) from drain (device sync) honestly.
"""
import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.types import DFRConfig
from repro.runtime import StreamRequest, StreamServer


CFG = DFRConfig(n_in=2, n_classes=3, n_nodes=8)

# every retirement mode, with the server kwargs it needs
RETIREMENT_MODES = (
    ("none", {}),
    ("none-inc", {"refresh_mode": "incremental"}),
    ("forget", {"refresh_mode": "incremental", "retirement": "forget",
                "forget": 0.9}),
    ("window", {"refresh_mode": "incremental", "retirement": "window",
                "retire_window": 6}),
)


def _make_stream(rid, n, t=16, seed=0, n_in=2, n_classes=3):
    r = np.random.default_rng(seed)
    return StreamRequest(
        rid=rid,
        u=r.normal(size=(n, t, n_in)).astype(np.float32),
        length=r.integers(4, t + 1, n).astype(np.int32),
        label=r.integers(0, n_classes, n).astype(np.int32),
    )


def _episode_streams(seed0=0):
    """More streams than slots and ragged lengths: the episode exercises
    admission, tail windows, retirement and slot refill."""
    return [_make_stream(i, n, seed=seed0 + i)
            for i, n in enumerate([8, 6, 10, 4, 7])]


def _serve(streams=None, cfg=CFG, **kw):
    srv = StreamServer(cfg, t_max=16, max_streams=3, window=2,
                       phase_steps=2, refresh_every=3, **kw)
    for s in (streams if streams is not None else _episode_streams()):
        srv.submit(s)
    done = srv.run_until_drained()
    return {r.rid: list(r.preds) for r in done}, srv


def _assert_states_bitwise_equal(sa, sb):
    for a, b in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_states_equal_cross_program(sa, sb):
    """Bitwise on every serving-relevant leaf (params, ridge statistics,
    factor, counters); the ``loss_ema`` *diagnostic* is compared to ~1 ulp
    instead - the host-staged and device-staged executables are different
    XLA programs, and the loss reduction may fuse with a different
    association order in each (observed: 1-ulp drift at fp32).  Predictions
    and the entire model state are still required to match exactly."""
    _assert_states_bitwise_equal(sa.params, sb.params)
    _assert_states_bitwise_equal(sa.ridge, sb.ridge)
    np.testing.assert_array_equal(np.asarray(sa.step), np.asarray(sb.step))
    a = np.asarray(sa.loss_ema, np.float32)
    b = np.asarray(sb.loss_ema, np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-9)


# ---------------------------------------------------------------------------
# Device staging == host staging, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kw", RETIREMENT_MODES,
                         ids=[m for m, _ in RETIREMENT_MODES])
def test_device_pool_is_bitwise_the_host_path(mode, kw):
    """The cursor-gathered device batch and the folded cohort refresh serve
    a full admission/retire episode bit-for-bit identically to the PR-4
    host-staged build (depth 0; donation exercised on the device side)."""
    preds_h, srv_h = _serve(staging="host", donate=False, **kw)
    preds_d, srv_d = _serve(staging="device", donate=True, **kw)
    assert preds_h == preds_d
    _assert_states_equal_cross_program(srv_h.states, srv_d.states)
    for a, b in zip(sorted(srv_h.completed, key=lambda r: r.rid),
                    sorted(srv_d.completed, key=lambda r: r.rid)):
        _assert_states_equal_cross_program(a.final_state, b.final_state)


def test_device_pool_matches_host_under_staggered_cohorts():
    """Cohort staggering (C=2, uneven cohorts -> padded fixed-shape rows in
    the fused refresh) also matches the host path's row refresh exactly."""
    for kw in ({"refresh_cohorts": 2},
               {"refresh_cohorts": 2, "refresh_mode": "incremental"}):
        preds_h, srv_h = _serve(staging="host", donate=False, **kw)
        preds_d, srv_d = _serve(**kw)
        assert preds_h == preds_d
        _assert_states_equal_cross_program(srv_h.states, srv_d.states)


# ---------------------------------------------------------------------------
# Pipelining: depth D == depth 0, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kw", RETIREMENT_MODES,
                         ids=[m for m, _ in RETIREMENT_MODES])
@pytest.mark.parametrize("depth", [1, 2])
def test_pipelined_serving_is_bitwise_the_synchronous_path(depth, mode, kw):
    """Depth-1/2 donated pipelining serves the multi-admission episode
    bit-for-bit like synchronous depth 0: the lag-D prediction ring defers
    only bookkeeping, never the serving schedule."""
    preds_0, srv_0 = _serve(pipeline_depth=0, **kw)
    preds_d, srv_d = _serve(pipeline_depth=depth, **kw)
    assert preds_0 == preds_d
    _assert_states_bitwise_equal(srv_0.states, srv_d.states)
    for a, b in zip(sorted(srv_0.completed, key=lambda r: r.rid),
                    sorted(srv_d.completed, key=lambda r: r.rid)):
        assert a.correct == b.correct
        assert b.done
        _assert_states_bitwise_equal(a.final_state, b.final_state)


# ---------------------------------------------------------------------------
# Donation safety
# ---------------------------------------------------------------------------


def test_donation_preserves_numerics_and_snapshots():
    """donate=True vs donate=False: identical predictions and identical
    retirement snapshots - and every snapshot gathered on the ``_snapshot_
    row`` path stays finite and readable after many later donated steps
    consumed the batched state it came from (no use-after-donate)."""
    preds_n, srv_n = _serve(donate=False, pipeline_depth=2)
    preds_y, srv_y = _serve(donate=True, pipeline_depth=2)
    assert preds_n == preds_y
    _assert_states_bitwise_equal(srv_n.states, srv_y.states)
    for a, b in zip(sorted(srv_n.completed, key=lambda r: r.rid),
                    sorted(srv_y.completed, key=lambda r: r.rid)):
        # snapshots of early-retired streams were taken many donated
        # dispatches ago; they must still be materializable and equal
        _assert_states_bitwise_equal(a.final_state, b.final_state)
        for leaf in jax.tree_util.tree_leaves(b.final_state):
            assert np.all(np.isfinite(np.asarray(leaf, np.float64)))


def test_snapshot_survives_interleaved_donated_steps():
    """Direct use-after-donate probe: snapshot a live slot mid-episode,
    run more donated steps, then read the snapshot - its buffers must be
    independent of the donated state tree."""
    srv = StreamServer(CFG, t_max=16, max_streams=2, window=2,
                       phase_steps=1, refresh_every=2, donate=True)
    for s in _episode_streams():
        srv.submit(s)
    for _ in range(3):
        srv.step()
    snap = srv._snapshot_row(0)
    ref = [np.asarray(leaf).copy() for leaf in jax.tree_util.tree_leaves(snap)]
    for _ in range(4):
        srv.step()           # donated dispatches consume srv.states
    srv.drain()
    for leaf, r in zip(jax.tree_util.tree_leaves(snap), ref):
        np.testing.assert_array_equal(np.asarray(leaf), r)


# ---------------------------------------------------------------------------
# dtype honored (PR-4 host staging hardcoded float32)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("staging", ["host", "device"])
def test_bf16_config_is_not_silently_upcast(staging):
    """A bf16 config must serve in bf16: the staged batch and the state
    leaves carry cfg.dtype on both staging paths (regression for the PR-4
    float32 hardcode), and both paths agree exactly."""
    cfg = dataclasses.replace(CFG, dtype=jnp.bfloat16)

    def fresh_streams():
        return [_make_stream(0, 6, seed=3), _make_stream(1, 4, seed=4)]

    preds, srv = _serve(fresh_streams(), cfg=cfg, staging=staging,
                        refresh_mode="incremental")
    assert srv.states.ridge.B.dtype == jnp.bfloat16
    assert srv.states.ridge.Lt.dtype == jnp.bfloat16
    assert srv.states.params.W.dtype == jnp.bfloat16
    if staging == "device":
        assert srv.pool.u.dtype == jnp.bfloat16
        # both staging paths quantize identically -> identical service
        preds_h, _ = _serve(fresh_streams(), cfg=cfg, staging="host",
                            refresh_mode="incremental")
        assert preds == preds_h
    for r in srv.completed:
        assert len(r.preds) == r.n_samples


# ---------------------------------------------------------------------------
# Pool capacity, truncation signaling, latency accounting
# ---------------------------------------------------------------------------


def test_pool_grows_for_longer_streams_submitted_later():
    """A stream longer than the current pool capacity grows the pool (and
    re-stages queued payloads); service stays exact for every stream."""
    srv = StreamServer(CFG, t_max=16, max_streams=2, window=2,
                       phase_steps=2, refresh_every=3)
    srv.submit(_make_stream(0, 4, seed=0))
    assert srv.pool.capacity == 4
    srv.submit(_make_stream(1, 9, seed=1))   # rounds up to window multiple
    assert srv.pool.capacity == 10
    for _ in range(2):
        srv.step()
    srv.submit(_make_stream(2, 13, seed=2))  # grows mid-service
    assert srv.pool.capacity == 14
    done = srv.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    for r in done:
        assert len(r.preds) == r.n_samples
    # exactness across the growth: same episode on the host path
    preds_d = {r.rid: list(r.preds) for r in done}
    preds_h, _ = _serve([_make_stream(0, 4, seed=0),
                         _make_stream(1, 9, seed=1),
                         _make_stream(2, 13, seed=2)],
                        staging="host", donate=False)
    # NOTE: submission timing differs (stream 2 arrives mid-episode above),
    # so only the first two streams see identical schedules
    assert preds_d[0] == preds_h[0]


def test_fused_infer_slots_dispatch_serves_through_the_pool():
    """The slot-axis fused-infer dispatch (`ops.streaming_logits_slots`,
    the TPU latency path exercised here through its XLA ref) serves the
    device-staged episode end to end and agrees with the shared-forward
    inference on (nearly) every sample - the two compute the same math
    through different op orders, so borderline argmaxes may flip."""
    preds_f, srv = _serve(fused_infer=True)
    preds_s, _ = _serve(fused_infer=False)
    assert sorted(preds_f) == sorted(preds_s)
    total = agree = 0
    for rid in preds_f:
        assert len(preds_f[rid]) == len(preds_s[rid])
        total += len(preds_f[rid])
        agree += sum(int(a == b)
                     for a, b in zip(preds_f[rid], preds_s[rid]))
    assert agree / total >= 0.97
    for r in srv.completed:
        assert len(r.preds) == r.n_samples


def test_run_until_drained_truncation_is_not_silent():
    """Hitting max_steps with live streams warns with the undrained count;
    strict=True raises instead.  A full drain stays warning-free."""
    def build():
        srv = StreamServer(CFG, t_max=16, max_streams=1, window=2,
                           phase_steps=1, refresh_every=3)
        for s in _episode_streams():
            srv.submit(s)
        return srv

    srv = build()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        srv.run_until_drained(max_steps=2)
    assert any("still live or queued" in str(x.message) for x in w)

    with pytest.raises(RuntimeError, match="still live or queued"):
        build().run_until_drained(max_steps=2, strict=True)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        done = build().run_until_drained()
    assert not [x for x in w if issubclass(x.category, RuntimeWarning)]
    assert len(done) == len(_episode_streams())


def test_latency_records_are_bounded_and_split():
    """step/dispatch/drain records ride a bounded ring and the percentile
    report carries the honest dispatch-vs-drain split."""
    srv = StreamServer(CFG, t_max=16, max_streams=2, window=2,
                       phase_steps=1, refresh_every=3, pipeline_depth=1,
                       latency_window=8)
    for s in _episode_streams():
        srv.submit(s)
    srv.run_until_drained()
    assert srv.global_step > 8          # the episode outran the ring
    assert len(srv.step_times_s) == 8   # ... which stayed bounded
    assert len(srv.dispatch_times_s) == 8
    assert 0 < len(srv.drain_times_s) <= 8
    lat = srv.latency_percentiles_ms()
    for key in ("p50_ms", "p99_ms", "dispatch_p50_ms", "dispatch_p99_ms",
                "drain_p50_ms", "drain_p99_ms"):
        assert key in lat and lat[key] >= 0.0
    # dispatch never includes the blocking read: it is bounded by the total
    assert lat["dispatch_p50_ms"] <= lat["p50_ms"] + 1e-6


def test_latency_empty_rings_report_nan_not_zero():
    """A server that never stepped has NO latency measurement - the report
    must say NaN, never a fake (and impossible) 0.0 ms percentile."""
    srv = StreamServer(CFG, t_max=16, max_streams=2, window=2,
                       phase_steps=1, refresh_every=3)
    lat = srv.latency_percentiles_ms()
    for key in ("p50_ms", "p99_ms", "dispatch_p50_ms", "dispatch_p99_ms",
                "drain_p50_ms", "drain_p99_ms"):
        assert np.isnan(lat[key]), key
    # one served episode populates every ring with real (finite) readings
    srv.submit(_make_stream(0, 4))
    srv.run_until_drained()
    lat = srv.latency_percentiles_ms()
    assert all(np.isfinite(v) for v in lat.values())


def test_truncation_warning_counts_live_and_queued():
    """The undrained count in the truncation warning must be live + queued
    - 5 streams through 1 slot stopped at step 2 leaves all 5 undrained
    (none of the episode's streams finishes in 2 windows)."""
    srv = StreamServer(CFG, t_max=16, max_streams=1, window=2,
                       phase_steps=1, refresh_every=3)
    streams = _episode_streams()
    for s in streams:
        srv.submit(s)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        srv.run_until_drained(max_steps=2)
    msgs = [str(x.message) for x in w
            if issubclass(x.category, RuntimeWarning)]
    assert msgs and f"{len(streams)} stream(s)" in msgs[0]
    # and the count is self-consistent with the scheduler's own view
    assert len(srv.sched.live()) + len(srv.sched.queue) == len(streams)


def test_drain_after_truncation_is_idempotent_and_resumable():
    """After a truncated run, drain() is a no-op on repeat (no in-flight
    entries left, no double bookkeeping) and the episode can resume to a
    clean finish with every prediction intact."""
    srv = StreamServer(CFG, t_max=16, max_streams=2, window=2,
                       phase_steps=1, refresh_every=3, pipeline_depth=2)
    streams = _episode_streams()
    for s in streams:
        srv.submit(s)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        srv.run_until_drained(max_steps=3)
    assert not srv._inflight                 # run_until_drained flushed
    counts = {r.rid: len(r.preds) for r in streams}
    srv.drain()                              # idempotent: nothing in flight
    srv.drain()
    assert {r.rid: len(r.preds) for r in streams} == counts
    # the truncated server resumes where it stopped and finishes clean
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        done = srv.run_until_drained()
    assert not [x for x in w if issubclass(x.category, RuntimeWarning)]
    assert sorted(r.rid for r in done) == sorted(r.rid for r in streams)
    for r in done:
        assert r.done and len(r.preds) == r.n_samples
