"""Runtime: fault-tolerant trainer (failure injection + deterministic
recovery), straggler watchdog, continuous-batching server."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.transformer import Transformer
from repro.runtime import ElasticRestart, Request, Server, StragglerWatchdog
from repro.runtime.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# a tiny quadratic "model" so trainer tests run in milliseconds
# ---------------------------------------------------------------------------


def _quad_step(params, opt_state, step, batch):
    lr = 0.1
    grads = jax.tree_util.tree_map(lambda p: 2 * (p - batch["target"]), params)
    new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    loss = sum(jnp.sum((p - batch["target"]) ** 2)
               for p in jax.tree_util.tree_leaves(params))
    return new, opt_state, {"loss": loss}


def _batch_fn(step):
    return {"target": jnp.asarray(float(step % 3), jnp.float32)}


def test_trainer_runs_and_checkpoints(tmp_path):
    params = {"w": jnp.zeros((4,), jnp.float32)}
    tr = Trainer(TrainerConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=5),
                 _quad_step, _batch_fn)
    p, o, step = tr.run(params, (), num_steps=12)
    assert step == 12
    assert tr.ckpt.latest_step() == 12
    assert len(tr.metrics_log) == 12


def test_trainer_recovers_from_injected_fault(tmp_path):
    """A fault at step 7 restores from the step-5 checkpoint and replays;
    final params must equal an uninterrupted run (determinism)."""
    params = {"w": jnp.zeros((4,), jnp.float32)}

    clean = Trainer(TrainerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5),
                    _quad_step, _batch_fn)
    p_clean, _, _ = clean.run(params, (), num_steps=12)

    fired = {"n": 0}

    def fault(step):
        if step == 7 and fired["n"] == 0:
            fired["n"] = 1
            raise RuntimeError("injected device loss")

    faulty = Trainer(TrainerConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5),
                     _quad_step, _batch_fn, fault_hook=fault)
    p_fault, _, _ = faulty.run(params, (), num_steps=12)
    assert fired["n"] == 1
    np.testing.assert_allclose(np.asarray(p_clean["w"]), np.asarray(p_fault["w"]))


def test_trainer_gives_up_after_max_retries(tmp_path):
    params = {"w": jnp.zeros((2,), jnp.float32)}

    def always_fail(step):
        raise RuntimeError("persistent failure")

    tr = Trainer(TrainerConfig(ckpt_dir=str(tmp_path / "c"), ckpt_every=5,
                               max_retries_per_step=2),
                 _quad_step, _batch_fn, fault_hook=always_fail)
    with pytest.raises(RuntimeError):
        tr.run(params, (), num_steps=3)


def test_straggler_watchdog_verdicts():
    wd = StragglerWatchdog(threshold=2.0, strikes_to_evict=2)
    for _ in range(10):
        assert wd.observe("h0", 1.0) == "ok"
    assert wd.observe("h1", 5.0) == "suspect"
    assert wd.observe("h1", 5.0) == "evict"
    assert "h1" in wd.evicted
    # healthy host decays strikes
    wd.observe("h2", 5.0)
    wd.observe("h2", 1.0)
    assert wd.strikes["h2"] == 0


def test_server_continuous_batching_matches_sequential():
    """Server outputs == one-request-at-a-time decode (batching is
    transparent), with max_batch smaller than #requests (slot reuse)."""
    cfg = get_reduced("smollm-135m")
    model = Transformer(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 3, 7)]

    server = Server(model, params, max_batch=2, max_len=64)
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=p, max_tokens=4))
    done = {r.rid: r.out_tokens for r in server.run_until_drained()}
    assert set(done) == {0, 1, 2, 3}

    # sequential reference, one request alone in a batch of 1
    for rid, prompt in enumerate(prompts):
        ref = Server(model, params, max_batch=1, max_len=64)
        ref.submit(Request(rid=99, prompt=prompt, max_tokens=4))
        ref_tokens = ref.run_until_drained()[0].out_tokens
        assert done[rid] == ref_tokens, rid
