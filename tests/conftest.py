import os
import sys

# Tests see the default single CPU device (the dry-run sets its own flags in
# a separate process).  Keep XLA quiet and single-threaded-friendly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Multi-device lane: REPRO_FORCE_DEVICES=N splits the host CPU into N XLA
# devices BEFORE jax initializes (device counts lock on first jax import),
# so the slot-sharding parity tests (tests/test_stream_sharded.py) exercise
# real multi-device meshes on CPU-only CI.  Unset, tests run exactly as
# before on the single default device; the sharded tests that need devices
# skip (and a subprocess fallback re-runs them with the flag set).
_force = os.environ.get("REPRO_FORCE_DEVICES")
if _force:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_force)}"
    ).strip()

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import DFRConfig, DFRParams, TimeSeriesBatch


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_cfg():
    return DFRConfig(n_in=3, n_classes=4, n_nodes=8, nonlinearity="tanh")


@pytest.fixture(scope="session")
def small_batch(rng):
    b, t, v = 12, 20, 3
    u = rng.normal(size=(b, t, v)).astype(np.float32)
    lengths = rng.integers(5, t + 1, b).astype(np.int32)
    labels = (np.arange(b) % 4).astype(np.int32)
    for i in range(b):
        u[i, lengths[i]:] = 0.0
    return TimeSeriesBatch(
        u=jnp.asarray(u), length=jnp.asarray(lengths), label=jnp.asarray(labels)
    )


@pytest.fixture(scope="session")
def spd_system(rng):
    """(A, B) with B guaranteed SPD, paper-scale-ish s."""
    s, n_y, n_train = 57, 5, 300
    R = rng.normal(size=(s, n_train)).astype(np.float32)
    B = R @ R.T + 0.05 * np.eye(s, dtype=np.float32)
    A = rng.normal(size=(n_y, s)).astype(np.float32)
    return jnp.asarray(A), jnp.asarray(B)
