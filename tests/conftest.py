import os
import sys

# Tests see the default single CPU device (the dry-run sets its own flags in
# a separate process).  Keep XLA quiet and single-threaded-friendly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import DFRConfig, DFRParams, TimeSeriesBatch


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_cfg():
    return DFRConfig(n_in=3, n_classes=4, n_nodes=8, nonlinearity="tanh")


@pytest.fixture(scope="session")
def small_batch(rng):
    b, t, v = 12, 20, 3
    u = rng.normal(size=(b, t, v)).astype(np.float32)
    lengths = rng.integers(5, t + 1, b).astype(np.int32)
    labels = (np.arange(b) % 4).astype(np.int32)
    for i in range(b):
        u[i, lengths[i]:] = 0.0
    return TimeSeriesBatch(
        u=jnp.asarray(u), length=jnp.asarray(lengths), label=jnp.asarray(labels)
    )


@pytest.fixture(scope="session")
def spd_system(rng):
    """(A, B) with B guaranteed SPD, paper-scale-ish s."""
    s, n_y, n_train = 57, 5, 300
    R = rng.normal(size=(s, n_train)).astype(np.float32)
    B = R @ R.T + 0.05 * np.eye(s, dtype=np.float32)
    A = rng.normal(size=(n_y, s)).astype(np.float32)
    return jnp.asarray(A), jnp.asarray(B)
