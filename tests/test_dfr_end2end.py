"""End-to-end DFR system behaviour (the paper's pipeline on synthetic data)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DFRModel, OnlineDFR
from repro.core.readout import DistributedDFRReadout, ReadoutConfig
from repro.core.types import DFRConfig, TimeSeriesBatch
from repro.data import load


@pytest.fixture(scope="module")
def jpvow_small():
    return load("JPVOW", size_cap=72)


def test_fit_reaches_nontrivial_accuracy(jpvow_small):
    train, test = jpvow_small
    cfg = DFRConfig(n_in=12, n_classes=9, n_nodes=20, epochs=8)
    m = DFRModel.create(cfg)
    params = m.fit(train, minibatch=4)
    acc = float(m.accuracy(test, params))
    assert acc > 3.0 / 9.0, acc  # far above chance on 9 classes


def test_ridge_only_interpolates_train(jpvow_small):
    train, _ = jpvow_small
    cfg = DFRConfig(n_in=12, n_classes=9, n_nodes=20)
    m = DFRModel.create(cfg)
    from repro.core.types import DFRParams
    params = m.fit_ridge(train, DFRParams.init(cfg))
    assert float(m.accuracy(train, params)) > 0.95


def test_online_stepper_matches_features_and_learns(jpvow_small):
    train, _ = jpvow_small
    cfg = DFRConfig(n_in=12, n_classes=9, n_nodes=16)
    online = OnlineDFR(cfg)
    state = online.init()
    # stream the training set in windows of 8 (the edge loop)
    for lo in range(0, train.batch - 7, 8):
        state, metrics = online.step(
            state, train.u[lo:lo+8], train.length[lo:lo+8],
            train.label[lo:lo+8], jnp.float32(0.5), jnp.float32(0.5),
        )
    assert int(state.ridge.count) >= 64
    state = online.refresh_output(state, jnp.float32(1e-2))
    preds = online.infer(state, train.u[:32], train.length[:32])
    acc = float(jnp.mean((preds == train.label[:32]).astype(jnp.float32)))
    assert acc > 2.0 / 9.0


def test_distributed_readout_single_device_path(jpvow_small):
    """The psum-free (axis_names=()) path: accumulate -> solve -> predict."""
    train, _ = jpvow_small
    rc = ReadoutConfig(feature_dim=12, n_classes=9, n_nodes=16)
    ro = DistributedDFRReadout(rc, axis_names=())
    params, ridge_state = ro.init()
    h = train.u  # treat raw series as 'backbone features' (D = 12)
    ridge_state = ro.accumulate(ridge_state, params, h, train.label,
                                lengths=train.length)
    fitted = ro.solve(ridge_state, params, jnp.float32(1e-2))
    preds = ro.predict(fitted, h, lengths=train.length)
    acc = float(jnp.mean((preds == train.label).astype(jnp.float32)))
    assert acc > 0.6  # far above the 1/9 chance level (regularized fit)


def test_distributed_readout_psum_consistency(jpvow_small):
    """shard_map over 1-device mesh: psum path == local path exactly."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    train, _ = jpvow_small
    mesh = jax.make_mesh((1,), ("data",))
    rc = ReadoutConfig(feature_dim=12, n_classes=9, n_nodes=8)
    ro_local = DistributedDFRReadout(rc, axis_names=())
    ro_dist = DistributedDFRReadout(rc, axis_names=("data",))
    params, rs = ro_local.init()
    h, lab = train.u[:16], train.label[:16]

    local_state = ro_local.accumulate(rs, params, h, lab)
    local_W = ro_local.solve(local_state, params, jnp.float32(1e-2)).W

    def shard_fn(h, lab):
        st = ro_dist.accumulate(rs, params, h, lab)
        return ro_dist.solve(st, params, jnp.float32(1e-2)).W

    dist_W = shard_map(
        shard_fn, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P()
    )(h, lab)
    np.testing.assert_allclose(np.asarray(local_W), np.asarray(dist_W),
                               rtol=1e-4, atol=1e-4)


def test_grid_search_runs_and_improves_with_divisions(jpvow_small):
    from repro.core.grid_search import grid_search
    train, test = jpvow_small
    cfg = DFRConfig(n_in=12, n_classes=9, n_nodes=16)
    g1 = grid_search(cfg, train, test, divs=1)
    g3 = grid_search(cfg, train, test, divs=3)
    assert g3["n_points"] > g1["n_points"]
    assert g3["acc"] >= g1["acc"] - 0.05
