"""Quantized int8 serving + step blocking (PR 7): equivalence battery.

The contracts under test (see runtime/stream_server.py module docstring):

  * the fp32 serving path is BITWISE the PR-6 path: predictions and final
    model state of a full multi-admission episode reproduce the committed
    golden fixture in every retirement mode (``quantize='none'`` and
    ``step_block=1`` compile the exact pre-PR-7 program);
  * step blocking (``step_block=T``) serves the ``step_block=1`` episode
    exactly - same predictions, same model state - across retirement
    modes, pipeline depths and the quantized path (the block clamp keeps
    the admission schedule identical);
  * ``quantize='int8'`` changes ONLY the served logits: training,
    statistics and refreshes are bit-for-bit the fp32 episode, slots arm
    at their first ridge-refresh boundary, and the argmax agreement with
    fp32 serving stays inside the measured band;
  * the int8 kernel equals its XLA oracle (integer math is exact, so
    interpret-vs-xla is tight), zero-range windows and bf16 configs are
    NaN-free, and the quantize/dequantize round trip obeys the half-step
    error bound;
  * invalid knob combinations fail loudly at construction.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import masking, online
from repro.core.types import DFRConfig
from repro.kernels import ops
from repro.runtime import StreamRequest, StreamServer

CFG = DFRConfig(n_in=2, n_classes=3, n_nodes=8)

RETIREMENT_MODES = (
    ("none", {}),
    ("none-inc", {"refresh_mode": "incremental"}),
    ("forget", {"refresh_mode": "incremental", "retirement": "forget",
                "forget": 0.9}),
    ("window", {"refresh_mode": "incremental", "retirement": "window",
                "retire_window": 6}),
)

GOLDEN = "tests/golden/stream_fp32_golden.npz"


def _make_stream(rid, n, t=16, seed=0, n_in=2, n_classes=3):
    r = np.random.default_rng(seed)
    return StreamRequest(
        rid=rid,
        u=r.normal(size=(n, t, n_in)).astype(np.float32),
        length=r.integers(4, t + 1, n).astype(np.int32),
        label=r.integers(0, n_classes, n).astype(np.int32),
    )


def _episode_streams(seed0=0):
    return [_make_stream(i, n, seed=seed0 + i)
            for i, n in enumerate([8, 6, 10, 4, 7])]


def _serve(streams=None, cfg=CFG, **kw):
    srv = StreamServer(cfg, t_max=16, max_streams=3, window=2,
                       phase_steps=2, refresh_every=3, **kw)
    for s in (streams if streams is not None else _episode_streams()):
        srv.submit(s)
    done = srv.run_until_drained()
    return {r.rid: list(r.preds) for r in done}, srv


def _assert_states_bitwise_equal(sa, sb):
    for a, b in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_states_equal_cross_program(sa, sb):
    """Bitwise on every serving-relevant leaf; loss_ema (diagnostic) to
    ~1 ulp - different XLA programs may fuse its reduction differently
    (the test_stream_pipeline.py idiom)."""
    _assert_states_bitwise_equal(sa.params, sb.params)
    _assert_states_bitwise_equal(sa.ridge, sb.ridge)
    np.testing.assert_array_equal(np.asarray(sa.step), np.asarray(sb.step))
    a = np.asarray(sa.loss_ema, np.float32)
    b = np.asarray(sb.loss_ema, np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-9)


def _agreement(pa, pb):
    assert sorted(pa) == sorted(pb)
    total = agree = 0
    for rid in pa:
        assert len(pa[rid]) == len(pb[rid])
        total += len(pa[rid])
        agree += sum(int(x == y) for x, y in zip(pa[rid], pb[rid]))
    return agree / total


# ---------------------------------------------------------------------------
# fp32 regression: bitwise the PR-6 golden fixture
# ---------------------------------------------------------------------------

GOLDEN_MODES = (
    ("none", {}),
    ("none-inc", {"refresh_mode": "incremental"}),
    ("forget", {"refresh_mode": "incremental", "retirement": "forget",
                "forget": 0.9}),
    ("window", {"refresh_mode": "incremental", "retirement": "window",
                "retire_window": 6}),
)
GOLDEN_STATE_LEAVES = (
    ("params_p", lambda s: s.params.p),
    ("params_q", lambda s: s.params.q),
    ("params_W", lambda s: s.params.W),
    ("params_b", lambda s: s.params.b),
    ("ridge_A", lambda s: s.ridge.A),
    ("ridge_B", lambda s: s.ridge.B),
    ("ridge_count", lambda s: s.ridge.count),
    ("ridge_Lt", lambda s: s.ridge.Lt),
    ("ridge_factor_beta", lambda s: s.ridge.factor_beta),
    ("step", lambda s: s.step),
    ("loss_ema", lambda s: s.loss_ema),
)


@pytest.fixture(scope="module")
def golden():
    fix = np.load(GOLDEN, allow_pickle=False)
    if str(fix["jax_version"]) != jax.__version__ or \
            str(fix["platform"]) != jax.default_backend():
        pytest.skip(
            "golden fixture generated on jax "
            f"{fix['jax_version']}/{fix['platform']}; this env is "
            f"{jax.__version__}/{jax.default_backend()} - bitwise pinning "
            "only holds for the exact compiler"
        )
    return fix


@pytest.mark.parametrize("mode,kw", GOLDEN_MODES,
                         ids=[m for m, _ in GOLDEN_MODES])
def test_fp32_serving_is_bitwise_the_pr6_golden(golden, mode, kw):
    """The default-path (quantize='none', step_block=1) episode reproduces
    the pre-PR-7 fixture bit for bit: predictions AND every PR-6 model
    state leaf.  This is the regression gate for 'the fp32 path must stay
    bitwise identical'."""
    preds, srv = _serve(**kw)
    for rid, p in preds.items():
        np.testing.assert_array_equal(
            np.asarray(p, np.int32), golden[f"{mode}/preds/{rid}"]
        )
    for name, get in GOLDEN_STATE_LEAVES:
        np.testing.assert_array_equal(
            np.asarray(get(srv.states)), golden[f"{mode}/state/{name}"],
            err_msg=f"{mode}: state leaf {name} drifted from the PR-6 fixture",
        )


# ---------------------------------------------------------------------------
# Step blocking: step_block=T == step_block=1, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kw", RETIREMENT_MODES,
                         ids=[m for m, _ in RETIREMENT_MODES])
@pytest.mark.parametrize("block", [2, 4])
def test_step_blocking_serves_the_unblocked_episode(block, mode, kw):
    """A blocked episode produces the step_block=1 predictions exactly and
    the same model state (cross-program tolerance on the diagnostic only):
    the block clamp pins admissions and refreshes to the unblocked
    schedule, and each sub-step is the same fused pool step."""
    preds_1, srv_1 = _serve(**kw)
    preds_b, srv_b = _serve(step_block=block, **kw)
    assert preds_1 == preds_b
    _assert_states_equal_cross_program(srv_1.states, srv_b.states)
    assert srv_1.global_step == srv_b.global_step
    for a, b in zip(sorted(srv_1.completed, key=lambda r: r.rid),
                    sorted(srv_b.completed, key=lambda r: r.rid)):
        assert a.correct == b.correct
        _assert_states_equal_cross_program(a.final_state, b.final_state)


def test_step_blocking_composes_with_pipelining_and_quantization():
    """step_block x pipeline_depth x quantize all compose: the blocked
    pipelined quantized episode equals the unblocked quantized one."""
    preds_q, srv_q = _serve(quantize="int8")
    preds_c, srv_c = _serve(quantize="int8", step_block=3, pipeline_depth=2)
    assert preds_q == preds_c
    _assert_states_equal_cross_program(srv_q.states, srv_c.states)


def test_step_blocking_dispatches_fewer_programs():
    """The point of blocking: a blocked episode runs fewer host dispatch
    rounds (step() calls) while serving every sample."""
    _, srv_1 = _serve()
    _, srv_b = _serve(step_block=4)
    assert len(srv_b.step_times_s) < len(srv_1.step_times_s)
    assert srv_1.global_step == srv_b.global_step
    for r in srv_b.completed:
        assert len(r.preds) == r.n_samples


# ---------------------------------------------------------------------------
# int8 serving: training untouched, slots arm, accuracy band
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kw", RETIREMENT_MODES,
                         ids=[m for m, _ in RETIREMENT_MODES])
def test_int8_serving_never_touches_training(mode, kw):
    """quantize='int8' changes ONLY the served argmax: params, ridge
    statistics, factors and counters are bit-for-bit the fp32 episode in
    every retirement mode (the fast path reads pre-update state; the
    absmax calibration writes only QuantParams)."""
    preds_f, srv_f = _serve(**kw)
    preds_q, srv_q = _serve(quantize="int8", **kw)
    _assert_states_equal_cross_program(srv_f.states, srv_q.states)
    # the measured band: int8 logits rarely flip the argmax at this size
    assert _agreement(preds_f, preds_q) >= 0.9


@pytest.mark.parametrize("mode,kw", RETIREMENT_MODES,
                         ids=[m for m, _ in RETIREMENT_MODES])
def test_scales_fold_at_refresh_boundaries(mode, kw):
    """Slots arm (w_scale, x_scale > 0) once their first cohort refresh
    fires, in every retirement mode; the absmax calibration is live from
    the first served window."""
    srv = StreamServer(CFG, t_max=16, max_streams=2, window=2,
                       phase_steps=2, refresh_every=3, quantize="int8", **kw)
    srv.submit(_make_stream(0, 12, seed=0))
    srv.submit(_make_stream(1, 12, seed=1))
    srv.step()
    assert np.all(np.asarray(srv.states.quant.x_absmax) > 0)
    assert np.all(np.asarray(srv.states.quant.w_scale) == 0)  # unarmed yet
    # phase_steps=2 SGD steps, then the first refresh at global step 3
    for _ in range(5):
        srv.step()
    srv.drain()
    ws = np.asarray(srv.states.quant.w_scale)
    xs = np.asarray(srv.states.quant.x_scale)
    assert np.all(ws > 0), f"{mode}: slots never armed (w_scale={ws})"
    assert np.all(xs > 0)
    wq = np.asarray(srv.states.quant.Wq)
    assert wq.dtype == np.int8 and np.any(wq != 0)
    # the folded codes reproduce W to within one scale step
    W = np.asarray(srv.states.params.W, np.float32)
    np.testing.assert_allclose(
        wq * ws[:, None, None], W, atol=float(ws.max()) * 0.5 + 1e-7
    )
    srv.run_until_drained()


def test_unarmed_slots_serve_fp32():
    """Before the first refresh boundary every prediction comes from the
    fp32 path: an episode truncated before any refresh matches the fp32
    server sample for sample."""
    def run(**kw):
        srv = StreamServer(CFG, t_max=16, max_streams=2, window=2,
                           phase_steps=2, refresh_every=100, **kw)
        srv.submit(_make_stream(0, 8, seed=0))
        srv.submit(_make_stream(1, 8, seed=1))
        done = srv.run_until_drained()
        return {r.rid: list(r.preds) for r in done}

    assert run() == run(quantize="int8")


# ---------------------------------------------------------------------------
# Kernel parity + edge cases
# ---------------------------------------------------------------------------


def _quant_operands(seed=0, nb=3, t=12, nx=8, ny=3, dtype=np.float32):
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(nb, t, CFG.n_in)).astype(dtype)
    mask = masking.make_mask(jax.random.PRNGKey(0), nx, CFG.n_in, u.dtype)
    j = masking.apply_mask(mask, jnp.asarray(u))
    lengths = jnp.asarray(rng.integers(3, t + 1, nb), jnp.int32)
    p, q = jnp.float32(0.4), jnp.float32(0.6)
    nr = nx * (nx + 1)
    W = rng.normal(size=(ny, nr)).astype(np.float32) * 0.05
    w_scale = ops.symmetric_scale(jnp.max(jnp.abs(jnp.asarray(W))))
    Wq = ops.quantize_symmetric(jnp.asarray(W), w_scale)
    b = jnp.asarray(rng.normal(size=(ny,)).astype(np.float32))
    return j, lengths, p, q, W, Wq, w_scale, b, nx


def test_q8_kernel_matches_its_oracle_exactly():
    """Pallas interpret vs the XLA oracle: the integer contract is shared
    op for op, so the two backends agree to fp32 rounding of the final
    dequant (integer intermediate math is exact)."""
    j, lengths, p, q, W, Wq, w_scale, b, nx = _quant_operands()
    x_scale = jnp.float32(0.02)
    out_xla = ops.streaming_logits_q8(
        j, lengths, p, q, Wq, w_scale, x_scale, b, nx, backend="xla")
    out_itp = ops.streaming_logits_q8(
        j, lengths, p, q, Wq, w_scale, x_scale, b, nx, backend="interpret")
    np.testing.assert_allclose(np.asarray(out_itp), np.asarray(out_xla),
                               rtol=1e-6, atol=1e-6)


def test_q8_logits_track_fp32_within_band():
    """With calibrated scales the int8 logits stay near the fp32 fused
    logits - the honest quantization-noise band at Nx=8."""
    j, lengths, p, q, W, Wq, w_scale, b, nx = _quant_operands()
    ref = ops.streaming_logits(
        j, lengths, p, q, jnp.asarray(W), b, nx, backend="xla")
    # calibrate the state scale from the actual fp32 trajectory
    from repro.core import reservoir as core_res
    x = core_res.run_reservoir(p, q, j, lengths=lengths)
    x_scale = ops.symmetric_scale(jnp.max(jnp.abs(x)))
    out = ops.streaming_logits_q8(
        j, lengths, p, q, Wq, w_scale, x_scale, b, nx, backend="xla")
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    rel = float(jnp.max(jnp.abs(out - ref))) / scale
    assert rel < 0.05, f"int8 logits off by {rel:.3%} of fp32 range"


def test_q8_zero_range_window_is_nan_free():
    """An all-zero input window (zero-range reservoir trajectory) must
    produce finite logits equal to the bias: all codes are zero and the
    epsilon-floored scales dequantize zeros exactly."""
    j = jnp.zeros((2, 6, 8), jnp.float32)
    lengths = jnp.asarray([6, 3], jnp.int32)
    _, _, p, q, W, Wq, w_scale, b, nx = _quant_operands()
    # unarmed scales (0) take the safe-scale path; armed tiny scales the
    # epsilon floor - both must be finite
    for xs, ws in ((jnp.float32(0.0), jnp.float32(0.0)),
                   (ops.symmetric_scale(jnp.float32(0.0)), w_scale)):
        out = ops.streaming_logits_q8(
            j, lengths, p, q, Wq, ws, xs, b, nx, backend="xla")
        assert np.all(np.isfinite(np.asarray(out)))
        np.testing.assert_allclose(
            np.asarray(out), np.broadcast_to(np.asarray(b), out.shape),
            rtol=1e-6, atol=1e-6)


def test_q8_serving_accepts_zero_streams_end_to_end():
    """A stream of all-zero samples serves NaN-free through the quantized
    server (scales floor at epsilon, logits collapse to the bias)."""
    z = StreamRequest(
        rid=0,
        u=np.zeros((6, 16, 2), np.float32),
        length=np.full((6,), 16, np.int32),
        label=np.zeros((6,), np.int32),
    )
    preds, srv = _serve([z, _make_stream(1, 6, seed=1)], quantize="int8")
    assert len(preds[0]) == 6
    for leaf in jax.tree_util.tree_leaves(srv.states.quant):
        assert np.all(np.isfinite(np.asarray(leaf, np.float64)))


def test_bf16_inputs_feed_the_int8_path():
    """A bf16 config serves through quantize='int8' (the wrapper upcasts
    the staged window to f32 for the integer kernel; scales stay f32
    bookkeeping), NaN-free, with the blocked path agreeing exactly."""
    cfg = dataclasses.replace(CFG, dtype=jnp.bfloat16)
    streams = lambda: [_make_stream(0, 6, seed=3), _make_stream(1, 6, seed=4)]
    preds_q, srv = _serve(streams(), cfg=cfg, quantize="int8",
                          refresh_mode="incremental")
    assert srv.states.params.W.dtype == jnp.bfloat16
    assert srv.states.quant.w_scale.dtype == jnp.float32   # fp32 bookkeeping
    assert srv.states.quant.x_absmax.dtype == jnp.float32
    for r in srv.completed:
        assert len(r.preds) == r.n_samples
    preds_b, _ = _serve(streams(), cfg=cfg, quantize="int8",
                        refresh_mode="incremental", step_block=2)
    assert preds_q == preds_b


# ---------------------------------------------------------------------------
# Round-trip error bound (hypothesis)
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_half_step_bound():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="optional dep: property tests only")
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 10_000), scale=st.floats(1e-6, 1e3),
           n=st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def check(seed, scale, n):
        """|dequantize(quantize(v)) - v| <= scale/2 for in-range v: the
        defining bound of symmetric round-to-nearest int8."""
        rng = np.random.default_rng(seed)
        v = (rng.uniform(-1.0, 1.0, n) * scale * 127.0).astype(np.float32)
        s = ops.symmetric_scale(jnp.max(jnp.abs(jnp.asarray(v))))
        q = ops.quantize_symmetric(jnp.asarray(v), s)
        rt = ops.dequantize_symmetric(q, s)
        err = np.max(np.abs(np.asarray(rt) - v))
        bound = float(s) * (0.5 + 1e-3)   # half a step + fp32 slack
        assert err <= bound, f"round-trip err {err} > {bound}"

    check()


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------


def test_invalid_knob_combinations_fail_loudly():
    mk = lambda **kw: StreamServer(CFG, t_max=16, **kw)
    with pytest.raises(ValueError, match="unknown quantize"):
        mk(quantize="int4")
    with pytest.raises(ValueError, match="staging='device'"):
        mk(quantize="int8", staging="host")
    with pytest.raises(ValueError, match="step_block"):
        mk(step_block=0)
    with pytest.raises(ValueError, match="staging='device'"):
        mk(step_block=2, staging="host")


def test_fold_quant_rows_scatter_contract():
    """fold_quant_rows arms exactly the eligible rows and leaves the rest
    untouched (padding rows in a staggered cohort must not arm)."""
    state = online.init_state(CFG)
    batched = jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (4, *leaf.shape)).copy(), state)
    batched = dataclasses.replace(
        batched,
        quant=dataclasses.replace(
            batched.quant, x_absmax=jnp.asarray([0.5, 0.5, 0.5, 0.5])),
    )
    rows = jnp.asarray([1, 3], jnp.int32)
    el = jnp.asarray([True, False])
    out = online.fold_quant_rows(batched, rows, el)
    ws = np.asarray(out.quant.w_scale)
    assert ws[1] > 0 and ws[0] == 0 and ws[2] == 0 and ws[3] == 0
    assert np.asarray(out.quant.x_scale)[1] > 0
    np.testing.assert_array_equal(np.asarray(out.quant.x_absmax),
                                  np.asarray(batched.quant.x_absmax))
