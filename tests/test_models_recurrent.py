"""RWKV6 / Mamba2-SSD: chunked-parallel scan == sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rwkv as rw
from repro.models import ssm


def sequential_rwkv(r, k, v, w, bonus, s0):
    """Token-by-token reference of the RWKV6 recurrence."""
    b, t, h, d = r.shape
    s = np.asarray(s0, np.float64)
    outs = np.zeros((b, t, h, d))
    rn, kn, vn, wn = (np.asarray(a, np.float64) for a in (r, k, v, w))
    bn = np.asarray(bonus, np.float64)
    for ti in range(t):
        kv = np.einsum("bhd,bhe->bhde", kn[:, ti], vn[:, ti])
        outs[:, ti] = np.einsum("bhd,bhde->bhe", rn[:, ti] * bn[None], kv) + \
            np.einsum("bhd,bhde->bhe", rn[:, ti], s)
        s = wn[:, ti][..., None] * s + kv
    return outs, s


@pytest.mark.parametrize("t,chunk", [(32, 8), (48, 16), (16, 16)])
def test_rwkv_chunked_matches_sequential(t, chunk):
    rng = np.random.default_rng(t)
    b, h, d = 2, 3, 8
    r = jnp.asarray(rng.normal(size=(b, t, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, t, h, d)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(b, t, h, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 0.99, size=(b, t, h, d)).astype(np.float32))
    bonus = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32)) * 0.1
    s0 = jnp.zeros((b, h, d, d))
    got, s_got = rw.rwkv_attention_chunked(r, k, v, w, bonus, s0, chunk=chunk)
    want, s_want = sequential_rwkv(r, k, v, w, bonus, s0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_got), s_want, rtol=2e-3, atol=2e-3)


def test_rwkv_decode_consistent_with_chunked():
    """Running T steps of decode == chunked block over the same tokens."""
    rng = np.random.default_rng(0)
    b, t, h, d = 1, 6, 2, 4
    r = jnp.asarray(rng.normal(size=(b, t, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, t, h, d)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(b, t, h, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.6, 0.95, size=(b, t, h, d)).astype(np.float32))
    bonus = jnp.zeros((h, d))
    s0 = jnp.zeros((b, h, d, d))
    chunked, s_c = rw.rwkv_attention_chunked(r, k, v, w, bonus, s0, chunk=t)
    seq, s_s = sequential_rwkv(r, k, v, w, bonus, s0)
    np.testing.assert_allclose(np.asarray(chunked), seq, rtol=2e-3, atol=2e-3)


def sequential_ssd(xh, a_log, bm, cm, s0):
    b, t, h, p = xh.shape
    n = bm.shape[-1]
    s = np.asarray(s0, np.float64)
    ys = np.zeros((b, t, h, p))
    xn, an, bn, cn = (np.asarray(v, np.float64) for v in (xh, a_log, bm, cm))
    for ti in range(t):
        s = np.exp(an[:, ti])[..., None, None] * s + np.einsum(
            "bn,bhp->bhnp", bn[:, ti], xn[:, ti]
        )
        ys[:, ti] = np.einsum("bn,bhnp->bhp", cn[:, ti], s)
    return ys, s


@pytest.mark.parametrize("t,chunk", [(32, 8), (24, 24)])
def test_ssd_chunked_matches_sequential(t, chunk):
    rng = np.random.default_rng(t)
    b, h, p, n = 2, 2, 4, 6
    xh = jnp.asarray(rng.normal(size=(b, t, h, p)).astype(np.float32))
    a_log = jnp.asarray(-rng.uniform(0.01, 0.5, size=(b, t, h)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, t, n)).astype(np.float32)) * 0.4
    cm = jnp.asarray(rng.normal(size=(b, t, n)).astype(np.float32))
    s0 = jnp.zeros((b, h, n, p))
    got, s_got = ssm.ssd_chunked(xh, a_log, bm, cm, s0, chunk=chunk)
    want, s_want = sequential_ssd(xh, a_log, bm, cm, s0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_got), s_want, rtol=2e-3, atol=2e-3)


def test_ssm_block_decode_matches_prefill():
    """One ssm_block_apply over T tokens == T single-token applies."""
    key = jax.random.PRNGKey(0)
    d, t, b = 32, 8, 1
    p = ssm.ssm_block_init(key, d, ssm_state=8, head_dim=16, expand=2,
                           dtype=jnp.float32)
    vals = jax.tree_util.tree_map(
        lambda pv: pv.value, p, is_leaf=lambda x: hasattr(x, "axes")
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d)) * 0.5
    st0 = ssm.ssm_state_init(b, d, 8, 16, 2)
    full, _ = ssm.ssm_block_apply(vals, x, st0, ssm_state=8, head_dim=16,
                                  expand=2, chunk=t)
    st = ssm.ssm_state_init(b, d, 8, 16, 2)
    outs = []
    for ti in range(t):
        o, st = ssm.ssm_block_apply(vals, x[:, ti:ti+1], st, ssm_state=8,
                                    head_dim=16, expand=2, chunk=1)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), rtol=5e-3,
                               atol=5e-3)
