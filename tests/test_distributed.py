"""Distribution: guarded specs, sharded train step == single-device step
(8 virtual host devices via subprocess), compression collectives."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def test_guarded_spec_divisibility(monkeypatch):
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = shd.guarded_spec((8, 128), ("kv", "kv_alt"), FakeMesh(),
                            dict(shd.DEFAULT_RULES))
    # 8 kv heads indivisible by 16 -> falls to head_dim via kv_alt
    assert spec == P(None, "model")
    spec2 = shd.guarded_spec((32, 128), ("kv", "kv_alt"), FakeMesh(),
                             dict(shd.DEFAULT_RULES))
    # both divisible, but 'model' already used by dim0 -> dim1 unsharded
    assert spec2 == P("model", None)


def test_guarded_spec_multi_axis_batch():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = shd.guarded_spec((64, 128), ("batch", None), FakeMesh(),
                            dict(shd.DEFAULT_RULES))
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k): indivisible -> replicated
    spec = shd.guarded_spec((1, 128), ("batch", None), FakeMesh(),
                            dict(shd.DEFAULT_RULES))
    assert spec == P(None, None)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_reduced
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models.lm import make_train_step
    from repro.models.transformer import Transformer
    from repro.optim.optimizers import make_optimizer
    from repro.optim.schedule import constant_schedule

    cfg = get_reduced("smollm-135m")
    model = Transformer(cfg)
    opt = make_optimizer("adamw")
    step_fn = make_train_step(model, opt, constant_schedule(1e-3), accum=1)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}

    def run(mesh):
        with shd.use_mesh(mesh):
            params, axes = model.init(jax.random.PRNGKey(0))
            if mesh is not None:
                params = jax.device_put(
                    params, shd.guarded_shardings(params, axes, mesh))
            opt_state = opt.init(params)
            p2, _, m = jax.jit(step_fn)(params, opt_state, jnp.asarray(0), batch)
            return float(m["loss"]), jax.device_get(p2)

    loss_single, p_single = run(None)
    mesh = make_host_mesh(data=4, model=2)
    loss_shard, p_shard = run(mesh)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree_util.tree_leaves(p_single),
                             jax.tree_util.tree_leaves(p_shard))]
    print(json.dumps({"loss_single": loss_single, "loss_shard": loss_shard,
                      "max_param_diff": max(diffs)}))
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """2x4 mesh (8 virtual devices, subprocess) == single device numerics."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/root"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(rec["loss_single"] - rec["loss_shard"]) < 2e-2
    assert rec["max_param_diff"] < 2e-2


def test_compressed_psum_single_shard_identity():
    """With axis size 1, compressed psum == plain quantized passthrough and
    the error feedback residual shrinks the bias across steps."""
    from repro.optim.compression import tree_compressed_psum

    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                          jnp.float32)}
    res = jax.tree_util.tree_map(jnp.zeros_like, g)

    def step(grads, res):
        return jax.jit(
            lambda gg, rr: tree_compressed_psum(gg, (), rr)
        )(grads, res)

    # () axis: degenerate psum - exercise quantize/dequantize + residual
    out, res = step(g, res)
    err1 = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    out2, res = step(g, res)
    err2 = float(jnp.max(jnp.abs(out2["w"] + res["w"] - g["w"])))
    assert err1 < 0.02 * float(jnp.max(jnp.abs(g["w"])))
    assert err2 <= err1 + 1e-6


# ---------------------------------------------------------------------------
# Ensemble member axis (online serving): rule table + psum-exact online step
# ---------------------------------------------------------------------------


def test_member_axis_shards_ensemble_state():
    """The 'member' logical axis shards the ensemble K axis over the data
    axes, with the divisibility guard and per-array uniqueness intact."""

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 2}

    rules = dict(shd.DEFAULT_RULES)
    # K=16 divisible by pod*data=8 -> sharded; trailing dims replicated
    spec = shd.guarded_spec((16, 10, 992), ("member", None, None),
                            FakeMesh(), rules)
    assert spec == P(("pod", "data"), None, None)
    # K=4 indivisible by 8 -> replicated (guard, not an error)
    spec = shd.guarded_spec((4,), ("member",), FakeMesh(), rules)
    assert spec == P(None)


def test_ensemble_logical_axes_cover_state():
    """ensemble_logical_axes() mirrors the OnlineState tree leaf-for-leaf
    and every leaf leads with 'member'."""
    from repro.core.online import OnlineEnsemble, ensemble_logical_axes
    from repro.core.types import DFRConfig

    cfg = DFRConfig(n_in=2, n_classes=3, n_nodes=6)
    state = OnlineEnsemble(cfg, 4).init()
    axes = ensemble_logical_axes()
    state_leaves, state_def = jax.tree_util.tree_flatten(state)
    axes_leaves, axes_def = jax.tree_util.tree_flatten(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    assert state_def == axes_def
    for leaf, ax in zip(state_leaves, axes_leaves):
        assert ax[0] == "member"
        assert len(ax) == leaf.ndim


def test_slot_axis_rule_resolution():
    """The 'slot' logical axis prefers a dedicated serving-mesh axis, falls
    back to the production data axes, and replicates when indivisible."""

    class SlotMesh:
        axis_names = ("slot",)
        shape = {"slot": 8}

    class ProdMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 2}

    rules = dict(shd.DEFAULT_RULES)
    # serving mesh: S=64 divisible by 8 -> sharded over "slot"
    spec = shd.guarded_spec((64, 57, 57), ("slot", None, None),
                            SlotMesh(), rules)
    assert spec == P("slot", None, None)
    # production mesh (no "slot" axis): falls back to the data axes
    spec = shd.guarded_spec((64, 57, 57), ("slot", None, None),
                            ProdMesh(), rules)
    assert spec == P(("pod", "data"), None, None)
    # indivisible slot count -> replicated (guard, not an error)
    spec = shd.guarded_spec((6,), ("slot",), SlotMesh(), rules)
    assert spec == P(None)


def test_combined_slot_member_spec():
    """An ensemble-of-slots state ((S, K, ...) leaves) on a 2-D serving
    mesh shards slot AND member at once; on the production mesh the
    uniqueness guard gives 'slot' the data axes and replicates 'member'."""

    class SlotMemberMesh:
        axis_names = ("slot", "member")
        shape = {"slot": 4, "member": 2}

    class ProdMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 2}

    rules = dict(shd.DEFAULT_RULES)
    spec = shd.guarded_spec((8, 4, 57, 57), ("slot", "member", None, None),
                            SlotMemberMesh(), rules)
    assert spec == P("slot", "member", None, None)
    spec = shd.guarded_spec((8, 8, 57, 57), ("slot", "member", None, None),
                            ProdMesh(), rules)
    assert spec == P(("pod", "data"), None, None, None)


def test_slot_logical_axes_cover_state():
    """slot_logical_axes() / ensemble_slot_logical_axes() mirror the
    OnlineState tree leaf-for-leaf with 'slot' leading (and 'member'
    second for the ensemble-of-slots variant)."""
    from repro.core.online import (
        ensemble_slot_logical_axes, init_state, slot_logical_axes,
    )
    from repro.core.types import DFRConfig

    cfg = DFRConfig(n_in=2, n_classes=3, n_nodes=6)
    state = init_state(cfg)
    state_leaves, state_def = jax.tree_util.tree_flatten(state)
    for axes_tree, lead in ((slot_logical_axes(), ("slot",)),
                            (ensemble_slot_logical_axes(),
                             ("slot", "member"))):
        axes_leaves, axes_def = jax.tree_util.tree_flatten(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))
        assert state_def == axes_def
        for leaf, ax in zip(state_leaves, axes_leaves):
            assert ax[:len(lead)] == lead
            # batching stacks len(lead) leading dims onto each leaf
            assert len(ax) == leaf.ndim + len(lead)


def test_online_step_psum_matches_unsharded():
    """online_step(axis_names=('data',)) inside shard_map over a 1-device
    data mesh reproduces the plain step exactly ((A, B)/grad sums are
    associative, so the psum is the identity at world size 1)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.core import online
    from repro.core.types import DFRConfig

    cfg = DFRConfig(n_in=2, n_classes=2, n_nodes=6)
    system = online.OnlineDFR(cfg)
    state = system.init()
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(4, 10, 2)).astype(np.float32))
    ln = jnp.asarray(rng.integers(3, 11, 4), jnp.int32)
    lab = jnp.asarray(rng.integers(0, 2, 4), jnp.int32)
    lr = jnp.float32(0.2)

    ref_state, ref_metrics = system.step(state, u, ln, lab, lr, lr)

    mesh = jax.make_mesh((1,), ("data",))
    P_ = PartitionSpec
    sharded = shard_map(
        lambda st, uu, ll, yy: online.online_step(
            cfg, system.mask, st, uu, ll, yy, lr, lr, axis_names=("data",)
        ),
        mesh=mesh,
        in_specs=(P_(), P_("data"), P_("data"), P_("data")),
        out_specs=P_(),
        check_rep=False,
    )
    got_state, got_metrics = jax.jit(sharded)(state, u, ln, lab)
    for a, b in zip(jax.tree_util.tree_leaves(ref_state),
                    jax.tree_util.tree_leaves(got_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(ref_metrics["loss"]),
                               float(got_metrics["loss"]), rtol=1e-6)
