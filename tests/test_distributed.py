"""Distribution: guarded specs, sharded train step == single-device step
(8 virtual host devices via subprocess), compression collectives."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def test_guarded_spec_divisibility(monkeypatch):
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = shd.guarded_spec((8, 128), ("kv", "kv_alt"), FakeMesh(),
                            dict(shd.DEFAULT_RULES))
    # 8 kv heads indivisible by 16 -> falls to head_dim via kv_alt
    assert spec == P(None, "model")
    spec2 = shd.guarded_spec((32, 128), ("kv", "kv_alt"), FakeMesh(),
                             dict(shd.DEFAULT_RULES))
    # both divisible, but 'model' already used by dim0 -> dim1 unsharded
    assert spec2 == P("model", None)


def test_guarded_spec_multi_axis_batch():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = shd.guarded_spec((64, 128), ("batch", None), FakeMesh(),
                            dict(shd.DEFAULT_RULES))
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k): indivisible -> replicated
    spec = shd.guarded_spec((1, 128), ("batch", None), FakeMesh(),
                            dict(shd.DEFAULT_RULES))
    assert spec == P(None, None)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_reduced
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models.lm import make_train_step
    from repro.models.transformer import Transformer
    from repro.optim.optimizers import make_optimizer
    from repro.optim.schedule import constant_schedule

    cfg = get_reduced("smollm-135m")
    model = Transformer(cfg)
    opt = make_optimizer("adamw")
    step_fn = make_train_step(model, opt, constant_schedule(1e-3), accum=1)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}

    def run(mesh):
        with shd.use_mesh(mesh):
            params, axes = model.init(jax.random.PRNGKey(0))
            if mesh is not None:
                params = jax.device_put(
                    params, shd.guarded_shardings(params, axes, mesh))
            opt_state = opt.init(params)
            p2, _, m = jax.jit(step_fn)(params, opt_state, jnp.asarray(0), batch)
            return float(m["loss"]), jax.device_get(p2)

    loss_single, p_single = run(None)
    mesh = make_host_mesh(data=4, model=2)
    loss_shard, p_shard = run(mesh)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree_util.tree_leaves(p_single),
                             jax.tree_util.tree_leaves(p_shard))]
    print(json.dumps({"loss_single": loss_single, "loss_shard": loss_shard,
                      "max_param_diff": max(diffs)}))
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """2x4 mesh (8 virtual devices, subprocess) == single device numerics."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/root"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(rec["loss_single"] - rec["loss_shard"]) < 2e-2
    assert rec["max_param_diff"] < 2e-2


def test_compressed_psum_single_shard_identity():
    """With axis size 1, compressed psum == plain quantized passthrough and
    the error feedback residual shrinks the bias across steps."""
    from repro.optim.compression import tree_compressed_psum

    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                          jnp.float32)}
    res = jax.tree_util.tree_map(jnp.zeros_like, g)

    def step(grads, res):
        return jax.jit(
            lambda gg, rr: tree_compressed_psum(gg, (), rr)
        )(grads, res)

    # () axis: degenerate psum - exercise quantize/dequantize + residual
    out, res = step(g, res)
    err1 = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    out2, res = step(g, res)
    err2 = float(jnp.max(jnp.abs(out2["w"] + res["w"] - g["w"])))
    assert err1 < 0.02 * float(jnp.max(jnp.abs(g["w"])))
    assert err2 <= err1 + 1e-6
