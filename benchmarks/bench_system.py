"""End-to-end system benchmark: paper Tables 9/11 analogue.

The paper compares FPGA (fused on-device pipeline) vs the same algorithm as
plain software on the on-board ARM.  The CPU-container analogue:

  * 'sw_only'  - the op-by-op NumPy implementation (faithful Alg. 1-4 loops
    + unjitted reservoir), i.e. what "run the C code on the processor" is
    to the FPGA,
  * 'fused'    - the end-to-end jitted online system (one XLA program per
    step: reservoir -> DPRR -> truncated bp -> SGD -> (A,B) accumulation,
    plus a jitted ridge refresh), our stand-in for "everything in
    hardware",
  * the 'non-pipelined' row of Table 11 maps to the fused system with the
    ridge solve in packed (sequential) form instead of blocked.

Reported: train time, inference time, ratio (the paper's 13x claim is
FPGA-vs-ARM; here the ratio quantifies fusion/compilation win on identical
silicon - see EXPERIMENTS.md for the mapping).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OnlineDFR, masking, ridge
from repro.core.types import DFRConfig
from repro.data import PAPER_DATASETS, load


def _sw_only_epoch(cfg: DFRConfig, mask, u, lengths, labels):
    """Plain NumPy op-by-op reservoir + DPRR + ridge (no jit, no fusion)."""
    mask_n = np.asarray(mask)
    p, q = 0.1, 0.1
    s = cfg.s
    A = np.zeros((cfg.n_classes, s), np.float32)
    B = np.zeros((s, s), np.float32)
    for i in range(u.shape[0]):
        t_len = int(lengths[i])
        x_prev = np.zeros(cfg.n_nodes, np.float32)
        r_outer = np.zeros((cfg.n_nodes, cfg.n_nodes), np.float32)
        r_sum = np.zeros(cfg.n_nodes, np.float32)
        for k in range(t_len):
            j_k = mask_n @ np.asarray(u[i, k])
            a = p * (j_k + x_prev)
            x_k = np.empty_like(x_prev)
            ring = x_prev[-1]
            for n in range(cfg.n_nodes):          # the paper's node loop
                ring = a[n] + q * ring
                x_k[n] = ring
            r_outer += np.outer(x_k, x_prev)
            r_sum += x_k
            x_prev = x_k
        rt = np.concatenate([r_outer.reshape(-1), r_sum, [1.0]])
        onehot = np.zeros(cfg.n_classes, np.float32)
        onehot[int(labels[i])] = 1.0
        A += np.outer(onehot, rt)
        B += np.outer(rt, rt)
    W = ridge.ridge_cholesky_packed_numpy(A, B + 1e-2 * np.eye(s, dtype=np.float32))
    return W


def run(full: bool = False) -> List[Dict]:
    name = "JPVOW"
    spec = PAPER_DATASETS[name]
    cap = 60 if not full else 270
    train, test = load(name, size_cap=cap)
    cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes, n_nodes=30)

    # --- sw_only ---
    t0 = time.perf_counter()
    _sw_only_epoch(cfg, masking.make_mask(jax.random.PRNGKey(0), cfg.n_nodes,
                                          cfg.n_in),
                   np.asarray(train.u), np.asarray(train.length),
                   np.asarray(train.label))
    sw_train = time.perf_counter() - t0

    # --- fused online system ---
    online = OnlineDFR(cfg)
    state = online.init()
    # warm up compile, then time steady-state
    state, _ = online.step(state, train.u[:4], train.length[:4],
                           train.label[:4], jnp.float32(0.5), jnp.float32(0.5))
    t0 = time.perf_counter()
    for lo in range(0, train.batch - 3, 4):
        state, _ = online.step(state, train.u[lo:lo+4], train.length[lo:lo+4],
                               train.label[lo:lo+4], jnp.float32(0.5),
                               jnp.float32(0.5))
    state = online.refresh_output(state, jnp.float32(1e-2))
    jax.block_until_ready(state.params.W)
    fused_train = time.perf_counter() - t0

    # --- inference ---
    online.infer(state, test.u[:4], test.length[:4])  # warm
    t0 = time.perf_counter()
    preds = online.infer(state, test.u, test.length)
    jax.block_until_ready(preds)
    fused_infer = time.perf_counter() - t0

    return [{
        "table": "T9/T11-system", "dataset": name, "n_train": int(train.batch),
        "sw_only_train_s": round(sw_train, 2),
        "fused_train_s": round(fused_train, 2),
        "fused_infer_s": round(fused_infer, 3),
        "train_speedup": round(sw_train / fused_train, 1),
        "paper_fpga_speedup": 13.2,  # 5.56s / 0.42s (Table 9, for context)
    }]
