"""Rebuild EXPERIMENTS.md S2/S3 tables from the final dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.build_experiments
"""
from __future__ import annotations

import glob
import json
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "dryrun"


def load(tag, mesh):
    out = {}
    for f in glob.glob(str(ART / f"*__{mesh}__{tag}.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def table(base, opt):
    lines = [
        "| arch | shape | dom (base->opt) | compute_s | memory_s b->o | "
        "collective_s b->o | frac base | frac opt | useful_flops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for k in sorted(base):
        b = base[k]
        o = opt.get(k, b)
        if b["status"] == "skipped":
            lines.append(
                f"| {k[0]} | {k[1]} | SKIP | - | - | - | - | - | - |")
            continue
        if b["status"] != "ok":
            lines.append(f"| {k[0]} | {k[1]} | ERROR | - | - | - | - | - | - |")
            continue
        rb = b["roofline"]
        ro = o["roofline"] if o["status"] == "ok" else rb
        lines.append(
            f"| {k[0]} | {k[1]} | {rb['dominant'][:4]}->{ro['dominant'][:4]} "
            f"| {ro['compute_s']:.2f} "
            f"| {rb['memory_s']:.2f}->{ro['memory_s']:.2f} "
            f"| {rb['collective_s']:.2f}->{ro['collective_s']:.2f} "
            f"| {rb['roofline_fraction']:.3f} | {ro['roofline_fraction']:.3f} "
            f"| {(o.get('useful_flops_ratio') or 0):.3f} |"
        )
    return "\n".join(lines)


def main():
    base_s = load("final_base", "pod16x16")
    opt_s = load("final_opt", "pod16x16")
    base_m = load("final_base", "pod2x16x16")
    opt_m = load("final_opt", "pod2x16x16")
    if not base_m:  # fall back to the first-pass multi-pod artifacts
        base_m = load("baseline", "pod2x16x16")

    n_ok_s = sum(1 for r in base_s.values() if r["status"] == "ok")
    n_skip_s = sum(1 for r in base_s.values() if r["status"] == "skipped")
    n_ok_m = sum(1 for r in base_m.values() if r["status"] == "ok")
    n_skip_m = sum(1 for r in base_m.values() if r["status"] == "skipped")

    txt = ROOT.joinpath("EXPERIMENTS.md").read_text()

    block = f"""## S3. Roofline - final tables (single-pod 16x16)

`base` = as-designed framework defaults (XLA blockwise attention);
`opt` = `attn_impl=pallas` (flash-attention kernel; VMEM-resident interior)
plus the framework-wide S4 fixes (fsdp_gather, bf16 router, convert-aware
TPU-target accounting).  {n_ok_s} compiled cells + {n_skip_s} documented
long_500k skips.

{table(base_s, opt_s)}

Reading guide: decode cells are inherently memory-bound (one token cannot
amortize parameter reads) - the memory term is their figure of merit, and
roofline_frac ~ 0 is expected, not a defect.  The headline gains:
smollm/prefill_32k reaches **frac 1.000 (compute-bound at the MXU)**,
qwen2-vl/prefill 0.526, qwen1.5-110b/prefill 0.445, smollm/train 0.132
(12x over its 0.011 baseline).  useful_flops = 6ND / compiled-FLOPs: the
remaining gap is causal-masking waste in the XLA fallback cells, remat
recompute, MoE capacity slack (1.25x) and dispatch einsum FLOPs.

### Multi-pod (2 x 16 x 16): {n_ok_m} ok + {n_skip_m} documented skips

{table(base_m, opt_m or base_m)}
"""
    start = txt.index("## S3.")
    end = txt.index("## S4.")
    txt = txt[:start] + block + "\n---\n\n" + txt[end:]
    ROOT.joinpath("EXPERIMENTS.md").write_text(txt)
    print("EXPERIMENTS.md S3 rebuilt:",
          f"single {n_ok_s}ok/{n_skip_s}skip, multi {n_ok_m}ok/{n_skip_m}skip")


if __name__ == "__main__":
    main()
