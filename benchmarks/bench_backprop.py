"""Backprop-vs-grid-search benchmarks: paper Tables 5 and 6, Fig. 7."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import DFRModel
from repro.core.grid_search import grid_search, grid_search_until
from repro.core.types import DFRConfig
from repro.data import PAPER_DATASETS, load

# Table 6 external baselines (quoted from the paper; we do not re-train
# MLP/FCN/... here - they contextualize our bp accuracy on REAL data, while
# this benchmark reports bp on the synthetic stand-ins, see DESIGN.md Sec 6).
PAPER_TABLE6 = {
    "ARAB": {"MLP": 0.969, "FCN": 0.994, "ResNet": 0.996, "TWIESN": 0.853, "paper_bp": 0.981},
    "JPVOW": {"MLP": 0.976, "FCN": 0.993, "ResNet": 0.992, "TWIESN": 0.965, "paper_bp": 0.978},
    "ECG": {"MLP": 0.748, "FCN": 0.872, "ResNet": 0.867, "TWIESN": 0.737, "paper_bp": 0.850},
    "LIB": {"MLP": 0.780, "FCN": 0.964, "ResNet": 0.954, "TWIESN": 0.794, "paper_bp": 0.806},
    "UWAV": {"MLP": 0.901, "FCN": 0.934, "ResNet": 0.926, "TWIESN": 0.754, "paper_bp": 0.850},
    "WAF": {"MLP": 0.894, "FCN": 0.982, "ResNet": 0.989, "TWIESN": 0.949, "paper_bp": 0.983},
}

DEFAULT_SETS = ("JPVOW", "ECG", "LIB")
FULL_SETS = tuple(PAPER_DATASETS)


def table5_bp_vs_grid(
    datasets=DEFAULT_SETS, size_cap: int | None = None, n_nodes: int = 30,
    match_protocol: bool = False,
) -> List[Dict]:
    """NOTE: size_cap=None uses the full Table-4 sizes for the default sets
    (JPVOW 270 / ECG 100 / LIB 180): with s = 931 ridge features, starving
    the train set below ~200 samples makes epoch selection noise-bound."""
    """Table 5: bp accuracy/time vs grid search.

    match_protocol=True runs the paper's exact protocol (grow grid divisions
    until gs accuracy matches bp) - expensive; default compares against a
    fixed 4-division grid (64 points x 4 betas) plus reports the protocol
    ratio for the paper's headline claim on one dataset.
    """
    rows = []
    for name in datasets:
        spec = PAPER_DATASETS[name]
        train, test = load(name, size_cap=size_cap)
        cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes, n_nodes=n_nodes)
        m = DFRModel.create(cfg)

        t0 = time.perf_counter()
        params = m.fit(train, minibatch=4)
        bp_time = time.perf_counter() - t0
        bp_acc = float(m.accuracy(test, params))

        if match_protocol:
            gs = grid_search_until(cfg, train, test, target_acc=bp_acc, max_divs=12)
            gs_time, gs_acc, divs = gs["total_time_s"], gs["acc"], gs["divs"]
        else:
            gs = grid_search(cfg, train, test, divs=4)
            gs_time, gs_acc, divs = gs["time_s"], gs["acc"], 4
        rows.append({
            "table": "T5-bp-vs-gs", "dataset": name,
            "bp_acc": round(bp_acc, 3), "bp_time_s": round(bp_time, 1),
            "gs_acc": round(gs_acc, 3), "gs_time_s": round(gs_time, 1),
            "gs_divs": divs,
            "gs_over_bp_time": round(gs_time / bp_time, 2),
            "bp_p": round(float(params.p), 4), "bp_q": round(float(params.q), 4),
        })
    return rows


def table6_accuracy_context(datasets=("JPVOW", "ECG")) -> List[Dict]:
    rows = []
    for name in datasets:
        if name not in PAPER_TABLE6:
            continue
        spec = PAPER_DATASETS[name]
        train, test = load(name)
        cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes, n_nodes=30)
        m = DFRModel.create(cfg)
        params = m.fit(train, minibatch=4)
        rows.append({
            "table": "T6-context", "dataset": name,
            "ours_bp_synthetic": round(float(m.accuracy(test, params)), 3),
            **{f"paper_{k}": v for k, v in PAPER_TABLE6[name].items()},
        })
    return rows


def run(full: bool = False) -> List[Dict]:
    sets = FULL_SETS if full else DEFAULT_SETS
    rows = table5_bp_vs_grid(datasets=sets)
    rows += table6_accuracy_context(("JPVOW",) if not full else tuple(PAPER_TABLE6))
    return rows
