"""Backprop-vs-grid-search benchmarks: paper Tables 5 and 6, Fig. 7.

Also owns the fused-training-kernel table (PR 10): ``train_fused_table``
measures the no-materialized-X fused forward + closed-form truncated VJP
(``backprop.grads_truncated_fused``) against the scan baseline
(``grads_truncated``: run_reservoir -> stacked X -> compute_dprr ->
autodiff), with host-independent HLO memory columns proving the
O(T Nx) -> O(Nx^2) per-sample activation-memory drop.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List

from repro.core import DFRModel
from repro.core.grid_search import grid_search, grid_search_until
from repro.core.types import DFRConfig
from repro.data import PAPER_DATASETS, load

# Table 6 external baselines (quoted from the paper; we do not re-train
# MLP/FCN/... here - they contextualize our bp accuracy on REAL data, while
# this benchmark reports bp on the synthetic stand-ins, see DESIGN.md Sec 6).
PAPER_TABLE6 = {
    "ARAB": {"MLP": 0.969, "FCN": 0.994, "ResNet": 0.996, "TWIESN": 0.853, "paper_bp": 0.981},
    "JPVOW": {"MLP": 0.976, "FCN": 0.993, "ResNet": 0.992, "TWIESN": 0.965, "paper_bp": 0.978},
    "ECG": {"MLP": 0.748, "FCN": 0.872, "ResNet": 0.867, "TWIESN": 0.737, "paper_bp": 0.850},
    "LIB": {"MLP": 0.780, "FCN": 0.964, "ResNet": 0.954, "TWIESN": 0.794, "paper_bp": 0.806},
    "UWAV": {"MLP": 0.901, "FCN": 0.934, "ResNet": 0.926, "TWIESN": 0.754, "paper_bp": 0.850},
    "WAF": {"MLP": 0.894, "FCN": 0.982, "ResNet": 0.989, "TWIESN": 0.949, "paper_bp": 0.983},
}

DEFAULT_SETS = ("JPVOW", "ECG", "LIB")
FULL_SETS = tuple(PAPER_DATASETS)


def table5_bp_vs_grid(
    datasets=DEFAULT_SETS, size_cap: int | None = None, n_nodes: int = 30,
    match_protocol: bool = False,
) -> List[Dict]:
    """NOTE: size_cap=None uses the full Table-4 sizes for the default sets
    (JPVOW 270 / ECG 100 / LIB 180): with s = 931 ridge features, starving
    the train set below ~200 samples makes epoch selection noise-bound."""
    """Table 5: bp accuracy/time vs grid search.

    match_protocol=True runs the paper's exact protocol (grow grid divisions
    until gs accuracy matches bp) - expensive; default compares against a
    fixed 4-division grid (64 points x 4 betas) plus reports the protocol
    ratio for the paper's headline claim on one dataset.
    """
    rows = []
    for name in datasets:
        spec = PAPER_DATASETS[name]
        train, test = load(name, size_cap=size_cap)
        cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes, n_nodes=n_nodes)
        m = DFRModel.create(cfg)

        t0 = time.perf_counter()
        params = m.fit(train, minibatch=4)
        bp_time = time.perf_counter() - t0
        bp_acc = float(m.accuracy(test, params))

        if match_protocol:
            gs = grid_search_until(cfg, train, test, target_acc=bp_acc, max_divs=12)
            gs_time, gs_acc, divs = gs["total_time_s"], gs["acc"], gs["divs"]
        else:
            gs = grid_search(cfg, train, test, divs=4)
            gs_time, gs_acc, divs = gs["time_s"], gs["acc"], 4
        rows.append({
            "table": "T5-bp-vs-gs", "dataset": name,
            "bp_acc": round(bp_acc, 3), "bp_time_s": round(bp_time, 1),
            "gs_acc": round(gs_acc, 3), "gs_time_s": round(gs_time, 1),
            "gs_divs": divs,
            "gs_over_bp_time": round(gs_time / bp_time, 2),
            "bp_p": round(float(params.p), 4), "bp_q": round(float(params.q), 4),
        })
    return rows


def table6_accuracy_context(datasets=("JPVOW", "ECG")) -> List[Dict]:
    rows = []
    for name in datasets:
        if name not in PAPER_TABLE6:
            continue
        spec = PAPER_DATASETS[name]
        train, test = load(name)
        cfg = DFRConfig(n_in=spec.n_in, n_classes=spec.n_classes, n_nodes=30)
        m = DFRModel.create(cfg)
        params = m.fit(train, minibatch=4)
        rows.append({
            "table": "T6-context", "dataset": name,
            "ours_bp_synthetic": round(float(m.accuracy(test, params)), 3),
            **{f"paper_{k}": v for k, v in PAPER_TABLE6[name].items()},
        })
    return rows


def run(full: bool = False) -> List[Dict]:
    sets = FULL_SETS if full else DEFAULT_SETS
    rows = table5_bp_vs_grid(datasets=sets)
    rows += table6_accuracy_context(("JPVOW",) if not full else tuple(PAPER_TABLE6))
    return rows


# ---------------------------------------------------------------------------
# Fused training-path kernel vs scan baseline (PR 10)
# ---------------------------------------------------------------------------


def _best_time(fn, *args, reps: int = 3) -> float:
    import jax

    out = fn(*args)                       # warm the jit cache
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _paired_times(fn_a, fn_b, *args, reps: int = 3):
    """Best-of-``reps`` for two programs with ALTERNATING reps (the PR-5
    paired round-robin protocol): back-to-back A/B pairs see the same
    host load, so their ratio is robust to drift that would skew
    timing all of A then all of B."""
    import jax

    for fn in (fn_a, fn_b):               # warm both jit caches first
        jax.block_until_ready(fn(*args))
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def _program_memory(fn, *args) -> Dict:
    """Host-independent memory columns of ``jit(fn)(*args)``: HLO traffic
    bytes (launch.hlo_cost) and - where XLA exposes it - the compiled
    executable's temp-buffer allocation, the direct witness that the
    (B, T, Nx) state sequence is (or is not) materialized between the
    forward and the backward."""
    import jax

    from repro.launch import hlo_cost

    compiled = jax.jit(fn).lower(*args).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    out = {"hlo_flops": cost.flops, "hlo_mem_bytes": cost.mem_bytes}
    try:
        out["temp_alloc_bytes"] = int(
            compiled.memory_analysis().temp_size_in_bytes)
    except Exception:                     # backend doesn't expose it
        out["temp_alloc_bytes"] = None
    return out


def _train_fused_cell(nx: int, b: int, t_len: int, reps: int) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.core import backprop as bp
    from repro.core.types import DFRParams

    cfg = DFRConfig(n_in=3, n_classes=4, n_nodes=nx, nonlinearity="tanh")
    f = cfg.f()
    key = jax.random.PRNGKey(nx * 1000 + b)
    params = DFRParams(
        p=jnp.float32(0.3), q=jnp.float32(0.4),
        W=0.05 * jax.random.normal(key, (4, cfg.n_rep)),
        b=jnp.zeros(4, jnp.float32),
    )
    j_seq = jax.random.normal(jax.random.PRNGKey(b), (b, t_len, nx),
                              jnp.float32)
    lengths = jnp.full((b,), t_len, jnp.int32)
    onehot = jax.nn.one_hot(jnp.arange(b) % 4, 4)

    scan_fn = jax.jit(lambda pp, j, y, le: bp.grads_truncated(
        pp, j, y, f, le))
    fused_fn = jax.jit(lambda pp, j, y, le: bp.grads_truncated_fused(
        pp, j, y, f, le))
    t_scan, t_fused = _paired_times(scan_fn, fused_fn, params, j_seq,
                                    onehot, lengths, reps=reps)
    mem_scan = _program_memory(
        lambda pp, j, y, le: bp.grads_truncated(pp, j, y, f, le),
        params, j_seq, onehot, lengths)
    mem_fused = _program_memory(
        lambda pp, j, y, le: bp.grads_truncated_fused(pp, j, y, f, le),
        params, j_seq, onehot, lengths)
    return {
        "table": "train-fused", "cell": f"Nx{nx}/B{b}/T{t_len}",
        "fused_time_s": round(t_fused, 6),
        "scan_samples_per_s": round(b / t_scan, 1),
        "fused_samples_per_s": round(b / t_fused, 1),
        "fused_over_scan_speedup": round(t_scan / t_fused, 3),
        **{f"scan_{k}": v for k, v in mem_scan.items()},
        **{f"fused_{k}": v for k, v in mem_fused.items()},
    }


def train_fused_table(
    nx_list=(8, 16), batches=(16, 64, 256), t_len: int = 64, reps: int = 3,
    long_ts=(256, 1024), smoke: bool = False,
) -> List[Dict]:
    """Fused vs scan truncated-BP gradients: (Nx, B) grid at T=``t_len``
    plus a T sweep (``long_ts``) at the largest (Nx, B) cell.

    Per cell: best-of-``reps`` wall time of one jitted grad step for both
    paths (samples/sec + speedup), plus the memory columns of each
    program.  The scan baseline's backward must hold the stacked (B, T,
    Nx) states; the fused path carries only the O(Nx^2) DPRR accumulator -
    ``*_temp_alloc_bytes`` makes the drop auditable per cell, and the T
    sweep shows it staying flat while the scan baseline's grows with T
    (which is also where the wall-clock crossover lives: at short T the
    stacked states fit in cache and there is nothing to win).
    """
    if smoke:
        nx_list, batches, t_len, reps, long_ts = (8,), (16,), 16, 1, ()
    rows: List[Dict] = []
    for nx in nx_list:
        for b in batches:
            rows.append(_train_fused_cell(nx, b, t_len, reps))
    for t_long in long_ts:
        rows.append(_train_fused_cell(nx_list[-1], batches[-1], t_long, reps))
    # the acceptance cell gets extra pairs: it gates CI at a ratio
    rows.append(_refine_population_row(smoke=smoke,
                                       reps=reps if smoke else max(reps, 5)))
    return rows


def _refine_population_row(smoke: bool = False, reps: int = 3) -> Dict:
    """The acceptance cell: population refinement through the fused path
    vs the scan path at Nx=16, B=256 (Nx=8, B=32 in smoke mode).  T=1024
    with a full-window SGD step (minibatch = B) is the long-episode
    regime the fused kernel exists for - the scan path must stack
    K x (B, T, Nx) states per step, far past cache, while the fused
    path's activations stay O(K B Nx^2)."""
    import jax
    import jax.numpy as jnp

    from repro.core import masking, population
    from repro.core.types import DFRParams

    nx, b, k, t_len = (8, 32, 2, 16) if smoke else (16, 256, 4, 1024)
    cfg = DFRConfig(n_in=3, n_classes=4, n_nodes=nx, nonlinearity="tanh")
    mask = masking.make_mask(jax.random.PRNGKey(0), cfg.n_nodes, cfg.n_in,
                             cfg.dtype)
    key = jax.random.PRNGKey(1)
    pop = DFRParams(
        p=jnp.linspace(0.1, 0.8, k).astype(cfg.dtype),
        q=jnp.linspace(-0.5, 0.5, k).astype(cfg.dtype),
        W=0.05 * jax.random.normal(key, (k, cfg.n_classes, cfg.n_rep),
                                   cfg.dtype),
        b=jnp.zeros((k, cfg.n_classes), cfg.dtype),
    )
    u = jax.random.normal(jax.random.PRNGKey(2), (b, t_len, cfg.n_in),
                          cfg.dtype)
    lengths = jnp.full((b,), t_len, jnp.int32)
    y = jax.nn.one_hot(jnp.arange(b) % 4, 4, dtype=cfg.dtype)
    lr = jnp.asarray(0.05, cfg.dtype)

    def go(fused):
        return jax.jit(partial(
            population.refine_population, cfg, mask, pop, u, lengths, y,
            lr, lr, steps=1, minibatch=b, fused=fused,
        ))

    t_scan, t_fused = _paired_times(go(False), go(True), reps=reps)
    return {
        "table": "train-fused", "cell": f"refine/Nx{nx}/B{b}/K{k}/T{t_len}",
        "fused_time_s": round(t_fused, 6),
        "scan_samples_per_s": round(b * k / t_scan, 1),
        "fused_samples_per_s": round(b * k / t_fused, 1),
        "fused_over_scan_speedup": round(t_scan / t_fused, 3),
    }


def run_train_fused(full: bool = False) -> List[Dict]:
    return train_fused_table(reps=5 if full else 3)


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 rep: the CI training-kernel lane")
    args = ap.parse_args()
    for row in train_fused_table(smoke=args.smoke):
        print(json.dumps(row))
