"""Roofline report builder: reads artifacts/dryrun/*.json -> markdown table.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--tag baseline]
"""
from __future__ import annotations

import argparse
import glob
import json
import pathlib
from typing import Dict, List

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_records(tag: str = "baseline") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(str(ART / f"*__{tag}.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_row(r: Dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | - | - | - "
                f"| - | - | sub-quadratic required |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | - | - | "
                f"- | - | - | {r.get('error','')[:60]} |")
    rl = r["roofline"]
    ur = r.get("useful_flops_ratio")
    note = {
        "compute": "MXU-bound",
        "memory": "HBM-bound",
        "collective": "ICI-bound",
    }[rl["dominant"]]
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rl['dominant']} "
        f"| {rl['compute_s']:.2f} | {rl['memory_s']:.2f} "
        f"| {rl['collective_s']:.2f} | {rl['roofline_fraction']:.3f} "
        f"| {ur:.3f} | {note} |"
    )


HEADER = (
    "| arch | shape | mesh | dominant | compute_s | memory_s | collective_s "
    "| roofline_frac | useful_flops | note |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def build_table(tag: str = "baseline", mesh: str | None = None) -> str:
    rows = load_records(tag)
    if mesh:
        rows = [r for r in rows if r["mesh"] == mesh]
    lines = [HEADER]
    for r in rows:
        lines.append(fmt_row(r))
    return "\n".join(lines)


def summary_csv(tag: str = "baseline") -> List[Dict]:
    out = []
    for r in load_records(tag):
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        out.append({
            "table": "roofline", "cell": f"{r['arch']}/{r['shape']}/{r['mesh']}",
            "dominant": rl["dominant"],
            "bound_s": round(rl["bound_s"], 3),
            "roofline_frac": round(rl["roofline_fraction"], 4),
        })
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    print(build_table(args.tag, args.mesh))


if __name__ == "__main__":
    main()
